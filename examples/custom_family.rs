//! Experiment E13 — the operator registry's extensibility path, end to
//! end: a constraint family defined OUTSIDE the library crate, registered
//! at runtime, and immediately usable through every consumer — spec
//! parsing, the `LpSpec` builder, the CPU objective's blockwise
//! projection, and primal validation — with zero edits to `solver/`,
//! `sparse/`, or `runtime/`.
//!
//! The family here is `interval:<lo>:<hi>` — the box [lo, hi]^w (paper §4:
//! new formulations compose locally from dual-objective and blockwise-
//! projection primitives; the shared optimization loop is untouched).
//!
//! Run: cargo run --release --example custom_family

use std::any::Any;

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{check_primal, LpSpec, ObjectiveFunction};
use dualip::projection::{registry, BlockProjection, ProjectionKind};
use dualip::reference::CpuObjective;
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};

/// [lo, hi]^w — per-edge allocations bounded away from the unit box.
struct Interval {
    lo: f32,
    hi: f32,
}

impl BlockProjection for Interval {
    fn family(&self) -> &str {
        "interval"
    }

    fn spec(&self) -> String {
        format!("interval:{}:{}", self.lo, self.hi)
    }

    fn project(&self, v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = x.clamp(self.lo, self.hi);
        }
    }

    fn violation(&self, v: &[f32]) -> f64 {
        v.iter()
            .map(|&x| ((self.lo - x) as f64).max((x - self.hi) as f64).max(0.0))
            .fold(0.0, f64::max)
    }

    fn separable(&self) -> bool {
        true // uniform bounds: slab rows may split freely
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

fn main() -> anyhow::Result<()> {
    // 1. Register the family: a parser from spec args plus conformance
    //    samples (the generic proptest suite covers registered families
    //    through these automatically).
    registry::register_family(
        "interval",
        &["interval:0:0.5", "interval:0.1:0.9"],
        |args: &str| {
            let (lo_s, hi_s) = args.split_once(':')?;
            let lo: f32 = lo_s.parse().ok()?;
            let hi: f32 = hi_s.parse().ok()?;
            (lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo <= hi)
                .then(|| Box::new(Interval { lo, hi }) as Box<dyn BlockProjection>)
        },
    );

    // 2. The spec string now resolves everywhere.
    let k = ProjectionKind::parse("interval:0:0.25").expect("registered family parses");
    println!(
        "registered family: {} (spec {}, separable {})",
        k.name(),
        k.spec(),
        k.separable()
    );
    assert_eq!(ProjectionKind::parse(&k.spec()), Some(k), "spec round-trips");

    // 3. Build a problem through LpSpec with the new polytope and solve it
    //    on the untouched optimization loop.
    let base = generate(&SyntheticConfig {
        num_requests: 2_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed: 7,
        ..Default::default()
    });
    let lp = LpSpec::new(base.a.clone(), base.cost.clone(), base.b.clone())
        .projection("interval:0:0.25")
        .build()
        .map_err(anyhow::Error::msg)?;

    let mut objective = CpuObjective::new(&lp);
    let opts = SolveOptions {
        max_iters: 200,
        gamma: GammaSchedule::paper_fig5(),
        max_step_size: 1e-2,
        initial_step_size: 1e-5,
        ..Default::default()
    };
    let mut agd = Agd::default();
    let result = agd.maximize(&mut objective, &vec![0.0f32; lp.dual_dim()], &opts);
    println!("{}", dualip::metrics::solve_report("interval-family", &result));

    // 4. Validation runs the registered operator's own feasibility oracle.
    let x = objective.primal(&result.lam, result.final_gamma);
    let report = check_primal(&lp, &x, 1e-3);
    println!(
        "primal: objective={:.4} ‖(Ax−b)₊‖₂={:.3e} simple-viol={:.2e}",
        report.objective, report.complex_infeas, report.simple_infeas_max
    );
    assert!(
        report.simple_infeas_max < 1e-4,
        "projected primal must satisfy the custom polytope"
    );
    assert!(
        x.iter().all(|&v| (-1e-6..=0.25 + 1e-6).contains(&v)),
        "every edge allocation inside [0, 0.25]"
    );
    println!("custom family solved end-to-end — no solver/sparse/runtime edits");
    Ok(())
}
