//! End-to-end sharded slab solve (DESIGN.md §6, companion to experiment
//! E15): a matching LP with a global count-cap row solved through the
//! device-thread `WorkerPool` under the slab execution strategy —
//! no artifacts required — with the paper's λ-only communication
//! accounting printed per layer:
//!
//! - one-time data distribution (each shard's real edges × planes),
//! - per-iteration traffic: two |λ| broadcasts + one chunk-segmented
//!   reduce, independent of shard edge counts,
//! - per-shard evaluation CPU time (what each device would compute),
//!
//! and the §6 determinism contract demonstrated end to end: the 3-shard
//! solve is **bit-identical** to the single-shard slab solve.
//!
//! Run: cargo run --release --example distributed_shards

use std::sync::Arc;

use dualip::backend::{KernelTiers, SlabCpuObjective};
use dualip::distributed::{solve_distributed_with, ExecStrategy, LinkModel};
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::{comm_report, shard_report, solve_report};
use dualip::problem::{check_primal, jacobi_row_normalize, ObjectiveFunction};
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};

fn main() -> anyhow::Result<()> {
    let shards = 3usize;
    let mut lp = generate(&SyntheticConfig {
        num_requests: 20_000,
        num_resources: 200,
        avg_nnz_per_row: 8.0,
        seed: 15,
        ..Default::default()
    });
    // a global row (Σx ≤ cap) rides along: global coefficients are dense
    // over edges, so every shard contributes to its dual row — the
    // chunk-ordered reduce handles it like any other λ entry
    let cap = 0.25 * lp.num_sources() as f32;
    lp.push_global_row(vec![1.0; lp.nnz()], cap);
    jacobi_row_normalize(&mut lp);
    println!(
        "instance: I={} J={} nnz={} dual_dim={} (incl. 1 global row), {shards} shards",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        lp.dual_dim()
    );
    let lp = Arc::new(lp);

    let opts = SolveOptions {
        max_iters: 250,
        gamma: GammaSchedule::paper_fig5(),
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };

    // --- sharded solve through the device-thread pool --------------------
    let out = solve_distributed_with(
        lp.clone(),
        ExecStrategy::Slab { threads: 1 },
        shards,
        &opts,
    )?;
    let iters = out.result.iterations as u64;
    println!("{}", solve_report(&format!("sharded-slab-{shards}"), &out.result));
    println!("{}", comm_report(&out.comm, iters));
    println!("{}", shard_report(&out.shard_eval_ms, &out.comm, iters, &KernelTiers::of_lp(&lp)));
    println!(
        "estimated NCCL wire time/iter: nvlink {:.1}µs, ethernet {:.1}µs",
        LinkModel::nvlink().iter_time(lp.dual_dim()) * 1e6,
        LinkModel::ethernet().iter_time(lp.dual_dim()) * 1e6,
    );

    // comm-byte accounting, spelled out: the reduce payload is a function
    // of |λ| and the fixed chunk grid — NOT of the 160k edges
    let per_iter = (out.comm.bcast_bytes + out.comm.reduce_bytes - 4 * lp.dual_dim() as u64)
        as f64
        / iters as f64;
    let edge_bytes = 4 * lp.nnz() as f64;
    println!(
        "λ-only traffic: {per_iter:.0} B/iter vs {edge_bytes:.0} B of primal edge data \
         ({:.1}% — the edges never move after the one-time scatter)",
        100.0 * per_iter / edge_bytes
    );

    // --- the determinism contract: bit-identical to single-shard ---------
    let mut single = SlabCpuObjective::new(&lp, 1).map_err(anyhow::Error::msg)?;
    let mut agd = Agd::default();
    let r1 = agd.maximize(&mut single, &vec![0.0f32; lp.dual_dim()], &opts);
    anyhow::ensure!(
        r1.lam
            .iter()
            .zip(&out.result.lam)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "sharded solve diverged from single-shard slab"
    );
    println!("verified: {shards}-shard λ bit-identical to the single-shard slab solve");

    // --- primal recovery + feasibility across the shard merge ------------
    let x = single.primal(&out.result.lam, out.result.final_gamma);
    let rep = check_primal(&lp, &x, 1e-3);
    let count = x.iter().map(|&v| v as f64).sum::<f64>();
    println!(
        "primal: cᵀx={:.4} ‖(Ax−b)₊‖₂={:.3e} active rows={:.1}% | Σx={count:.1} (cap {cap})",
        rep.objective,
        rep.complex_infeas,
        rep.active_fraction * 100.0
    );
    Ok(())
}
