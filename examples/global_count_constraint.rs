//! Experiment E11 — the paper's §4 extensibility story, demonstrated.
//!
//! "a global count constraint in a matching problem, Σ_ij x_ij ≤ M. While
//!  it's trivial to compute Ax and Aᵀλ for this constraint, appending it to
//!  the matching problem in the Spark Scala solver requires extensive
//!  changes across the code base."
//!
//! Here it is one `push_global_row` call: the AGD loop, the slab kernels,
//! the AOT artifacts and the collectives are all unchanged — only the
//! coordinator-side gather/scatter (which is generic over dual rows) sees
//! the extra row.
//!
//! Run: cargo run --release --example global_count_constraint

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::ObjectiveFunction;
use dualip::runtime::{default_artifacts_dir, HloObjective};
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};

fn solve(lp: &dualip::problem::MatchingLp, label: &str) -> anyhow::Result<(f64, f64)> {
    let mut obj = HloObjective::new(lp, default_artifacts_dir())?;
    let opts = SolveOptions {
        max_iters: 250,
        gamma: GammaSchedule::Fixed(0.01),
        max_step_size: 1e-2,
        initial_step_size: 1e-5,
        ..Default::default()
    };
    let mut agd = Agd::default();
    let r = agd.maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);
    let x = obj.primal(&r.lam, r.final_gamma);
    let total: f64 = x.iter().map(|&v| v as f64).sum();
    let cx: f64 = lp.cost.iter().zip(&x).map(|(c, v)| *c as f64 * *v as f64).sum();
    println!(
        "{label}: g={:.4} cᵀx={cx:.4} total allocation Σx={total:.2}",
        r.final_obj.dual_obj
    );
    Ok((total, cx))
}

fn main() -> anyhow::Result<()> {
    let base = generate(&SyntheticConfig {
        num_requests: 5_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed: 3,
        ..Default::default()
    });

    // 1. the plain matching LP
    let (total_unconstrained, cx_u) = solve(&base, "without global row")?;

    // 2. same LP + global count cap at 60% of the unconstrained volume —
    //    ONE extra line of problem construction, nothing else changes.
    let cap = (0.6 * total_unconstrained) as f32;
    let mut capped = generate(&SyntheticConfig {
        num_requests: 5_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed: 3,
        ..Default::default()
    });
    capped.push_global_row(vec![1.0; capped.nnz()], cap);
    let (total_capped, cx_c) = solve(&capped, "with Σx ≤ 0.6·V global row")?;

    println!(
        "cap {cap:.2}: allocation {total_unconstrained:.2} → {total_capped:.2}, \
         objective {cx_u:.2} → {cx_c:.2}"
    );
    assert!(
        total_capped <= cap as f64 * 1.02,
        "global count constraint violated: {total_capped} > {cap}"
    );
    println!("global count constraint enforced — no solver/kernel change required");
    Ok(())
}
