//! END-TO-END DRIVER (DESIGN.md E2E): the full production path on a real
//! (synthetic, Appendix-B) matching workload —
//!
//!   generator → Jacobi preconditioning → bucketed slab layout →
//!   AOT Pallas/HLO kernels via PJRT on 4 sharded workers →
//!   λ-only collectives → AGD with γ-continuation →
//!   primal recovery + feasibility validation (Lemma A.1 check).
//!
//! Reports the paper's headline quantities: per-iteration time (baseline vs
//! sharded slab path, measured and modeled-parallel), convergence, comm
//! volume. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: cargo run --release --example matching_allocation [sources] [iters]

use std::sync::Arc;

use dualip::distributed::{solve_distributed, LinkModel};
use dualip::gen::{generate, workloads};
use dualip::metrics::{comm_report, solve_report, stats};
use dualip::problem::{check_primal, jacobi_row_normalize, ObjectiveFunction};
use dualip::reference::CpuObjective;
use dualip::runtime::default_artifacts_dir;
use dualip::solver::{GammaSchedule, SolveOptions};
use dualip::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let sources: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(100_000);
    let iters: usize = argv.get(2).map(|s| s.parse()).transpose()?.unwrap_or(200);
    let workers = 4usize;
    let art = default_artifacts_dir();

    // ---- generate ------------------------------------------------------
    let sw = Stopwatch::start();
    let cfg = workloads::parity_instance(42);
    let mut lp = generate(&dualip::gen::SyntheticConfig { num_requests: sources, ..cfg });
    println!(
        "generated I={} J={} nnz={} in {:.0}ms",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        sw.elapsed_ms()
    );

    // ---- condition -----------------------------------------------------
    let scaling = jacobi_row_normalize(&mut lp);
    println!("jacobi row normalization: {} empty rows", scaling.empty_rows);
    let lp = Arc::new(lp);

    let opts = SolveOptions {
        max_iters: iters,
        gamma: GammaSchedule::paper_fig5(),
        // row-normalized dual Hessian has ~unit diagonal ⇒ larger stable cap
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };

    // ---- baseline timing (few iterations of the Scala-equivalent) -------
    let base_iters = 5usize.min(iters);
    let mut cpu = CpuObjective::new(&lp);
    let sw = Stopwatch::start();
    let lam0 = vec![0.0f32; lp.dual_dim()];
    for _ in 0..base_iters {
        let _ = cpu.calculate(&lam0, 0.16);
    }
    let baseline_ms = sw.elapsed_ms() / base_iters as f64;
    println!("baseline (per-edge tuple loop): {baseline_ms:.1} ms/iter");

    // ---- distributed solve ----------------------------------------------
    let out = solve_distributed(lp.clone(), &art, workers, &opts)?;
    println!("{}", solve_report(&format!("dist-{workers}w"), &out.result));
    println!("{}", comm_report(&out.comm, out.result.iterations as u64));

    let tmax = stats(&out.iter_compute_max_ms);
    let tsum = stats(&out.iter_compute_sum_ms);
    let comm_est = LinkModel::nvlink().iter_time(lp.dual_dim()) * 1e3;
    println!(
        "compute/iter: serialized {:.1} ms, modeled-parallel {:.1} ms (+{comm_est:.2} ms comm) \
         → modeled speedup vs baseline: {:.1}×",
        tsum.median,
        tmax.median,
        baseline_ms / (tmax.median + comm_est)
    );

    // ---- Lemma A.1: primal infeasibility bounded by dual suboptimality ---
    // ‖(Ax−b)₊‖ ≤ √(2L(g(λ*) − g(λ))) with L = ‖A‖²/γ.
    let g_star = out
        .result
        .trajectory
        .iter()
        .map(|t| t.dual_obj)
        .fold(f64::NEG_INFINITY, f64::max);
    let l_const = lp.a.op_norm_sq_upper() / out.result.final_gamma as f64;
    let mut violations = 0usize;
    for t in &out.result.trajectory {
        // only check iterations at the final γ (the bound is per-γ)
        if (t.gamma - out.result.final_gamma).abs() > 1e-9 {
            continue;
        }
        let bound = (2.0 * l_const * (g_star - t.dual_obj).max(0.0)).sqrt();
        if t.infeas_pos_norm > bound + 1e-6 {
            violations += 1;
        }
    }
    println!("Lemma A.1 check: {violations} violations over trajectory (expect 0)");

    // ---- primal recovery + validation ------------------------------------
    let mut single = dualip::runtime::HloObjective::new(&lp, &art)?;
    let x = single.primal(&out.result.lam, out.result.final_gamma);
    let rep = check_primal(&lp, &x, 1e-3);
    println!(
        "primal: cᵀx={:.6e} ‖(Ax−b)₊‖₂={:.3e} (rel {:.2e}) simple-viol={:.1e} active-rows={:.1}%",
        rep.objective,
        rep.complex_infeas,
        rep.complex_infeas / rep.objective.abs().max(1.0),
        rep.simple_infeas_max,
        rep.active_fraction * 100.0
    );
    println!(
        "smoothed duality gap: {:.3e} (rel {:.2e})",
        (rep.objective + 0.5 * out.result.final_gamma as f64 * out.result.final_obj.xsq_weighted
            - g_star)
            .abs(),
        (rep.objective - g_star).abs() / g_star.abs().max(1.0)
    );
    Ok(())
}
