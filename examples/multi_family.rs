//! Multiple matching constraint families (paper Definition 1): budget,
//! pacing and fairness rows coexist — the formulation the Scala DuaLip
//! could not express (it allowed a single matching block).
//!
//! Each family k contributes J dual rows; the solver, kernels and
//! collectives are untouched — only the generator's m changes (purely
//! local composition, paper §4).
//!
//! Run: cargo run --release --example multi_family

use std::sync::Arc;

use dualip::distributed::solve_distributed;
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::solve_report;
use dualip::problem::{check_primal, jacobi_row_normalize, ObjectiveFunction};
use dualip::runtime::default_artifacts_dir;
use dualip::solver::{GammaSchedule, SolveOptions};

fn main() -> anyhow::Result<()> {
    // Three families sharing one eligibility pattern (Appendix B:
    // a_kij = s_jk · c_ij): think budget / pacing / fairness caps.
    let mut lp = generate(&SyntheticConfig {
        num_requests: 20_000,
        num_resources: 200,
        avg_nnz_per_row: 8.0,
        num_families: 3,
        seed: 13,
        ..Default::default()
    });
    println!(
        "instance: I={} J={} m={} nnz={} dual_dim={}",
        lp.num_sources(),
        lp.num_dests(),
        lp.num_families(),
        lp.nnz(),
        lp.dual_dim()
    );
    jacobi_row_normalize(&mut lp);
    let lp = Arc::new(lp);

    let opts = SolveOptions {
        max_iters: 250,
        gamma: GammaSchedule::paper_fig5(),
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let out = solve_distributed(lp.clone(), default_artifacts_dir(), 2, &opts)?;
    println!("{}", solve_report("multi-family", &out.result));

    // per-family dual/slack summary
    let mut single = dualip::runtime::HloObjective::new(&lp, default_artifacts_dir())?;
    let x = single.primal(&out.result.lam, out.result.final_gamma);
    let rep = check_primal(&lp, &x, 1e-3);
    println!(
        "primal: cᵀx={:.4} ‖(Ax−b)₊‖₂={:.3e} active rows={:.1}%",
        rep.objective,
        rep.complex_infeas,
        rep.active_fraction * 100.0
    );

    let jj = lp.num_dests();
    let mut ax = vec![0.0f32; lp.dual_dim()];
    lp.a.scatter_ax(&x, &mut ax);
    for k in 0..lp.num_families() {
        let lam_k = &out.result.lam[k * jj..(k + 1) * jj];
        let active_duals = lam_k.iter().filter(|&&l| l > 1e-6).count();
        let tight = (0..jj)
            .filter(|&j| {
                let r = k * jj + j;
                (ax[r] - lp.b[r]).abs() <= 1e-3 * lp.b[r].abs().max(1.0)
            })
            .count();
        println!(
            "family {k}: {active_duals}/{jj} active duals, {tight}/{jj} tight rows"
        );
    }
    Ok(())
}
