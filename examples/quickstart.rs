//! Quickstart: the operator-centric programming model on a small matching
//! LP (paper §4, Table 1).
//!
//! The three roles compose explicitly:
//!   - `ObjectiveFunction` — encapsulates LP data + dual gradient,
//!   - `ProjectionMap`     — blockwise simple-constraint projections,
//!   - `Maximizer`         — dual ascent over λ ≥ 0.
//!
//! Run: cargo run --release --example quickstart

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{check_primal, ObjectiveFunction};
use dualip::reference::CpuObjective;
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};

fn main() -> anyhow::Result<()> {
    // 1. A small Appendix-B synthetic matching instance: 2 000 users,
    //    100 campaigns, ~8 eligible campaigns per user, per-user simplex
    //    capacity (Eq. 4-5) and per-campaign budget rows (Eq. 3).
    let lp = generate(&SyntheticConfig {
        num_requests: 2_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed: 7,
        ..Default::default()
    });
    println!(
        "instance: I={} J={} nnz={} dual_dim={}",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        lp.dual_dim()
    );

    // 2. Plug the LP into an ObjectiveFunction (CPU reference backend here;
    //    swap in runtime::HloObjective or distributed::DistributedObjective
    //    without touching anything below this line).
    let mut objective = CpuObjective::new(&lp);

    // 3. Maximize the smoothed dual with AGD + γ-continuation.
    let opts = SolveOptions {
        max_iters: 300,
        gamma: GammaSchedule::paper_fig5(), // 0.16 → 0.01, halved every 25
        max_step_size: 1e-2,
        initial_step_size: 1e-5,
        ..Default::default()
    };
    let mut maximizer = Agd::default();
    let init = vec![0.0f32; lp.dual_dim()];
    let result = maximizer.maximize(&mut objective, &init, &opts);

    println!("{}", dualip::metrics::solve_report("quickstart", &result));

    // 4. Recover and validate the primal.
    let x = objective.primal(&result.lam, result.final_gamma);
    let report = check_primal(&lp, &x, 1e-3);
    println!(
        "primal: objective={:.4} ‖(Ax−b)₊‖₂={:.3e} simple-viol={:.1e} active={:.0}%",
        report.objective,
        report.complex_infeas,
        report.simple_infeas_max,
        report.active_fraction * 100.0
    );

    // The dual value lower-bounds the smoothed primal value at x*:
    let g = result.final_obj.dual_obj;
    let smoothed_primal =
        report.objective + 0.5 * result.final_gamma as f64 * result.final_obj.xsq_weighted;
    println!("weak duality: g = {g:.4} ≤ smoothed primal = {smoothed_primal:.4}");
    Ok(())
}
