"""AOT pipeline: lower the L2 slab-step graphs to HLO text artifacts.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 rust crate links) rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Python runs ONLY here (``make artifacts``); the rust binary is
self-contained afterwards.

Usage: python -m compile.aot [--out-dir ../artifacts] [--widths 8,16,...]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import make_slab_step

# Slab geometry. T is the fixed row count per slab execution; rust pads the
# last tile of each bucket with mask=0 rows. Widths are the log2 buckets of
# per-source eligible-destination counts (paper §6: ranges [2^{t-1}, 2^t)).
DEFAULT_T = 1024
DEFAULT_WIDTHS = (4, 8, 16, 32, 64, 128, 256, 512)
KINDS = ("simplex", "box")


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_slab(kind: str, t: int, w: int) -> str:
    spec = jax.ShapeDtypeStruct((t, w), jnp.float32)
    gspec = jax.ShapeDtypeStruct((1,), jnp.float32)
    fn = make_slab_step(kind)
    lowered = jax.jit(fn).lower(spec, spec, spec, gspec)
    return to_hlo_text(lowered)


def artifact_name(kind: str, t: int, w: int) -> str:
    return f"slab_{kind}_t{t}_w{w}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--rows", type=int, default=DEFAULT_T)
    ap.add_argument(
        "--widths",
        default=",".join(str(w) for w in DEFAULT_WIDTHS),
        help="comma-separated slab widths (log2 bucket upper bounds)",
    )
    args = ap.parse_args()

    widths = [int(w) for w in args.widths.split(",")]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    # Full row-tile artifacts (the production family) plus single-row
    # artifacts (rows=1) used by the projection-batching benchmark as the
    # per-slice launch baseline (paper §6, experiment E9).
    for rows in (args.rows, 1):
        for kind in KINDS:
            for w in widths:
                name = artifact_name(kind, rows, w)
                path = os.path.join(args.out_dir, name)
                text = lower_slab(kind, rows, w)
                with open(path, "w") as f:
                    f.write(text)
                manifest.append(f"{kind} {rows} {w} {name}")
                print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
