"""Pure-jnp oracle for the slab dual-step kernels.

This is the correctness reference for the Pallas kernels in ``slab.py``:
every function here is written in the most direct jnp style (no tiling, no
fusion tricks) and is used by pytest to validate the kernel outputs
element-wise, and by hypothesis sweeps across shapes.

Math (paper §3.1): given the pre-combined dual load per edge
``u = (A^T λ)_edge``, value coefficients ``c`` and ridge parameter ``γ``,

    v = -(u + c) / γ
    x = Π_C(v)          (per-row projection onto the simple polytope)

Rows are per-source variable blocks, padded to the slab width; ``mask`` is 1
on real edges and 0 on padding. Padded lanes never contribute to the
projection and are exactly 0 in the output.
"""

import jax.numpy as jnp

# Large-but-finite stand-in for -inf. Using a finite value keeps cumsum
# arithmetic NaN-free on padded lanes (−inf − (−inf) = NaN would poison the
# sort-threshold computation).
NEG = -1.0e30


def project_box(v, mask):
    """Row-wise projection onto the unit box [0, 1]^w, respecting mask."""
    return jnp.clip(v, 0.0, 1.0) * mask


def project_simplex_ineq(v, mask):
    """Row-wise projection onto {x >= 0, sum(x) <= 1} (the per-source
    impression-capacity polytope, paper Eq. (4)-(5)).

    Algorithm: if sum(max(v,0)) <= 1 the nonnegativity clamp is already the
    projection; otherwise project onto the *equality* simplex via the
    sort-threshold method (Held/Michelot): with v sorted descending,
    theta = (cumsum(v)[rho-1] - 1)/rho where rho is the largest k with
    v_(k) > (cumsum(v)[k-1] - 1)/k, and x = max(v - theta, 0).
    """
    w = v.shape[-1]
    vm = jnp.where(mask > 0, v, NEG)
    vp = jnp.maximum(vm, 0.0)
    s = jnp.sum(vp, axis=-1, keepdims=True)

    vs = jnp.sort(vm, axis=-1)[..., ::-1]  # descending, padding sinks to end
    cssv = jnp.cumsum(vs, axis=-1) - 1.0
    ks = jnp.arange(1, w + 1, dtype=v.dtype)
    cond = (vs - cssv / ks) > 0.0
    rho = jnp.maximum(jnp.sum(cond, axis=-1, keepdims=True), 1)
    theta = jnp.take_along_axis(cssv, rho - 1, axis=-1) / rho.astype(v.dtype)

    x_eq = jnp.maximum(vm - theta, 0.0)
    x = jnp.where(s <= 1.0, vp, x_eq)
    return x * mask


def slab_step_ref(u, c, mask, gamma, kind="simplex"):
    """Reference for the full slab dual step.

    Returns (x, cx, xsq):
      x   [T,w]  projected primal block rows  Π_C(-(u+c)/γ)
      cx  scalar Σ c⊙x   (partial primal objective contribution)
      xsq scalar Σ x²    (partial ridge penalty contribution)
    """
    v = -(u + c) / gamma
    v = v * mask
    if kind == "simplex":
        x = project_simplex_ineq(v, mask)
    elif kind == "box":
        x = project_box(v, mask)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    cx = jnp.sum(c * mask * x)
    xsq = jnp.sum(x * x)
    return x, cx, xsq
