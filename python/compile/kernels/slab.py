"""L1 — Pallas slab kernels for the batched projection dual step.

The paper (§6 "Batched projection operator") batches per-source projections
into dense padded slabs bucketed by log2 slice length, turning many tiny
kernel launches into a handful of high-occupancy ones. Here the same design
is expressed as ONE fused Pallas kernel per (row-tile, width) shape:

    v  = -(u + c) / γ        (dual-to-primal map, paper §3.1)
    x  = Π_C(v)              (row-wise simplex / box projection)

fused so a slab makes a single HBM↔VMEM round trip instead of three
(the CUDA version's scale, project and reduce kernels).

TPU adaptation (DESIGN.md §Hardware-Adaptation): rows = sources, lanes =
padded eligible destinations. BlockSpec tiles the row dimension; a full row
(w ≤ 512 f32) fits in one VMEM vector tile, so the row-wise sort for the
simplex threshold never leaves VMEM. ``interpret=True`` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls; real-TPU perf is estimated
analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0e30  # finite -inf stand-in; keeps padded-lane cumsum NaN-free

# Row-tile height: chosen so one (ROW_TILE, w<=512) f32 block plus the sort
# scratch stays well under a ~16 MiB VMEM budget (512*512*4B = 1 MiB/block,
# x4 live arrays + sort double-buffer ≈ 6 MiB).
ROW_TILE = 256


BISECT_ITERS = 28


def _simplex_rows(v, mask, w):
    """Row-wise projection onto {x >= 0, sum(x) <= 1} by bisection on the
    threshold θ of x = max(v − θ, 0).

    PERF (EXPERIMENTS.md §Perf L1-1): the sort-threshold method (ref.py's
    oracle) lowers to an XLA variadic sort that dominates kernel time on
    CPU (1.67 ms / [1024,16] slab); f(θ) = Σ max(v−θ,0) is monotone, so a
    fixed-trip bisection — element-wise ops + row reductions only, fully
    vectorized across rows AND lanes, branch-free — reaches f32-exact θ
    (|θ−θ*| ≤ max v · 2⁻²⁸) in 28 trips at 0.73 ms/slab (2.3×). On TPU the
    same rewrite avoids the Mosaic sort entirely (DESIGN.md §Perf).
    """
    del w
    vm = v * mask
    vp = jnp.maximum(vm, 0.0) * mask
    s = jnp.sum(vp, axis=-1, keepdims=True)

    lo = jnp.zeros_like(s)
    hi = jnp.max(vm, axis=-1, keepdims=True)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        f = jnp.sum(jnp.maximum(vm - mid, 0.0) * mask, axis=-1, keepdims=True)
        big = f > 1.0
        return jnp.where(big, mid, lo), jnp.where(big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    x_eq = jnp.maximum(vm - theta, 0.0) * mask
    return jnp.where(s <= 1.0, vp, x_eq)


def _slab_kernel(u_ref, c_ref, mask_ref, gamma_ref, x_ref, *, kind, w):
    """Fused dual-step kernel body over one (ROW_TILE, w) block."""
    u = u_ref[...]
    c = c_ref[...]
    mask = mask_ref[...]
    gamma = gamma_ref[0, 0]

    v = (-(u + c) / gamma) * mask
    if kind == "simplex":
        x = _simplex_rows(v, mask, w)
    else:  # box
        x = jnp.clip(v, 0.0, 1.0) * mask
    x_ref[...] = x


@functools.partial(jax.jit, static_argnames=("kind",))
def slab_project(u, c, mask, gamma, kind="simplex"):
    """Run the fused slab kernel over a [T, w] slab.

    gamma is a shape-(1,) runtime input (NOT baked into the artifact) so a
    single AOT executable serves the whole γ-continuation schedule.
    Returns the projected primal block rows x [T, w].
    """
    t, w = u.shape
    row_tile = min(ROW_TILE, t)
    assert t % row_tile == 0, (t, row_tile)
    grid = (t // row_tile,)

    block = pl.BlockSpec((row_tile, w), lambda i: (i, 0))
    gamma2 = gamma.reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_slab_kernel, kind=kind, w=w),
        grid=grid,
        in_specs=[
            block,
            block,
            block,
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((t, w), u.dtype),
        interpret=True,
    )(u, c, mask, gamma2)
