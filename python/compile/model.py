"""L2 — JAX dual-step graph wrapping the L1 Pallas slab kernel.

One function per (kind, slab shape): ``slab_step`` computes the projected
primal block rows plus the two scalar partials the leader needs to assemble
the dual objective

    g(λ) = cᵀx + γ/2 ‖x‖² + λᵀ(Ax − b)

from per-worker contributions (paper §6, distributed iteration step 1).

The gather of λ into per-edge ``u = (A^T λ)_edge`` and the scatter-add of
``a ⊙ x`` into the gradient are deliberately NOT part of this graph: they
are shape-dependent, memory-bound ops done by the rust coordinator, which
keeps the AOT artifact family independent of problem size (DESIGN.md §2).
"""

import jax.numpy as jnp

from compile.kernels import slab as slab_kernels


def slab_step(u, c, mask, gamma, kind="simplex"):
    """Full slab dual step: project + reduce.

    Args:
      u:     [T, w] f32, pre-combined dual load per edge (Σ_k a_k λ_k).
      c:     [T, w] f32, value coefficients (0 on padding).
      mask:  [T, w] f32, 1 on real edges, 0 on padding.
      gamma: [1] f32, ridge parameter (runtime input).

    Returns (x, cx, xsq):
      x   [T, w] projected primal rows,
      cx  [1]    Σ c⊙x,
      xsq [1]    Σ x².
    """
    x = slab_kernels.slab_project(u, c, mask, gamma, kind=kind)
    cx = jnp.sum(c * mask * x).reshape(1)
    xsq = jnp.sum(x * x).reshape(1)
    return x, cx, xsq


def make_slab_step(kind):
    """Close over the static ``kind`` so jax.jit sees a pure tensor fn."""

    def fn(u, c, mask, gamma):
        return slab_step(u, c, mask, gamma, kind=kind)

    fn.__name__ = f"slab_step_{kind}"
    return fn
