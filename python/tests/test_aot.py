"""AOT pipeline tests: lowering produces loadable, well-formed HLO text."""

import os

import numpy as np
import jax.numpy as jnp

from compile.aot import lower_slab, artifact_name, DEFAULT_T, DEFAULT_WIDTHS, KINDS


def test_lower_slab_produces_hlo_text():
    text = lower_slab("box", 8, 4)
    assert "ENTRY" in text
    assert "HloModule" in text
    # 4 params (u, c, mask, gamma), tuple root
    assert "f32[8,4]" in text
    assert "f32[1]" in text


def test_lowered_hlo_has_no_custom_calls():
    """interpret=True must lower pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the CPU PJRT plugin on the rust side."""
    for kind in KINDS:
        text = lower_slab(kind, 8, 4)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_artifact_naming_scheme():
    assert artifact_name("simplex", 1024, 64) == "slab_simplex_t1024_w64.hlo.txt"


def test_manifest_covers_default_family():
    """If artifacts have been built, the manifest must list every
    (kind, width) combination with existing files."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        import pytest

        pytest.skip("artifacts not built yet (run `make artifacts`)")
    lines = [l.split() for l in open(manifest).read().strip().splitlines()]
    seen = {(l[0], int(l[2])) for l in lines}
    for kind in KINDS:
        for w in DEFAULT_WIDTHS:
            assert (kind, w) in seen
    for l in lines:
        assert os.path.exists(os.path.join(art, l[3])), l[3]


def test_hlo_numeric_roundtrip():
    """Compile the lowered stablehlo back through jax and compare numerics —
    guards against lowering-induced drift before the rust side ever runs."""
    from compile.model import make_slab_step
    import jax

    t, w = 8, 4
    rng = np.random.default_rng(0)
    u = jnp.array(rng.normal(size=(t, w)).astype(np.float32))
    c = jnp.array(rng.normal(size=(t, w)).astype(np.float32))
    mask = jnp.ones((t, w), jnp.float32)
    g = jnp.array([0.1], jnp.float32)

    fn = make_slab_step("simplex")
    expect = fn(u, c, mask, g)
    got = jax.jit(fn)(u, c, mask, g)
    for a, b in zip(expect, got):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
