"""Kernel-vs-oracle correctness: the CORE numeric signal for L1.

hypothesis sweeps shapes, sparsity, gamma and value scales; every case
asserts element-wise agreement with the pure-jnp reference plus the
polytope invariants (feasibility, idempotence-by-construction).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.slab import slab_project
from compile.model import slab_step

RNG = np.random.default_rng(1234)


def make_case(t, w, density, scale, seed):
    rng = np.random.default_rng(seed)
    u = (rng.normal(size=(t, w)) * scale).astype(np.float32)
    c = (rng.normal(size=(t, w)) * scale).astype(np.float32)
    mask = (rng.random((t, w)) < density).astype(np.float32)
    return jnp.array(u * mask), jnp.array(c * mask), jnp.array(mask)


@pytest.mark.parametrize("kind", ["simplex", "box"])
@pytest.mark.parametrize("w", [4, 8, 32, 128])
def test_kernel_matches_ref_basic(kind, w):
    u, c, mask = make_case(64, w, 0.6, 1.0, 7)
    gamma = jnp.array([0.05], dtype=jnp.float32)
    x = slab_project(u, c, mask, gamma, kind=kind)
    v = (-(u + c) / gamma[0]) * mask
    xr = (
        ref.project_simplex_ineq(v, mask)
        if kind == "simplex"
        else ref.project_box(v, mask)
    )
    # atol 1e-5: the kernel's bisection θ is f32-quantized vs the oracle's
    # exact sort-threshold θ (see slab.py PERF note)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xr), rtol=1e-4, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    t=st.sampled_from([8, 32, 256]),
    w=st.sampled_from([4, 8, 16, 64]),
    density=st.floats(0.05, 1.0),
    scale=st.floats(0.01, 100.0),
    gamma=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(["simplex", "box"]),
)
def test_kernel_matches_ref_hypothesis(t, w, density, scale, gamma, seed, kind):
    u, c, mask = make_case(t, w, density, scale, seed)
    g = jnp.array([gamma], dtype=jnp.float32)
    x = np.asarray(slab_project(u, c, mask, g, kind=kind))
    xr, cxr, xsqr = (np.asarray(a) for a in ref.slab_step_ref(u, c, mask, g, kind=kind))
    # scale-aware tolerance: v entries are O(scale/gamma)
    tol = max(1e-5, 1e-6 * scale / gamma)
    np.testing.assert_allclose(x, xr, rtol=1e-4, atol=tol)

    # polytope invariants
    assert np.all(x >= -tol)
    assert np.all(x * (1 - np.asarray(mask)) == 0), "padding must stay zero"
    if kind == "simplex":
        # capacity tolerance scales with lanes × θ-quantization (bisection
        # resolves θ to max(v)·2⁻²⁸; the residual accumulates across a row)
        assert np.all(x.sum(axis=1) <= 1 + w * tol + 1e-4)
    else:
        assert np.all(x <= 1 + tol)


@pytest.mark.parametrize("kind", ["simplex", "box"])
def test_projection_idempotent(kind):
    """Projecting an already-feasible point is the identity."""
    u, c, mask = make_case(32, 16, 0.5, 1.0, 11)
    g = jnp.array([0.1], dtype=jnp.float32)
    x1 = slab_project(u, c, mask, g, kind=kind)
    # feed x1 back as the raw point: v = x1 requires u,c with -(u+c)/g = x1
    u2 = -(x1 * g[0])
    c2 = jnp.zeros_like(u2)
    x2 = slab_project(u2, c2, mask, g, kind=kind)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-4, atol=1e-5)


def test_simplex_projection_optimality():
    """Π(v) must be closer to v than any other feasible point (random probes)."""
    rng = np.random.default_rng(5)
    v = jnp.array(rng.normal(size=(16, 8)).astype(np.float32) * 2)
    mask = jnp.ones((16, 8), dtype=jnp.float32)
    x = np.asarray(ref.project_simplex_ineq(v, mask))
    vn = np.asarray(v)
    d_star = ((x - vn) ** 2).sum(axis=1)
    for _ in range(200):
        y = rng.random((16, 8)).astype(np.float32)
        y = y / np.maximum(y.sum(axis=1, keepdims=True), 1.0)  # feasible
        d = ((y - vn) ** 2).sum(axis=1)
        assert np.all(d_star <= d + 1e-5)


def test_fully_padded_rows_are_zero():
    t, w = 16, 8
    u = jnp.zeros((t, w), dtype=jnp.float32)
    c = -jnp.ones((t, w), dtype=jnp.float32)  # would push x > 0 if unmasked
    mask = jnp.zeros((t, w), dtype=jnp.float32)
    g = jnp.array([0.01], dtype=jnp.float32)
    for kind in ("simplex", "box"):
        x = np.asarray(slab_project(u, c, mask, g, kind=kind))
        assert np.all(x == 0)


def test_gamma_is_runtime_input():
    """Same compiled fn, different gamma values → different (correct) x."""
    u, c, mask = make_case(32, 8, 0.8, 1.0, 3)
    for gv in (0.01, 0.16, 1.0):
        g = jnp.array([gv], dtype=jnp.float32)
        x = np.asarray(slab_project(u, c, mask, g, kind="box"))
        v = np.asarray((-(u + c) / gv) * mask)
        np.testing.assert_allclose(
            x, np.clip(v, 0, 1) * np.asarray(mask), rtol=1e-5, atol=1e-6
        )


def test_slab_step_partials():
    """cx and xsq outputs equal the reductions of the x output."""
    u, c, mask = make_case(64, 16, 0.5, 1.0, 9)
    g = jnp.array([0.05], dtype=jnp.float32)
    for kind in ("simplex", "box"):
        x, cx, xsq = slab_step(u, c, mask, g, kind=kind)
        xn = np.asarray(x)
        np.testing.assert_allclose(
            float(cx[0]), float((np.asarray(c) * np.asarray(mask) * xn).sum()), rtol=1e-4
        )
        np.testing.assert_allclose(float(xsq[0]), float((xn * xn).sum()), rtol=1e-4)
