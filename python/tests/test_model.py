"""L2 model tests: slab_step graph composition and end-to-end dual math.

Beyond kernel-vs-ref (test_kernel.py), these tests exercise the *dual step*
semantics the rust coordinator relies on: assembling g(λ) and ∇g(λ) from
slab outputs must match a dense from-scratch computation of the paper's
Eq. (2) on a tiny matching LP.
"""

import numpy as np
import jax.numpy as jnp

from compile.model import slab_step, make_slab_step
from compile.kernels.ref import project_simplex_ineq


def dense_dual(A, b, c, lam, gamma):
    """Direct dense computation of g(λ) and ∇g(λ) for a small LP with
    per-source simplex blocks. A: [m, I, J] (diag coefficients per family),
    c: [I, J], lam: [m, J]."""
    m, I, J = A.shape
    # u_ij = sum_k a_kij * lam_kj
    u = np.einsum("kij,kj->ij", A, lam)
    v = -(u + c) / gamma
    x = np.asarray(
        project_simplex_ineq(jnp.array(v, dtype=jnp.float32), jnp.ones((I, J), jnp.float32))
    )
    Ax = np.einsum("kij,ij->kj", A, x)
    grad = Ax - b
    g = (c * x).sum() + gamma / 2 * (x * x).sum() + (lam * grad).sum()
    return g, grad, x


def test_dual_step_matches_dense():
    rng = np.random.default_rng(42)
    m, I, J = 2, 24, 8
    A = (rng.random((m, I, J)) * (rng.random((m, I, J)) < 0.6)).astype(np.float32)
    c = -rng.random((I, J)).astype(np.float32)  # negative cost = value
    b = rng.random((m, J)).astype(np.float32) * I * 0.1
    lam = rng.random((m, J)).astype(np.float32)
    gamma = 0.05

    g_ref, grad_ref, x_ref = dense_dual(A, b, c, lam, gamma)

    # slab path: each source is one row of width J (single bucket, no padding)
    u = np.einsum("kij,kj->ij", A, lam).astype(np.float32)
    mask = np.ones((I, J), dtype=np.float32)
    x, cx, xsq = slab_step(
        jnp.array(u), jnp.array(c), jnp.array(mask), jnp.array([gamma], jnp.float32)
    )
    x = np.asarray(x)
    np.testing.assert_allclose(x, x_ref, rtol=1e-4, atol=1e-5)

    Ax = np.einsum("kij,ij->kj", A, x)
    grad = Ax - b
    g = float(cx[0]) + gamma / 2 * float(xsq[0]) + (lam * grad).sum()
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4)


def test_make_slab_step_kinds():
    fns = {k: make_slab_step(k) for k in ("simplex", "box")}
    rng = np.random.default_rng(0)
    u = jnp.array(rng.normal(size=(8, 4)).astype(np.float32))
    c = jnp.zeros((8, 4), jnp.float32)
    mask = jnp.ones((8, 4), jnp.float32)
    g = jnp.array([0.5], jnp.float32)
    xs, _, _ = fns["simplex"](u, c, mask, g)
    xb, _, _ = fns["box"](u, c, mask, g)
    assert np.all(np.asarray(xs).sum(1) <= 1 + 1e-5)
    assert np.all(np.asarray(xb) <= 1 + 1e-6)


def test_gradient_is_danskin_derivative():
    """∇g from the slab path must equal the numerical derivative of g(λ)
    (Danskin's theorem) away from projection kinks."""
    rng = np.random.default_rng(3)
    m, I, J = 1, 16, 4
    A = (rng.random((m, I, J)) + 0.5).astype(np.float32)
    c = -rng.random((I, J)).astype(np.float32)
    b = rng.random((m, J)).astype(np.float32)
    lam = (rng.random((m, J)) + 0.1).astype(np.float32)
    gamma = 0.2

    g0, grad, _ = dense_dual(A, b, c, lam, gamma)
    eps = 1e-3
    for k in range(m):
        for j in range(J):
            lp = lam.copy()
            lp[k, j] += eps
            gp, _, _ = dense_dual(A, b, c, lp, gamma)
            lm = lam.copy()
            lm[k, j] -= eps
            gm, _, _ = dense_dual(A, b, c, lm, gamma)
            num = (gp - gm) / (2 * eps)
            np.testing.assert_allclose(num, grad[k, j], rtol=5e-2, atol=5e-3)
