// Known-bad snippet for A1: a `vec!` allocation in a helper reachable
// from the `eval_chunk_partials` hot path. Counted under
// `backend.alloc`; with no checked-in budget the count fires A1.
// `Vec::with_capacity` in the root itself is deliberately legal —
// sized one-shot buffers are how scratch gets hoisted. Not compiled —
// consumed by the audit self-check.
// audit:path(src/backend/fixture.rs)
// audit:expect(A1)
pub fn eval_chunk_partials(lam: &[f32]) -> f32 {
    let mut acc = Vec::with_capacity(lam.len());
    acc.extend_from_slice(lam);
    per_chunk(&acc)
}

fn per_chunk(lam: &[f32]) -> f32 {
    // hot-loop allocation: fires A1 via the reachability cone
    let scaled = vec![0.0f32; lam.len()];
    scaled.len() as f32 + lam.len() as f32
}
