// Known-good snippet: the suppression paths must NOT fire. A justified
// waiver covers the hash container; a SAFETY comment covers the unsafe
// block; the integer turbofish blesses the threaded sum. Zero expected
// findings — over-firing fails the self-check just like under-firing.
// audit:path(src/solver/fixture.rs)

pub struct S {
    // audit:allow(unordered-iter): scratch map is drained into a sorted Vec before any ordered use
    pub m: std::collections::HashMap<u32, u32>,
}

pub fn count(parts: &[Vec<u32>]) -> usize {
    std::thread::scope(|s| {
        let _ = s;
    });
    parts.iter().map(|p| p.len()).sum::<usize>()
}

pub fn pid() -> i32 {
    // SAFETY: getpid reads no memory and cannot fail
    unsafe { libc::getpid() }
}
