// Known-bad snippet for D1 tier 1: a hash container declared in a
// determinism-critical module. Not compiled — consumed by the audit
// self-check (`cargo run --bin audit -- --self-check`).
// audit:path(src/solver/fixture.rs)
// audit:expect(D1)
pub struct Scratch {
    pub by_row: std::collections::HashMap<u32, f32>,
}
