// Known-bad snippet for D1 tier 2: iterating a bound hash container in a
// determinism-critical module. The declaration line fires tier 1, the
// `.keys()` site fires the sharper tier-2 message.
// audit:path(src/engine/fixture.rs)
// audit:expect(D1)
// audit:expect(D1)
pub struct Cache {
    entries: std::collections::HashMap<u64, f32>,
}

impl Cache {
    pub fn dump(&self) -> Vec<u64> {
        self.entries.keys().copied().collect()
    }
}
