// Known-bad snippet for D2: ambient wall-clock reads outside
// util/timer.rs. `Instant::now` and `SystemTime` each fire once.
// audit:path(src/solver/fixture.rs)
// audit:expect(D2)
// audit:expect(D2)
pub fn elapsed_since_epoch_ms() -> (std::time::Instant, u64) {
    let t = std::time::Instant::now();
    let e = std::time::SystemTime::UNIX_EPOCH;
    let _ = e;
    (t, 0)
}
