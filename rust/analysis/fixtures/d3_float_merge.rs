// Known-bad snippet for D3: a bare float `.sum()` in a file that spawns
// threads — the reduction order depends on interleaving, breaking
// N-thread ≡ 1-thread. The fix is reduce_chunk_partials (chunk-index
// order) or an integer turbofish when the sum is integral.
// audit:path(src/backend/fixture.rs)
// audit:expect(D3)
pub fn eval(parts: &[f32]) -> f32 {
    std::thread::scope(|s| {
        let _ = s;
    });
    parts.iter().sum()
}
