// Known-bad snippet for D4: values produced by unordered-container
// iteration flow across a fn boundary into f32 accumulation in a
// determinism-critical module. The HashMap declaration and iteration
// also fire D1 (tier 1 on the type tokens, tier 2 on the iteration) —
// the expectations pin both rules so neither can silently swallow the
// other. Not compiled — consumed by the audit self-check.
// audit:path(src/backend/fixture.rs)
// audit:expect(D1)
// audit:expect(D1)
// audit:expect(D1)
// audit:expect(D4)
use std::collections::HashMap;

fn edge_weights(by_edge: &HashMap<u32, f32>) -> Vec<f32> {
    let mut out = Vec::with_capacity(by_edge.len());
    for (_, w) in by_edge.iter() {
        out.push(*w);
    }
    out
}

pub fn merge_total(by_edge: &HashMap<u32, f32>) -> f32 {
    let mut acc = 0.0f32;
    for w in edge_weights(by_edge) {
        acc += w;
    }
    acc
}
