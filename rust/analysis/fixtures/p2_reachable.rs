// Known-bad snippet for P2: an `.unwrap()` two call hops below a
// ServeDaemon request entry point. The finding must print the full
// chain `ServeDaemon::submit -> enqueue -> admit`. Not compiled —
// consumed by the audit self-check.
// audit:path(src/serve/fixture.rs)
// audit:expect(P2)
pub struct ServeDaemon {
    pub depth: usize,
}

impl ServeDaemon {
    pub fn submit(&self, req: u32) -> u32 {
        enqueue(req, self.depth)
    }
}

fn enqueue(req: u32, depth: usize) -> u32 {
    admit(req, depth)
}

fn admit(req: u32, depth: usize) -> u32 {
    // reachable panic: entry -> enqueue -> admit
    let slot = depth.checked_sub(1).unwrap();
    req + slot as u32
}
