// Known-bad snippet for R1: a projection family registered in src/ that
// no test tier references — one finding per missing tier
// (tests/conformance.rs, tests/backend_parity.rs, and
// tests/kernel_matrix.rs).
// audit:path(src/projection/fixture.rs)
// audit:expect(R1)
// audit:expect(R1)
// audit:expect(R1)
pub fn install(r: &mut Registry) {
    r.add_family("ghost_family", &["ghost_family:1"], parse_ghost);
}
