// Known-good counterpart to r1_registry.rs: the same uncovered family
// registration, but carrying a justified `registry-coverage` waiver — so
// the waiver path through `check_registry` (apply_waivers runs inside it,
// not just in check_file) is pinned. Zero expect lines: all three
// per-tier findings must be swallowed by the one waiver on the
// registration line.
// audit:path(src/projection/fixture_waived.rs)
pub fn install(r: &mut Registry) {
    // audit:allow(registry-coverage): prototype family behind a feature gate; tiers wired before the gate ships
    r.add_family("ghost_family", &["ghost_family:1"], parse_ghost);
}
