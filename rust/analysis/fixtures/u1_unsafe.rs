// Known-bad snippet for U1: an `unsafe` block with no adjacent
// `// SAFETY:` argument (must appear on the same line or within the three
// lines above).
// audit:path(src/util/fixture.rs)
// audit:expect(U1)
pub fn thread_id() -> i32 {
    unsafe { libc::getpid() }
}
