// Known-bad snippet for W0: a waiver naming an unknown rule, and a
// waiver with no justification — which is itself W0 AND leaves the D1 it
// tried to cover standing.
// audit:path(src/sparse/fixture.rs)
// audit:expect(W0)
// audit:expect(W0)
// audit:expect(D1)
// audit:allow(no-such-rule): slug typo — does not match any catalog entry
pub fn a() {}

// audit:allow(unordered-iter):
pub struct S {
    pub m: std::collections::HashMap<u32, u32>,
}
