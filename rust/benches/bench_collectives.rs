//! Experiment E10 — the paper's §6 communication claim: per-iteration
//! traffic is λ-proportional — broadcasts and reduce payloads sized by
//! the dual dimension (plus, for the sharded-slab reduce, the fixed chunk
//! grid) — independent of nnz and of the per-device split.
//!
//! Sweeps nnz (at fixed dual dim) and workers for BOTH execution
//! strategies: the slab strategy (runs everywhere) asserts
//! `2·4·|λ| + chunks·(4·|λ| + 16)` bytes per iteration; the HLO strategy
//! (skipped without artifacts) asserts the flat `3·4·|λ| + 16` pattern.
//! Prints the α-β model's estimated wire time on NVLink/Ethernet.
//!
//! Run: cargo bench --bench bench_collectives

use std::sync::Arc;

use dualip::distributed::{DistributedObjective, ExecStrategy, LinkModel};
use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::ObjectiveFunction;
use dualip::runtime::default_artifacts_dir;
use dualip::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let art = default_artifacts_dir();
    let have_artifacts = art.join("manifest.txt").exists();
    let dests = 200usize;
    let iters = 5usize;

    let mut csv = CsvWriter::create(
        "results/e10_collectives.csv",
        &["exec", "nnz", "workers", "dual_dim", "bytes_per_iter", "expected"],
    )?;

    println!("E10 — per-iteration comm bytes (must depend ONLY on dual dim + chunk grid)");
    println!(
        "{:>6} {:>10} {:>8} {:>9} {:>14} {:>14}",
        "exec", "nnz", "workers", "dual", "B/iter", "expected"
    );
    for &sources in &[2_000usize, 8_000, 32_000] {
        for &workers in &[1usize, 2, 4] {
            let lp = Arc::new(generate(&SyntheticConfig {
                num_requests: sources,
                num_resources: dests,
                avg_nnz_per_row: 10.0,
                seed: 1,
                ..Default::default()
            }));
            let dual = lp.dual_dim();
            let lam = vec![0.01f32; dual];

            // --- slab strategy (no artifacts needed) ---------------------
            let mut dist = DistributedObjective::new_with(
                lp.clone(),
                ExecStrategy::Slab { threads: 1 },
                workers,
            )?;
            let before = dist.comm();
            for _ in 0..iters {
                let _ = dist.calculate(&lam, 0.01);
            }
            let after = dist.comm();
            let bytes = (after.bcast_bytes + after.reduce_bytes)
                - (before.bcast_bytes + before.reduce_bytes);
            let per_iter = bytes as f64 / iters as f64;
            // 2 bcasts (4·dual each) + 1 segmented reduce of
            // chunks × (4·dual + 16)
            let expected = (2 * 4 * dual + dist.num_chunks() * (4 * dual + 16)) as f64;
            println!(
                "{:>6} {:>10} {:>8} {:>9} {:>14.0} {:>14.0}",
                "slab",
                lp.nnz(),
                workers,
                dual,
                per_iter,
                expected
            );
            assert_eq!(per_iter, expected, "slab comm volume must be λ/chunk-sized only");
            csv.row(&[
                "slab".to_string(),
                lp.nnz().to_string(),
                workers.to_string(),
                dual.to_string(),
                format!("{per_iter:.0}"),
                format!("{expected:.0}"),
            ])?;

            // --- HLO strategy (artifact-gated) ---------------------------
            if have_artifacts {
                let mut dist = DistributedObjective::new(lp.clone(), &art, workers)?;
                let before = dist.comm();
                for _ in 0..iters {
                    let _ = dist.calculate(&lam, 0.01);
                }
                let after = dist.comm();
                let bytes = (after.bcast_bytes + after.reduce_bytes)
                    - (before.bcast_bytes + before.reduce_bytes);
                let per_iter = bytes as f64 / iters as f64;
                // 2 bcasts (4·dual each) + 1 reduce (4·dual + 2×8)
                let expected = (3 * 4 * dual + 16) as f64;
                println!(
                    "{:>6} {:>10} {:>8} {:>9} {:>14.0} {:>14.0}",
                    "hlo",
                    lp.nnz(),
                    workers,
                    dual,
                    per_iter,
                    expected
                );
                assert_eq!(per_iter, expected, "hlo comm volume must be λ-sized only");
                csv.row(&[
                    "hlo".to_string(),
                    lp.nnz().to_string(),
                    workers.to_string(),
                    dual.to_string(),
                    format!("{per_iter:.0}"),
                    format!("{expected:.0}"),
                ])?;
            }
        }
    }
    csv.flush()?;
    if !have_artifacts {
        println!("(HLO strategy skipped: no artifacts at {})", art.display());
    }

    println!("\nα-β wire-time estimates per iteration (3 ops of 4·|λ| bytes):");
    for dual in [1_000usize, 10_000, 100_000] {
        println!(
            "  |λ|={dual:>7}: NVLink {:>8.1} µs   Ethernet {:>8.1} µs",
            LinkModel::nvlink().iter_time(dual) * 1e6,
            LinkModel::ethernet().iter_time(dual) * 1e6
        );
    }
    println!("\nPASS: comm volume independent of nnz and workers; wrote results/e10_collectives.csv");
    Ok(())
}
