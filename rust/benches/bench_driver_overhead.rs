//! Experiment E16 — steppable-driver overhead + cooperative-executor
//! throughput.
//!
//! Three measurements, emitted to `results/BENCH_driver_overhead.json`:
//!
//! 1. **Driver overhead**: `Agd::maximize` (the `SolveDriver` path) vs a
//!    frozen inline copy of the pre-driver `run_loop` + AGD closure, on
//!    the same instance and schedule. The two must be bit-identical in λ;
//!    the per-iteration wall-clock difference is the price of the state
//!    machine. CI (fast mode) fails if it exceeds 3%.
//! 2. **Cooperative executor vs run-to-completion**: `solve_batch_coop`
//!    (round-robin quanta) vs `solve_batch` at 1/4/16 concurrent jobs on
//!    a 4-thread pool, with bit-identity asserted between the two paths.
//! 3. **Deadline-primed warm start**: a solve killed by a wall-clock
//!    deadline publishes its anytime λ; the follow-up solve of the same
//!    pattern starts warm — the warm-iteration reduction is reported.
//!
//! Run: cargo bench --bench bench_driver_overhead
//!      [DUALIP_BENCH_FAST=1 for CI size + the 3% overhead gate]

use dualip::backend::CpuBackend;
use dualip::engine::{EngineConfig, SolveEngine, SolveJob};
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::{BenchJson, JsonValue};
use dualip::problem::{jacobi_row_normalize, MatchingLp, ObjectiveFunction, ObjectiveResult};
use dualip::solver::{
    Agd, GammaSchedule, IterRecord, Maximizer, SolveOptions, SolveResult, StopReason,
    StoppingCriteria,
};
use dualip::util::mathvec;
use dualip::util::timer::Stopwatch;

/// Frozen copy of the seed repo's run-to-completion loop (`run_loop` +
/// the AGD closure, momentum never restarted) — the overhead comparator.
/// Deliberately NOT routed through the driver.
fn legacy_agd_solve(
    obj: &mut dyn ObjectiveFunction,
    initial: &[f32],
    opts: &SolveOptions,
) -> SolveResult {
    let sw = Stopwatch::start();
    let mut lam = initial.to_vec();
    let mut y = initial.to_vec();
    let mut lam_prev = initial.to_vec();
    let mut y_prev: Vec<f32> = Vec::new();
    let mut grad_prev: Vec<f32> = Vec::new();
    let mut trajectory = Vec::new();
    let mut last: Option<ObjectiveResult> = None;
    let mut iters = 0usize;

    for t in 0..opts.max_iters {
        let gamma = opts.gamma.gamma_at(t);
        let eta_cap = opts.max_step_size * opts.gamma.step_cap_scale(t) as f64;
        let res = obj.calculate(&y, gamma);
        let eta = if t == 0 || y_prev.is_empty() {
            opts.initial_step_size.min(eta_cap)
        } else {
            let dy = mathvec::dist2(&y, &y_prev);
            let dg = mathvec::dist2(&res.grad, &grad_prev);
            if dy > 0.0 && dg > 0.0 {
                (dy / dg).min(eta_cap)
            } else {
                eta_cap
            }
        };
        lam_prev.copy_from_slice(&lam);
        lam.copy_from_slice(&y);
        mathvec::axpy(eta as f32, &res.grad, &mut lam);
        mathvec::clamp_nonneg(&mut lam);
        let momentum_t = t + 1;
        let beta = momentum_t as f32 / (momentum_t as f32 + 3.0);
        y_prev = y.clone();
        grad_prev = res.grad.clone();
        let mut y_next = vec![0.0f32; y.len()];
        mathvec::extrapolate(&lam, &lam_prev, beta, &mut y_next);
        mathvec::clamp_nonneg(&mut y_next);
        y = y_next;

        iters = t + 1;
        let grad_norm = mathvec::norm2(&res.grad);
        if t % opts.record_every == 0 || t + 1 == opts.max_iters {
            trajectory.push(IterRecord {
                iter: t,
                dual_obj: res.dual_obj,
                grad_norm,
                infeas_pos_norm: res.infeas_pos_norm,
                cx: res.cx,
                gamma,
                step_size: eta,
                wall_ms: sw.elapsed_ms(),
            });
        }
        last = Some(res);
    }

    SolveResult {
        lam,
        final_obj: last.expect("bench runs at least one iteration"),
        trajectory,
        stop_reason: StopReason::MaxIters,
        iterations: iters,
        total_wall_ms: sw.elapsed_ms(),
        final_gamma: opts.gamma.gamma_at(iters.saturating_sub(1)),
    }
}

fn instance(sources: usize, dests: usize, seed: u64) -> MatchingLp {
    let mut lp = generate(&SyntheticConfig {
        num_requests: sources,
        num_resources: dests,
        avg_nnz_per_row: 8.0,
        seed,
        ..Default::default()
    });
    jacobi_row_normalize(&mut lp);
    lp
}

fn engine_cfg(threads: usize, cache: usize, iters: usize) -> EngineConfig {
    EngineConfig {
        opts: SolveOptions {
            max_iters: iters,
            max_step_size: 1.0,
            initial_step_size: 1e-4,
            gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 10 },
            stopping: StoppingCriteria {
                stall_tol: Some(1e-6),
                stall_patience: 10,
                ..Default::default()
            },
            record_every: 200,
        },
        warm_tail: 5,
        threads,
        cache_capacity: cache,
        backend: CpuBackend::Slab,
        objective_threads: 1,
        shards: 1,
        deadline_ms: None,
        quantum: 16,
    }
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, iters, reps) =
        if fast { (4_000, 64, 200, 3) } else { (20_000, 256, 400, 5) };

    println!(
        "E16 — driver overhead + cooperative executor: I={sources} J={dests} \
         iters={iters} reps={reps}{}",
        if fast { " (fast)" } else { "" }
    );
    let mut bench = BenchJson::new("driver_overhead");
    bench
        .meta("sources", JsonValue::UInt(sources as u64))
        .meta("dests", JsonValue::UInt(dests as u64))
        .meta("iters", JsonValue::UInt(iters as u64))
        .meta("reps", JsonValue::UInt(reps as u64))
        .meta("fast", JsonValue::Bool(fast));

    // ---- 1. per-iteration driver overhead vs the frozen legacy loop ----
    let lp = instance(sources, dests, 0);
    let opts = SolveOptions {
        max_iters: iters,
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 25 },
        record_every: 1, // worst case for the driver's recording path
        ..Default::default()
    };
    let init = vec![0.0f32; lp.dual_dim()];

    let mut obj = CpuBackend::Slab.objective(&lp, 1);
    // warm scratch + page-in before timing
    let _ = legacy_agd_solve(&mut obj, &init, &opts);

    let mut legacy_best_us = f64::INFINITY;
    let mut driver_best_us = f64::INFINITY;
    let mut legacy_last = None;
    let mut driver_last = None;
    for _ in 0..reps {
        let sw = Stopwatch::start();
        let r = legacy_agd_solve(&mut obj, &init, &opts);
        legacy_best_us = legacy_best_us.min(sw.elapsed_ms() * 1e3 / r.iterations as f64);
        legacy_last = Some(r);

        let sw = Stopwatch::start();
        let r = Agd::default().maximize(&mut obj, &init, &opts);
        driver_best_us = driver_best_us.min(sw.elapsed_ms() * 1e3 / r.iterations as f64);
        driver_last = Some(r);
    }
    let (legacy_r, driver_r) = (legacy_last.unwrap(), driver_last.unwrap());

    // bit-identity: the state machine must reproduce the legacy loop
    anyhow::ensure!(legacy_r.lam.len() == driver_r.lam.len());
    for (i, (a, b)) in legacy_r.lam.iter().zip(&driver_r.lam).enumerate() {
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "driver λ[{i}] diverged from the legacy loop: {a} vs {b}"
        );
    }
    anyhow::ensure!(legacy_r.trajectory.len() == driver_r.trajectory.len());
    anyhow::ensure!(
        legacy_r.final_obj.dual_obj.to_bits() == driver_r.final_obj.dual_obj.to_bits(),
        "driver final objective diverged"
    );

    let overhead_pct = (driver_best_us / legacy_best_us - 1.0) * 100.0;
    println!(
        "per-iteration: legacy {legacy_best_us:.2}µs vs driver {driver_best_us:.2}µs \
         → overhead {overhead_pct:+.2}%"
    );
    bench
        .meta("legacy_iter_us", JsonValue::Num(legacy_best_us))
        .meta("driver_iter_us", JsonValue::Num(driver_best_us))
        .meta("driver_overhead_pct", JsonValue::Num(overhead_pct));

    // ---- 2. cooperative executor vs run-to-completion scheduler --------
    let (job_sources, job_iters) = if fast { (1_500, 150) } else { (6_000, 300) };
    for &jobs in &[1usize, 4, 16] {
        let make_jobs = || -> Vec<SolveJob> {
            (0..jobs)
                .map(|k| SolveJob::new(k as u64, instance(job_sources, 48, 10 + k as u64)))
                .collect()
        };
        // zero-capacity caches: both paths solve the identical cold work
        let rtc_engine = SolveEngine::new(engine_cfg(4, 0, job_iters));
        let sw = Stopwatch::start();
        let (rtc, _) = rtc_engine.solve_batch(make_jobs());
        let rtc_ms = sw.elapsed_ms();

        let coop_engine = SolveEngine::new(engine_cfg(4, 0, job_iters));
        let sw = Stopwatch::start();
        let (coop, report) = coop_engine.solve_batch_coop(make_jobs());
        let coop_ms = sw.elapsed_ms();

        for (a, b) in rtc.iter().zip(&coop) {
            anyhow::ensure!(
                a.dual_obj.to_bits() == b.dual_obj.to_bits()
                    && a.iterations == b.iterations
                    && a.lam.iter().zip(&b.lam).all(|(x, y)| x.to_bits() == y.to_bits()),
                "cooperative job {} diverged from run-to-completion",
                a.id
            );
        }
        let ratio = rtc_ms / coop_ms.max(1e-9);
        println!(
            "{jobs:>3} jobs: run-to-completion {rtc_ms:.1}ms vs cooperative {coop_ms:.1}ms \
             ({} rounds, throughput ratio {ratio:.2})",
            report.rounds
        );
        bench.row(&[
            ("section", JsonValue::Str("executor".into())),
            ("jobs", JsonValue::UInt(jobs as u64)),
            ("run_to_completion_ms", JsonValue::Num(rtc_ms)),
            ("cooperative_ms", JsonValue::Num(coop_ms)),
            ("coop_rounds", JsonValue::UInt(report.rounds as u64)),
            ("throughput_ratio", JsonValue::Num(ratio)),
        ]);
    }

    // ---- 3. deadline-killed solve warms its successor ------------------
    let warm_lp = || instance(job_sources, 48, 77);
    let cold_engine = SolveEngine::new(engine_cfg(1, 16, job_iters));
    let cold = cold_engine.submit(SolveJob::new(0, warm_lp()));

    let engine = SolveEngine::new(engine_cfg(2, 16, job_iters));
    // aim the deadline mid-solve; even if the machine outruns it the
    // follow-up still measures the warm-start path
    let deadline = (cold.wall_ms * 0.4).max(1.0);
    let (killed, kreport) =
        engine.solve_batch_coop(vec![SolveJob::new(1, warm_lp()).with_deadline_ms(deadline)]);
    let warm = engine.submit(SolveJob::new(2, warm_lp()));
    anyhow::ensure!(warm.warm, "killed/primed solve must publish a warm start");
    let reduction = cold.iterations as f64 / warm.iterations.max(1) as f64;
    println!(
        "deadline priming: cold {} iters; killed stop {:?} after {} iters \
         (deadline {deadline:.1}ms, {} deadline stops); warm re-solve {} iters \
         ({reduction:.2}x fewer)",
        cold.iterations,
        killed[0].stop_reason,
        killed[0].iterations,
        kreport.deadline_stops,
        warm.iterations,
    );
    bench
        .meta("cold_iters", JsonValue::UInt(cold.iterations as u64))
        .meta("deadline_ms", JsonValue::Num(deadline))
        .meta("killed_iters", JsonValue::UInt(killed[0].iterations as u64))
        .meta(
            "killed_stop",
            JsonValue::Str(format!("{:?}", killed[0].stop_reason)),
        )
        .meta("warm_iters", JsonValue::UInt(warm.iterations as u64))
        .meta("warm_iter_reduction", JsonValue::Num(reduction));

    let path = bench.write("results")?;
    println!("wrote {}", path.display());

    // CI smoke gate: the steppable driver must stay within 3% of the
    // legacy loop per iteration (ISSUE 5 acceptance)
    if fast {
        anyhow::ensure!(
            overhead_pct <= 3.0,
            "driver overhead {overhead_pct:.2}% exceeds the 3% gate"
        );
    }
    Ok(())
}
