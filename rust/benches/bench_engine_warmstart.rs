//! Experiment E12 — serving-side warm-start claim: on a stream of
//! same-pattern instances with perturbed `c`/`b`, a warm-started re-solve
//! reaches the matched stopping criterion (objective stall at the floor γ)
//! in measurably fewer AGD iterations than a cold solve. Since first-order
//! LP wall-clock is iteration-bound, iteration savings are the serving
//! win; the batch scheduler additionally overlaps jobs across the pool.
//!
//! Emits machine-readable `results/BENCH_engine_warmstart.json` (cold vs
//! warm iterations and wall-ms per job + aggregate speedup) so the perf
//! trajectory is tracked across PRs.
//!
//! Run: cargo bench --bench bench_engine_warmstart
//!      [DUALIP_BENCH_FAST=1 for CI size]

use dualip::cli::{commands, Args};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, jobs) = if fast { (5_000, 100, 8) } else { (50_000, 500, 16) };
    let argv = [
        "engine-batch".to_string(),
        "--sources".into(),
        sources.to_string(),
        "--dests".into(),
        dests.to_string(),
        "--jobs".into(),
        jobs.to_string(),
        "--threads".into(),
        "8".into(),
        "--perturb".into(),
        "0.05".into(),
        "--seed".into(),
        "0".into(),
    ];
    let args = Args::parse(argv.into_iter())?;
    commands::cmd_engine_batch(&args)
}
