//! Figure 3 reproduction (experiment E4): solve time vs number of devices
//! and speedup relative to one device (paper: 3.86× at 4 GPUs vs ideal 4×).
//!
//! Single-core testbed ⇒ multi-device points use the modeled-parallel time
//! per iteration (max over worker shard walltimes + NVLink α-β comm); the
//! 1-device point is directly measured. DESIGN.md §5 documents the
//! substitution.
//!
//! Run: cargo bench --bench bench_fig3_scaling

use std::sync::Arc;

use dualip::distributed::{DistributedObjective, LinkModel};
use dualip::gen::{generate, workloads};
use dualip::metrics::stats;
use dualip::problem::ObjectiveFunction;
use dualip::runtime::default_artifacts_dir;
use dualip::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[100_000] } else { &[250_000, 500_000, 1_000_000] };
    let evals = if fast { 3 } else { 6 };
    let art = default_artifacts_dir();
    let gamma = 0.01f32;

    let mut csv = CsvWriter::create(
        "results/fig3_scaling.csv",
        &["sources", "workers", "ms_per_iter", "speedup_vs_1"],
    )?;

    println!("Fig 3 — per-iteration time vs devices (modeled-parallel) and speedup");
    for &sources in sizes {
        let cfg = dualip::gen::SyntheticConfig {
            num_requests: sources,
            ..workloads::table2_row(25, 0)
        };
        let lp = Arc::new(generate(&cfg));
        let lam = vec![0.01f32; lp.dual_dim()];
        let comm_ms = LinkModel::nvlink().iter_time(lp.dual_dim()) * 1e3;

        let mut t1 = f64::NAN;
        for workers in 1..=4usize {
            let mut dist = DistributedObjective::new(lp.clone(), &art, workers)?;
            let _ = dist.calculate(&lam, gamma); // warm
            for _ in 0..evals {
                let _ = dist.calculate(&lam, gamma);
            }
            let ms = stats(&dist.iter_compute_max_ms()[1..]).median + comm_ms;
            if workers == 1 {
                t1 = ms;
            }
            let speedup = t1 / ms;
            println!(
                "  I={sources:>9} workers={workers}: {ms:>8.1} ms/iter  speedup {speedup:.2}× (ideal {workers}×)"
            );
            csv.row(&[
                sources.to_string(),
                workers.to_string(),
                format!("{ms:.2}"),
                format!("{speedup:.3}"),
            ])?;
        }
    }
    csv.flush()?;
    println!("\nwrote results/fig3_scaling.csv (paper: 3.86× @ 4 devices)");
    Ok(())
}
