//! Experiment E9 — the paper's §6 batching claim: executing projections as
//! one dense padded slab per log₂ bucket beats launching one kernel per
//! source slice ("tiny kernels, launch overhead, low occupancy").
//!
//! Both paths run the SAME fused dual-step artifact; only the launch
//! granularity differs: [1024, w] once vs [1, w] × 1024. Also reports the
//! padding waste the geometric bucketing trades for those launches.
//!
//! Run: cargo bench --bench bench_projection_batching

use dualip::projection::ProjectionKind;
use dualip::runtime::{default_artifacts_dir, Engine};
use dualip::util::rng::Rng;
use dualip::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new(default_artifacts_dir())?;
    let t = engine.tile_rows();
    let mut rng = Rng::new(9);
    let gamma = 0.05f32;
    let kind = ProjectionKind::Simplex;

    println!("E9 — batched slab vs per-slice launches (rows = {t}, fused simplex step)");
    println!("{:>6} {:>14} {:>14} {:>10}", "width", "batched ms", "per-slice ms", "ratio");

    let mut csv = dualip::util::csv::CsvWriter::create(
        "results/e9_projection_batching.csv",
        &["width", "rows", "batched_ms", "per_slice_ms", "ratio"],
    )?;

    for &w in &[8usize, 32, 128] {
        let n = t * w;
        let u: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.3) as f32).collect();
        let c: Vec<f32> = (0..n).map(|_| -(rng.uniform() as f32)).collect();
        let mask = vec![1.0f32; n];

        // batched: one [t, w] launch
        let ul = engine.literal_2d(&u, w)?;
        let cl = engine.literal_2d(&c, w)?;
        let ml = engine.literal_2d(&mask, w)?;
        let _ = engine.run_slab(kind, w, &ul, &cl, &ml, gamma)?; // warm/compile
        let reps = 5;
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let _ = engine.run_slab(kind, w, &ul, &cl, &ml, gamma)?;
        }
        let batched_ms = sw.elapsed_ms() / reps as f64;

        // per-slice: t launches of [1, w]
        let row_lits: Vec<(xla::Literal, xla::Literal, xla::Literal)> = (0..t)
            .map(|r| {
                let s = r * w;
                Ok((
                    engine.literal_2d(&u[s..s + w], w)?,
                    engine.literal_2d(&c[s..s + w], w)?,
                    engine.literal_2d(&mask[s..s + w], w)?,
                ))
            })
            .collect::<anyhow::Result<_>>()?;
        let (u0, c0, m0) = &row_lits[0];
        let _ = engine.run_slab_rows(kind, 1, w, u0, c0, m0, gamma)?; // warm/compile
        let sw = Stopwatch::start();
        for (ur, cr, mr) in &row_lits {
            let _ = engine.run_slab_rows(kind, 1, w, ur, cr, mr, gamma)?;
        }
        let per_slice_ms = sw.elapsed_ms();

        let ratio = per_slice_ms / batched_ms;
        println!("{w:>6} {batched_ms:>14.2} {per_slice_ms:>14.2} {ratio:>9.1}x");
        csv.row(&[
            w.to_string(),
            t.to_string(),
            format!("{batched_ms:.3}"),
            format!("{per_slice_ms:.3}"),
            format!("{ratio:.1}"),
        ])?;
    }
    csv.flush()?;

    // padding waste of geometric bucketing on a realistic degree mix
    let cfg = dualip::gen::SyntheticConfig {
        num_requests: 50_000,
        num_resources: 500,
        avg_nnz_per_row: 10.0,
        ..Default::default()
    };
    let lp = dualip::gen::generate(&cfg);
    let layout = dualip::sparse::SlabLayout::build(&lp.a, &lp.cost, 0, lp.num_sources(), &|_| kind)
        .map_err(anyhow::Error::msg)?;
    println!(
        "\ngeometric bucketing on Appendix-B mix: {} launches, padding factor {:.2} (paper: < 2)",
        layout.num_launches(),
        layout.padding_factor()
    );
    println!("wrote results/e9_projection_batching.csv");
    Ok(())
}
