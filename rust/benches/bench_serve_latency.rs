//! Experiment E17 — resident serve daemon: steady-state latency, warm-hit
//! rate, and the delta-vs-rebuild speedup.
//!
//! Three measurements, emitted to `results/BENCH_serve_latency.json`:
//!
//! 1. **Steady-state stream latency**: a drifting request stream
//!    (`gen::workloads::drift_stream`) played through `ServeDaemon` in
//!    bursts — p50/p99 solve wall-clock, warm-hit rate, and the shed
//!    counters. The drain must be clean: every submitted request resolves
//!    to exactly one outcome, none of them `Failed`, and the resident slab
//!    absorbs the whole stream with **zero repacks** (pure c/b drift) —
//!    both asserted.
//! 2. **Delta vs rebuild**: absorbing a same-pattern drifted instance into
//!    the resident slab (`absorb_planes` — cost-plane patch, zero
//!    structural work) vs building the slab layout from scratch
//!    (`ResidentInstance::new`), with the patched slab's parity against a
//!    rebuild asserted.
//! 3. **Snapshot round-trip**: the daemon's durable warm-start state is
//!    encoded, decoded, and re-encoded — byte-identical the second time —
//!    and the written JSON is read back to check the `schema_version`
//!    stamp and the headline metrics (the CI smoke gate).
//!
//! Run: cargo bench --bench bench_serve_latency
//!      [DUALIP_BENCH_FAST=1 for CI sizes]

use dualip::gen::workloads::{drift_stream, perturb_instance, DriftStreamSpec, PerturbSpec};
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::{stats, BenchJson, JsonValue};
use dualip::problem::{jacobi_row_normalize, MatchingLp};
use dualip::serve::{Outcome, ResidentInstance, ServeConfig, ServeDaemon};
use dualip::solver::{GammaSchedule, SolveOptions, StoppingCriteria};
use dualip::util::timer::Stopwatch;

fn instance(sources: usize, dests: usize, seed: u64) -> MatchingLp {
    let mut lp = generate(&SyntheticConfig {
        num_requests: sources,
        num_resources: dests,
        avg_nnz_per_row: 8.0,
        seed,
        ..Default::default()
    });
    jacobi_row_normalize(&mut lp);
    lp
}

fn serve_cfg(threads: usize, iters: usize) -> ServeConfig {
    ServeConfig {
        opts: SolveOptions {
            max_iters: iters,
            max_step_size: 1.0,
            initial_step_size: 1e-4,
            gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 10 },
            stopping: StoppingCriteria {
                stall_tol: Some(1e-6),
                stall_patience: 10,
                ..Default::default()
            },
            record_every: 200,
        },
        warm_tail: 5,
        threads,
        cache_capacity: 16,
        objective_threads: 1,
        quantum: 16,
        max_queue: 64,
        default_slo_ms: None,
        audit_parity: false,
    }
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, iters, requests, burst, reps) =
        if fast { (4_000, 64, 200, 10, 4, 3) } else { (20_000, 256, 400, 24, 6, 5) };

    println!(
        "E17 — serve daemon latency: I={sources} J={dests} iters={iters} \
         requests={requests} burst={burst}{}",
        if fast { " (fast)" } else { "" }
    );
    let mut bench = BenchJson::new("serve_latency");
    bench
        .meta("sources", JsonValue::UInt(sources as u64))
        .meta("dests", JsonValue::UInt(dests as u64))
        .meta("iters", JsonValue::UInt(iters as u64))
        .meta("requests", JsonValue::UInt(requests as u64))
        .meta("burst", JsonValue::UInt(burst as u64))
        .meta("fast", JsonValue::Bool(fast));

    // ---- 1. steady-state latency over a drifting stream ----------------
    let base = instance(sources, dests, 0);
    let spec = DriftStreamSpec {
        n: requests,
        drift: PerturbSpec { c_rel: 0.05, b_rel: 0.05 },
        ..Default::default()
    };
    let stream = drift_stream(&base, &spec, 1);
    let mut daemon = ServeDaemon::new(serve_cfg(4, iters));
    let outcomes = daemon.run_stream(&stream, burst);

    // clean drain: one terminal outcome per request, nothing failed,
    // nothing left queued
    anyhow::ensure!(daemon.pending() == 0, "drain left {} requests queued", daemon.pending());
    anyhow::ensure!(
        outcomes.len() == requests,
        "{} outcomes for {requests} requests",
        outcomes.len()
    );
    let mut wall = Vec::new();
    let mut warm_solves = 0usize;
    let mut shed = 0usize;
    for o in &outcomes {
        match &o.outcome {
            Outcome::Solved(r) => {
                wall.push(r.wall_ms);
                warm_solves += r.warm as usize;
            }
            Outcome::Shed(_) => shed += 1,
            Outcome::Failed(e) => anyhow::bail!("request {} failed: {e}", o.id),
        }
    }
    anyhow::ensure!(!wall.is_empty(), "no request solved");
    // pure c/b drift: the whole stream must be absorbed as plane patches
    let patch = daemon.resident().expect("resident after stream").report;
    anyhow::ensure!(patch.repacked == 0, "c/b drift stream repacked {} buckets", patch.repacked);
    anyhow::ensure!(daemon.stats().instance_loads == 1, "stream must reuse the resident slab");

    let st = stats(&wall);
    let hit_rate = warm_solves as f64 / wall.len() as f64;
    println!(
        "stream: {} solved / {shed} shed — p50 {:.1}ms p99 {:.1}ms (mean {:.1}ms); \
         warm-hit rate {:.0}%",
        st.n,
        st.median,
        st.p99,
        st.mean,
        100.0 * hit_rate
    );
    println!("{}", daemon.report());
    bench
        .meta("solved", JsonValue::UInt(st.n as u64))
        .meta("shed", JsonValue::UInt(shed as u64))
        .meta("p50_wall_ms", JsonValue::Num(st.median))
        .meta("p99_wall_ms", JsonValue::Num(st.p99))
        .meta("mean_wall_ms", JsonValue::Num(st.mean))
        .meta("warm_hit_rate", JsonValue::Num(hit_rate))
        .meta("plane_absorbs", JsonValue::UInt(daemon.stats().plane_absorbs));
    for (k, r) in outcomes.iter().enumerate() {
        if let Outcome::Solved(r) = &r.outcome {
            bench.row(&[
                ("req", JsonValue::UInt(k as u64)),
                ("warm", JsonValue::Bool(r.warm)),
                ("iterations", JsonValue::UInt(r.iterations as u64)),
                ("wall_ms", JsonValue::Num(r.wall_ms)),
            ]);
        }
    }

    // ---- 2. delta absorb vs from-scratch rebuild ------------------------
    let drifted = perturb_instance(&base, &PerturbSpec { c_rel: 0.05, b_rel: 0.05 }, 7);
    let mut resident = ResidentInstance::new(base.clone()).map_err(anyhow::Error::msg)?;
    let mut absorb_best_ms = f64::INFINITY;
    let mut rebuild_best_ms = f64::INFINITY;
    for _ in 0..reps {
        let sw = Stopwatch::start();
        resident.absorb_planes(&drifted).map_err(anyhow::Error::msg)?;
        absorb_best_ms = absorb_best_ms.min(sw.elapsed_ms());

        let sw = Stopwatch::start();
        let fresh = ResidentInstance::new(drifted.clone()).map_err(anyhow::Error::msg)?;
        rebuild_best_ms = rebuild_best_ms.min(sw.elapsed_ms());
        std::hint::black_box(fresh.grid().len());
    }
    // the shortcut must not cost correctness: patched slab == rebuilt slab
    resident.parity_check().map_err(anyhow::Error::msg)?;
    let speedup = rebuild_best_ms / absorb_best_ms.max(1e-9);
    println!(
        "delta vs rebuild: absorb_planes {absorb_best_ms:.3}ms vs rebuild \
         {rebuild_best_ms:.3}ms → {speedup:.1}x"
    );
    bench
        .meta("absorb_ms", JsonValue::Num(absorb_best_ms))
        .meta("rebuild_ms", JsonValue::Num(rebuild_best_ms))
        .meta("delta_speedup", JsonValue::Num(speedup));

    // ---- 3. snapshot round-trip + emitted-schema smoke ------------------
    let bytes = daemon.snapshot_bytes().map_err(anyhow::Error::msg)?;
    let restored = ServeDaemon::restore(serve_cfg(4, iters), &bytes)
        .map_err(anyhow::Error::msg)?;
    let again = restored.snapshot_bytes().map_err(anyhow::Error::msg)?;
    anyhow::ensure!(bytes == again, "snapshot re-encode is not byte-identical");
    anyhow::ensure!(
        restored.cache().tick() == daemon.cache().tick(),
        "restored cache clock drifted"
    );
    println!("snapshot: {} bytes, byte-stable across decode/encode", bytes.len());
    bench.meta("snapshot_bytes", JsonValue::UInt(bytes.len() as u64));

    let path = bench.write("results")?;
    println!("wrote {}", path.display());

    // CI smoke gate: the emitted JSON must carry the versioned schema and
    // the headline metrics this bench exists to track
    let text = std::fs::read_to_string(&path)?;
    let schema = [
        "\"schema_version\"",
        "\"p50_wall_ms\"",
        "\"p99_wall_ms\"",
        "\"warm_hit_rate\"",
        "\"delta_speedup\"",
    ];
    for needle in schema {
        anyhow::ensure!(text.contains(needle), "{} missing {needle}", path.display());
    }
    Ok(())
}
