//! Experiment E15 — sharded slab execution scaling: per-iteration
//! evaluation time of the chunk-sharded slab objective at shard counts
//! S ∈ {1, 2, 4, 8}, the bit-identity contract (every S reproduces the
//! single-shard bits exactly, asserted), and the paper's §6 λ-only
//! traffic claim: per-iteration communication is `2·4·|λ|` broadcast
//! bytes plus one segmented reduce of `chunks × (4·|λ| + 16)` bytes —
//! proportional to the dual dimension and the fixed chunk grid, never to
//! shard edge counts (asserted across an nnz sweep).
//!
//! Emits machine-readable `results/BENCH_shard_scaling.json` so the
//! scaling trajectory is tracked across PRs.
//!
//! Run: cargo bench --bench bench_shard_scaling
//!      [DUALIP_BENCH_FAST=1 for CI size]

use dualip::backend::{ShardedSlabObjective, SlabCpuObjective};
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::{BenchJson, JsonValue};
use dualip::problem::{MatchingLp, ObjectiveFunction};
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};
use dualip::util::rng::Rng;
use dualip::util::timer::Stopwatch;

fn instance(sources: usize, dests: usize, nnz_per_row: f64) -> MatchingLp {
    generate(&SyntheticConfig {
        num_requests: sources,
        num_resources: dests,
        avg_nnz_per_row: nnz_per_row,
        seed: 0,
        ..Default::default()
    })
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, reps) = if fast { (5_000, 100, 15) } else { (50_000, 500, 30) };
    let lp = instance(sources, dests, 10.0);
    let gamma = 0.05f32;
    let mut rng = Rng::new(7);
    let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.1) as f32).collect();
    let dual = lp.dual_dim();

    println!(
        "E15 — sharded slab scaling: I={} J={} nnz={} dual_dim={dual} reps={reps}{}",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        if fast { " (fast)" } else { "" }
    );

    let time_iters = |obj: &mut dyn ObjectiveFunction| -> f64 {
        let _ = obj.calculate(&lam, gamma); // warm caches and scratch
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let _ = obj.calculate(&lam, gamma);
        }
        sw.elapsed_ms() * 1e3 / reps as f64 // µs per iteration
    };

    // --- single-shard baseline ------------------------------------------
    let mut one = SlabCpuObjective::new(&lp, 1).map_err(anyhow::Error::msg)?;
    let one_us = time_iters(&mut one);
    let r1 = one.calculate(&lam, gamma);

    let mut bench = BenchJson::new("shard_scaling");
    bench
        .meta("sources", JsonValue::UInt(lp.num_sources() as u64))
        .meta("dests", JsonValue::UInt(lp.num_dests() as u64))
        .meta("nnz", JsonValue::UInt(lp.nnz() as u64))
        .meta("dual_dim", JsonValue::UInt(dual as u64))
        .meta("chunks", JsonValue::UInt(one.num_chunks() as u64))
        .meta("reps", JsonValue::UInt(reps as u64))
        .meta("gamma", JsonValue::Num(gamma as f64))
        .meta("fast", JsonValue::Bool(fast));

    println!(
        "{:>8} {:>14} {:>10} {:>14} {:>12} {:>10}",
        "shards", "iter µs", "speedup", "λ-B/iter", "imbalance", "bitident"
    );
    println!("{:>8} {:>14.1} {:>10.2}x {:>14} {:>12} {:>10}", 1, one_us, 1.0, "-", "-", "-");
    bench.row(&[
        ("shards", JsonValue::UInt(1)),
        ("iter_us", JsonValue::Num(one_us)),
        ("speedup_vs_1shard", JsonValue::Num(1.0)),
    ]);

    // --- shard sweep: timing + λ-traffic + bit-identity ------------------
    for &shards in &[2usize, 4, 8] {
        let mut sh = ShardedSlabObjective::new(&lp, shards, 1).map_err(anyhow::Error::msg)?;
        let us = time_iters(&mut sh);
        let comm_before = sh.comm();
        let rs = sh.calculate(&lam, gamma);
        let comm_after = sh.comm();

        // bit-identity contract: the whole point of the chunk-ordered
        // allreduce — any shard count reproduces the 1-shard bits
        anyhow::ensure!(
            rs.dual_obj.to_bits() == r1.dual_obj.to_bits()
                && rs.cx.to_bits() == r1.cx.to_bits()
                && rs.grad.iter().zip(&r1.grad).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{shards}-shard evaluation is not bit-identical to 1 shard"
        );

        // λ-only traffic: 2 broadcasts + chunks segments of (4·dual + 16)
        let per_iter = (comm_after.bcast_bytes + comm_after.reduce_bytes)
            - (comm_before.bcast_bytes + comm_before.reduce_bytes);
        let expected = (2 * 4 * dual + sh.num_chunks() * (4 * dual + 16)) as u64;
        anyhow::ensure!(
            per_iter == expected,
            "comm volume must be λ/chunk-sized only: got {per_iter}, expected {expected}"
        );

        println!(
            "{:>8} {:>14.1} {:>10.2}x {:>14} {:>12.2} {:>10}",
            shards,
            us,
            one_us / us,
            per_iter,
            sh.imbalance(),
            "yes"
        );
        bench.row(&[
            ("shards", JsonValue::UInt(shards as u64)),
            ("iter_us", JsonValue::Num(us)),
            ("speedup_vs_1shard", JsonValue::Num(one_us / us)),
            ("bytes_per_iter", JsonValue::UInt(per_iter)),
            ("imbalance", JsonValue::Num(sh.imbalance())),
            ("chunks", JsonValue::UInt(sh.num_chunks() as u64)),
            ("bit_identical", JsonValue::Bool(true)),
        ]);
    }

    // --- traffic is independent of shard edge counts ---------------------
    // quadruple the edges at a fixed dual dimension: reduce payload may
    // shift only with the (bounded) chunk-grid size, never with nnz
    let mut traffic = Vec::new();
    for &scale in &[1usize, 4] {
        let lp2 = instance(sources * scale, dests, 10.0);
        let mut sh = ShardedSlabObjective::new(&lp2, 4, 1).map_err(anyhow::Error::msg)?;
        let lam2 = vec![0.01f32; lp2.dual_dim()];
        let before = sh.comm();
        let _ = sh.calculate(&lam2, gamma);
        let after = sh.comm();
        let per_iter =
            (after.bcast_bytes + after.reduce_bytes) - (before.bcast_bytes + before.reduce_bytes);
        let expected =
            (2 * 4 * lp2.dual_dim() + sh.num_chunks() * (4 * lp2.dual_dim() + 16)) as u64;
        anyhow::ensure!(per_iter == expected, "traffic formula violated at nnz scale {scale}");
        println!(
            "nnz sweep: {:>9} edges -> {per_iter} λ-B/iter ({} chunks)",
            lp2.nnz(),
            sh.num_chunks()
        );
        bench.row(&[
            ("nnz_sweep_edges", JsonValue::UInt(lp2.nnz() as u64)),
            ("bytes_per_iter", JsonValue::UInt(per_iter)),
            ("chunks", JsonValue::UInt(sh.num_chunks() as u64)),
        ]);
        traffic.push((lp2.nnz() as f64, per_iter as f64));
    }
    let (small, big) = (traffic[0], traffic[1]);
    let edge_ratio = big.0 / small.0;
    let byte_ratio = big.1 / small.1;
    anyhow::ensure!(
        byte_ratio < edge_ratio / 2.0,
        "λ traffic must not scale with edges: {edge_ratio:.1}x edges -> {byte_ratio:.2}x bytes"
    );
    bench.meta("nnz_sweep_byte_ratio", JsonValue::Num(byte_ratio));

    // --- end-to-end: a short solve is bit-identical across shard counts --
    let opts = SolveOptions {
        max_iters: if fast { 25 } else { 60 },
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1e-2,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let mut agd = Agd::default();
    let solve_1 = agd.maximize(&mut one, &vec![0.0; dual], &opts);
    let mut sh4 = ShardedSlabObjective::new(&lp, 4, 1).map_err(anyhow::Error::msg)?;
    let mut agd4 = Agd::default();
    let solve_4 = agd4.maximize(&mut sh4, &vec![0.0; dual], &opts);
    anyhow::ensure!(
        solve_1.lam.iter().zip(&solve_4.lam).all(|(a, b)| a.to_bits() == b.to_bits()),
        "4-shard solve trajectory diverged from single-shard slab"
    );
    println!(
        "solve bit-identity: 4-shard == 1-shard over {} iterations (λ bitwise equal)",
        solve_1.iterations
    );
    bench.meta("solve_bit_identical", JsonValue::Bool(true));

    let path = bench.write("results")?;
    println!("wrote {}", path.display());
    Ok(())
}
