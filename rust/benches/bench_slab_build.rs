//! Experiment E18 — slab build & repack pipeline throughput: the
//! counting-sort layout build (serial vs chunk-parallel fill at 2/4/8
//! threads, bit-identity enforced), pow2 vs quarter-step width policies
//! (padding factor on uniform and power-law degree workloads), and the
//! serve-path repack engine (width-crossing `patch_edge_indexed` cycles,
//! `patch_costs` refresh, `SlabIndex` construction).
//!
//! Emits machine-readable `results/BENCH_slab_build.json` (build ms per
//! workload/policy/thread-count with speedup-vs-serial, padding factors,
//! µs per repack op) so the build-path perf trajectory is tracked across
//! PRs.
//!
//! Run: cargo bench --bench bench_slab_build
//!      [DUALIP_BENCH_FAST=1 for CI size — also asserts 4-thread build
//!       speedup ≥ 1.0 on the default pow2 policy]

use dualip::gen::{generate, power_law_instance, PowerLawConfig, SyntheticConfig};
use dualip::metrics::{BenchJson, JsonValue};
use dualip::problem::MatchingLp;
use dualip::sparse::slabs::EdgePatch;
use dualip::sparse::{BuildOptions, SlabIndex, SlabLayout, WidthPolicy};
use dualip::util::timer::Stopwatch;

fn build_once(lp: &MatchingLp, opts: BuildOptions) -> anyhow::Result<SlabLayout> {
    let kind_of = |i: usize| lp.projection.kind_of(i);
    SlabLayout::build_opts(&lp.a, &lp.cost, 0, lp.num_sources(), &kind_of, opts)
        .map_err(anyhow::Error::msg)
}

/// Best-of-`reps` build wall-clock in ms (min is robust to CI noise),
/// plus the layout from the final rep for downstream gates.
fn time_build(
    lp: &MatchingLp,
    opts: BuildOptions,
    reps: usize,
) -> anyhow::Result<(SlabLayout, f64)> {
    let mut best = f64::INFINITY;
    let mut layout = build_once(lp, opts)?; // warm allocator and caches
    for _ in 0..reps {
        let sw = Stopwatch::start();
        layout = build_once(lp, opts)?;
        best = best.min(sw.elapsed_ms());
    }
    Ok((layout, best))
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, reps, cycles) =
        if fast { (30_000, 1_000, 5, 200) } else { (1_000_000, 20_000, 3, 500) };

    let uniform = generate(&SyntheticConfig {
        num_requests: sources,
        num_resources: dests,
        avg_nnz_per_row: 12.0,
        seed: 0,
        ..Default::default()
    });
    let powerlaw = power_law_instance(&PowerLawConfig {
        num_sources: sources,
        num_dests: dests,
        seed: 0,
        ..Default::default()
    });

    println!(
        "E18 — slab build & repack: I={sources} J={dests} uniform nnz={} \
         powerlaw nnz={} reps={reps}{}",
        uniform.nnz(),
        powerlaw.nnz(),
        if fast { " (fast)" } else { "" }
    );
    println!(
        "{:>10} {:>9} {:>8} {:>12} {:>10} {:>9}",
        "workload", "policy", "threads", "build ms", "speedup", "padding"
    );

    let mut bench = BenchJson::new("slab_build");
    bench
        .meta("sources", JsonValue::UInt(sources as u64))
        .meta("dests", JsonValue::UInt(dests as u64))
        .meta("uniform_nnz", JsonValue::UInt(uniform.nnz() as u64))
        .meta("powerlaw_nnz", JsonValue::UInt(powerlaw.nnz() as u64))
        .meta("reps", JsonValue::UInt(reps as u64))
        .meta("repack_cycles", JsonValue::UInt(cycles as u64))
        .meta("fast", JsonValue::Bool(fast));

    let mut padding_by = [[0.0f64; 2]; 2]; // [workload][policy]
    for (wi, (wname, lp)) in [("uniform", &uniform), ("powerlaw", &powerlaw)].iter().enumerate() {
        for (pi, policy) in [WidthPolicy::Pow2, WidthPolicy::QuarterStep].into_iter().enumerate() {
            let serial_opts = BuildOptions { policy, threads: 0 };
            let (serial, serial_ms) = time_build(lp, serial_opts, reps)?;
            let padding = serial.padding_factor();
            padding_by[wi][pi] = padding;
            println!(
                "{:>10} {:>9} {:>8} {:>12.2} {:>10.2}x {:>9.3}",
                wname,
                policy.name(),
                "serial",
                serial_ms,
                1.0,
                padding
            );
            bench.row(&[
                ("workload", JsonValue::Str(wname.to_string())),
                ("policy", JsonValue::Str(policy.name().into())),
                ("threads", JsonValue::UInt(1)),
                ("build_ms", JsonValue::Num(serial_ms)),
                ("speedup_vs_serial", JsonValue::Num(1.0)),
                ("padding_factor", JsonValue::Num(padding)),
                ("rows", JsonValue::UInt(serial.total_rows() as u64)),
            ]);
            for threads in [2usize, 4, 8] {
                let opts = BuildOptions { policy, threads };
                let (parallel, ms) = time_build(lp, opts, reps)?;
                // determinism contract: any fill-pool width is bit-identical
                parallel.bit_eq(&serial).map_err(|e| {
                    anyhow::anyhow!("{wname}/{} {threads}-thread build: {e}", policy.name())
                })?;
                let speedup = serial_ms / ms;
                println!(
                    "{:>10} {:>9} {:>8} {:>12.2} {:>10.2}x {:>9.3}",
                    wname,
                    policy.name(),
                    threads,
                    ms,
                    speedup,
                    padding
                );
                bench.row(&[
                    ("workload", JsonValue::Str(wname.to_string())),
                    ("policy", JsonValue::Str(policy.name().into())),
                    ("threads", JsonValue::UInt(threads as u64)),
                    ("build_ms", JsonValue::Num(ms)),
                    ("speedup_vs_serial", JsonValue::Num(speedup)),
                    ("padding_factor", JsonValue::Num(padding)),
                    ("rows", JsonValue::UInt(parallel.total_rows() as u64)),
                ]);
                // CI smoke gate (default policy): the parallel fill must not
                // lose to the serial build it replaces
                if fast && threads == 4 && policy == WidthPolicy::Pow2 {
                    anyhow::ensure!(
                        speedup >= 1.0,
                        "{wname}: 4-thread build slower than serial ({speedup:.2}x)"
                    );
                }
            }
        }
    }

    // quarter-step exists to tame skewed-degree padding; gate the claim on
    // the adversarial workload and report the uniform delta alongside
    anyhow::ensure!(
        padding_by[1][1] < padding_by[1][0],
        "quarter-step padding {:.3} !< pow2 {:.3} on power-law degrees",
        padding_by[1][1],
        padding_by[1][0]
    );
    bench
        .meta("powerlaw_padding_pow2", JsonValue::Num(padding_by[1][0]))
        .meta("powerlaw_padding_quarter", JsonValue::Num(padding_by[1][1]))
        .meta("uniform_padding_pow2", JsonValue::Num(padding_by[0][0]))
        .meta("uniform_padding_quarter", JsonValue::Num(padding_by[0][1]));

    // ---- repack engine: width-crossing edge deltas through the resident
    // index, on the skewed workload's default-policy layout -------------
    let mut lp = powerlaw.clone();
    let mut layout = build_once(&lp, BuildOptions::default())?;
    let pristine = build_once(&lp, BuildOptions::default())?;

    let sw = Stopwatch::start();
    let mut index = SlabIndex::build(&layout, 0, lp.num_sources());
    let index_ms = sw.elapsed_ms();
    index.parity_check(&layout).map_err(anyhow::Error::msg)?;

    // sources one past a pow2 width boundary: deleting the last edge drops
    // the row a width class (repack), re-inserting raises it back (repack)
    let cands: Vec<usize> = (0..lp.num_sources())
        .filter(|&s| {
            let deg = lp.a.src_ptr[s + 1] - lp.a.src_ptr[s];
            matches!(deg, 5 | 9 | 17 | 33)
        })
        .take(64)
        .collect();
    anyhow::ensure!(!cands.is_empty(), "power-law workload has no width-boundary sources");

    let mut patch_ms = 0.0f64;
    let mut repacked = 0usize;
    for c in 0..cycles {
        let s = cands[c % cands.len()];
        let kind = lp.projection.kind_of(s);
        let e1 = lp.a.src_ptr[s + 1];
        let dest = lp.a.dest_idx[e1 - 1];
        let avals: Vec<f32> = lp.a.a.iter().map(|plane| plane[e1 - 1]).collect();
        let cval = lp.cost[e1 - 1];

        let p = lp.remove_edge(s, dest).map_err(anyhow::Error::msg)?;
        let sw = Stopwatch::start();
        let del = layout
            .patch_edge_indexed(&lp.a, &lp.cost, s, p, false, kind, &mut index)
            .map_err(anyhow::Error::msg)?;
        patch_ms += sw.elapsed_ms();

        let p = lp.insert_edge(s, dest, &avals, cval).map_err(anyhow::Error::msg)?;
        let sw = Stopwatch::start();
        let ins = layout
            .patch_edge_indexed(&lp.a, &lp.cost, s, p, true, kind, &mut index)
            .map_err(anyhow::Error::msg)?;
        patch_ms += sw.elapsed_ms();
        repacked += usize::from(del == EdgePatch::Repacked);
        repacked += usize::from(ins == EdgePatch::Repacked);
    }
    // every cycle restores the CSR, so the patched layout must be
    // bit-identical to the untouched build — the repack-engine parity gate
    layout.bit_eq(&pristine).map_err(|e| anyhow::anyhow!("repack parity: {e}"))?;
    index.parity_check(&layout).map_err(anyhow::Error::msg)?;
    let patch_us = patch_ms * 1e3 / (2 * cycles) as f64;

    let mut cost_ms = f64::INFINITY;
    for _ in 0..reps {
        let sw = Stopwatch::start();
        layout.patch_costs(&lp.cost);
        cost_ms = cost_ms.min(sw.elapsed_ms());
    }

    println!(
        "repack: {patch_us:.1} µs/patch ({repacked}/{} width-crossing), \
         patch_costs {cost_ms:.2} ms, index build {index_ms:.2} ms",
        2 * cycles
    );
    bench.row(&[
        ("workload", JsonValue::Str("powerlaw".into())),
        ("op", JsonValue::Str("patch_edge_indexed".into())),
        ("us_per_op", JsonValue::Num(patch_us)),
        ("repacked_ops", JsonValue::UInt(repacked as u64)),
    ]);
    bench.row(&[
        ("workload", JsonValue::Str("powerlaw".into())),
        ("op", JsonValue::Str("patch_costs".into())),
        ("us_per_op", JsonValue::Num(cost_ms * 1e3)),
    ]);
    bench.row(&[
        ("workload", JsonValue::Str("powerlaw".into())),
        ("op", JsonValue::Str("index_build".into())),
        ("us_per_op", JsonValue::Num(index_ms * 1e3)),
    ]);

    let path = bench.write("results")?;
    println!("wrote {}", path.display());
    Ok(())
}
