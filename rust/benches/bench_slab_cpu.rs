//! Experiment E14 — slab-native batched CPU objective vs the reference
//! tuple-layout baseline: per-iteration `calculate` wall-clock on the
//! default synthetic workload, single-threaded speedup (the serving hot
//! path win), thread scaling, and the bit-identity of multithreaded
//! evaluation.
//!
//! Emits machine-readable `results/BENCH_slab_cpu.json` (per-iteration µs
//! per backend/thread-count, speedup vs reference, padding factor, plus
//! per-family rows for the batched kernel tiers of `capped_simplex`,
//! `weighted_simplex`, and `box_vec`) so the perf trajectory is tracked
//! across PRs.
//!
//! Run: cargo bench --bench bench_slab_cpu
//!      [DUALIP_BENCH_FAST=1 for CI size — also asserts speedup ≥ 1.0,
//!       overall and per batched family]

use dualip::backend::SlabCpuObjective;
use dualip::gen::{generate, SyntheticConfig};
use dualip::metrics::{BenchJson, JsonValue};
use dualip::problem::ObjectiveFunction;
use dualip::projection::{ProjectionKind, ProjectionMap};
use dualip::reference::CpuObjective;
use dualip::util::rng::Rng;
use dualip::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let (sources, dests, reps) = if fast { (5_000, 100, 20) } else { (50_000, 500, 30) };
    let cfg = SyntheticConfig {
        num_requests: sources,
        num_resources: dests,
        avg_nnz_per_row: 10.0,
        seed: 0,
        ..Default::default()
    };
    let lp = generate(&cfg);
    let gamma = 0.05f32;
    // evaluate at a representative non-zero dual (λ = 0 over-activates the
    // simplex sort branch relative to mid-solve iterates)
    let mut rng = Rng::new(7);
    let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.1) as f32).collect();

    println!(
        "E14 — slab vs reference CPU objective: I={} J={} nnz={} reps={reps}{}",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        if fast { " (fast)" } else { "" }
    );

    let time_iters = |obj: &mut dyn ObjectiveFunction| -> f64 {
        let _ = obj.calculate(&lam, gamma); // warm caches and scratch
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let _ = obj.calculate(&lam, gamma);
        }
        sw.elapsed_ms() * 1e3 / reps as f64 // µs per iteration
    };

    let mut reference = CpuObjective::new(&lp);
    let ref_us = time_iters(&mut reference);
    let ref_obj = reference.calculate(&lam, gamma);

    let mut slab1 = SlabCpuObjective::new(&lp, 1).map_err(anyhow::Error::msg)?;
    let padding = slab1.layout().padding_factor();
    let launches = slab1.layout().num_launches();
    let chunks = slab1.num_chunks();
    let slab1_us = time_iters(&mut slab1);
    let slab1_obj = slab1.calculate(&lam, gamma);
    let speedup = ref_us / slab1_us;

    // value sanity: the fast path must still be solving the same problem
    let rel = (slab1_obj.dual_obj - ref_obj.dual_obj).abs() / ref_obj.dual_obj.abs().max(1.0);
    anyhow::ensure!(rel < 1e-3, "slab dual_obj diverges from reference: rel {rel:.3e}");

    println!("{:>12} {:>8} {:>14} {:>10}", "backend", "threads", "iter µs", "speedup");
    println!("{:>12} {:>8} {:>14.1} {:>10.2}x", "reference", 1, ref_us, 1.0);
    println!("{:>12} {:>8} {:>14.1} {:>10.2}x", "slab", 1, slab1_us, speedup);

    let mut bench = BenchJson::new("slab_cpu");
    bench
        .meta("sources", JsonValue::UInt(lp.num_sources() as u64))
        .meta("dests", JsonValue::UInt(lp.num_dests() as u64))
        .meta("nnz", JsonValue::UInt(lp.nnz() as u64))
        .meta("dual_dim", JsonValue::UInt(lp.dual_dim() as u64))
        .meta("padding_factor", JsonValue::Num(padding))
        .meta("launches", JsonValue::UInt(launches as u64))
        .meta("chunks", JsonValue::UInt(chunks as u64))
        .meta("reps", JsonValue::UInt(reps as u64))
        .meta("gamma", JsonValue::Num(gamma as f64))
        .meta("fast", JsonValue::Bool(fast))
        .meta("speedup_1t", JsonValue::Num(speedup));
    bench.row(&[
        ("backend", JsonValue::Str("reference".into())),
        ("threads", JsonValue::UInt(1)),
        ("iter_us", JsonValue::Num(ref_us)),
        ("speedup_vs_reference", JsonValue::Num(1.0)),
    ]);
    bench.row(&[
        ("backend", JsonValue::Str("slab".into())),
        ("threads", JsonValue::UInt(1)),
        ("iter_us", JsonValue::Num(slab1_us)),
        ("speedup_vs_reference", JsonValue::Num(speedup)),
    ]);

    for &threads in &[2usize, 4, 8] {
        let mut slab_t = SlabCpuObjective::new(&lp, threads).map_err(anyhow::Error::msg)?;
        let us = time_iters(&mut slab_t);
        let rt = slab_t.calculate(&lam, gamma);
        // determinism contract: any pool width is bit-identical to 1 thread
        anyhow::ensure!(
            rt.dual_obj.to_bits() == slab1_obj.dual_obj.to_bits()
                && rt
                    .grad
                    .iter()
                    .zip(&slab1_obj.grad)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
            "{threads}-thread slab result is not bit-identical to 1 thread"
        );
        println!("{:>12} {:>8} {:>14.1} {:>10.2}x", "slab", threads, us, ref_us / us);
        bench.row(&[
            ("backend", JsonValue::Str("slab".into())),
            ("threads", JsonValue::UInt(threads as u64)),
            ("iter_us", JsonValue::Num(us)),
            ("speedup_vs_reference", JsonValue::Num(ref_us / us)),
        ]);
    }

    // Per-family kernel tiers: the three families kernelized by the
    // registry's batched `project_rows` overrides (DESIGN.md §12), each
    // timed on the same matrix with a uniform projection map. The fast
    // run gates each at ≥ 1.0x — a batched override slower than looping
    // the scalar projection through the reference path means the kernel
    // regressed outright.
    let family_specs = [
        ("capped_simplex", "capped_simplex:0.5:1"),
        ("weighted_simplex", "weighted_simplex:2:1,2"),
        ("box_vec", "box_vec:0.5,1.5"),
    ];
    let mut family_speedups: Vec<(&str, f64)> = Vec::new();
    for (family, spec) in family_specs {
        let kind = ProjectionKind::parse(spec)
            .ok_or_else(|| anyhow::anyhow!("bench spec {spec} must parse"))?;
        let mut lp_fam = lp.clone();
        lp_fam.projection = ProjectionMap::Uniform(kind);
        let mut fam_ref = CpuObjective::new(&lp_fam);
        let fam_ref_us = time_iters(&mut fam_ref);
        let mut fam_slab = SlabCpuObjective::new(&lp_fam, 1).map_err(anyhow::Error::msg)?;
        let tiers = fam_slab.kernel_tiers();
        anyhow::ensure!(
            tiers.scalar.is_empty() && tiers.batched.contains(family),
            "{family}: expected every bucket on the batched tier, got {}",
            tiers.summary()
        );
        let fam_slab_us = time_iters(&mut fam_slab);
        let fam_ref_obj = fam_ref.calculate(&lam, gamma);
        let fam_slab_obj = fam_slab.calculate(&lam, gamma);
        let rel = (fam_slab_obj.dual_obj - fam_ref_obj.dual_obj).abs()
            / fam_ref_obj.dual_obj.abs().max(1.0);
        anyhow::ensure!(rel < 1e-3, "{family}: slab dual_obj diverges: rel {rel:.3e}");
        let fam_speedup = fam_ref_us / fam_slab_us;
        println!(
            "{:>12} {:>8} {:>14.1} {:>10.2}x  [{family}]",
            "slab",
            1,
            fam_slab_us,
            fam_speedup
        );
        for (backend, us, sp) in
            [("reference", fam_ref_us, 1.0), ("slab", fam_slab_us, fam_speedup)]
        {
            bench.row(&[
                ("backend", JsonValue::Str(backend.into())),
                ("family", JsonValue::Str(family.into())),
                ("threads", JsonValue::UInt(1)),
                ("iter_us", JsonValue::Num(us)),
                ("speedup_vs_reference", JsonValue::Num(sp)),
            ]);
        }
        family_speedups.push((family, fam_speedup));
    }

    let path = bench.write("results")?;
    println!(
        "padding factor {padding:.2}, {launches} launches, {chunks} chunks; \
         single-threaded slab speedup {speedup:.2}x"
    );
    println!("wrote {}", path.display());

    // CI smoke gate: the slab layout must never be slower than the
    // comparator it exists to beat (the full-size run reports, the fast
    // run enforces — CI machines are noisy but a <1.0x would mean the hot
    // path regressed outright), and the same bar holds per batched
    // kernel family
    if fast {
        anyhow::ensure!(
            speedup >= 1.0,
            "slab backend slower than reference on CI workload: {speedup:.2}x"
        );
        for (family, sp) in &family_speedups {
            anyhow::ensure!(
                *sp >= 1.0,
                "batched {family} kernel slower than reference on CI workload: {sp:.2}x"
            );
        }
    }
    Ok(())
}
