//! §6 layout claim: the contiguous CSC/slab layout beats the Scala-style
//! sequence-of-tuples object layout on the Ax / Aᵀλ hot loops ("pointer/
//! boxing overhead, poorer cache locality … raise memory traffic and
//! wall-time without adding information").
//!
//! Measures per-edge cost of gather (u = Aᵀλ) + scatter (grad += A·x) under
//! both layouts at matched math.
//!
//! Run: cargo bench --bench bench_spmv

use dualip::gen::{generate, SyntheticConfig};
use dualip::util::csv::CsvWriter;
use dualip::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let sources = if fast { 100_000 } else { 500_000 };
    let lp = generate(&SyntheticConfig {
        num_requests: sources,
        num_resources: 1000,
        avg_nnz_per_row: 10.0,
        seed: 5,
        ..Default::default()
    });
    let nnz = lp.nnz();
    let lam = vec![0.02f32; lp.dual_dim()];
    let x: Vec<f32> = (0..nnz).map(|e| (e % 7) as f32 * 0.1).collect();
    println!("spmv layouts — I={} nnz={nnz}", lp.num_sources());

    // --- flat CSC-style (contiguous edge arrays) --------------------------
    let mut u = vec![0.0f32; nnz];
    let mut grad = vec![0.0f32; lp.dual_dim()];
    let reps = 10;
    // warm
    lp.a.gather_dual(&lam, &mut u);
    lp.a.scatter_ax(&x, &mut grad);
    let sw = Stopwatch::start();
    for _ in 0..reps {
        lp.a.gather_dual(&lam, &mut u);
        lp.a.scatter_ax(&x, &mut grad);
    }
    let flat_ms = sw.elapsed_ms() / reps as f64;

    // --- Scala-style tuple sequences (one boxed Vec per source) ----------
    struct Tup {
        dest: u32,
        a: f32,
        _cost: f32,
    }
    let blocks: Vec<Vec<Tup>> = (0..lp.num_sources())
        .map(|i| {
            (lp.a.src_ptr[i]..lp.a.src_ptr[i + 1])
                .map(|e| Tup { dest: lp.a.dest_idx[e], a: lp.a.a[0][e], _cost: lp.cost[e] })
                .collect()
        })
        .collect();
    let mut u2 = vec![0.0f32; nnz];
    let mut grad2 = vec![0.0f32; lp.dual_dim()];
    let sw = Stopwatch::start();
    for _ in 0..reps {
        let mut e = 0usize;
        for block in &blocks {
            for t in block {
                u2[e] = t.a * lam[t.dest as usize];
                e += 1;
            }
        }
        grad2.iter_mut().for_each(|g| *g = 0.0);
        let mut e2 = 0usize;
        for block in &blocks {
            for t in block {
                grad2[t.dest as usize] += t.a * x[e2];
                e2 += 1;
            }
        }
    }
    let tuple_ms = sw.elapsed_ms() / reps as f64;

    // numerics must agree
    for (a, b) in u.iter().zip(&u2) {
        assert!((a - b).abs() < 1e-5);
    }

    let per_edge_flat = flat_ms * 1e6 / nnz as f64;
    let per_edge_tuple = tuple_ms * 1e6 / nnz as f64;
    println!("flat CSC slab layout : {flat_ms:>8.2} ms/pass ({per_edge_flat:.2} ns/edge)");
    println!("tuple-sequence layout: {tuple_ms:>8.2} ms/pass ({per_edge_tuple:.2} ns/edge)");
    println!("layout speedup: {:.2}×", tuple_ms / flat_ms);

    let mut csv = CsvWriter::create(
        "results/e_spmv_layout.csv",
        &["layout", "ms_per_pass", "ns_per_edge"],
    )?;
    csv.row(&["flat_csc".into(), format!("{flat_ms:.3}"), format!("{per_edge_flat:.3}")])?;
    csv.row(&["tuple_seq".into(), format!("{tuple_ms:.3}"), format!("{per_edge_tuple:.3}")])?;
    csv.flush()?;
    println!("wrote results/e_spmv_layout.csv");
    Ok(())
}
