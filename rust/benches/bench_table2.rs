//! Table 2 reproduction (experiment E3): average time per AGD iteration —
//! baseline ("Scala"-equivalent per-edge loop) vs the slab path on 1–4
//! simulated devices, across problem sizes.
//!
//! Paper: sources ∈ {25M, 50M, 75M, 100M}, J = 10 000, sparsity 0.001, on
//! A100s. Here (DESIGN.md §5): sources scaled by 1/100, J = 1 000, same
//! density; workers are threads on ONE core, so multi-device cells report
//! the **modeled-parallel** time (max over worker shard walltimes + α-β
//! NVLink comm estimate). The claim under test is the *shape*: ≥10× slab
//! speedup over the baseline at matched iteration semantics, and ~1/N
//! worker scaling.
//!
//! Run: cargo bench --bench bench_table2  [DUALIP_BENCH_FAST=1 for CI size]

use std::sync::Arc;

use dualip::distributed::{DistributedObjective, LinkModel};
use dualip::gen::{generate, workloads};
use dualip::metrics::stats;
use dualip::problem::ObjectiveFunction;
use dualip::reference::CpuObjective;
use dualip::runtime::default_artifacts_dir;
use dualip::util::csv::CsvWriter;
use dualip::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DUALIP_BENCH_FAST").is_ok();
    let paper_sizes: &[usize] = if fast { &[25] } else { &[25, 50, 75, 100] };
    let workers_list: &[usize] = &[1, 2, 3, 4];
    let evals = if fast { 3 } else { 6 };
    let art = default_artifacts_dir();
    let gamma = 0.01f32;

    let mut csv = CsvWriter::create(
        "results/table2_iteration_time.csv",
        &["paper_sources_m", "sources", "backend", "workers", "ms_per_iter", "model"],
    )?;

    println!("Table 2 — avg seconds per AGD iteration (modeled-parallel for N>1)");
    println!("{:>10} {:>12} {:>10} {:>10} {:>10} {:>10} {:>10}",
             "sources", "baseline", "1 dev", "2 dev", "3 dev", "4 dev", "speedup4");

    for &pm in paper_sizes {
        let cfg = workloads::table2_row(pm, 0);
        let lp = Arc::new(generate(&cfg));
        let lam = vec![0.01f32; lp.dual_dim()];

        // baseline: per-edge tuple loop (single thread, like Spark executor math)
        let mut cpu = CpuObjective::new(&lp);
        let mut t_base = Vec::new();
        for _ in 0..evals.min(4) {
            let sw = Stopwatch::start();
            let _ = cpu.calculate(&lam, gamma);
            t_base.push(sw.elapsed_ms());
        }
        let base_ms = stats(&t_base).median;
        csv.row(&[
            pm.to_string(),
            cfg.num_requests.to_string(),
            "baseline".into(),
            "1".into(),
            format!("{base_ms:.2}"),
            "measured".into(),
        ])?;

        let mut row = vec![base_ms];
        for &w in workers_list {
            let mut dist = DistributedObjective::new(lp.clone(), &art, w)?;
            // warm + measure
            let _ = dist.calculate(&lam, gamma);
            for _ in 0..evals {
                let _ = dist.calculate(&lam, gamma);
            }
            let series: Vec<f64> = dist.iter_compute_max_ms()[1..].to_vec();
            let comm_ms = LinkModel::nvlink().iter_time(lp.dual_dim()) * 1e3;
            let ms = stats(&series).median + comm_ms;
            row.push(ms);
            csv.row(&[
                pm.to_string(),
                cfg.num_requests.to_string(),
                "slab".into(),
                w.to_string(),
                format!("{ms:.2}"),
                "modeled-parallel".into(),
            ])?;
        }
        println!(
            "{:>9}M {:>11.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>9.2}x",
            pm, row[0], row[1], row[2], row[3], row[4],
            row[0] / row[4]
        );
    }
    csv.flush()?;
    println!("\nwrote results/table2_iteration_time.csv");
    println!("paper shape: baseline/slab-4dev ≥ 10×; slab scales ~1/N in workers");
    Ok(())
}
