//! Differential mode: `audit --baseline <json>` fails only on *new*
//! findings (DESIGN.md §10).
//!
//! The baseline is a previous `--format json` report. A finding's
//! identity is `(file, rule, slug, message)` — deliberately ignoring the
//! line so unrelated edits that shift code downward don't churn the
//! diff. This generalizes the ratchet to every rule: grandfathered
//! findings stay visible in the full report but no longer gate.
//!
//! The parser below is a minimal recursive-descent JSON reader —
//! dependency-free, like the rest of `analysis/` — sufficient for our
//! own emitter's output plus reasonable hand edits (arbitrary
//! whitespace, escapes, nested values).

use std::collections::BTreeSet;

use super::report::{AuditReport, Finding};

/// A parsed baseline: the identity set of its findings.
pub struct Baseline {
    ids: BTreeSet<(String, String, String, String)>,
}

impl Baseline {
    /// Parse a `--format json` report.
    pub fn parse(src: &str) -> Result<Baseline, String> {
        let value = parse_json(src)?;
        let findings = value
            .get("findings")
            .ok_or_else(|| "baseline has no `findings` array".to_string())?;
        let Json::Array(items) = findings else {
            return Err("baseline `findings` is not an array".to_string());
        };
        let mut ids = BTreeSet::new();
        for item in items {
            let field = |k: &str| -> Result<String, String> {
                match item.get(k) {
                    Some(Json::Str(s)) => Ok(s.clone()),
                    _ => Err(format!("baseline finding lacks string field `{k}`")),
                }
            };
            ids.insert((field("file")?, field("rule")?, field("slug")?, field("message")?));
        }
        Ok(Baseline { ids })
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Findings of `report` absent from the baseline, in report order.
    pub fn new_findings<'r>(&self, report: &'r AuditReport) -> Vec<&'r Finding> {
        report
            .findings
            .iter()
            .filter(|f| {
                !self.ids.contains(&(
                    f.file.clone(),
                    f.rule.to_string(),
                    f.slug.to_string(),
                    f.message.clone(),
                ))
            })
            .collect()
    }
}

/// Minimal JSON value.
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

fn parse_json(src: &str) -> Result<Json, String> {
    let bytes = src.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing bytes at offset {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at offset {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err("unexpected end of input".to_string());
    };
    match c {
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Object(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Object(fields));
                    }
                    _ => return Err(format!("expected `,` or `}}` at offset {pos}", pos = *pos)),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Array(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at offset {pos}", pos = *pos)),
                }
            }
        }
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        b'f' if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        b'n' if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        b'-' | b'0'..=b'9' => {
            let start = *pos;
            *pos += 1;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
            {
                *pos += 1;
            }
            let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
            text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{text}`: {e}"))
        }
        c => Err(format!("unexpected byte `{}` at offset {}", c as char, *pos)),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at offset {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let Some(&esc) = b.get(*pos) else {
                    return Err("dangling escape".to_string());
                };
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex).map_err(|e| e.to_string())?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|e| format!("bad \\u: {e}"))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("unknown escape `\\{}`", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // multi-byte UTF-8 passes through byte-wise
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?);
            }
        }
    }
    Err("unterminated string".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(findings: &[(&str, u32, &'static str, &'static str, &str)]) -> AuditReport {
        let mut r = AuditReport::default();
        r.files = 1;
        for &(file, line, rule, slug, msg) in findings {
            r.findings.push(Finding::new(file, line, rule, slug, msg.to_string()));
        }
        r
    }

    #[test]
    fn round_trips_our_own_json_output() {
        let r = report_with(&[
            ("src/a.rs", 3, "D1", "unordered-iter", "has \"quotes\" and \\slashes\\"),
            ("src/b.rs", 0, "P1", "panic-budget", "tab\there"),
        ]);
        let base = Baseline::parse(&r.render_json()).expect("parse own output");
        assert_eq!(base.len(), 2);
        assert!(base.new_findings(&r).is_empty(), "identical report has no new findings");
    }

    #[test]
    fn line_shifts_are_not_new_but_new_messages_are() {
        let old = report_with(&[("src/a.rs", 3, "D1", "unordered-iter", "same msg")]);
        let base = Baseline::parse(&old.render_json()).unwrap();
        let shifted = report_with(&[("src/a.rs", 40, "D1", "unordered-iter", "same msg")]);
        assert!(base.new_findings(&shifted).is_empty());
        let changed = report_with(&[
            ("src/a.rs", 3, "D1", "unordered-iter", "same msg"),
            ("src/a.rs", 9, "P2", "panic-reachable", "fresh"),
        ]);
        let new = base.new_findings(&changed);
        assert_eq!(new.len(), 1);
        assert_eq!(new[0].rule, "P2");
    }

    #[test]
    fn malformed_baselines_error_instead_of_passing() {
        assert!(Baseline::parse("").is_err());
        assert!(Baseline::parse("{\"counts\": {}}").is_err(), "missing findings");
        assert!(Baseline::parse("{\"findings\": [{\"file\": \"x\"}]}").is_err());
        assert!(Baseline::parse("{\"findings\": []} trailing").is_err());
        let ok = Baseline::parse("{\"findings\": []}").unwrap();
        assert!(ok.is_empty());
    }
}
