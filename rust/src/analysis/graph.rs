//! Conservative crate-wide call graph (DESIGN.md §10).
//!
//! Nodes are the [`FnItem`]s of every `src/` file; edges come from three
//! token-level call shapes scanned inside each fn body:
//!
//! * **method calls** `recv.name(..)` — resolved by *method-name
//!   fallback*: every fn named `name` defined with a receiver, falling
//!   back to free fns of that name. Without type information this
//!   over-approximates dispatch (including trait objects), which is the
//!   safe direction for reachability rules;
//! * **qualified calls** `Path::name(..)` — scoped: `Self` maps to the
//!   enclosing receiver; otherwise fns whose receiver equals the final
//!   path segment, then free fns defined in the module of that name. A
//!   qualified call that matches nothing (e.g. `Vec::new`, `f32::max`)
//!   is *external* and lands in the `unresolved` bucket rather than
//!   being name-matched against unrelated constructors;
//! * **plain calls** `name(..)` — free fns of that name, else
//!   `unresolved`. UFCS `<T as Tr>::name(..)` uses method-name fallback.
//!
//! Turbofish (`name::<..>(`) is recognized in all three shapes. Calls
//! written inside macro invocation arguments are scanned like ordinary
//! tokens (over-approximation again). The `unresolved` bucket is part of
//! the public result so the conservatism is auditable, not silent.
//!
//! Construction is **total** (any token stream produces a graph) and
//! **deterministic**: nodes are sorted by `(file, line, name)` before
//! edges are resolved, so shuffled input file order yields a
//! byte-identical graph.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::items::{extract_fns, FnItem};
use super::lexer::{Tok, TokKind};
use super::rules::{is_keyword, AnalyzedFile};

/// The crate-wide call graph.
pub struct CallGraph {
    /// All fn items, sorted by `(file, line, name)`.
    pub fns: Vec<FnItem>,
    /// `edges[i]` — callee ids of `fns[i]`, ascending and deduplicated.
    pub edges: Vec<Vec<usize>>,
    /// Call names that matched no known fn item, with occurrence counts.
    pub unresolved: BTreeMap<String, usize>,
}

/// One syntactic call site inside a fn body.
enum CallShape {
    /// `expr.name(` — method-name fallback resolution.
    Method(String),
    /// `Q::name(` — path-scoped resolution (`Q` is the final segment).
    Qualified(String, String),
    /// `>::name(` — UFCS; resolved like a method call.
    Ufcs(String),
    /// `name(` — free fns only.
    Plain(String),
}

impl CallGraph {
    /// Build the graph over every file in `files` (order-insensitive).
    pub fn build(files: &[AnalyzedFile]) -> CallGraph {
        let mut fns: Vec<FnItem> = files.iter().flat_map(extract_fns).collect();
        fns.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.name.as_str())
                .cmp(&(b.file.as_str(), b.line, b.name.as_str()))
        });

        // resolution indexes
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut by_name_recv: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut by_name_free: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut by_recv_name: BTreeMap<(&str, &str), Vec<usize>> = BTreeMap::new();
        for (id, f) in fns.iter().enumerate() {
            by_name.entry(&f.name).or_default().push(id);
            match &f.recv {
                Some(r) => {
                    by_name_recv.entry(&f.name).or_default().push(id);
                    by_recv_name.entry((r, &f.name)).or_default().push(id);
                }
                None => {
                    by_name_free.entry(&f.name).or_default().push(id);
                }
            }
        }

        let by_rel: BTreeMap<&str, &AnalyzedFile> =
            files.iter().map(|f| (f.rel.as_str(), f)).collect();

        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); fns.len()];
        let mut unresolved: BTreeMap<String, usize> = BTreeMap::new();

        for id in 0..fns.len() {
            let item = &fns[id];
            let Some(file) = by_rel.get(item.file.as_str()) else { continue };
            // token spans of *other* fns nested inside this body — their
            // calls belong to the nested item, not to us
            let nested: Vec<(usize, usize)> = fns
                .iter()
                .filter(|o| {
                    o.file == item.file
                        && o.sig.0 > item.body.0
                        && o.body.1 <= item.body.1
                })
                .map(|o| (o.sig.0, o.body.1 + 1))
                .collect();

            let mut targets: BTreeSet<usize> = BTreeSet::new();
            for shape in scan_calls(&file.toks, item.body.0, item.body.1, &nested) {
                let resolved: &[usize] = match &shape {
                    CallShape::Method(name) | CallShape::Ufcs(name) => by_name_recv
                        .get(name.as_str())
                        .or_else(|| by_name_free.get(name.as_str()))
                        .map_or(&[], |v| v.as_slice()),
                    CallShape::Qualified(q, name) => {
                        let q = if q == "Self" {
                            item.recv.as_deref().unwrap_or("Self")
                        } else {
                            q.as_str()
                        };
                        if let Some(v) = by_recv_name.get(&(q, name.as_str())) {
                            v.as_slice()
                        } else {
                            // free fns in a module named like the path
                            // segment (`collective::reduce(..)`)
                            let ql = q.to_ascii_lowercase();
                            let in_module: Vec<usize> = by_name_free
                                .get(name.as_str())
                                .map_or(&[][..], |v| v.as_slice())
                                .iter()
                                .copied()
                                .filter(|&t| {
                                    let file = fns[t].file.as_str();
                                    file.ends_with(&format!("/{ql}.rs"))
                                        || file.ends_with(&format!("/{ql}/mod.rs"))
                                        || fns[t].module == ql
                                })
                                .collect();
                            if in_module.is_empty() {
                                let key = format!("{q}::{name}");
                                *unresolved.entry(key).or_insert(0) += 1;
                            }
                            targets.extend(in_module);
                            continue;
                        }
                    }
                    CallShape::Plain(name) => {
                        by_name_free.get(name.as_str()).map_or(&[], |v| v.as_slice())
                    }
                };
                if resolved.is_empty() {
                    let key = match shape {
                        CallShape::Method(n) | CallShape::Ufcs(n) => format!(".{n}"),
                        CallShape::Qualified(q, n) => format!("{q}::{n}"),
                        CallShape::Plain(n) => n,
                    };
                    *unresolved.entry(key).or_insert(0) += 1;
                } else {
                    targets.extend(resolved.iter().copied());
                }
            }
            targets.remove(&id); // self-recursion adds nothing to reachability
            edges[id] = targets.into_iter().collect();
        }

        let _ = by_name; // kept for symmetry; fallback uses recv/free splits
        CallGraph { fns, edges, unresolved }
    }

    /// Ids of non-test fns with `name`, optionally constrained to `recv`.
    pub fn find(&self, recv: Option<&str>, name: &str) -> Vec<usize> {
        self.fns
            .iter()
            .enumerate()
            .filter(|(_, f)| {
                !f.in_test
                    && f.name == name
                    && match recv {
                        Some(r) => f.recv.as_deref() == Some(r),
                        None => true,
                    }
            })
            .map(|(id, _)| id)
            .collect()
    }

    /// Forward BFS from `roots`: reached id → parent id (`None` at a
    /// root). Deterministic: roots and neighbors visit in ascending id
    /// order; test-only fns are never traversed.
    pub fn reach_forward(&self, roots: &[usize]) -> BTreeMap<usize, Option<usize>> {
        self.bfs(roots, |id| self.edges[id].iter().copied())
    }

    /// Reverse BFS from `roots` (callers of, transitively). Same
    /// determinism and test-exclusion guarantees as [`Self::reach_forward`].
    pub fn reach_reverse(&self, roots: &[usize]) -> BTreeMap<usize, Option<usize>> {
        let mut rev: Vec<Vec<usize>> = vec![Vec::new(); self.fns.len()];
        for (src, outs) in self.edges.iter().enumerate() {
            for &dst in outs {
                rev[dst].push(src);
            }
        }
        self.bfs(roots, move |id| rev[id].clone().into_iter())
    }

    fn bfs<I, F>(&self, roots: &[usize], mut next: F) -> BTreeMap<usize, Option<usize>>
    where
        I: Iterator<Item = usize>,
        F: FnMut(usize) -> I,
    {
        let mut parents: BTreeMap<usize, Option<usize>> = BTreeMap::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut sorted_roots: Vec<usize> = roots.to_vec();
        sorted_roots.sort_unstable();
        for r in sorted_roots {
            if !self.fns[r].in_test && !parents.contains_key(&r) {
                parents.insert(r, None);
                queue.push_back(r);
            }
        }
        while let Some(id) = queue.pop_front() {
            for t in next(id) {
                if !self.fns[t].in_test && !parents.contains_key(&t) {
                    parents.insert(t, Some(id));
                    queue.push_back(t);
                }
            }
        }
        parents
    }

    /// `entry -> ... -> target` display chain from a BFS parent map.
    pub fn chain(&self, target: usize, parents: &BTreeMap<usize, Option<usize>>) -> String {
        let mut path = vec![target];
        let mut cur = target;
        while let Some(Some(p)) = parents.get(&cur) {
            path.push(*p);
            cur = *p;
        }
        path.reverse();
        let names: Vec<String> = path.iter().map(|&id| self.fns[id].display()).collect();
        names.join(" -> ")
    }
}

/// Scan `toks[lo..hi]` for call sites, skipping `skip` token ranges.
fn scan_calls(
    toks: &[Tok],
    lo: usize,
    hi: usize,
    skip: &[(usize, usize)],
) -> Vec<CallShape> {
    let mut out = Vec::new();
    let mut i = lo;
    while i < hi && i < toks.len() {
        if let Some(&(_, end)) = skip.iter().find(|&&(a, b)| a <= i && i < b) {
            i = end;
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident || is_keyword(&t.text) {
            i += 1;
            continue;
        }
        if !callable_at(toks, i) {
            i += 1;
            continue;
        }
        let name = t.text.clone();
        let prev = if i > 0 { toks[i - 1].text.as_str() } else { "" };
        match prev {
            "." => out.push(CallShape::Method(name)),
            "::" => {
                let pp = toks.get(i.wrapping_sub(2));
                match pp {
                    Some(p) if p.kind == TokKind::Ident && !is_keyword(&p.text) => {
                        out.push(CallShape::Qualified(p.text.clone(), name));
                    }
                    Some(p) if p.text == ">" => out.push(CallShape::Ufcs(name)),
                    // `::name(` crate-root path or macro-expanded — treat
                    // as plain so free fns still resolve
                    _ => out.push(CallShape::Plain(name)),
                }
            }
            "fn" => {} // a definition, not a call (nested-fn guard)
            _ => out.push(CallShape::Plain(name)),
        }
        i += 1;
    }
    out
}

/// Is the ident at `i` followed by `(`, directly or via turbofish
/// `::<..>(`? (`.collect::<Vec<_>>(` lexes as `. collect :: < .. > (`.)
pub(crate) fn callable_at(toks: &[Tok], i: usize) -> bool {
    match toks.get(i + 1).map(|t| t.text.as_str()) {
        Some("(") => true,
        Some("::") if toks.get(i + 2).map(|t| t.text.as_str()) == Some("<") => {
            let mut depth = 0isize;
            let mut j = i + 2;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "<" => depth += 1,
                    ">" if j > 0 && toks[j - 1].text == "-" => {}
                    ">" => {
                        depth -= 1;
                        if depth == 0 {
                            return toks.get(j + 1).map(|t| t.text.as_str()) == Some("(");
                        }
                    }
                    "{" | ";" => return false,
                    _ => {}
                }
                j += 1;
            }
            false
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(files: &[(&str, &str)]) -> CallGraph {
        let parsed: Vec<AnalyzedFile> =
            files.iter().map(|(rel, src)| AnalyzedFile::parse(rel, src)).collect();
        CallGraph::build(&parsed)
    }

    fn edge_names(g: &CallGraph, from: &str) -> Vec<String> {
        let id = g.fns.iter().position(|f| f.display() == from).unwrap();
        g.edges[id].iter().map(|&t| g.fns[t].display()).collect()
    }

    #[test]
    fn method_call_vs_field_access() {
        let g = graph(&[(
            "src/serve/x.rs",
            "struct S { handler: u32 }\n\
             impl S {\n\
                 fn handler(&self) -> u32 { 1 }\n\
                 fn go(&self) -> u32 { let _v = self.handler; self.handler() }\n\
             }\n",
        )]);
        assert_eq!(edge_names(&g, "S::go"), vec!["S::handler"]);
    }

    #[test]
    fn qualified_self_and_cross_file_resolution() {
        let g = graph(&[
            (
                "src/solver/a.rs",
                "pub struct Driver;\n\
                 impl Driver {\n\
                     pub fn step(&self) { Self::tick(); crate::solver::helper(); }\n\
                     fn tick() {}\n\
                 }\n",
            ),
            ("src/solver/b.rs", "pub fn helper() {}\n"),
        ]);
        let got = edge_names(&g, "Driver::step");
        assert_eq!(got, vec!["Driver::tick", "helper"]);
    }

    #[test]
    fn external_qualified_calls_go_to_unresolved_not_name_fallback() {
        let g = graph(&[(
            "src/backend/x.rs",
            "pub struct Obj;\n\
             impl Obj { pub fn new() -> Obj { Obj } }\n\
             pub fn build() -> Vec<u32> { let _o = Obj::new(); Vec::new() }\n",
        )]);
        // `Vec::new` must NOT resolve to Obj::new by bare-name fallback
        assert_eq!(edge_names(&g, "build"), vec!["Obj::new"]);
        assert_eq!(g.unresolved.get("Vec::new"), Some(&1));
    }

    #[test]
    fn ufcs_and_trait_object_dispatch_use_method_fallback() {
        let g = graph(&[(
            "src/projection/x.rs",
            "pub trait Op { fn apply(&self) -> u32 { 0 } }\n\
             pub struct A;\n\
             impl Op for A { fn apply(&self) -> u32 { 1 } }\n\
             pub fn via_obj(o: &dyn Op) -> u32 { o.apply() }\n\
             pub fn via_ufcs(a: &A) -> u32 { <A as Op>::apply(a) }\n",
        )]);
        // both dispatch forms over-approximate to every `apply` with a recv
        assert_eq!(edge_names(&g, "via_obj"), vec!["Op::apply", "A::apply"].into_iter().map(String::from).collect::<Vec<_>>());
        assert_eq!(edge_names(&g, "via_ufcs"), vec!["Op::apply", "A::apply"].into_iter().map(String::from).collect::<Vec<_>>());
    }

    #[test]
    fn generics_turbofish_and_macro_bodies() {
        let g = graph(&[(
            "src/sparse/x.rs",
            "pub fn target(v: u32) -> u32 { v }\n\
             pub fn caller(xs: &[u32]) -> Vec<u32> {\n\
                 let v: Vec<u32> = xs.iter().copied().collect::<Vec<u32>>();\n\
                 assert!(target(1) > 0, \"{}\", target(2));\n\
                 v\n\
             }\n",
        )]);
        // turbofish `.collect::<..>(` is a (std, unresolved) method call;
        // calls inside macro args are still attributed to the caller
        assert_eq!(edge_names(&g, "caller"), vec!["target"]);
        assert_eq!(g.unresolved.get(".collect"), Some(&1));
        assert!(g.unresolved.contains_key(".iter"));
    }

    #[test]
    fn closure_calls_attribute_to_the_defining_fn() {
        let g = graph(&[(
            "src/engine/x.rs",
            "pub fn leaf() -> u32 { 3 }\n\
             pub fn spawns() -> u32 { let f = || leaf(); f() }\n",
        )]);
        assert_eq!(edge_names(&g, "spawns"), vec!["leaf"]);
    }

    #[test]
    fn nested_fn_calls_do_not_leak_to_the_outer_fn() {
        let g = graph(&[(
            "src/util/x.rs",
            "pub fn leaf() {}\n\
             pub fn outer() {\n\
                 fn inner() { leaf(); }\n\
                 inner();\n\
             }\n",
        )]);
        assert_eq!(edge_names(&g, "outer"), vec!["inner"]);
        assert_eq!(edge_names(&g, "inner"), vec!["leaf"]);
    }

    #[test]
    fn reachability_chains_and_test_fn_exclusion() {
        let g = graph(&[(
            "src/serve/x.rs",
            "pub struct D;\n\
             impl D { pub fn submit(&self) { route(); } }\n\
             fn route() { admit(); }\n\
             fn admit() {}\n\
             fn orphan() {}\n\
             #[cfg(test)]\n\
             mod tests { fn t() { super::admit(); } }\n",
        )]);
        let entries = g.find(Some("D"), "submit");
        assert_eq!(entries.len(), 1);
        let parents = g.reach_forward(&entries);
        let admit = g.find(None, "admit")[0];
        assert!(parents.contains_key(&admit));
        assert_eq!(g.chain(admit, &parents), "D::submit -> route -> admit");
        let orphan = g.find(None, "orphan")[0];
        assert!(!parents.contains_key(&orphan));
        assert!(!g.fns.iter().any(|f| f.name == "t" && !f.in_test));
    }

    /// Property: construction is total and deterministic over shuffled
    /// file order (hand-rolled — no proptest dependency).
    #[test]
    fn graph_is_deterministic_over_shuffled_file_order() {
        let files: Vec<(String, String)> = (0..8)
            .map(|i| {
                (
                    format!("src/solver/f{i}.rs"),
                    format!(
                        "pub struct T{i};\n\
                         impl T{i} {{ pub fn m{i}(&self) -> u32 {{ shared() }} }}\n\
                         pub fn free{i}() {{ T{i}.m{i}(); }}\n\
                         pub fn shared() -> u32 {{ {i} }}\n"
                    ),
                )
            })
            .collect();
        let render = |order: &[usize]| -> String {
            let parsed: Vec<AnalyzedFile> = order
                .iter()
                .map(|&i| AnalyzedFile::parse(&files[i].0, &files[i].1))
                .collect();
            let g = CallGraph::build(&parsed);
            let mut s = String::new();
            for (id, f) in g.fns.iter().enumerate() {
                s.push_str(&format!(
                    "{} {} -> {:?}\n",
                    f.file,
                    f.display(),
                    g.edges[id].iter().map(|&t| g.fns[t].display()).collect::<Vec<_>>()
                ));
            }
            s.push_str(&format!("{:?}", g.unresolved));
            s
        };
        let baseline = render(&(0..files.len()).collect::<Vec<_>>());
        let mut rng = crate::util::rng::Rng::new(0xD11A);
        let mut order: Vec<usize> = (0..files.len()).collect();
        for _ in 0..16 {
            // Fisher–Yates on the file order
            for i in (1..order.len()).rev() {
                let j = (rng.next_u64() as usize) % (i + 1);
                order.swap(i, j);
            }
            assert_eq!(render(&order), baseline, "order {order:?}");
        }
    }
}
