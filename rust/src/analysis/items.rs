//! Item extraction for the crate-wide call graph (DESIGN.md §10).
//!
//! Sits directly on the [`super::lexer`] token stream: finds every `fn`
//! item in a file together with the receiver type of its enclosing
//! `impl`/`trait` block and its brace-matched body token span. This is
//! deliberately *not* a Rust parser — it recognizes exactly the shapes
//! the graph rules need (free fns, inherent/trait methods, trait default
//! methods, nested fns) and stays total on any token stream: malformed
//! input degrades to fewer recognized items, never a panic.
//!
//! Conservatism notes (the graph rules inherit these):
//!
//! * closures have no item identity — calls inside a closure are
//!   attributed to the defining `fn` (sound for reachability: the
//!   closure only runs if the definer or something it handed the
//!   closure to runs);
//! * nested `fn` items are their own nodes; their token spans are
//!   subtracted from the enclosing fn's scan range;
//! * `impl Trait` in return position is skipped by a `->` look-behind,
//!   so it never opens a phantom receiver context.

use super::lexer::{Tok, TokKind};
use super::rules::AnalyzedFile;

/// One `fn` item: identity, receiver, and token spans.
#[derive(Clone, Debug)]
pub struct FnItem {
    /// Crate-root-relative path of the defining file.
    pub file: String,
    /// Top-level module key, ratchet-style (`src/backend/x.rs` →
    /// `backend`, `src/lib.rs` → `root`, non-src roots → first segment).
    pub module: String,
    pub name: String,
    /// Receiver type of the innermost enclosing `impl`/`trait` block
    /// (`impl ObjectiveFunction for SlabCpuObjective` → `SlabCpuObjective`;
    /// trait default methods carry the trait name). `None` for free fns.
    pub recv: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token range `[fn_idx, body_open)` — the signature.
    pub sig: (usize, usize),
    /// Token range `(body_open, body_close)` — the body content,
    /// exclusive of the outer braces.
    pub body: (usize, usize),
    /// Whether the item sits inside `#[cfg(test)]`.
    pub in_test: bool,
}

impl FnItem {
    /// Short display name for chains: `Recv::name` or `name`.
    pub fn display(&self) -> String {
        match &self.recv {
            Some(r) => format!("{r}::{}", self.name),
            None => self.name.clone(),
        }
    }

    /// Fully qualified display: `module::Recv::name`.
    pub fn qual(&self) -> String {
        match &self.recv {
            Some(r) => format!("{}::{r}::{}", self.module, self.name),
            None => format!("{}::{}", self.module, self.name),
        }
    }
}

/// A receiver context: the body token span of one `impl`/`trait` block.
struct TypeCtx {
    recv: String,
    lo: usize,
    hi: usize,
}

/// Module key for graph grouping — `src/` files use the ratchet module
/// (`backend`, `root`, ...); other roots use their first path segment.
pub fn module_key(rel: &str) -> String {
    if let Some(rest) = rel.strip_prefix("src/") {
        return match rest.split_once('/') {
            Some((dir, _)) => dir.to_string(),
            None => "root".to_string(),
        };
    }
    match rel.split_once('/') {
        Some((dir, _)) => dir.to_string(),
        None => "ext".to_string(),
    }
}

/// Skip a balanced `<...>` run starting at the `<` in `toks[i]`; returns
/// the index just past the matching `>`. `->` arrows inside (closure
/// bounds like `Fn(usize) -> f32`) are ignored by a `-` look-behind.
fn skip_angles(toks: &[Tok], mut i: usize) -> usize {
    debug_assert_eq!(toks[i].text, "<");
    let mut depth = 0isize;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "<" => depth += 1,
            ">" if i > 0 && toks[i - 1].text == "-" => {}
            ">" => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            // a `{`/`;` at angle depth means the stream is not the
            // generics we assumed — bail rather than overrun
            "{" | ";" => return i,
            _ => {}
        }
        i += 1;
    }
    i
}

/// Index just past the `}` matching the `{` at `toks[open]` (or the end
/// of the stream for unbalanced input).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    debug_assert_eq!(toks[open].text, "{");
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    i
}

/// Collect `impl`/`trait` receiver contexts.
fn type_contexts(toks: &[Tok]) -> Vec<TypeCtx> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || (t.text != "impl" && t.text != "trait") {
            i += 1;
            continue;
        }
        // return-position `-> impl Trait` opens no receiver context
        if t.text == "impl"
            && i >= 2
            && toks[i - 1].text == ">"
            && toks[i - 2].text == "-"
        {
            i += 1;
            continue;
        }
        // `impl Fn(..)`-style bounds in argument position: the next `{`
        // we would find belongs to a fn body; the `for`-reset walk below
        // still lands on *some* ident, which is harmless — nested fns are
        // rare and the attribution stays conservative.
        let mut j = i + 1;
        if j < toks.len() && toks[j].text == "<" {
            j = skip_angles(toks, j);
        }
        let mut recv: Option<String> = None;
        while j < toks.len() {
            let tj = &toks[j];
            match tj.text.as_str() {
                "{" => break,
                ";" => break, // `trait X: Y;`-like degenerate input
                "<" => {
                    j = skip_angles(toks, j);
                    continue;
                }
                "for" if tj.kind == TokKind::Ident => recv = None,
                "where" if tj.kind == TokKind::Ident => {
                    // scan on to the `{`; where-clauses carry no braces
                }
                _ if tj.kind == TokKind::Ident => recv = Some(tj.text.clone()),
                _ => {}
            }
            j += 1;
        }
        if j < toks.len() && toks[j].text == "{" {
            let end = match_brace(toks, j);
            if let Some(recv) = recv {
                out.push(TypeCtx { recv, lo: j, hi: end });
            }
            // contexts can nest (impl blocks inside mod blocks are
            // transparent; impls never nest in real Rust) — keep walking
            // from just inside so nested trait/impl text is still seen
            i = j + 1;
        } else {
            i = j + 1;
        }
    }
    out
}

/// Extract every `fn` item of one analyzed file.
pub fn extract_fns(f: &AnalyzedFile) -> Vec<FnItem> {
    let toks = &f.toks;
    let ctxs = type_contexts(toks);
    let module = module_key(&f.rel);
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].kind != TokKind::Ident || toks[i].text != "fn" {
            i += 1;
            continue;
        }
        // `fn(..)` pointer types and `Fn(..)` bounds: no name ident next
        let Some(name_tok) = toks.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        // scan the signature for the body `{` (or `;` for bodyless
        // trait-required methods / extern decls)
        let mut j = i + 2;
        let mut paren = 0isize;
        let mut body_open: Option<usize> = None;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" | "[" => paren += 1,
                ")" | "]" => paren -= 1,
                "<" if paren == 0 => {
                    j = skip_angles(toks, j);
                    continue;
                }
                "{" if paren == 0 => {
                    body_open = Some(j);
                    break;
                }
                ";" if paren == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = body_open else {
            i = j + 1;
            continue;
        };
        let close = match_brace(toks, open);
        // innermost enclosing receiver context
        let recv = ctxs
            .iter()
            .filter(|c| c.lo < i && i < c.hi)
            .max_by_key(|c| c.lo)
            .map(|c| c.recv.clone());
        out.push(FnItem {
            file: f.rel.clone(),
            module: module.clone(),
            name: name_tok.text.clone(),
            recv,
            line: toks[i].line,
            sig: (i, open),
            body: (open + 1, close.saturating_sub(1)),
            in_test: f.in_test(toks[i].line),
        });
        i = open + 1; // nested fns inside the body are found by the walk
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(rel: &str, src: &str) -> Vec<FnItem> {
        extract_fns(&AnalyzedFile::parse(rel, src))
    }

    #[test]
    fn free_and_method_fns_with_receivers() {
        let src = "pub fn free(x: u32) -> u32 { x }\n\
                   pub struct S;\n\
                   impl S { pub fn m(&self) -> u32 { 1 } }\n\
                   impl std::fmt::Display for S {\n\
                       fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result { Ok(()) }\n\
                   }\n";
        let fs = items("src/backend/x.rs", src);
        assert_eq!(fs.len(), 3, "{fs:?}");
        assert_eq!((fs[0].name.as_str(), fs[0].recv.as_deref()), ("free", None));
        assert_eq!((fs[1].name.as_str(), fs[1].recv.as_deref()), ("m", Some("S")));
        assert_eq!((fs[2].name.as_str(), fs[2].recv.as_deref()), ("fmt", Some("S")));
        assert_eq!(fs[0].module, "backend");
        assert_eq!(fs[1].qual(), "backend::S::m");
    }

    #[test]
    fn generic_impls_and_trait_defaults() {
        let src = "impl<'a, T: Clone> Wrap<'a, T> { fn get(&self) -> &T { &self.0 } }\n\
                   pub trait Proj { fn rows(&self) -> usize { 1 } fn must(&self) -> usize; }\n";
        let fs = items("src/projection/x.rs", src);
        let names: Vec<(String, Option<String>)> =
            fs.iter().map(|f| (f.name.clone(), f.recv.clone())).collect();
        assert_eq!(
            names,
            vec![
                ("get".into(), Some("Wrap".into())),
                ("rows".into(), Some("Proj".into())),
            ],
            "bodyless required method must not appear"
        );
    }

    #[test]
    fn return_position_impl_trait_is_not_a_receiver() {
        let src = "fn mk() -> impl Iterator<Item = u32> { (0..3).map(|x| x) }\n\
                   fn after() {}\n";
        let fs = items("src/solver/x.rs", src);
        assert_eq!(fs.len(), 2);
        assert_eq!(fs[0].recv, None);
        assert_eq!(fs[1].recv, None, "phantom impl ctx must not leak");
    }

    #[test]
    fn nested_fns_are_separate_items_inside_the_outer_span() {
        let src = "fn outer() -> u32 {\n    fn inner(v: u32) -> u32 { v + 1 }\n    inner(2)\n}\n";
        let fs = items("src/util/x.rs", src);
        assert_eq!(fs.len(), 2);
        let (outer, inner) = (&fs[0], &fs[1]);
        assert!(outer.body.0 < inner.sig.0 && inner.body.1 <= outer.body.1);
    }

    #[test]
    fn cfg_test_fns_are_marked() {
        let src = "pub fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n";
        let fs = items("src/serve/x.rs", src);
        assert_eq!(fs.len(), 2);
        assert!(!fs[0].in_test);
        assert!(fs[1].in_test);
    }

    #[test]
    fn where_clauses_and_fn_pointer_types_do_not_confuse_the_scan() {
        let src = "fn apply<F>(f: F) -> u32 where F: Fn(u32) -> u32 { f(1) }\n\
                   type Cb = fn(usize) -> f32;\n\
                   fn uses(c: Cb) -> f32 { c(0) }\n";
        let fs = items("src/engine/x.rs", src);
        let names: Vec<&str> = fs.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["apply", "uses"]);
    }
}
