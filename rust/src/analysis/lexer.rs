//! Minimal Rust token scanner for the audit pass (DESIGN.md §10).
//!
//! Deliberately NOT a parser: the vendored-shim build must stay offline,
//! so there is no `syn`/`proc-macro2` here — just a hand-rolled scanner
//! that is exact about the three things that make naive `grep` lie:
//!
//! * **string/char literals** (including raw strings `r#"…"#` and byte
//!   strings) — pattern text inside a literal is not code;
//! * **comments** (line, doc, nested block) — kept as a side channel,
//!   because waivers (`// audit:allow(rule): why`) and `// SAFETY:`
//!   obligations live there;
//! * **`#[cfg(test)]` regions** — the invariants target production code;
//!   test modules may iterate hash maps and `unwrap()` freely.
//!
//! Numbers never swallow `.` (`1.5` lexes as three tokens), which keeps
//! method-call detection (`.sum`, `.unwrap`) purely positional, and `::`
//! is fused into one token so path patterns (`Instant::now`) are a flat
//! ident/punct sequence.

/// Token class. Only the distinctions the rules need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    /// String literal (normal, raw, or byte); `text` is the *inner*
    /// content, delimiters stripped.
    Str,
    /// Char literal, inner content.
    Char,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line or block) anchored at its starting line; `text` is
/// the inner content without `//`/`/*` delimiters.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Scanner output: code tokens plus the comment side channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Scan `src` into tokens and comments. Never fails: unterminated
/// literals/comments run to end-of-file (the real compiler rejects those
/// files anyway; the auditor should not panic on them).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;

    // Helper: number of '\n' in b[from..to).
    let count_newlines = |from: usize, to: usize| -> u32 {
        b[from..to].iter().filter(|&&c| c == '\n').count() as u32
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                line,
                text: b[start..j].iter().collect::<String>().trim().to_string(),
            });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            // nested block comment
            let start = i + 2;
            let start_line = line;
            let mut depth = 1usize;
            let mut j = start;
            while j < n && depth > 0 {
                if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let end = if depth == 0 { j - 2 } else { j };
            line += count_newlines(i, j);
            out.comments.push(Comment {
                line: start_line,
                text: b[start..end].iter().collect::<String>().trim().to_string(),
            });
            i = j;
            continue;
        }
        // identifiers and prefixed literals (r"", r#""#, b"", br"", b'')
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            let word: String = b[start..j].iter().collect();
            let is_raw_prefix = matches!(word.as_str(), "r" | "br" | "rb");
            let is_byte_prefix = word == "b";
            if (is_raw_prefix && j < n && (b[j] == '"' || b[j] == '#'))
                || (is_byte_prefix && j < n && b[j] == '"')
            {
                // raw/byte string: consume `#`*, then `"` … `"` `#`*
                let before_hashes = j;
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    j += 1;
                    let lit_start = j;
                    'scan: while j < n {
                        if b[j] == '"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    let lit_end = j.min(n);
                    let tok_line = line;
                    line += count_newlines(start, lit_end);
                    out.toks.push(Tok {
                        kind: TokKind::Str,
                        text: b[lit_start..lit_end].iter().collect(),
                        line: tok_line,
                    });
                    i = (lit_end + 1 + hashes).min(n);
                    continue;
                }
                // `r#ident` raw identifier: rewind and fall through as ident
                j = before_hashes;
            }
            if is_byte_prefix && j < n && b[j] == '\'' {
                // byte char literal b'x'
                let (tok, nj, nl) = scan_char_lit(&b, j, line);
                out.toks.push(tok);
                line = nl;
                i = nj;
                continue;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: word, line });
            i = j;
            continue;
        }
        // numbers (dot-free by design; see module docs)
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                text: b[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // strings
        if c == '"' {
            let start = i + 1;
            let mut j = start;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '"' {
                    break;
                }
                j += 1;
            }
            let end = j.min(n);
            let tok_line = line;
            line += count_newlines(i, end);
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: b[start..end.min(n)].iter().collect(),
                line: tok_line,
            });
            i = (end + 1).min(n);
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            // lifetime: 'ident not closed by another quote
            let mut j = i + 1;
            if j < n && (b[j].is_alphabetic() || b[j] == '_') && b[j] != '\\' {
                let ls = j;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    // 'a' — a one-char literal
                    out.toks.push(Tok {
                        kind: TokKind::Char,
                        text: b[ls..j].iter().collect(),
                        line,
                    });
                    i = j + 1;
                    continue;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[ls..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            let (tok, nj, nl) = scan_char_lit(&b, i, line);
            out.toks.push(tok);
            line = nl;
            i = nj;
            continue;
        }
        // `::` fused
        if c == ':' && i + 1 < n && b[i + 1] == ':' {
            out.toks.push(Tok { kind: TokKind::Punct, text: "::".to_string(), line });
            i += 2;
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// Scan a char literal starting at the opening `'` (index `i`); returns
/// (token, next index, next line).
fn scan_char_lit(b: &[char], i: usize, line: u32) -> (Tok, usize, u32) {
    let n = b.len();
    let start = i + 1;
    let mut j = start;
    while j < n {
        if b[j] == '\\' {
            j += 2;
            continue;
        }
        if b[j] == '\'' {
            break;
        }
        j += 1;
    }
    let end = j.min(n);
    let tok = Tok {
        kind: TokKind::Char,
        text: b[start..end.min(n)].iter().collect(),
        line,
    };
    (tok, (end + 1).min(n), line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(l: &Lexed) -> Vec<&str> {
        l.toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn strings_hide_pattern_text() {
        let l = lex(r#"let s = "HashMap::iter() Instant::now()"; s.len();"#);
        assert!(!idents(&l).contains(&"HashMap"));
        assert!(idents(&l).contains(&"len"));
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_strings_and_hashes() {
        let l = lex(r###"let s = r#"unsafe "quoted" HashMap"#; t.sum();"###);
        assert!(!idents(&l).contains(&"HashMap"));
        assert!(!idents(&l).contains(&"unsafe"));
        assert!(idents(&l).contains(&"sum"));
    }

    #[test]
    fn comments_are_side_channel() {
        let l = lex("// audit:allow(wall-clock): bench driver\nlet x = 1; /* SAFETY: nope */");
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].text.starts_with("audit:allow"));
        assert_eq!(l.comments[0].line, 1);
        assert!(l.comments[1].text.contains("SAFETY:"));
        assert!(!idents(&l).contains(&"SAFETY"));
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("/* outer /* inner */ still comment */ fn x() {}");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(idents(&l), vec!["fn", "x"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) -> char { 'y' }");
        let lifetimes: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<_> = l.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 1);
        assert_eq!(chars[0].text, "y");
    }

    #[test]
    fn escaped_char_literals() {
        let l = lex(r"let q = '\''; let b = '\\'; let nl = '\n';");
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 3);
        assert!(idents(&l).contains(&"nl"));
    }

    #[test]
    fn double_colon_is_fused() {
        let l = lex("Instant::now()");
        let texts: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["Instant", "::", "now", "(", ")"]);
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let l = lex("let a = \"x\ny\";\nlet b = 2;");
        let b_tok = l.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn numbers_do_not_swallow_dots() {
        let l = lex("let x = 1.5; v.sum();");
        let texts: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"1"));
        assert!(texts.contains(&"5"));
        assert!(texts.contains(&"sum"));
    }
}
