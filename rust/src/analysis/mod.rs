//! `dualip-audit` — the in-repo static-analysis pass (DESIGN.md §10).
//!
//! Every guarantee this repo ships — N-thread ≡ 1-thread evaluation
//! (`backend/`), S-shard ≡ 1-shard solves (`distributed/`),
//! checkpoint/resume ≡ straight runs (`solver/driver.rs`), byte-stable
//! snapshots (`serve/snapshot.rs`) — is a *determinism invariant* that
//! lives in tests and reviewers' heads. The patterns that silently break
//! those invariants (unordered hash-map iteration, ambient wall-clock
//! reads, unordered float reductions, panics on the serve hot path) are
//! all *statically visible*, so this module makes them machine-checked:
//! a dependency-free token scan ([`lexer`]) feeds a rule catalog
//! ([`rules`]) over `src/`, `benches/`, and `examples/`, with a
//! panic-budget ratchet ([`ratchet`]) that CI only lets go down, and a
//! fixture self-check ([`selfcheck`]) so the auditor cannot rot.
//!
//! On top of the file-local pass sits a crate-wide layer: [`items`]
//! extracts every `fn` with its receiver type, [`graph`] builds a
//! conservative call graph (method-name fallback, explicit `unresolved`
//! bucket), and [`taint`] runs the cross-file reachability rules — P2
//! `panic-reachable` (path-sensitive: findings print the call chain
//! from the serve/solve entry point), D4 `determinism-taint` (unordered
//! iteration feeding float accumulation across fn boundaries), and A1
//! `hot-loop-alloc` (allocation sites in the `eval_chunk_partials` /
//! `project_rows` cone, ratcheted like P1).
//!
//! Run it as `cargo run --bin audit` (`--format json|sarif` for
//! machines, `--baseline <json>` to fail only on new findings,
//! `--update-ratchet` after removing panic/alloc sites, `--self-check`
//! for the fixtures). Exit code 0 means every invariant holds or
//! carries a justified `// audit:allow(rule): why` waiver.

pub mod baseline;
pub mod graph;
pub mod items;
pub mod lexer;
pub mod ratchet;
pub mod report;
pub mod rules;
pub mod selfcheck;
pub mod taint;
pub mod walk;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub use baseline::Baseline;
pub use graph::CallGraph;
pub use items::FnItem;
pub use ratchet::Ratchet;
pub use report::{AuditReport, Finding};
pub use rules::{check_file, check_registry, panic_counts, AnalyzedFile};
pub use selfcheck::{run_fixtures, FixtureResult};
pub use taint::{check_graph, GraphRules};

/// Resolve the directories of one audit root. `root` is the crate root
/// (the directory holding `src/`); `examples/` may live beside it or one
/// level up (this repo shares `examples/` with the python side).
struct Layout {
    src: PathBuf,
    benches: PathBuf,
    examples: PathBuf,
    tests: PathBuf,
    ratchet: PathBuf,
}

impl Layout {
    fn of(root: &Path) -> Layout {
        let examples = if root.join("examples").exists() {
            root.join("examples")
        } else {
            root.join("../examples")
        };
        Layout {
            src: root.join("src"),
            benches: root.join("benches"),
            examples,
            tests: root.join("tests"),
            ratchet: root.join("analysis/ratchet.toml"),
        }
    }
}

/// Load and analyze every `.rs` file under `dir`, rel-prefixed `prefix/`.
fn load_dir(dir: &Path, prefix: &str) -> Result<Vec<AnalyzedFile>, String> {
    let mut out = Vec::new();
    for p in walk::rs_files(dir)? {
        let rel = format!("{prefix}/{}", walk::rel_path(dir, &p));
        let src = walk::read_to_string(&p)?;
        out.push(AnalyzedFile::parse(&rel, &src));
    }
    Ok(out)
}

/// Audit the tree rooted at `root` (the crate root). Walks `src/`,
/// `benches/`, and `examples/`, runs the full rule catalog, counts the
/// P1 panic budget, and compares it against `analysis/ratchet.toml`
/// (budget 0 everywhere if the file is absent).
pub fn audit_tree(root: &Path) -> Result<AuditReport, String> {
    let layout = Layout::of(root);
    let src = load_dir(&layout.src, "src")?;
    let benches = load_dir(&layout.benches, "benches")?;
    let examples = load_dir(&layout.examples, "examples")?;
    let tests = load_dir(&layout.tests, "tests")?;

    let mut report = AuditReport {
        files: src.len() + benches.len() + examples.len(),
        ..Default::default()
    };

    // in-file rules over every walked file
    for f in src.iter().chain(&benches).chain(&examples) {
        report.findings.extend(check_file(f));
    }

    // R1: registry three-tier coverage
    let (r1, notes) = check_registry(&src, &tests);
    report.findings.extend(r1);
    report.notes.extend(notes);

    // P2/D4/A1: crate-wide call-graph rules over src/
    let gr = check_graph(&src);
    report.findings.extend(gr.findings);
    report.notes.extend(gr.notes);

    // P1: per-module counts vs the ratchet
    let mut totals: BTreeMap<String, rules::PanicCounts> = BTreeMap::new();
    for f in &src {
        if let Some(module) = f.module() {
            let c = panic_counts(f);
            let t = totals.entry(module).or_default();
            t.unwrap += c.unwrap;
            t.expect += c.expect;
            t.panics += c.panics;
            t.index += c.index;
        }
    }
    for (module, c) in &totals {
        for (metric, count) in c.metrics() {
            report.counts.insert(format!("{module}.{metric}"), count);
        }
    }
    // A1 counts join the same ratchet under `module.alloc` keys
    for (key, count) in &gr.alloc_counts {
        report.counts.insert(key.clone(), *count);
    }
    let ratchet = if layout.ratchet.exists() {
        Ratchet::parse(&walk::read_to_string(&layout.ratchet)?)?
    } else {
        report
            .notes
            .push("no analysis/ratchet.toml — every panic budget defaults to 0".to_string());
        Ratchet::default()
    };
    let (p1, notes) = ratchet.compare(&report.counts);
    report.findings.extend(p1);
    report.notes.extend(notes);
    // A1 ratchet findings name the module only — attach the actual sites
    for f in &mut report.findings {
        if f.rule == "A1" {
            if let Some((key, _)) = f.message.split_once(" = ") {
                if let Some(sites) = gr.alloc_sites.get(key) {
                    let shown = sites.iter().take(6).cloned().collect::<Vec<_>>().join("; ");
                    let more = sites.len().saturating_sub(6);
                    f.message.push_str(&format!("; sites: {shown}"));
                    if more > 0 {
                        f.message.push_str(&format!(" (+{more} more)"));
                    }
                }
            }
        }
    }

    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(report)
}

/// Rewrite `analysis/ratchet.toml` to the actual counts (after an audit).
pub fn update_ratchet(root: &Path, report: &AuditReport) -> Result<(), String> {
    let path = Layout::of(root).ratchet;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    }
    std::fs::write(&path, Ratchet::render(&report.counts))
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Run the fixture self-check for the tree rooted at `root`.
pub fn self_check(root: &Path) -> Result<Vec<FixtureResult>, String> {
    let layout = Layout::of(root);
    run_fixtures(&root.join("analysis/fixtures"), &layout.tests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    /// Build a minimal crate layout under a temp dir.
    fn scaffold(name: &str, files: &[(&str, &str)]) -> PathBuf {
        let root = std::env::temp_dir().join(format!("dualip_audit_{name}"));
        let _ = fs::remove_dir_all(&root);
        for (rel, content) in files {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(&p, content).unwrap();
        }
        root
    }

    #[test]
    fn clean_scaffold_audits_clean() {
        let root = scaffold(
            "clean",
            &[
                ("src/lib.rs", "pub mod solver;\n"),
                ("src/solver/mod.rs", "pub fn step(x: f32) -> f32 { x * 2.0 }\n"),
            ],
        );
        let r = audit_tree(&root).unwrap();
        assert!(r.clean(), "{:?}", r.findings);
        assert_eq!(r.files, 2);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn injected_violation_is_found_and_located() {
        let root = scaffold(
            "inject",
            &[(
                "src/solver/bad.rs",
                "use std::collections::HashMap;\npub fn f(m: &HashMap<u32, u32>) -> u32 {\n    m.values().sum()\n}\n",
            )],
        );
        let r = audit_tree(&root).unwrap();
        assert!(!r.clean());
        let d1: Vec<_> = r.findings.iter().filter(|f| f.rule == "D1").collect();
        assert!(d1.len() >= 2, "{:?}", r.findings);
        assert_eq!(d1[0].file, "src/solver/bad.rs");
        assert_eq!(d1[0].line, 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn panic_sites_without_budget_fail_the_ratchet() {
        let root = scaffold(
            "nobudget",
            &[("src/serve/mod.rs", "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n")],
        );
        let r = audit_tree(&root).unwrap();
        assert!(r.findings.iter().any(|f| f.rule == "P1"), "{:?}", r.findings);
        assert_eq!(r.counts.get("serve.unwrap"), Some(&1));
        // checking in the budget makes it clean; update_ratchet writes it
        update_ratchet(&root, &r).unwrap();
        let r2 = audit_tree(&root).unwrap();
        assert!(r2.clean(), "{:?}", r2.findings);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reachable_panic_prints_the_call_chain() {
        let root = scaffold(
            "p2chain",
            &[
                (
                    "src/serve/daemon.rs",
                    "pub struct ServeDaemon;\n\
                     impl ServeDaemon { pub fn submit(&self) { route(); } }\n\
                     fn route() { admit(); }\n\
                     fn admit() { Some(1).unwrap(); }\n",
                ),
                ("analysis/ratchet.toml", "[panic_budget]\nserve.unwrap = 1\n"),
            ],
        );
        let r = audit_tree(&root).unwrap();
        let p2: Vec<_> = r.findings.iter().filter(|f| f.rule == "P2").collect();
        assert_eq!(p2.len(), 1, "{:?}", r.findings);
        assert_eq!((p2[0].file.as_str(), p2[0].line), ("src/serve/daemon.rs", 4));
        assert!(
            p2[0].message.contains("ServeDaemon::submit -> route -> admit"),
            "chain missing: {}",
            p2[0].message
        );
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn hot_loop_allocs_ratchet_as_a1_with_sites() {
        let root = scaffold(
            "a1cone",
            &[(
                "src/backend/hot.rs",
                "pub fn eval_chunk_partials(n: usize) -> f32 { helper(n) }\n\
                 fn helper(n: usize) -> f32 { let v = vec![0.0f32; n]; v.len() as f32 }\n",
            )],
        );
        let r = audit_tree(&root).unwrap();
        assert_eq!(r.counts.get("backend.alloc"), Some(&1), "{:?}", r.counts);
        let a1: Vec<_> = r.findings.iter().filter(|f| f.rule == "A1").collect();
        assert_eq!(a1.len(), 1, "{:?}", r.findings);
        assert!(
            a1[0].message.contains("src/backend/hot.rs:2 `vec!` in `helper`"),
            "sites missing: {}",
            a1[0].message
        );
        // budgeting the count makes the tree clean, exactly like P1
        update_ratchet(&root, &r).unwrap();
        let r2 = audit_tree(&root).unwrap();
        assert!(r2.clean(), "{:?}", r2.findings);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn ratchet_decrease_passes_increase_fails() {
        let src_ok = "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n";
        let src_more =
            "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() + v.last().copied().unwrap() }\n";
        let ratchet = "[panic_budget]\nsolver.unwrap = 1\n";
        let root = scaffold(
            "ratchet",
            &[("src/solver/mod.rs", src_ok), ("analysis/ratchet.toml", ratchet)],
        );
        assert!(audit_tree(&root).unwrap().clean());
        fs::write(root.join("src/solver/mod.rs"), src_more).unwrap();
        let r = audit_tree(&root).unwrap();
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "P1");
        assert!(r.findings[0].message.contains("exceeds"));
        let _ = fs::remove_dir_all(&root);
    }
}
