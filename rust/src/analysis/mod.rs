//! `dualip-audit` — the in-repo static-analysis pass (DESIGN.md §10).
//!
//! Every guarantee this repo ships — N-thread ≡ 1-thread evaluation
//! (`backend/`), S-shard ≡ 1-shard solves (`distributed/`),
//! checkpoint/resume ≡ straight runs (`solver/driver.rs`), byte-stable
//! snapshots (`serve/snapshot.rs`) — is a *determinism invariant* that
//! lives in tests and reviewers' heads. The patterns that silently break
//! those invariants (unordered hash-map iteration, ambient wall-clock
//! reads, unordered float reductions, panics on the serve hot path) are
//! all *statically visible*, so this module makes them machine-checked:
//! a dependency-free token scan ([`lexer`]) feeds a rule catalog
//! ([`rules`]) over `src/`, `benches/`, and `examples/`, with a
//! panic-budget ratchet ([`ratchet`]) that CI only lets go down, and a
//! fixture self-check ([`selfcheck`]) so the auditor cannot rot.
//!
//! Run it as `cargo run --bin audit` (`--format json` for machines,
//! `--update-ratchet` after removing panic sites, `--self-check` for the
//! fixtures). Exit code 0 means every invariant holds or carries a
//! justified `// audit:allow(rule): why` waiver.

pub mod lexer;
pub mod ratchet;
pub mod report;
pub mod rules;
pub mod selfcheck;
pub mod walk;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub use ratchet::Ratchet;
pub use report::{AuditReport, Finding};
pub use rules::{check_file, check_registry, panic_counts, AnalyzedFile};
pub use selfcheck::{run_fixtures, FixtureResult};

/// Resolve the directories of one audit root. `root` is the crate root
/// (the directory holding `src/`); `examples/` may live beside it or one
/// level up (this repo shares `examples/` with the python side).
struct Layout {
    src: PathBuf,
    benches: PathBuf,
    examples: PathBuf,
    tests: PathBuf,
    ratchet: PathBuf,
}

impl Layout {
    fn of(root: &Path) -> Layout {
        let examples = if root.join("examples").exists() {
            root.join("examples")
        } else {
            root.join("../examples")
        };
        Layout {
            src: root.join("src"),
            benches: root.join("benches"),
            examples,
            tests: root.join("tests"),
            ratchet: root.join("analysis/ratchet.toml"),
        }
    }
}

/// Load and analyze every `.rs` file under `dir`, rel-prefixed `prefix/`.
fn load_dir(dir: &Path, prefix: &str) -> Result<Vec<AnalyzedFile>, String> {
    let mut out = Vec::new();
    for p in walk::rs_files(dir)? {
        let rel = format!("{prefix}/{}", walk::rel_path(dir, &p));
        let src = walk::read_to_string(&p)?;
        out.push(AnalyzedFile::parse(&rel, &src));
    }
    Ok(out)
}

/// Audit the tree rooted at `root` (the crate root). Walks `src/`,
/// `benches/`, and `examples/`, runs the full rule catalog, counts the
/// P1 panic budget, and compares it against `analysis/ratchet.toml`
/// (budget 0 everywhere if the file is absent).
pub fn audit_tree(root: &Path) -> Result<AuditReport, String> {
    let layout = Layout::of(root);
    let src = load_dir(&layout.src, "src")?;
    let benches = load_dir(&layout.benches, "benches")?;
    let examples = load_dir(&layout.examples, "examples")?;
    let tests = load_dir(&layout.tests, "tests")?;

    let mut report = AuditReport {
        files: src.len() + benches.len() + examples.len(),
        ..Default::default()
    };

    // in-file rules over every walked file
    for f in src.iter().chain(&benches).chain(&examples) {
        report.findings.extend(check_file(f));
    }

    // R1: registry three-tier coverage
    let (r1, notes) = check_registry(&src, &tests);
    report.findings.extend(r1);
    report.notes.extend(notes);

    // P1: per-module counts vs the ratchet
    let mut totals: BTreeMap<String, rules::PanicCounts> = BTreeMap::new();
    for f in &src {
        if let Some(module) = f.module() {
            let c = panic_counts(f);
            let t = totals.entry(module).or_default();
            t.unwrap += c.unwrap;
            t.expect += c.expect;
            t.panics += c.panics;
            t.index += c.index;
        }
    }
    for (module, c) in &totals {
        for (metric, count) in c.metrics() {
            report.counts.insert(format!("{module}.{metric}"), count);
        }
    }
    let ratchet = if layout.ratchet.exists() {
        Ratchet::parse(&walk::read_to_string(&layout.ratchet)?)?
    } else {
        report
            .notes
            .push("no analysis/ratchet.toml — every panic budget defaults to 0".to_string());
        Ratchet::default()
    };
    let (p1, notes) = ratchet.compare(&report.counts);
    report.findings.extend(p1);
    report.notes.extend(notes);

    report.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(report)
}

/// Rewrite `analysis/ratchet.toml` to the actual counts (after an audit).
pub fn update_ratchet(root: &Path, report: &AuditReport) -> Result<(), String> {
    let path = Layout::of(root).ratchet;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    }
    std::fs::write(&path, Ratchet::render(&report.counts))
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Run the fixture self-check for the tree rooted at `root`.
pub fn self_check(root: &Path) -> Result<Vec<FixtureResult>, String> {
    let layout = Layout::of(root);
    run_fixtures(&root.join("analysis/fixtures"), &layout.tests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    /// Build a minimal crate layout under a temp dir.
    fn scaffold(name: &str, files: &[(&str, &str)]) -> PathBuf {
        let root = std::env::temp_dir().join(format!("dualip_audit_{name}"));
        let _ = fs::remove_dir_all(&root);
        for (rel, content) in files {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(&p, content).unwrap();
        }
        root
    }

    #[test]
    fn clean_scaffold_audits_clean() {
        let root = scaffold(
            "clean",
            &[
                ("src/lib.rs", "pub mod solver;\n"),
                ("src/solver/mod.rs", "pub fn step(x: f32) -> f32 { x * 2.0 }\n"),
            ],
        );
        let r = audit_tree(&root).unwrap();
        assert!(r.clean(), "{:?}", r.findings);
        assert_eq!(r.files, 2);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn injected_violation_is_found_and_located() {
        let root = scaffold(
            "inject",
            &[(
                "src/solver/bad.rs",
                "use std::collections::HashMap;\npub fn f(m: &HashMap<u32, u32>) -> u32 {\n    m.values().sum()\n}\n",
            )],
        );
        let r = audit_tree(&root).unwrap();
        assert!(!r.clean());
        let d1: Vec<_> = r.findings.iter().filter(|f| f.rule == "D1").collect();
        assert!(d1.len() >= 2, "{:?}", r.findings);
        assert_eq!(d1[0].file, "src/solver/bad.rs");
        assert_eq!(d1[0].line, 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn panic_sites_without_budget_fail_the_ratchet() {
        let root = scaffold(
            "nobudget",
            &[("src/serve/mod.rs", "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n")],
        );
        let r = audit_tree(&root).unwrap();
        assert!(r.findings.iter().any(|f| f.rule == "P1"), "{:?}", r.findings);
        assert_eq!(r.counts.get("serve.unwrap"), Some(&1));
        // checking in the budget makes it clean; update_ratchet writes it
        update_ratchet(&root, &r).unwrap();
        let r2 = audit_tree(&root).unwrap();
        assert!(r2.clean(), "{:?}", r2.findings);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn ratchet_decrease_passes_increase_fails() {
        let src_ok = "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n";
        let src_more =
            "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() + v.last().copied().unwrap() }\n";
        let ratchet = "[panic_budget]\nsolver.unwrap = 1\n";
        let root = scaffold(
            "ratchet",
            &[("src/solver/mod.rs", src_ok), ("analysis/ratchet.toml", ratchet)],
        );
        assert!(audit_tree(&root).unwrap().clean());
        fs::write(root.join("src/solver/mod.rs"), src_more).unwrap();
        let r = audit_tree(&root).unwrap();
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "P1");
        assert!(r.findings[0].message.contains("exceeds"));
        let _ = fs::remove_dir_all(&root);
    }
}
