//! The P1 panic-budget ratchet (DESIGN.md §10).
//!
//! `analysis/ratchet.toml` pins, per top-level `src/` module, how many
//! panic-capable sites (`.unwrap()`, `.expect(`, `panic!`-family macros,
//! direct index expressions) production code currently contains. The
//! audit recounts on every run and compares:
//!
//! * count **above** budget → P1 finding (CI fails): new panic sites
//!   must be converted to `Result`/shed outcomes, not accumulated;
//! * count **below** budget → informational note: the budget can be
//!   lowered (`cargo run --bin audit -- --update-ratchet` rewrites the
//!   file to the actual counts);
//! * module absent from the file → budget 0, so brand-new modules start
//!   panic-free by default and must check in an explicit budget.
//!
//! Since PR 10 the same mechanism also ratchets **A1 `hot-loop-alloc`**
//! counts (forbidden allocation sites in the `eval_chunk_partials` /
//! `project_rows` reachability cone, see `taint.rs`): keys ending in
//! `.alloc` live in a `[hot_loop_alloc]` section and compare as A1
//! findings; everything else stays P1. Both are unwaivable — budgets
//! only go down.
//!
//! The file is a deliberately tiny TOML subset — comments, optional
//! `[panic_budget]` / `[hot_loop_alloc]` section headers, and
//! `module.metric = count` lines — parsed here so the offline
//! vendored-shim build needs no TOML crate.

use std::collections::BTreeMap;

use super::report::Finding;

/// Parsed ratchet: budgets keyed `module.metric`, with the source line
/// of each key for finding locations.
#[derive(Debug, Default)]
pub struct Ratchet {
    budgets: BTreeMap<String, (usize, u32)>,
}

impl Ratchet {
    /// Parse ratchet text. Unknown syntax is an error — a malformed
    /// ratchet silently parsed as empty would zero every budget and fail
    /// CI with misleading findings.
    pub fn parse(text: &str) -> Result<Ratchet, String> {
        let mut budgets = BTreeMap::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let lineno = (idx + 1) as u32;
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                if line != "[panic_budget]" && line != "[hot_loop_alloc]" {
                    return Err(format!(
                        "ratchet.toml:{lineno}: unknown section {line}"
                    ));
                }
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(format!("ratchet.toml:{lineno}: expected `key = count`"));
            };
            let key = key.trim().trim_matches('"').to_string();
            let val: usize = val
                .trim()
                .parse()
                .map_err(|_| format!("ratchet.toml:{lineno}: non-integer budget"))?;
            if !key.contains('.') {
                return Err(format!(
                    "ratchet.toml:{lineno}: key must be `module.metric`"
                ));
            }
            if budgets.insert(key.clone(), (val, lineno)).is_some() {
                return Err(format!("ratchet.toml:{lineno}: duplicate key {key}"));
            }
        }
        Ok(Ratchet { budgets })
    }

    pub fn budget(&self, key: &str) -> usize {
        self.budgets.get(key).map(|&(v, _)| v).unwrap_or(0)
    }

    /// Rule identity for a count key: `.alloc` keys are A1 (hot-loop
    /// allocations), everything else P1 (panic budget).
    fn rule_for(key: &str) -> (&'static str, &'static str, &'static str) {
        if key.ends_with(".alloc") {
            ("A1", "hot-loop-alloc", "hoist the new allocation(s) out of the hot loop")
        } else {
            ("P1", "panic-budget", "convert the new panic site(s) to Result/shed outcomes")
        }
    }

    /// Compare actual counts against budgets. Returns P1/A1 findings
    /// for exceedances plus slack notes.
    pub fn compare(
        &self,
        counts: &BTreeMap<String, usize>,
    ) -> (Vec<Finding>, Vec<String>) {
        let mut findings = Vec::new();
        let mut notes = Vec::new();
        for (key, &count) in counts {
            let (rule, slug, action) = Self::rule_for(key);
            match self.budgets.get(key) {
                Some(&(budget, lineno)) if count > budget => {
                    findings.push(Finding::new(
                        "analysis/ratchet.toml",
                        lineno,
                        rule,
                        slug,
                        format!(
                            "{key} = {count} exceeds ratcheted budget {budget} — \
                             {action}; budgets only go down"
                        ),
                    ));
                }
                Some(&(budget, _)) if count < budget => {
                    notes.push(format!(
                        "{rule} slack: {key} = {count}, budget {budget} — run \
                         --update-ratchet to lower it"
                    ));
                }
                Some(_) => {}
                None if count > 0 => {
                    findings.push(Finding::new(
                        "analysis/ratchet.toml",
                        0,
                        rule,
                        slug,
                        format!(
                            "{key} = {count} but module has no checked-in budget — \
                             new modules start clean by default; add an explicit \
                             budget line if the sites are justified"
                        ),
                    ));
                }
                None => {}
            }
        }
        // budgets for metrics that no longer exist (module deleted /
        // renamed) rot silently — surface them
        for (key, &(budget, _)) in &self.budgets {
            if budget > 0 && !counts.contains_key(key) {
                let (rule, _, _) = Self::rule_for(key);
                notes.push(format!(
                    "{rule} stale: {key} budgeted {budget} but no such module.metric \
                     was counted — delete the line"
                ));
            }
        }
        (findings, notes)
    }

    /// Render a fresh ratchet file from actual counts (`--update-ratchet`).
    /// Byte-stable: sections in fixed order, keys sorted, zero counts
    /// omitted.
    pub fn render(counts: &BTreeMap<String, usize>) -> String {
        let mut out = String::from(
            "# dualip-audit P1 panic budget — panic-capable sites per src/ module\n\
             # (unwrap / expect / panic-family macros / direct index expressions),\n\
             # counted outside #[cfg(test)]. CI only lets these counts go DOWN.\n\
             # Regenerate after removing panic sites with:\n\
             #   cargo run --bin audit -- --update-ratchet\n\
             \n[panic_budget]\n",
        );
        for (k, v) in counts {
            if *v > 0 && !k.ends_with(".alloc") {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out.push_str(
            "\n# A1 hot-loop allocation budget — Vec::new / vec! / collect / Box::new\n\
             # sites in functions reachable from eval_chunk_partials / project_rows.\n\
             \n[hot_loop_alloc]\n",
        );
        for (k, v) in counts {
            if *v > 0 && k.ends_with(".alloc") {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(pairs: &[(&str, usize)]) -> BTreeMap<String, usize> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn parse_round_trips_render() {
        let c = counts(&[("solver.unwrap", 3), ("serve.index", 17), ("gen.expect", 0)]);
        let text = Ratchet::render(&c);
        let r = Ratchet::parse(&text).unwrap();
        assert_eq!(r.budget("solver.unwrap"), 3);
        assert_eq!(r.budget("serve.index"), 17);
        // zero counts are omitted → default budget 0
        assert_eq!(r.budget("gen.expect"), 0);
        assert_eq!(r.budget("never.seen"), 0);
    }

    #[test]
    fn increase_is_a_finding_decrease_is_a_note() {
        let r = Ratchet::parse("[panic_budget]\nsolver.unwrap = 3\nserve.unwrap = 5\n").unwrap();
        let (f, notes) = r.compare(&counts(&[("solver.unwrap", 4), ("serve.unwrap", 2)]));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "P1");
        assert!(f[0].message.contains("solver.unwrap = 4 exceeds"));
        assert_eq!(f[0].line, 2);
        assert!(notes.iter().any(|n| n.contains("serve.unwrap = 2")));
    }

    #[test]
    fn unbudgeted_module_defaults_to_zero() {
        let r = Ratchet::parse("[panic_budget]\n").unwrap();
        let (f, _) = r.compare(&counts(&[("newmod.panic", 1)]));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("no checked-in budget"));
        // ...but a zero count is fine
        let (f2, _) = r.compare(&counts(&[("newmod.panic", 0)]));
        assert!(f2.is_empty());
    }

    #[test]
    fn stale_budgets_are_noted() {
        let r = Ratchet::parse("[panic_budget]\ngone.unwrap = 9\n").unwrap();
        let (f, notes) = r.compare(&counts(&[]));
        assert!(f.is_empty());
        assert!(notes.iter().any(|n| n.contains("stale")));
    }

    #[test]
    fn alloc_keys_ratchet_as_a1_in_their_own_section() {
        let c = counts(&[("backend.alloc", 2), ("backend.unwrap", 4)]);
        let text = Ratchet::render(&c);
        // sectioned rendering: the alloc key must come after its header
        let panic_at = text.find("[panic_budget]").unwrap();
        let alloc_at = text.find("[hot_loop_alloc]").unwrap();
        let key_at = text.find("backend.alloc = 2").unwrap();
        assert!(panic_at < alloc_at && alloc_at < key_at);
        assert!(text.find("backend.unwrap = 4").unwrap() < alloc_at);
        // byte-stable round trip
        let r = Ratchet::parse(&text).unwrap();
        assert_eq!(r.budget("backend.alloc"), 2);
        assert_eq!(Ratchet::render(&c), text);
        // exceedance fires A1, not P1; unbudgeted alloc counts fire too
        let (f, _) = r.compare(&counts(&[("backend.alloc", 3), ("backend.unwrap", 4)]));
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].slug), ("A1", "hot-loop-alloc"));
        assert!(f[0].message.contains("hoist the new allocation"));
        let (f2, _) = r.compare(&counts(&[("fresh.alloc", 1)]));
        assert!(f2.iter().any(|x| x.rule == "A1" && x.message.contains("no checked-in budget")));
    }

    #[test]
    fn malformed_ratchet_is_an_error_not_empty() {
        assert!(Ratchet::parse("[wrong_section]\n").is_err());
        assert!(Ratchet::parse("solver.unwrap: 3\n").is_err());
        assert!(Ratchet::parse("[panic_budget]\nsolver.unwrap = many\n").is_err());
        assert!(Ratchet::parse("[panic_budget]\nnodot = 3\n").is_err());
        assert!(
            Ratchet::parse("[panic_budget]\na.b = 1\na.b = 2\n").is_err(),
            "duplicate keys rejected"
        );
    }
}
