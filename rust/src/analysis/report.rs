//! Finding model and rendering (text, machine-readable JSON, SARIF 2.1.0).

use std::collections::BTreeMap;
use std::fmt;

/// One audit finding: `file:line RULE message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Crate-root-relative path with `/` separators.
    pub file: String,
    /// 1-based line (0 when the finding is file- or tree-level).
    pub line: u32,
    /// Rule code (`D1`, `D2`, `D3`, `P1`, `U1`, `R1`, `W0`).
    pub rule: &'static str,
    /// Waiver slug (`unordered-iter`, ...).
    pub slug: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(
        file: &str,
        line: u32,
        rule: &'static str,
        slug: &'static str,
        message: String,
    ) -> Finding {
        Finding { file: file.to_string(), line, rule, slug, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Full result of one audit pass.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    /// P1 raw counts, keyed `module.metric` (always complete, whether or
    /// not any budget was exceeded) — the input to `--update-ratchet`.
    pub counts: BTreeMap<String, usize>,
    /// Informational lines (budget slack, skipped tiers); never fatal.
    pub notes: Vec<String>,
    /// Files scanned.
    pub files: usize,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable rendering: one finding per line, then a summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.to_string());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out.push_str(&format!(
            "audit: {} file(s), {} finding(s)\n",
            self.files,
            self.findings.len()
        ));
        out
    }

    /// Machine-readable rendering (`--format json`).
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"slug\": {}, \
                 \"message\": {}}}",
                json_str(&f.file),
                f.line,
                json_str(f.rule),
                json_str(f.slug),
                json_str(&f.message),
            ));
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"counts\": {");
        for (i, (k, v)) in self.counts.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    {}: {}", json_str(k), v));
        }
        if !self.counts.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("},\n  \"notes\": [");
        for (i, n) in self.notes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    {}", json_str(n)));
        }
        if !self.notes.is_empty() {
            out.push_str("\n  ");
        }
        let tail = format!("],\n  \"files\": {},\n  \"clean\": {}\n}}\n", self.files, self.clean());
        out.push_str(&tail);
        out
    }

    /// SARIF 2.1.0 rendering (`--format sarif`) for GitHub code scanning.
    ///
    /// Minimal valid shape: one run, driver `dualip-audit`, a rule entry
    /// per distinct rule id present, one `result` per finding with a
    /// physical location. SARIF requires `startLine >= 1`, so file- and
    /// tree-level findings (line 0) clamp to 1.
    pub fn render_sarif(&self) -> String {
        let mut rules: Vec<(&str, &str)> = Vec::new();
        for f in &self.findings {
            if !rules.iter().any(|&(r, _)| r == f.rule) {
                rules.push((f.rule, f.slug));
            }
        }
        rules.sort_unstable();
        let mut out = String::from(
            "{\n  \"$schema\": \"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json\",\n  \"version\": \"2.1.0\",\n  \"runs\": [\n    {\n      \"tool\": {\n        \"driver\": {\n          \"name\": \"dualip-audit\",\n          \"informationUri\": \"https://example.invalid/dualip-gpu/DESIGN.md\",\n          \"rules\": [",
        );
        for (i, (rule, slug)) in rules.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n            {{\"id\": {}, \"name\": {}, \"shortDescription\": {{\"text\": {}}}}}",
                json_str(rule),
                json_str(slug),
                json_str(slug),
            ));
        }
        if !rules.is_empty() {
            out.push_str("\n          ");
        }
        out.push_str("]\n        }\n      },\n      \"results\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n        {{\"ruleId\": {}, \"level\": \"error\", \"message\": {{\"text\": {}}}, \"locations\": [{{\"physicalLocation\": {{\"artifactLocation\": {{\"uri\": {}}}, \"region\": {{\"startLine\": {}}}}}}}]}}",
                json_str(f.rule),
                json_str(&f.message),
                json_str(&f.file),
                f.line.max(1),
            ));
        }
        if !self.findings.is_empty() {
            out.push_str("\n      ");
        }
        out.push_str("]\n    }\n  ]\n}\n");
        out
    }
}

/// JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_format_is_file_line_rule_message() {
        let f = Finding::new("src/a.rs", 7, "D1", "unordered-iter", "msg here".into());
        assert_eq!(f.to_string(), "src/a.rs:7 D1 msg here");
    }

    #[test]
    fn json_escapes_and_round_trips_structure() {
        let mut r = AuditReport::default();
        r.files = 2;
        r.findings.push(Finding::new(
            "src/a.rs",
            1,
            "D2",
            "wall-clock",
            "quote \" backslash \\ tab\t".into(),
        ));
        r.counts.insert("solver.unwrap".into(), 3);
        r.notes.push("note".into());
        let j = r.render_json();
        assert!(j.contains("\\\""));
        assert!(j.contains("\\\\"));
        assert!(j.contains("\\t"));
        assert!(j.contains("\"solver.unwrap\": 3"));
        assert!(j.contains("\"clean\": false"));
        // braces/brackets balance
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = j.matches(open).count();
            let c = j.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close}");
        }
    }

    #[test]
    fn sarif_clamps_line_zero_and_dedupes_rules() {
        let mut r = AuditReport::default();
        r.findings.push(Finding::new("analysis/ratchet.toml", 0, "P1", "panic-budget", "a".into()));
        r.findings.push(Finding::new("src/a.rs", 3, "P2", "panic-reachable", "b".into()));
        r.findings.push(Finding::new("src/b.rs", 9, "P2", "panic-reachable", "c".into()));
        let s = r.render_sarif();
        assert!(s.contains("\"version\": \"2.1.0\""));
        assert!(s.contains("sarif-schema-2.1.0.json"));
        assert!(s.contains("\"name\": \"dualip-audit\""));
        assert_eq!(s.matches("{\"id\": ").count(), 2, "one rule entry per distinct rule");
        assert_eq!(s.matches("\"ruleId\": ").count(), 3);
        assert!(s.contains("\"startLine\": 1"), "line 0 must clamp to 1");
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(s.matches(open).count(), s.matches(close).count());
        }
    }

    #[test]
    fn empty_report_is_clean_and_valid() {
        let r = AuditReport::default();
        assert!(r.clean());
        let j = r.render_json();
        assert!(j.contains("\"findings\": []"));
        assert!(j.contains("\"clean\": true"));
        assert!(r.render_text().contains("0 finding(s)"));
    }
}
