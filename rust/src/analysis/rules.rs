//! The audit rule catalog (DESIGN.md §10).
//!
//! Every rule enforces an invariant the rest of the repo *documents but
//! cannot compile-check*: N-thread ≡ 1-thread evaluation, S-shard ≡
//! 1-shard solves, checkpoint/resume ≡ straight runs, byte-stable
//! snapshots, and a panic-free serve hot path. The catalog:
//!
//! | rule | slug                     | invariant                                      |
//! |------|--------------------------|------------------------------------------------|
//! | D1   | `unordered-iter`         | no `HashMap`/`HashSet` in determinism-critical modules (iteration order reaches fingerprints, snapshots, λ) |
//! | D2   | `wall-clock`             | ambient clocks (`Instant::now`, `SystemTime`) only in `util/timer.rs`; everything else takes injected clocks |
//! | D3   | `unordered-float-merge`  | float accumulation in threaded code must go through the chunk-index-ordered merge helpers, never a bare `.sum()`/`.fold` |
//! | U1   | `missing-safety-comment` | every `unsafe` carries an adjacent `// SAFETY:` argument |
//! | W0   | `bad-waiver`             | waivers must name a known rule and carry a justification |
//! | P1   | `panic-budget`           | per-module `unwrap`/`expect`/`panic!`/index budget; the checked-in ratchet only goes down (see `ratchet.rs`) |
//! | R1   | `registry-coverage`      | every registered projection family is wired through all three test tiers (see `check_registry`) |
//!
//! A finding at line L is waived by `// audit:allow(<slug>): <why>` on
//! line L or L−1; the justification is mandatory (empty ⇒ W0).

use std::collections::BTreeSet;

use super::lexer::{lex, Comment, Lexed, Tok, TokKind};
use super::report::Finding;

/// Directories under `src/` where iteration order, clocks, and reduction
/// order can reach fingerprints, snapshots, collectives, or cached λ.
pub const CRITICAL_DIRS: &[&str] = &[
    "src/solver/",
    "src/backend/",
    "src/sparse/",
    "src/serve/",
    "src/distributed/",
    "src/engine/",
    "src/projection/",
    "src/runtime/",
];

/// The only file allowed to read ambient wall clocks (D2).
pub const CLOCK_HOME: &str = "src/util/timer.rs";

/// Rule slugs accepted by `audit:allow(...)` waivers.
pub const WAIVABLE_SLUGS: &[&str] = &[
    "unordered-iter",
    "wall-clock",
    "unordered-float-merge",
    "missing-safety-comment",
    "registry-coverage",
    "panic-reachable",
    "determinism-taint",
];

/// One source file, lexed and classified.
pub struct AnalyzedFile {
    /// Path relative to the crate root (`src/...`, `benches/...`,
    /// `examples/...`, `tests/...`) with `/` separators.
    pub rel: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// Lines inside `#[cfg(test)]` items (1-based, inclusive).
    test_ranges: Vec<(u32, u32)>,
}

/// A parsed `audit:allow(slug): justification` waiver.
#[derive(Debug)]
pub struct Waiver {
    pub line: u32,
    pub slug: String,
    pub justification: String,
}

impl AnalyzedFile {
    pub fn parse(rel: &str, src: &str) -> AnalyzedFile {
        let Lexed { toks, comments } = lex(src);
        let test_ranges = cfg_test_ranges(&toks);
        AnalyzedFile { rel: rel.to_string(), toks, comments, test_ranges }
    }

    /// Whether `line` sits inside a `#[cfg(test)]` item.
    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }

    fn is_critical(&self) -> bool {
        CRITICAL_DIRS.iter().any(|d| self.rel.starts_with(d))
    }

    /// Top-level module for the panic ratchet: `src/solver/x.rs` →
    /// `solver`, `src/lib.rs` → `root`, `src/bin/audit.rs` → `bin`.
    pub fn module(&self) -> Option<String> {
        let rest = self.rel.strip_prefix("src/")?;
        Some(match rest.split_once('/') {
            Some((dir, _)) => dir.to_string(),
            None => "root".to_string(),
        })
    }

    /// Waivers declared in this file's comments. A waiver comment must
    /// *start with* `audit:allow(` — prose that merely mentions the
    /// syntax (docs, this module) is not a waiver.
    pub fn waivers(&self) -> Vec<Waiver> {
        let mut out = Vec::new();
        for c in &self.comments {
            let Some(rest) = c.text.strip_prefix("audit:allow(") else { continue };
            let (slug, after) = match rest.split_once(')') {
                Some((s, a)) => (s.trim().to_string(), a),
                None => (rest.trim().to_string(), ""),
            };
            let justification = after.trim_start_matches(':').trim().to_string();
            out.push(Waiver { line: c.line, slug, justification });
        }
        out
    }

    /// Does any comment in lines `[lo, hi]` contain `needle`?
    fn comment_in_range(&self, lo: u32, hi: u32, needle: &str) -> bool {
        self.comments
            .iter()
            .any(|c| c.line >= lo && c.line <= hi && c.text.contains(needle))
    }
}

/// Find `#[cfg(test)]` item ranges by brace matching from each attribute.
fn cfg_test_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let is_attr = toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if !is_attr {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        // Scan to the end of the annotated item: the matching `}` of its
        // first `{`, or a `;` reached before any brace opens.
        let mut j = i + 7;
        let mut depth = 0usize;
        let mut end_line = start_line;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        end_line = toks[j].line;
                        break;
                    }
                }
                ";" if depth == 0 => {
                    end_line = toks[j].line;
                    break;
                }
                _ => {}
            }
            end_line = toks[j].line;
            j += 1;
        }
        out.push((start_line, end_line));
        i = j + 1;
    }
    out
}

/// Per-module panic-class counts (P1).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanicCounts {
    pub unwrap: usize,
    pub expect: usize,
    /// `panic!` / `unreachable!` / `todo!` / `unimplemented!` invocations.
    pub panics: usize,
    /// Direct index expressions (`x[i]`, `f()[i]`, `a[i][j]`) — each can
    /// panic on out-of-bounds.
    pub index: usize,
}

impl PanicCounts {
    pub fn metrics(&self) -> [(&'static str, usize); 4] {
        [
            ("unwrap", self.unwrap),
            ("expect", self.expect),
            ("panic", self.panics),
            ("index", self.index),
        ]
    }
}

/// Count panic-capable sites outside `#[cfg(test)]` (P1 raw input).
pub fn panic_counts(f: &AnalyzedFile) -> PanicCounts {
    let mut c = PanicCounts::default();
    let toks = &f.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if f.in_test(t.line) {
            continue;
        }
        // `.unwrap(` / `.expect(` with the call paren, so struct fields
        // named `unwrap` (this module's own counters!) don't count
        match t.text.as_str() {
            "unwrap" | "expect"
                if i > 0
                    && toks[i - 1].text == "."
                    && i + 1 < toks.len()
                    && toks[i + 1].text == "(" =>
            {
                if t.text == "unwrap" {
                    c.unwrap += 1
                } else {
                    c.expect += 1
                }
            }
            "panic" | "unreachable" | "todo" | "unimplemented"
                if t.kind == TokKind::Ident
                    && i + 1 < toks.len()
                    && toks[i + 1].text == "!" =>
            {
                c.panics += 1
            }
            "[" if i > 0 => {
                let p = &toks[i - 1];
                let indexes = p.kind == TokKind::Ident && !is_keyword(&p.text)
                    || p.text == ")"
                    || p.text == "]";
                if indexes {
                    c.index += 1;
                }
            }
            _ => {}
        }
    }
    c
}

pub(crate) fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "let" | "mut" | "ref" | "in" | "if" | "else" | "match" | "return" | "fn" | "impl"
            | "pub" | "use" | "mod" | "struct" | "enum" | "trait" | "where" | "for"
            | "while" | "loop" | "move" | "as" | "dyn" | "box" | "unsafe" | "const"
            | "static" | "type"
    )
}

/// Run the in-file rules (D1, D2, D3, U1, W0) and apply waivers.
/// P1 (ratchet) and R1 (registry coverage) are tree-level and live in
/// `ratchet.rs` / `check_registry`.
pub fn check_file(f: &AnalyzedFile) -> Vec<Finding> {
    let mut findings = Vec::new();
    rule_d1_unordered(f, &mut findings);
    rule_d2_wall_clock(f, &mut findings);
    rule_d3_float_merge(f, &mut findings);
    rule_u1_safety(f, &mut findings);
    apply_waivers(f, findings)
}

/// Drop findings covered by a same-line or line-above waiver with a
/// matching slug, then append W0 findings for malformed waivers.
/// (The graph rules in `taint.rs` apply the same drop half per site
/// file but never re-emit W0 — that would duplicate this pass.)
fn apply_waivers(f: &AnalyzedFile, findings: Vec<Finding>) -> Vec<Finding> {
    let waivers = f.waivers();
    let mut out: Vec<Finding> = findings
        .into_iter()
        .filter(|fi| {
            !waivers.iter().any(|w| {
                w.slug == fi.slug
                    && !w.justification.is_empty()
                    && (w.line == fi.line || w.line + 1 == fi.line)
            })
        })
        .collect();
    for w in waivers {
        if !WAIVABLE_SLUGS.contains(&w.slug.as_str()) {
            out.push(Finding::new(
                &f.rel,
                w.line,
                "W0",
                "bad-waiver",
                format!("waiver names unknown rule `{}`", w.slug),
            ));
        } else if w.justification.is_empty() {
            out.push(Finding::new(
                &f.rel,
                w.line,
                "W0",
                "bad-waiver",
                format!("waiver for `{}` carries no justification", w.slug),
            ));
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// D1 — unordered containers in determinism-critical modules.
///
/// Two tiers: any `HashMap`/`HashSet` token (the declaration is the root
/// cause — downstream iteration anywhere inherits the unorder), plus
/// explicit iteration sites over identifiers bound to hash containers in
/// this file (`.iter()`, `.keys()`, `for _ in &m`, ...), which get a
/// sharper message.
fn rule_d1_unordered(f: &AnalyzedFile, findings: &mut Vec<Finding>) {
    if !f.is_critical() {
        return;
    }
    let hash_names = ["HashMap", "HashSet"];
    let iter_methods =
        ["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain"];
    let toks = &f.toks;
    // bound names: `name: HashMap<...>` fields/args and `name = HashMap::...`,
    // seeing through path prefixes (`name: std::collections::HashMap<...>`)
    let mut bound: BTreeSet<&str> = BTreeSet::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident || !hash_names.contains(&toks[i].text.as_str()) {
            continue;
        }
        let mut p = i;
        while p >= 2 && toks[p - 1].text == "::" && toks[p - 2].kind == TokKind::Ident {
            p -= 2;
        }
        if p >= 2 && (toks[p - 1].text == ":" || toks[p - 1].text == "=") {
            if toks[p - 2].kind == TokKind::Ident && !is_keyword(&toks[p - 2].text) {
                bound.insert(toks[p - 2].text.as_str());
            }
        }
    }
    let mut flagged_lines: BTreeSet<u32> = BTreeSet::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if f.in_test(t.line) {
            continue;
        }
        // tier 1: the container token itself (one finding per line)
        if t.kind == TokKind::Ident && hash_names.contains(&t.text.as_str()) {
            if flagged_lines.insert(t.line) {
                findings.push(Finding::new(
                    &f.rel,
                    t.line,
                    "D1",
                    "unordered-iter",
                    format!(
                        "`{}` in determinism-critical module — iteration order is \
                         unordered; use BTreeMap/BTreeSet or sorted-key iteration",
                        t.text
                    ),
                ));
            }
            continue;
        }
        // tier 2: iteration over a bound hash container
        if t.kind == TokKind::Ident
            && bound.contains(t.text.as_str())
            && i + 2 < toks.len()
            && toks[i + 1].text == "."
            && iter_methods.contains(&toks[i + 2].text.as_str())
            && flagged_lines.insert(t.line)
        {
            findings.push(Finding::new(
                &f.rel,
                t.line,
                "D1",
                "unordered-iter",
                format!(
                    "iteration over unordered container `{}` in determinism-critical \
                     module",
                    t.text
                ),
            ));
        }
    }
}

/// D2 — ambient wall-clock reads outside `util/timer.rs`.
fn rule_d2_wall_clock(f: &AnalyzedFile, findings: &mut Vec<Finding>) {
    if f.rel == CLOCK_HOME {
        return;
    }
    let toks = &f.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if f.in_test(t.line) || t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "SystemTime" {
            findings.push(Finding::new(
                &f.rel,
                t.line,
                "D2",
                "wall-clock",
                "ambient `SystemTime` outside util/timer.rs — take an injected clock"
                    .to_string(),
            ));
        }
        if t.text == "Instant"
            && i + 2 < toks.len()
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "now"
        {
            findings.push(Finding::new(
                &f.rel,
                t.line,
                "D2",
                "wall-clock",
                "ambient `Instant::now` outside util/timer.rs — use util::timer \
                 (Stopwatch/PhaseTimers) or an injected clock"
                    .to_string(),
            ));
        }
    }
}

/// Identifiers that bless a `.sum()`/`.fold` statement as either integer
/// arithmetic or an explicitly ordered/order-insensitive reduction.
const D3_BLESSED: &[&str] = &[
    "len",
    "count",
    "is_empty",
    "max",
    "min",
    "rows",
    "real_edges",
    "padded_edges",
    "reduce_chunk",
    "reduce_chunk_partials",
    "eval_chunk_partials",
];

const D3_INT_TYPES: &[&str] = &["usize", "u64", "u32", "u16", "u8", "i64", "i32", "isize"];

/// D3 — bare float accumulation in threaded code.
///
/// In a file that spawns threads (`thread::scope` / `spawn`), a
/// `.sum()`/`.fold(` whose statement neither names a chunk-ordered merge
/// helper nor is provably integer/ordering-insensitive gets flagged: the
/// result of an unordered float reduction depends on thread interleaving,
/// which breaks the N-thread ≡ 1-thread guarantee.
fn rule_d3_float_merge(f: &AnalyzedFile, findings: &mut Vec<Finding>) {
    if !f.rel.starts_with("src/") {
        return;
    }
    let toks = &f.toks;
    let threaded = (0..toks.len()).any(|i| {
        if f.in_test(toks[i].line) {
            return false;
        }
        (toks[i].text == "thread"
            && i + 2 < toks.len()
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "scope")
            || toks[i].text == "spawn"
    });
    if !threaded {
        return;
    }
    for i in 1..toks.len() {
        let t = &toks[i];
        if f.in_test(t.line) || t.kind != TokKind::Ident {
            continue;
        }
        if (t.text != "sum" && t.text != "fold") || toks[i - 1].text != "." {
            continue;
        }
        // statement span: back to the nearest `;` / `{` / `}`
        let mut s = i;
        while s > 0 && !matches!(toks[s - 1].text.as_str(), ";" | "{" | "}") {
            s -= 1;
        }
        let stmt = &toks[s..i];
        let blessed = stmt
            .iter()
            .any(|t| t.kind == TokKind::Ident && D3_BLESSED.contains(&t.text.as_str()));
        // integer turbofish: `.sum::<usize>()`
        let int_turbofish = i + 3 < toks.len()
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "<"
            && D3_INT_TYPES.contains(&toks[i + 3].text.as_str());
        if !blessed && !int_turbofish {
            findings.push(Finding::new(
                &f.rel,
                t.line,
                "D3",
                "unordered-float-merge",
                format!(
                    "bare `.{}` in threaded code — merge per-chunk partials in \
                     chunk-index order (distributed::collective::reduce_chunk_partials)",
                    t.text
                ),
            ));
        }
    }
}

/// U1 — `unsafe` without an adjacent `// SAFETY:` argument (within the
/// three lines above, or on the same line).
fn rule_u1_safety(f: &AnalyzedFile, findings: &mut Vec<Finding>) {
    for t in &f.toks {
        if t.kind != TokKind::Ident || t.text != "unsafe" || f.in_test(t.line) {
            continue;
        }
        let lo = t.line.saturating_sub(3);
        if !f.comment_in_range(lo, t.line, "SAFETY:") {
            findings.push(Finding::new(
                &f.rel,
                t.line,
                "U1",
                "missing-safety-comment",
                "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
            ));
        }
    }
}

/// R1 — registry three-tier coverage.
///
/// Statically cross-references every projection family registered in
/// `src/` (`add_family("name", ...)` / `register_family("name", ...)`)
/// against the three test tiers the ROADMAP's registry-conformance item
/// demands: the generic conformance suite (`tests/conformance.rs`, which
/// pins the required-family list), the slab `project_rows` parity tests
/// (`tests/backend_parity.rs`), and the cross-backend kernel conformance
/// matrix (`tests/kernel_matrix.rs`, DESIGN.md §12). Registering a
/// family without wiring all three becomes a build-time finding instead
/// of a silent coverage gap.
///
/// `test_files` maps rel path → analyzed contents; if a tier file is
/// absent the check is skipped and a note is returned instead (partial
/// trees, e.g. the CI injection probe).
pub fn check_registry(
    src_files: &[AnalyzedFile],
    test_files: &[AnalyzedFile],
) -> (Vec<Finding>, Vec<String>) {
    const TIERS: [&str; 3] =
        ["tests/conformance.rs", "tests/backend_parity.rs", "tests/kernel_matrix.rs"];
    let mut notes = Vec::new();
    let mut tiers: Vec<&AnalyzedFile> = Vec::new();
    for t in TIERS {
        match test_files.iter().find(|f| f.rel == t) {
            Some(f) => tiers.push(f),
            None => notes.push(format!("R1: {t} not found — registry coverage not checked")),
        }
    }
    let mut findings = Vec::new();
    for f in src_files {
        let toks = &f.toks;
        for i in 0..toks.len() {
            let t = &toks[i];
            if f.in_test(t.line) || t.kind != TokKind::Ident {
                continue;
            }
            if t.text != "add_family" && t.text != "register_family" {
                continue;
            }
            if i + 2 >= toks.len()
                || toks[i + 1].text != "("
                || toks[i + 2].kind != TokKind::Str
            {
                continue;
            }
            let family = toks[i + 2].text.clone();
            for tier in &tiers {
                if !mentions(tier, &family) {
                    findings.push(Finding::new(
                        &f.rel,
                        t.line,
                        "R1",
                        "registry-coverage",
                        format!(
                            "family `{family}` registered here is not referenced by \
                             {} — wire every tier file (conformance / slab parity / \
                             kernel matrix), see DESIGN.md \"Adding a constraint family\"",
                            tier.rel
                        ),
                    ));
                }
            }
        }
    }
    let waived: Vec<Finding> = src_files
        .iter()
        .map(|f| {
            let mine: Vec<Finding> =
                findings.iter().filter(|fi| fi.file == f.rel).cloned().collect();
            apply_waivers(f, mine)
        })
        .collect::<Vec<_>>()
        .into_iter()
        .flatten()
        // apply_waivers re-emits W0s per call; check_file already reported
        // those, so keep only R1 here
        .filter(|fi| fi.rule == "R1")
        .collect();
    (waived, notes)
}

/// Whether a test file mentions `name` — as an identifier token or inside
/// any string literal (spec strings like `"weighted_simplex:2:1,2"`).
fn mentions(f: &AnalyzedFile, name: &str) -> bool {
    f.toks.iter().any(|t| match t.kind {
        TokKind::Ident => t.text == name,
        TokKind::Str => t.text.contains(name),
        _ => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> Vec<Finding> {
        check_file(&AnalyzedFile::parse(rel, src))
    }

    #[test]
    fn d1_fires_on_container_and_iteration_in_critical_module() {
        let src = "use std::collections::HashMap;\n\
                   struct S { m: HashMap<u32, f32> }\n\
                   impl S { fn go(&self) { for (k, v) in self.m.iter() { let _ = (k, v); } } }\n";
        let fs = check("src/solver/x.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "D1").count(), 3, "{fs:?}");
        assert!(fs.iter().any(|f| f.message.contains("iteration over")));
        // same file outside a critical dir is clean
        assert!(check("src/util/x.rs", src).is_empty());
    }

    #[test]
    fn d1_waiver_with_justification_suppresses() {
        let src = "// audit:allow(unordered-iter): lookup-only artifact cache, never iterated\n\
                   struct S { m: HashMap<u32, f32> }\n";
        assert!(check("src/runtime/x.rs", src).is_empty());
        // empty justification → W0 and the D1 stays
        let bad = "// audit:allow(unordered-iter):\nstruct S { m: HashMap<u32, f32> }\n";
        let fs = check("src/runtime/x.rs", bad);
        assert!(fs.iter().any(|f| f.rule == "D1"));
        assert!(fs.iter().any(|f| f.rule == "W0"));
    }

    #[test]
    fn d1_binding_sees_through_path_prefixes() {
        let src = "pub struct C { entries: std::collections::HashMap<u64, f32> }\n\
                   impl C { fn all(&self) -> Vec<u64> { self.entries.keys().collect() } }\n";
        let fs = check("src/engine/x.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "D1").count(), 2, "{fs:?}");
        assert!(fs.iter().any(|f| f.message.contains("iteration over")));
    }

    #[test]
    fn d1_skips_test_modules() {
        let src = "pub fn ok() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn f() { let m: HashMap<u32, u32> = HashMap::new(); let _ = m.iter(); }\n}\n";
        assert!(check("src/solver/x.rs", src).is_empty());
    }

    #[test]
    fn d2_flags_ambient_clocks_everywhere_but_timer() {
        let src = "fn f() -> std::time::Instant { std::time::Instant::now() }\n\
                   fn g() { let _ = std::time::SystemTime::UNIX_EPOCH; }\n";
        let fs = check("src/engine/x.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "D2").count(), 2, "{fs:?}");
        assert!(check("src/util/timer.rs", src).is_empty());
        // benches are walked too
        assert!(!check("benches/bench_x.rs", src).is_empty());
        // type-position Instant without ::now is fine
        assert!(check("src/engine/y.rs", "struct T { at: Instant }").is_empty());
    }

    #[test]
    fn d3_flags_bare_sum_in_threaded_file_only() {
        let body = "fn eval(xs: &[f32]) -> f32 {\n\
                    let parts: Vec<f32> = vec![];\n\
                    std::thread::scope(|s| { s.spawn(|| {}); });\n\
                    parts.iter().sum()\n}\n";
        let fs = check("src/backend/x.rs", body);
        assert_eq!(fs.iter().filter(|f| f.rule == "D3").count(), 1, "{fs:?}");
        // same accumulation without threads in the file: not flagged
        let seq = "fn eval(xs: &[f32]) -> f32 { xs.iter().sum() }\n";
        assert!(check("src/backend/y.rs", seq).is_empty());
    }

    #[test]
    fn d3_blesses_integer_sums_and_ordered_merges() {
        let src = "fn f(by_rank: &[Vec<u32>]) -> usize {\n\
                   std::thread::scope(|s| { s.spawn(|| {}); });\n\
                   let segments: usize = by_rank.iter().map(|p| p.len()).sum();\n\
                   let n = by_rank.iter().map(|p| p.iter().count()).sum::<usize>();\n\
                   segments + n\n}\n\
                   fn g(parts: &[Vec<f32>]) -> f32 {\n\
                   std::thread::scope(|s| { s.spawn(|| {}); });\n\
                   let (ax, cx, xsq) = reduce_chunk_partials(parts, 4); ax[0] + cx + xsq\n}\n";
        assert!(check("src/backend/z.rs", src).is_empty());
    }

    #[test]
    fn u1_requires_adjacent_safety_comment() {
        let bad = "pub fn t() { unsafe { libc::getpid(); } }\n";
        let fs = check("src/util/x.rs", bad);
        assert_eq!(fs.iter().filter(|f| f.rule == "U1").count(), 1);
        let good = "pub fn t() {\n    // SAFETY: libc::getpid has no preconditions\n    unsafe { libc::getpid(); }\n}\n";
        assert!(check("src/util/x.rs", good).is_empty());
    }

    #[test]
    fn w0_flags_unknown_slug() {
        let src = "// audit:allow(made-up-rule): because\npub fn f() {}\n";
        let fs = check("src/util/x.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "W0");
        assert!(fs[0].message.contains("made-up-rule"));
    }

    #[test]
    fn panic_counts_exclude_tests_and_count_indexing() {
        let src = "pub fn f(v: &[f32], m: &B) -> f32 {\n\
                   let a = v[0];\n\
                   let b = m.get().unwrap();\n\
                   let c = m.get().expect(\"x\");\n\
                   if v.is_empty() { panic!(\"boom\"); }\n\
                   a + b + c\n}\n\
                   #[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { let v = vec![1]; let _ = v[0] + Some(1).unwrap(); }\n}\n";
        let c = panic_counts(&AnalyzedFile::parse("src/solver/x.rs", src));
        assert_eq!((c.unwrap, c.expect, c.panics, c.index), (1, 1, 1, 1));
    }

    #[test]
    fn panic_counter_ignores_fields_named_unwrap() {
        let src = "pub struct C { pub unwrap: usize, pub expect: usize }\n\
                   pub fn f(c: &mut C) { c.unwrap += 1; let _ = c.expect; }\n";
        let c = panic_counts(&AnalyzedFile::parse("src/solver/x.rs", src));
        assert_eq!((c.unwrap, c.expect), (0, 0));
    }

    #[test]
    fn index_counting_skips_attributes_types_and_slice_patterns() {
        let src = "#[derive(Clone)]\npub struct S { v: [f32; 4] }\n\
                   pub fn f(s: &S, i: usize) -> f32 { s.v[i] }\n";
        let c = panic_counts(&AnalyzedFile::parse("src/solver/x.rs", src));
        assert_eq!(c.index, 1);
    }

    #[test]
    fn registry_coverage_cross_references_tiers() {
        let reg = AnalyzedFile::parse(
            "src/projection/registry.rs",
            "fn b(r: &mut R) { r.add_family(\"simplex\", S, p); r.add_family(\"ghost\", G, p); }\n",
        );
        let conf = AnalyzedFile::parse(
            "tests/conformance.rs",
            "fn t() { for f in [\"simplex\"] { check(f); } }\n",
        );
        let par = AnalyzedFile::parse(
            "tests/backend_parity.rs",
            "fn t() { let _ = parse(\"simplex\"); }\n",
        );
        let matrix = AnalyzedFile::parse(
            "tests/kernel_matrix.rs",
            "fn t() { for (s, k) in kinds(\"simplex\") { tier(s, k); } }\n",
        );
        let (fs, notes) = check_registry(&[reg], &[conf, par, matrix]);
        assert!(notes.is_empty());
        assert_eq!(fs.len(), 3, "{fs:?}"); // ghost missing from all three tiers
        assert!(fs.iter().all(|f| f.rule == "R1" && f.message.contains("ghost")));
        // missing tier file → note, not finding
        let reg2 = AnalyzedFile::parse(
            "src/projection/registry.rs",
            "fn b(r: &mut R) { r.add_family(\"simplex\", S, p); }\n",
        );
        let (fs2, notes2) = check_registry(&[reg2], &[]);
        assert!(fs2.is_empty());
        assert_eq!(notes2.len(), 3);
    }
}
