//! Fixture self-check: the auditor audits itself (DESIGN.md §10).
//!
//! `analysis/fixtures/` holds one known-bad snippet per rule. Each
//! fixture declares, in comments the rules never read as code:
//!
//! * `// audit:path(src/solver/fixture.rs)` — the *virtual* path the
//!   snippet is analyzed under (rule scoping is path-sensitive);
//! * `// audit:expect(D1)` — one line per expected finding (repeat for
//!   multiple; a fixture with no expect lines asserts zero findings).
//!
//! The self-check fails when the fired rule codes differ from the
//! expected multiset in either direction — so a rule that silently stops
//! firing (the classic way a hand-rolled analyzer rots) breaks CI just
//! as loudly as a rule that over-fires.

use std::path::Path;

use super::rules::{check_file, check_registry, AnalyzedFile};
use super::taint::check_graph;
use super::walk::{read_to_string, rs_files};

/// Outcome of one fixture.
#[derive(Debug)]
pub struct FixtureResult {
    pub fixture: String,
    pub expected: Vec<String>,
    pub fired: Vec<String>,
}

impl FixtureResult {
    pub fn pass(&self) -> bool {
        self.expected == self.fired
    }
}

/// Parse directives and run the rules over one fixture source.
/// `test_files` provides the R1 tier files (pass the real `tests/` set).
pub fn run_fixture(
    name: &str,
    src: &str,
    test_files: &[AnalyzedFile],
) -> Result<FixtureResult, String> {
    let mut vpath: Option<String> = None;
    let mut expected: Vec<String> = Vec::new();
    for line in src.lines() {
        let line = line.trim();
        if let Some(rest) = line.strip_prefix("// audit:path(") {
            vpath = Some(
                rest.strip_suffix(')')
                    .ok_or_else(|| format!("{name}: unterminated audit:path"))?
                    .to_string(),
            );
        }
        if let Some(rest) = line.strip_prefix("// audit:expect(") {
            expected.push(
                rest.strip_suffix(')')
                    .ok_or_else(|| format!("{name}: unterminated audit:expect"))?
                    .to_string(),
            );
        }
    }
    let vpath = vpath.ok_or_else(|| format!("{name}: missing audit:path directive"))?;
    let f = AnalyzedFile::parse(&vpath, src);
    let mut fired: Vec<String> =
        check_file(&f).into_iter().map(|fi| fi.rule.to_string()).collect();
    let (r1, _notes) = check_registry(std::slice::from_ref(&f), test_files);
    fired.extend(r1.into_iter().map(|fi| fi.rule.to_string()));
    // graph rules over the single-file "crate": P2/D4 fire as findings;
    // A1 fires once per module.alloc count (fixtures carry no ratchet,
    // so any count > 0 is the "no checked-in budget" case)
    let gr = check_graph(std::slice::from_ref(&f));
    fired.extend(gr.findings.into_iter().map(|fi| fi.rule.to_string()));
    fired.extend(gr.alloc_counts.values().filter(|&&c| c > 0).map(|_| "A1".to_string()));
    fired.sort();
    expected.sort();
    Ok(FixtureResult { fixture: name.to_string(), expected, fired })
}

/// Run every fixture under `fixtures_dir`; `tests_dir` supplies the R1
/// tier files. Returns per-fixture results; errors are malformed
/// fixtures or an empty/missing fixtures directory (the self-check
/// existing but checking nothing must itself be a failure).
pub fn run_fixtures(
    fixtures_dir: &Path,
    tests_dir: &Path,
) -> Result<Vec<FixtureResult>, String> {
    let files = rs_files(fixtures_dir)?;
    if files.is_empty() {
        return Err(format!(
            "no fixtures found under {} — the self-check would assert nothing",
            fixtures_dir.display()
        ));
    }
    let test_files: Vec<AnalyzedFile> = rs_files(tests_dir)
        .unwrap_or_default()
        .into_iter()
        .map(|p| {
            let rel = format!(
                "tests/{}",
                p.file_name().map(|s| s.to_string_lossy().to_string()).unwrap_or_default()
            );
            read_to_string(&p).map(|src| AnalyzedFile::parse(&rel, &src))
        })
        .collect::<Result<_, _>>()?;
    let mut out = Vec::new();
    for p in files {
        let name = p
            .file_name()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| p.display().to_string());
        let src = read_to_string(&p)?;
        out.push(run_fixture(&name, &src, &test_files)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_fires_expected_rule() {
        let src = "// audit:path(src/solver/fixture.rs)\n\
                   // audit:expect(D1)\n\
                   pub struct S { m: std::collections::HashMap<u32, u32> }\n";
        let r = run_fixture("d1.rs", src, &[]).unwrap();
        assert!(r.pass(), "{r:?}");
        assert_eq!(r.fired, vec!["D1"]);
    }

    #[test]
    fn over_and_under_firing_both_fail() {
        // expects D1 but the snippet is clean → under-fire
        let clean = "// audit:path(src/solver/fixture.rs)\n\
                     // audit:expect(D1)\n\
                     pub fn ok() {}\n";
        assert!(!run_fixture("c.rs", clean, &[]).unwrap().pass());
        // expects nothing but the snippet is dirty → over-fire
        let dirty = "// audit:path(src/solver/fixture.rs)\n\
                     pub struct S { m: std::collections::HashMap<u32, u32> }\n";
        assert!(!run_fixture("d.rs", dirty, &[]).unwrap().pass());
    }

    #[test]
    fn missing_path_directive_is_malformed() {
        assert!(run_fixture("x.rs", "// audit:expect(D1)\n", &[]).is_err());
    }

    #[test]
    fn graph_rules_fire_in_fixtures() {
        let src = "// audit:path(src/serve/fixture.rs)\n\
                   // audit:expect(P2)\n\
                   pub struct ServeDaemon;\n\
                   impl ServeDaemon { pub fn submit(&self) { helper(); } }\n\
                   fn helper() { Some(1).unwrap(); }\n";
        let r = run_fixture("p2.rs", src, &[]).unwrap();
        assert!(r.pass(), "{r:?}");
        assert_eq!(r.fired, vec!["P2"]);
    }
}
