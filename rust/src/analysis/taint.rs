//! Cross-file reachability rules on the call graph (DESIGN.md §10):
//!
//! * **P2 `panic-reachable`** — no `unwrap`/`expect`/panic-family macro
//!   (and, inside `src/serve/`, no unchecked index) in any fn
//!   transitively reachable from a `ServeDaemon` request entry point or
//!   `SolveDriver::step`. Findings are path-sensitive: each prints the
//!   full call chain `entry -> ... -> panicking fn`. Panic classes are
//!   scoped to the modules the entry points own (`serve`, `solver`,
//!   `backend`) — the graph's name-fallback resolution reaches utility
//!   modules whose panic budget P1 already ratchets, and double-charging
//!   them path-sensitively would drown the serve-path signal.
//! * **D4 `determinism-taint`** — a fn in `solver`/`backend`/`sparse`/
//!   `distributed` that accumulates f32/f64 values may not (transitively)
//!   call a fn that iterates an unordered hash container: the iteration
//!   order would leak into the float sum. Intra-fn cases are D1's job;
//!   D4 exists for the cross-fn flows D1 cannot see.
//! * **A1 `hot-loop-alloc`** — `Vec::new`/`vec![..]`/`.collect(..)`/
//!   `Box::new` are forbidden in fns reachable from the per-iteration
//!   hot paths `eval_chunk_partials`/`project_rows`. Ratcheted like P1
//!   (per-module `module.alloc` budgets in `analysis/ratchet.toml`)
//!   rather than zero-tolerance, so deliberate one-time setup that the
//!   cone over-approximates into can be budgeted without waivers.
//!   `Vec::with_capacity`/`to_vec` are deliberately *not* forbidden:
//!   sized one-shot buffers are how scratch gets hoisted.
//!
//! P2/D4 findings honor `audit:allow(panic-reachable)` /
//! `audit:allow(determinism-taint)` waivers at the *site* file; A1 is
//! count-ratcheted and unwaivable, like P1.

use std::collections::BTreeMap;

use super::graph::{callable_at, CallGraph};
use super::lexer::TokKind;
use super::report::Finding;
use super::rules::{is_keyword, AnalyzedFile};

/// Request entry points: `(receiver, method)` pairs.
pub const P2_ENTRIES: &[(&str, &str)] = &[
    ("ServeDaemon", "submit"),
    ("ServeDaemon", "drain"),
    ("ServeDaemon", "drain_budget"),
    ("ServeDaemon", "run_stream"),
    ("SolveDriver", "step"),
];

/// Modules whose panic sites P2 charges path-sensitively.
pub const P2_MODULES: &[&str] = &["serve", "solver", "backend"];

/// Hot-path roots: every fn of this *name* seeds the A1 cone.
pub const A1_ROOTS: &[&str] = &["eval_chunk_partials", "project_rows"];

/// Modules where float accumulation makes a fn a D4 sink.
pub const D4_SINK_MODULES: &[&str] = &["solver", "backend", "sparse", "distributed"];

/// Result of the graph pass: path-sensitive findings plus the A1
/// ratchet inputs.
pub struct GraphRules {
    pub findings: Vec<Finding>,
    /// `module.alloc` → count of forbidden allocation sites in the cone.
    pub alloc_counts: BTreeMap<String, usize>,
    /// `module.alloc` → human-readable site list (for ratchet-failure
    /// messages).
    pub alloc_sites: BTreeMap<String, Vec<String>>,
    pub notes: Vec<String>,
}

/// Run P2/D4/A1 over `files` (the `src/` tree).
pub fn check_graph(files: &[AnalyzedFile]) -> GraphRules {
    let graph = CallGraph::build(files);
    let by_rel: BTreeMap<&str, &AnalyzedFile> =
        files.iter().map(|f| (f.rel.as_str(), f)).collect();

    let mut findings = Vec::new();
    rule_p2_panic_reachable(&graph, &by_rel, &mut findings);
    rule_d4_determinism_taint(&graph, &by_rel, &mut findings);
    let (alloc_counts, alloc_sites) = rule_a1_hot_loop_alloc(&graph, &by_rel);

    let findings = waive(&by_rel, findings);
    let edge_count: usize = graph.edges.iter().map(Vec::len).sum();
    let notes = vec![format!(
        "call graph: {} fns, {} edges, {} unresolved call name(s)",
        graph.fns.len(),
        edge_count,
        graph.unresolved.len()
    )];
    GraphRules { findings, alloc_counts, alloc_sites, notes }
}

/// Token spans of fns nested inside `fns[id]`'s body (their sites and
/// calls belong to the nested item, which is its own graph node).
fn nested_spans(graph: &CallGraph, id: usize) -> Vec<(usize, usize)> {
    let item = &graph.fns[id];
    graph
        .fns
        .iter()
        .filter(|o| o.file == item.file && o.sig.0 > item.body.0 && o.body.1 <= item.body.1)
        .map(|o| (o.sig.0, o.body.1 + 1))
        .collect()
}

/// P2 — panic sites reachable from serve/solve entry points.
fn rule_p2_panic_reachable(
    graph: &CallGraph,
    by_rel: &BTreeMap<&str, &AnalyzedFile>,
    findings: &mut Vec<Finding>,
) {
    let mut entries: Vec<usize> = Vec::new();
    for (recv, name) in P2_ENTRIES {
        entries.extend(graph.find(Some(recv), name));
    }
    if entries.is_empty() {
        return;
    }
    let parents = graph.reach_forward(&entries);
    for (&id, _) in &parents {
        let item = &graph.fns[id];
        let allow_panics = P2_MODULES.contains(&item.module.as_str());
        let allow_index = item.file.starts_with("src/serve/");
        if !allow_panics && !allow_index {
            continue;
        }
        let Some(file) = by_rel.get(item.file.as_str()) else { continue };
        let skip = nested_spans(graph, id);
        let chain = graph.chain(id, &parents);
        for (line, what) in
            panic_sites(file, item.body.0, item.body.1, &skip, allow_panics, allow_index)
        {
            findings.push(Finding::new(
                &item.file,
                line,
                "P2",
                "panic-reachable",
                format!(
                    "`{what}` is reachable from a request entry point: {chain} — \
                     convert to a typed error or shed the outcome"
                ),
            ));
        }
    }
}

/// Panic-capable sites in `toks[lo..hi]`, as `(line, description)`.
fn panic_sites(
    f: &AnalyzedFile,
    lo: usize,
    hi: usize,
    skip: &[(usize, usize)],
    panics: bool,
    index: bool,
) -> Vec<(u32, String)> {
    let toks = &f.toks;
    let mut out = Vec::new();
    let mut i = lo;
    while i < hi && i < toks.len() {
        if let Some(&(_, end)) = skip.iter().find(|&&(a, b)| a <= i && i < b) {
            i = end;
            continue;
        }
        let t = &toks[i];
        match t.text.as_str() {
            "unwrap" | "expect"
                if panics
                    && i > 0
                    && toks[i - 1].text == "."
                    && i + 1 < toks.len()
                    && toks[i + 1].text == "(" =>
            {
                out.push((t.line, format!(".{}()", t.text)));
            }
            "panic" | "unreachable" | "todo" | "unimplemented"
                if panics
                    && t.kind == TokKind::Ident
                    && i + 1 < toks.len()
                    && toks[i + 1].text == "!" =>
            {
                out.push((t.line, format!("{}!", t.text)));
            }
            "[" if index && i > lo => {
                let p = &toks[i - 1];
                let indexes = p.kind == TokKind::Ident && !is_keyword(&p.text)
                    || p.text == ")"
                    || p.text == "]";
                if indexes {
                    out.push((t.line, "unchecked index".to_string()));
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// D4 — float accumulation downstream of hash-container iteration.
fn rule_d4_determinism_taint(
    graph: &CallGraph,
    by_rel: &BTreeMap<&str, &AnalyzedFile>,
    findings: &mut Vec<Finding>,
) {
    let sources: Vec<usize> = (0..graph.fns.len())
        .filter(|&id| {
            let item = &graph.fns[id];
            if item.in_test {
                return false;
            }
            by_rel
                .get(item.file.as_str())
                .is_some_and(|f| iterates_hash_container(f, item.body.0, item.body.1))
        })
        .collect();
    if sources.is_empty() {
        return;
    }
    // reverse reachability: which fns (transitively) call a source?
    let parents = graph.reach_reverse(&sources);
    for (&id, parent) in &parents {
        if parent.is_none() {
            continue; // the source itself — intra-fn flows are D1's job
        }
        let item = &graph.fns[id];
        if !D4_SINK_MODULES.contains(&item.module.as_str()) {
            continue;
        }
        let Some(file) = by_rel.get(item.file.as_str()) else { continue };
        if !accumulates_floats(file, item) {
            continue;
        }
        // walk toward the source: parents point one call deeper
        let mut path = vec![id];
        let mut cur = id;
        while let Some(Some(p)) = parents.get(&cur) {
            path.push(*p);
            cur = *p;
        }
        let chain: Vec<String> = path.iter().map(|&n| graph.fns[n].display()).collect();
        findings.push(Finding::new(
            &item.file,
            item.line,
            "D4",
            "determinism-taint",
            format!(
                "float accumulation in `{}` consumes values from unordered-container \
                 iteration: {} — sort the keys at the source or accumulate in a \
                 fixed order",
                item.display(),
                chain.join(" -> ")
            ),
        ));
    }
}

/// Does `toks[lo..hi]` iterate a hash container? Mirrors D1's binding
/// logic (seeing through path prefixes plus `&`/`mut`/lifetimes): an
/// iteration method on an identifier bound to a `HashMap`/`HashSet`
/// anywhere in the file, or a `for .. in` loop over one.
fn iterates_hash_container(f: &AnalyzedFile, lo: usize, hi: usize) -> bool {
    let hash_names = ["HashMap", "HashSet"];
    let iter_methods =
        ["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain"];
    let toks = &f.toks;
    // file-wide bound set: `name: HashMap<..>`, `name: &HashMap<..>`,
    // `name = HashMap::new()`, with path prefixes seen through
    let mut bound: Vec<&str> = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident || !hash_names.contains(&toks[i].text.as_str()) {
            continue;
        }
        let mut p = i;
        while p >= 2 && toks[p - 1].text == "::" && toks[p - 2].kind == TokKind::Ident {
            p -= 2;
        }
        while p >= 1
            && (toks[p - 1].text == "&"
                || toks[p - 1].text == "mut"
                || toks[p - 1].kind == TokKind::Lifetime)
        {
            p -= 1;
        }
        if p >= 2
            && (toks[p - 1].text == ":" || toks[p - 1].text == "=")
            && toks[p - 2].kind == TokKind::Ident
            && !is_keyword(&toks[p - 2].text)
        {
            bound.push(toks[p - 2].text.as_str());
        }
    }
    if bound.is_empty() {
        return false;
    }
    for i in lo..hi.min(toks.len()) {
        let t = &toks[i];
        if t.kind != TokKind::Ident || !bound.contains(&t.text.as_str()) {
            continue;
        }
        // `m.iter()` / `m.keys()` / ...
        if i + 2 < toks.len()
            && toks[i + 1].text == "."
            && iter_methods.contains(&toks[i + 2].text.as_str())
        {
            return true;
        }
        // `for (k, v) in &mut m { .. }`
        let mut p = i;
        while p >= 1 && (toks[p - 1].text == "&" || toks[p - 1].text == "mut") {
            p -= 1;
        }
        if p >= 1 && toks[p - 1].text == "in" {
            return true;
        }
    }
    false
}

/// Does the fn accumulate f32/f64? Requires both a float type mention in
/// the item's tokens and an accumulation shape (`.sum(`/`.fold(` or a
/// `+=` compound assignment).
fn accumulates_floats(f: &AnalyzedFile, item: &super::items::FnItem) -> bool {
    let toks = &f.toks;
    let (lo, hi) = (item.sig.0, item.body.1.min(toks.len()));
    let mut float = false;
    let mut accum = false;
    for i in lo..hi {
        let t = &toks[i];
        match t.kind {
            TokKind::Ident if t.text == "f32" || t.text == "f64" => float = true,
            TokKind::Num if t.text.ends_with("f32") || t.text.ends_with("f64") => float = true,
            _ => {}
        }
        match t.text.as_str() {
            "sum" | "fold" | "product"
                if i > 0 && toks[i - 1].text == "." && callable_at(toks, i) =>
            {
                accum = true;
            }
            "+" if i + 1 < hi && toks[i + 1].text == "=" => accum = true,
            _ => {}
        }
    }
    float && accum
}

/// A1 — allocation sites in the hot-path cone, counted per module.
fn rule_a1_hot_loop_alloc(
    graph: &CallGraph,
    by_rel: &BTreeMap<&str, &AnalyzedFile>,
) -> (BTreeMap<String, usize>, BTreeMap<String, Vec<String>>) {
    let mut roots: Vec<usize> = Vec::new();
    for name in A1_ROOTS {
        roots.extend(graph.find(None, name));
    }
    let parents = graph.reach_forward(&roots);
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    let mut sites: BTreeMap<String, Vec<String>> = BTreeMap::new();
    for (&id, _) in &parents {
        let item = &graph.fns[id];
        let Some(file) = by_rel.get(item.file.as_str()) else { continue };
        let skip = nested_spans(graph, id);
        // attribute to the root this BFS reached the fn from
        let mut cur = id;
        while let Some(Some(p)) = parents.get(&cur) {
            cur = *p;
        }
        let root = graph.fns[cur].name.clone();
        let key = format!("{}.alloc", item.module);
        for (line, what) in alloc_sites_in(file, item.body.0, item.body.1, &skip) {
            *counts.entry(key.clone()).or_insert(0) += 1;
            sites.entry(key.clone()).or_default().push(format!(
                "{}:{} `{what}` in `{}` (reachable from {root})",
                item.file,
                line,
                item.display()
            ));
        }
    }
    (counts, sites)
}

/// Forbidden allocation sites in `toks[lo..hi]`, as `(line, description)`.
fn alloc_sites_in(
    f: &AnalyzedFile,
    lo: usize,
    hi: usize,
    skip: &[(usize, usize)],
) -> Vec<(u32, String)> {
    let toks = &f.toks;
    let mut out = Vec::new();
    let mut i = lo;
    while i < hi && i < toks.len() {
        if let Some(&(_, end)) = skip.iter().find(|&&(a, b)| a <= i && i < b) {
            i = end;
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        match t.text.as_str() {
            "new" if i >= 2
                && toks[i - 1].text == "::"
                && (toks[i - 2].text == "Vec" || toks[i - 2].text == "Box")
                && callable_at(toks, i) =>
            {
                out.push((t.line, format!("{}::new", toks[i - 2].text)));
            }
            "vec" if i + 1 < toks.len() && toks[i + 1].text == "!" => {
                out.push((t.line, "vec!".to_string()));
            }
            "collect" if i > 0 && toks[i - 1].text == "." && callable_at(toks, i) => {
                out.push((t.line, ".collect(..)".to_string()));
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// Drop P2/D4 findings covered by a valid waiver in the site file (same
/// line or line above, matching slug, non-empty justification). W0 for
/// malformed waivers is `check_file`'s job — not duplicated here.
fn waive(
    by_rel: &BTreeMap<&str, &AnalyzedFile>,
    findings: Vec<Finding>,
) -> Vec<Finding> {
    findings
        .into_iter()
        .filter(|fi| {
            let Some(f) = by_rel.get(fi.file.as_str()) else { return true };
            !f.waivers().iter().any(|w| {
                w.slug == fi.slug
                    && !w.justification.is_empty()
                    && (w.line == fi.line || w.line + 1 == fi.line)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(files: &[(&str, &str)]) -> GraphRules {
        let parsed: Vec<AnalyzedFile> =
            files.iter().map(|(rel, src)| AnalyzedFile::parse(rel, src)).collect();
        check_graph(&parsed)
    }

    #[test]
    fn p2_fires_through_two_hops_with_the_full_chain() {
        let g = run(&[(
            "src/serve/daemon.rs",
            "pub struct ServeDaemon;\n\
             impl ServeDaemon { pub fn submit(&self) { route(); } }\n\
             fn route() { admit(); }\n\
             fn admit() { let v: Option<u32> = None; v.unwrap(); }\n",
        )]);
        let p2: Vec<_> = g.findings.iter().filter(|f| f.rule == "P2").collect();
        assert_eq!(p2.len(), 1, "{:?}", g.findings);
        assert_eq!(p2[0].line, 4);
        assert!(
            p2[0].message.contains("ServeDaemon::submit -> route -> admit"),
            "chain missing: {}",
            p2[0].message
        );
    }

    #[test]
    fn p2_ignores_unreached_fns_and_out_of_scope_modules() {
        let g = run(&[
            (
                "src/serve/daemon.rs",
                "pub struct ServeDaemon;\n\
                 impl ServeDaemon { pub fn submit(&self) { crate::util::helper(); } }\n\
                 fn orphan() { panic!(\"never reached\"); }\n",
            ),
            // util is outside P2_MODULES: its panics stay P1's business
            ("src/util/x.rs", "pub fn helper() { Some(1).unwrap(); }\n"),
        ]);
        assert!(
            g.findings.iter().all(|f| f.rule != "P2"),
            "{:?}",
            g.findings
        );
    }

    #[test]
    fn p2_unchecked_index_only_counts_inside_serve() {
        let g = run(&[
            (
                "src/serve/daemon.rs",
                "pub struct ServeDaemon;\n\
                 impl ServeDaemon { pub fn drain(&self, xs: &[u32]) -> u32 { pick(xs) } }\n\
                 fn pick(xs: &[u32]) -> u32 { xs[0] }\n",
            ),
            (
                "src/solver/d.rs",
                "pub struct SolveDriver;\n\
                 impl SolveDriver { pub fn step(&self, xs: &[u32]) -> u32 { xs[0] } }\n",
            ),
        ]);
        let p2: Vec<_> = g.findings.iter().filter(|f| f.rule == "P2").collect();
        assert_eq!(p2.len(), 1, "{:?}", g.findings);
        assert_eq!(p2[0].file, "src/serve/daemon.rs");
        assert!(p2[0].message.contains("unchecked index"));
    }

    #[test]
    fn p2_waivable_at_the_site() {
        let g = run(&[(
            "src/serve/daemon.rs",
            "pub struct ServeDaemon;\n\
             impl ServeDaemon { pub fn submit(&self) {\n\
                 // audit:allow(panic-reachable): queue invariant, len checked above\n\
                 Some(1).unwrap();\n\
             } }\n",
        )]);
        assert!(g.findings.iter().all(|f| f.rule != "P2"), "{:?}", g.findings);
    }

    #[test]
    fn d4_fires_across_fn_boundaries_but_not_within_one_fn() {
        let g = run(&[(
            "src/backend/x.rs",
            "use std::collections::HashMap;\n\
             pub fn weights(m: &HashMap<u32, f32>) -> Vec<f32> {\n\
                 m.values().copied().collect()\n\
             }\n\
             pub fn total(m: &HashMap<u32, f32>) -> f32 {\n\
                 let mut s = 0.0f32;\n\
                 for w in weights(m) { s += w; }\n\
                 s\n\
             }\n",
        )]);
        let d4: Vec<_> = g.findings.iter().filter(|f| f.rule == "D4").collect();
        assert_eq!(d4.len(), 1, "{:?}", g.findings);
        assert!(d4[0].message.contains("total -> weights"), "{}", d4[0].message);
        // the source itself must NOT get a D4 (intra-fn is D1's job)
        assert!(!d4.iter().any(|f| f.message.starts_with("float accumulation in `weights`")));
    }

    #[test]
    fn d4_requires_a_sink_module() {
        let g = run(&[(
            "src/cli/x.rs",
            "use std::collections::HashMap;\n\
             fn keys(m: &HashMap<u32, u32>) -> Vec<u32> { m.keys().copied().collect() }\n\
             pub fn show(m: &HashMap<u32, u32>) -> f64 {\n\
                 let mut s = 0.0f64; for k in keys(m) { s += k as f64; } s\n\
             }\n",
        )]);
        assert!(g.findings.iter().all(|f| f.rule != "D4"), "{:?}", g.findings);
    }

    #[test]
    fn a1_counts_allocations_in_the_cone_only() {
        let g = run(&[(
            "src/backend/x.rs",
            "pub fn eval_chunk_partials(n: usize) -> f32 { helper(n) }\n\
             fn helper(n: usize) -> f32 { let v = vec![0.0f32; n]; v.iter().sum() }\n\
             pub fn cold(n: usize) -> Vec<f32> { Vec::new() }\n",
        )]);
        assert_eq!(g.alloc_counts.get("backend.alloc"), Some(&1), "{:?}", g.alloc_counts);
        let sites = &g.alloc_sites["backend.alloc"];
        assert_eq!(sites.len(), 1);
        assert!(sites[0].contains("`vec!` in `helper` (reachable from eval_chunk_partials)"));
    }

    #[test]
    fn a1_spares_with_capacity_and_to_vec() {
        let g = run(&[(
            "src/projection/x.rs",
            "pub fn project_rows(n: usize) -> Vec<f32> {\n\
                 let mut v = Vec::with_capacity(n);\n\
                 v.extend([0.0f32; 4].to_vec());\n\
                 v\n\
             }\n",
        )]);
        assert!(g.alloc_counts.is_empty(), "{:?}", g.alloc_counts);
    }
}
