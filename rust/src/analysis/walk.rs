//! Deterministic source-tree walking for the audit pass.
//!
//! `read_dir` order is filesystem-dependent; the auditor sorts every
//! directory listing so findings, counts, and JSON output are byte-stable
//! across machines — the same requirement the rest of the repo puts on
//! its own outputs.

use std::fs;
use std::path::{Path, PathBuf};

/// All `.rs` files under `dir`, recursively, sorted by path. A missing
/// directory is an empty list (partial trees are legal audit roots); an
/// unreadable one is an error.
pub fn rs_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    if !dir.exists() {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    collect(dir, &mut out)?;
    out.sort();
    Ok(out)
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Read a file to string with a path-carrying error.
pub fn read_to_string(path: &Path) -> Result<String, String> {
    fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))
}

/// Render `path` relative to `root` with `/` separators (finding paths
/// must be platform-stable).
pub fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_empty_not_error() {
        assert!(rs_files(Path::new("/no/such/dir/exists")).unwrap().is_empty());
    }

    #[test]
    fn walk_is_sorted_and_recursive() {
        let dir = std::env::temp_dir().join("dualip_audit_walk_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("b")).unwrap();
        fs::write(dir.join("z.rs"), "").unwrap();
        fs::write(dir.join("a.rs"), "").unwrap();
        fs::write(dir.join("b/m.rs"), "").unwrap();
        fs::write(dir.join("b/skip.txt"), "").unwrap();
        let files = rs_files(&dir).unwrap();
        let rels: Vec<String> = files.iter().map(|p| rel_path(&dir, p)).collect();
        assert_eq!(rels, vec!["a.rs", "b/m.rs", "z.rs"]);
        let _ = fs::remove_dir_all(&dir);
    }
}
