//! CPU execution backends for the `ObjectiveFunction` contract.
//!
//! The contract (paper Table 1) is backend-agnostic; this module names the
//! CPU choices and owns the default:
//!
//! | backend        | layout                         | role                                  |
//! |----------------|--------------------------------|---------------------------------------|
//! | `slab`         | §6 bucketed padded slabs (SoA) | default serving hot path              |
//! | `sharded-slab` | same slabs, chunk-sharded      | §6 multi-device execution, in-process |
//! | `reference`    | per-source tuple vectors       | the §7 Scala comparator               |
//!
//! (The PJRT/HLO path in `runtime/` is a fourth, artifact-gated backend
//! and is selected separately.) `CpuBackend::objective_with` resolves a
//! choice plus a shard count into a concrete objective; `slab` with
//! `shards > 1` promotes to `sharded-slab`, whose results are
//! **bit-identical** to single-shard slab at any shard count (see
//! [`sharded`]). Both slab flavors fall back to `reference` when the slab
//! layout is unbuildable for an instance, and the fallback is observable
//! through `ObjectiveFunction::name`. [`TimedObjective`] wraps any backend
//! to attribute solve wall-clock to objective evaluation — the engine uses
//! it to report per-job eval time.

pub mod sharded;
pub mod slab_cpu;

pub use sharded::ShardedSlabObjective;
pub use slab_cpu::{ChunkPartial, SlabCpuObjective};

use std::collections::BTreeSet;

use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::projection::BlockProjection;
use crate::reference::CpuObjective;
use crate::util::timer::Stopwatch;

/// Which slab-kernel tier each projection family actually ran: families
/// whose buckets dispatched a batched `project_rows` override vs families
/// that fell back to the scalar row-by-row default. Surfaced through
/// `engine_report` / `shard_report` (DESIGN.md §12) so a registered
/// family quietly running the slow path is visible, not silent.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KernelTiers {
    /// Families running the hand-vectorized batched kernel.
    pub batched: BTreeSet<String>,
    /// Families on the scalar `project`-per-row fallback.
    pub scalar: BTreeSet<String>,
}

impl KernelTiers {
    /// Classify one resolved bucket operator into its tier set.
    pub fn record(&mut self, op: &dyn BlockProjection) {
        let set = if op.batched_project_rows() { &mut self.batched } else { &mut self.scalar };
        set.insert(op.family().to_string());
    }

    /// Tier map over every distinct projection kind an instance uses —
    /// what a slab backend built for `lp` would report, computable
    /// without the backend (used by the distributed CLI report path).
    pub fn of_lp(lp: &MatchingLp) -> KernelTiers {
        let mut kinds = BTreeSet::new();
        for i in 0..lp.num_sources() {
            kinds.insert(lp.projection.kind_of(i));
        }
        let mut tiers = KernelTiers::default();
        for k in kinds {
            tiers.record(k.op().as_ref());
        }
        tiers
    }

    pub fn is_empty(&self) -> bool {
        self.batched.is_empty() && self.scalar.is_empty()
    }

    /// Compact report fragment: `batched[a b] scalar[c]`.
    pub fn summary(&self) -> String {
        let join = |s: &BTreeSet<String>| s.iter().cloned().collect::<Vec<_>>().join(" ");
        format!("batched[{}] scalar[{}]", join(&self.batched), join(&self.scalar))
    }
}

/// Named CPU backend choice (CLI `--backend`, `EngineConfig::backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CpuBackend {
    /// Slab-native batched objective (`backend::slab_cpu`) — the default.
    /// Promoted to the sharded flavor when a shard count > 1 is requested
    /// (results are bit-equal either way).
    #[default]
    Slab,
    /// Chunk-sharded slab objective (`backend::sharded`): the §6
    /// distributed execution pattern in-process.
    ShardedSlab,
    /// Per-source tuple baseline (`reference::CpuObjective`).
    Reference,
}

impl CpuBackend {
    /// Parse a CLI spelling. `cpu` is accepted as a legacy alias for the
    /// reference backend, `sharded` for the sharded slab.
    pub fn parse(s: &str) -> Option<CpuBackend> {
        match s {
            "slab" => Some(CpuBackend::Slab),
            "sharded-slab" | "sharded" => Some(CpuBackend::ShardedSlab),
            "reference" | "cpu" => Some(CpuBackend::Reference),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CpuBackend::Slab => "slab",
            CpuBackend::ShardedSlab => "sharded-slab",
            CpuBackend::Reference => "reference",
        }
    }

    /// Build an objective for `lp` on this backend with a single shard —
    /// see [`Self::objective_with`].
    pub fn objective<'a>(self, lp: &'a MatchingLp, threads: usize) -> AnyObjective<'a> {
        self.objective_with(lp, threads, 1)
    }

    /// Build an objective for `lp` on this backend. `threads` is the slab
    /// evaluation pool width per shard (ignored by the reference
    /// backend); `shards` the shard count (`Slab` with `shards > 1` runs
    /// sharded — bit-identical, so the promotion is safe). A slab request
    /// that cannot build its layout (non-separable block wider than the
    /// slab maximum) falls back to the reference backend; check `.name()`
    /// on the result to see which backend actually runs.
    pub fn objective_with<'a>(
        self,
        lp: &'a MatchingLp,
        threads: usize,
        shards: usize,
    ) -> AnyObjective<'a> {
        let shards = shards.max(1);
        match self {
            CpuBackend::Slab if shards == 1 => match SlabCpuObjective::new(lp, threads) {
                Ok(o) => AnyObjective::Slab(o),
                Err(_) => AnyObjective::Reference(CpuObjective::new(lp)),
            },
            CpuBackend::Slab | CpuBackend::ShardedSlab => {
                match ShardedSlabObjective::new(lp, shards, threads) {
                    Ok(o) => AnyObjective::Sharded(o),
                    Err(_) => AnyObjective::Reference(CpuObjective::new(lp)),
                }
            }
            CpuBackend::Reference => AnyObjective::Reference(CpuObjective::new(lp)),
        }
    }
}

/// A backend-erased CPU objective (enum, not `Box<dyn>`, so call sites
/// keep static dispatch and borrowck-visible lifetimes).
pub enum AnyObjective<'a> {
    Slab(SlabCpuObjective<'a>),
    Sharded(ShardedSlabObjective<'a>),
    Reference(CpuObjective<'a>),
}

impl AnyObjective<'_> {
    /// Shard count this objective actually runs with (1 for the
    /// unsharded backends, including a reference fallback from a sharded
    /// request).
    pub fn shards(&self) -> usize {
        match self {
            AnyObjective::Sharded(o) => o.num_shards(),
            AnyObjective::Slab(_) | AnyObjective::Reference(_) => 1,
        }
    }

    /// Per-bucket kernel-tier counts `(batched, scalar)` of the slab
    /// layout this objective runs (both zero for the reference backend,
    /// which has no slab buckets).
    pub fn kernel_tier_counts(&self) -> (u64, u64) {
        match self {
            AnyObjective::Slab(o) => o.kernel_tier_counts(),
            AnyObjective::Sharded(o) => o.kernel_tier_counts(),
            AnyObjective::Reference(_) => (0, 0),
        }
    }

    /// Family-level tier map of this objective's buckets (empty for the
    /// reference backend).
    pub fn kernel_tiers(&self) -> KernelTiers {
        match self {
            AnyObjective::Slab(o) => o.kernel_tiers(),
            AnyObjective::Sharded(o) => o.kernel_tiers(),
            AnyObjective::Reference(_) => KernelTiers::default(),
        }
    }
}

impl ObjectiveFunction for AnyObjective<'_> {
    fn dual_dim(&self) -> usize {
        match self {
            AnyObjective::Slab(o) => o.dual_dim(),
            AnyObjective::Sharded(o) => o.dual_dim(),
            AnyObjective::Reference(o) => o.dual_dim(),
        }
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        match self {
            AnyObjective::Slab(o) => o.calculate(lam, gamma),
            AnyObjective::Sharded(o) => o.calculate(lam, gamma),
            AnyObjective::Reference(o) => o.calculate(lam, gamma),
        }
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        match self {
            AnyObjective::Slab(o) => o.primal(lam, gamma),
            AnyObjective::Sharded(o) => o.primal(lam, gamma),
            AnyObjective::Reference(o) => o.primal(lam, gamma),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            AnyObjective::Slab(o) => o.name(),
            AnyObjective::Sharded(o) => o.name(),
            AnyObjective::Reference(o) => o.name(),
        }
    }
}

/// Wrapper that accumulates wall time spent inside `calculate` — the
/// objective-eval share of a solve, reported per job by the engine.
pub struct TimedObjective<O> {
    pub inner: O,
    /// Total wall-clock spent in `calculate` so far.
    pub eval_ms: f64,
    /// Number of `calculate` calls.
    pub evals: u64,
}

impl<O: ObjectiveFunction> TimedObjective<O> {
    pub fn new(inner: O) -> TimedObjective<O> {
        TimedObjective { inner, eval_ms: 0.0, evals: 0 }
    }
}

impl<O: ObjectiveFunction> ObjectiveFunction for TimedObjective<O> {
    fn dual_dim(&self) -> usize {
        self.inner.dual_dim()
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        let sw = Stopwatch::start();
        let r = self.inner.calculate(lam, gamma);
        self.eval_ms += sw.elapsed_ms();
        self.evals += 1;
        r
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        self.inner.primal(lam, gamma)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::projection::ProjectionKind;
    use crate::sparse::slabs::MAX_WIDTH;
    use crate::sparse::BlockedMatrix;

    #[test]
    fn parse_and_names() {
        assert_eq!(CpuBackend::parse("slab"), Some(CpuBackend::Slab));
        assert_eq!(CpuBackend::parse("sharded-slab"), Some(CpuBackend::ShardedSlab));
        assert_eq!(CpuBackend::parse("sharded"), Some(CpuBackend::ShardedSlab));
        assert_eq!(CpuBackend::parse("reference"), Some(CpuBackend::Reference));
        assert_eq!(CpuBackend::parse("cpu"), Some(CpuBackend::Reference));
        assert_eq!(CpuBackend::parse("hlo"), None);
        assert_eq!(CpuBackend::default(), CpuBackend::Slab);
        assert_eq!(CpuBackend::Slab.name(), "slab");
        assert_eq!(CpuBackend::ShardedSlab.name(), "sharded-slab");
        assert_eq!(CpuBackend::Reference.name(), "reference");
    }

    #[test]
    fn shard_count_promotes_slab_and_keeps_bits() {
        let lp = generate(&SyntheticConfig {
            num_requests: 200,
            num_resources: 16,
            seed: 6,
            ..Default::default()
        });
        let lam = vec![0.02f32; lp.dual_dim()];
        let mut one = CpuBackend::Slab.objective_with(&lp, 1, 1);
        let mut four = CpuBackend::Slab.objective_with(&lp, 1, 4);
        let mut named = CpuBackend::ShardedSlab.objective_with(&lp, 1, 3);
        assert_eq!(one.name(), "cpu-slab");
        assert_eq!(four.name(), "cpu-sharded-slab");
        assert_eq!(named.name(), "cpu-sharded-slab");
        let a = one.calculate(&lam, 0.1);
        let b = four.calculate(&lam, 0.1);
        let c = named.calculate(&lam, 0.1);
        assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
        assert_eq!(a.dual_obj.to_bits(), c.dual_obj.to_bits());
        for ((x, y), z) in a.grad.iter().zip(&b.grad).zip(&c.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
            assert_eq!(x.to_bits(), z.to_bits());
        }
    }

    #[test]
    fn sharded_falls_back_to_reference_when_layout_unbuildable() {
        let deg = MAX_WIDTH + 1;
        let a = BlockedMatrix {
            num_sources: 1,
            num_dests: deg,
            num_families: 1,
            src_ptr: vec![0, deg],
            dest_idx: (0..deg as u32).collect(),
            a: vec![vec![1.0; deg]],
        };
        let lp = MatchingLp::new_uniform(
            a,
            vec![-1.0; deg],
            vec![0.5; deg],
            ProjectionKind::Simplex,
        );
        let obj = CpuBackend::ShardedSlab.objective_with(&lp, 1, 3);
        assert_eq!(obj.name(), "cpu-reference");
    }

    #[test]
    fn objective_dispatch_and_names() {
        let lp = generate(&SyntheticConfig {
            num_requests: 100,
            num_resources: 16,
            seed: 2,
            ..Default::default()
        });
        let mut slab = CpuBackend::Slab.objective(&lp, 1);
        let mut reference = CpuBackend::Reference.objective(&lp, 1);
        assert_eq!(slab.name(), "cpu-slab");
        assert_eq!(reference.name(), "cpu-reference");
        let lam = vec![0.0f32; lp.dual_dim()];
        let a = slab.calculate(&lam, 0.1);
        let b = reference.calculate(&lam, 0.1);
        assert!((a.dual_obj - b.dual_obj).abs() < 1e-4 * (1.0 + b.dual_obj.abs()));
    }

    #[test]
    fn slab_falls_back_to_reference_when_layout_unbuildable() {
        let deg = MAX_WIDTH + 1;
        let a = BlockedMatrix {
            num_sources: 1,
            num_dests: deg,
            num_families: 1,
            src_ptr: vec![0, deg],
            dest_idx: (0..deg as u32).collect(),
            a: vec![vec![1.0; deg]],
        };
        let lp = MatchingLp::new_uniform(
            a,
            vec![-1.0; deg],
            vec![0.5; deg],
            ProjectionKind::Simplex,
        );
        let obj = CpuBackend::Slab.objective(&lp, 1);
        assert_eq!(obj.name(), "cpu-reference");
    }

    #[test]
    fn builtin_families_report_batched_tier() {
        let lp = generate(&SyntheticConfig {
            num_requests: 60,
            num_resources: 8,
            seed: 3,
            ..Default::default()
        });
        let slab = CpuBackend::Slab.objective(&lp, 1);
        let sharded = CpuBackend::ShardedSlab.objective_with(&lp, 1, 2);
        let reference = CpuBackend::Reference.objective(&lp, 1);
        let (batched, scalar) = slab.kernel_tier_counts();
        assert!(batched > 0, "builtin buckets must run batched kernels");
        assert_eq!(scalar, 0, "no builtin family may fall back to the scalar default");
        assert_eq!(sharded.kernel_tier_counts(), (batched, scalar));
        assert_eq!(reference.kernel_tier_counts(), (0, 0));
        let tiers = slab.kernel_tiers();
        assert!(tiers.scalar.is_empty(), "{tiers:?}");
        assert_eq!(sharded.kernel_tiers(), tiers);
        assert!(reference.kernel_tiers().is_empty());
        assert_eq!(KernelTiers::of_lp(&lp), tiers);
        assert!(tiers.summary().starts_with("batched["), "{}", tiers.summary());
    }

    #[test]
    fn kernel_tiers_expose_scalar_fallback_families() {
        use crate::projection::registry;
        use crate::projection::BlockProjection;
        // A runtime-registered family WITHOUT a project_rows override: the
        // slab backend still runs it (through the scalar default), and the
        // tier report must say so instead of hiding the slow path.
        struct TierProbe;
        impl BlockProjection for TierProbe {
            fn family(&self) -> &str {
                "tier_probe_scalar"
            }
            fn spec(&self) -> String {
                "tier_probe_scalar".to_string()
            }
            fn project(&self, v: &mut [f32]) {
                for x in v.iter_mut() {
                    *x = x.clamp(0.0, 0.25);
                }
            }
            fn violation(&self, v: &[f32]) -> f64 {
                v.iter()
                    .map(|&x| ((x - 0.25) as f64).max((-x) as f64).max(0.0))
                    .fold(0.0, f64::max)
            }
            fn separable(&self) -> bool {
                true
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        registry::register_family("tier_probe_scalar", &["tier_probe_scalar"], |args: &str| {
            args.is_empty().then(|| Box::new(TierProbe) as Box<dyn BlockProjection>)
        });
        let kind = crate::projection::ProjectionKind::parse("tier_probe_scalar").unwrap();
        let a = BlockedMatrix {
            num_sources: 3,
            num_dests: 2,
            num_families: 1,
            src_ptr: vec![0, 2, 4, 6],
            dest_idx: vec![0, 1, 0, 1, 0, 1],
            a: vec![vec![1.0; 6]],
        };
        let lp = MatchingLp::new_uniform(a, vec![-1.0; 6], vec![0.5, 0.5], kind);
        let obj = CpuBackend::Slab.objective(&lp, 1);
        let (batched, scalar) = obj.kernel_tier_counts();
        assert_eq!(batched, 0);
        assert!(scalar > 0, "scalar-default buckets must be counted");
        let tiers = obj.kernel_tiers();
        assert!(tiers.scalar.contains("tier_probe_scalar"), "{tiers:?}");
        assert_eq!(KernelTiers::of_lp(&lp), tiers);
        assert!(tiers.summary().contains("scalar[tier_probe_scalar]"), "{}", tiers.summary());
    }

    #[test]
    fn timed_wrapper_counts_and_delegates() {
        let lp = generate(&SyntheticConfig {
            num_requests: 80,
            num_resources: 8,
            seed: 4,
            ..Default::default()
        });
        let mut obj = TimedObjective::new(CpuBackend::Slab.objective(&lp, 1));
        let lam = vec![0.0f32; lp.dual_dim()];
        let _ = obj.calculate(&lam, 0.1);
        let _ = obj.calculate(&lam, 0.1);
        assert_eq!(obj.evals, 2);
        assert!(obj.eval_ms >= 0.0);
        assert_eq!(obj.name(), "cpu-slab");
        assert_eq!(obj.primal(&lam, 0.1).len(), lp.nnz());
    }
}
