//! In-process sharded slab objective — the paper's §6 distributed design
//! (per-device slab evaluation, λ-only exchange) run inside one process,
//! selectable wherever a CPU backend is (`CpuBackend::ShardedSlab`, the
//! engine's `EngineConfig::shards`, CLI `--shards`).
//!
//! Construction mirrors the device story: build the full
//! [`SlabLayout`] once (rank 0 partitions on CPU), cut its fixed chunk
//! grid into contiguous ranges balanced by **real** edge count
//! (`distributed::balanced_partition` over the grid's cumulative edge
//! pointer), and give each shard a [`SlabCpuObjective`] view over its
//! range with its own thread budget. Each `calculate` evaluates shards
//! concurrently (scoped threads — shard state is borrowed, no `'static`
//! bound) and merges their per-chunk partials through the deterministic
//! chunk-index-ordered allreduce
//! (`distributed::collective::reduce_chunk_partials`), so an S-shard
//! evaluation — and therefore a whole AGD solve — is **bit-identical** to
//! the single-shard slab solve. Logical traffic is counted per iteration
//! exactly as the device pool counts it: two |λ| broadcasts (the momentum
//! pair) plus one chunk-segmented reduce whose payload is
//! `num_chunks × (|λ| + 2)` values — independent of shard edge counts.
//!
//! The difference from `distributed::WorkerPool` with the slab strategy
//! is thread topology only: this type spawns scoped threads per call and
//! borrows the instance (so the engine can run it on jobs it owns),
//! while the pool keeps persistent device threads behind channels for
//! the distributed drivers. Both produce the same bits.

use std::sync::Arc;

use super::slab_cpu::{ChunkPartial, SlabCpuObjective};
use crate::distributed::collective::{reduce_chunk_partials, CommSnapshot, CommStats};
use crate::distributed::partition::{balanced_partition, imbalance};
use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::sparse::slabs::{BuildOptions, SlabChunk, SlabLayout};
use crate::util::timer::thread_cpu_time_ms;

/// Leader-side shard plan shared by BOTH sharded execution paths (this
/// module's in-process objective and `distributed::WorkerPool`'s slab
/// strategy): the canonical layout + fixed chunk grid, contiguous chunk
/// ranges balanced by real edge count, and the per-shard edge counts the
/// one-time scatter accounting is computed from. Keeping the construction
/// in one place is what keeps the two paths bit-equal by construction.
pub struct SlabShardPlan {
    pub layout: Arc<SlabLayout>,
    pub grid: Arc<Vec<SlabChunk>>,
    /// Chunk-grid range `[lo, hi)` owned by each shard (ascending,
    /// contiguous — the precondition of the chunk-ordered allreduce).
    pub ranges: Vec<(usize, usize)>,
    /// Real (non-padding) edges owned by each shard.
    pub shard_edges: Vec<usize>,
    /// Real-edge load imbalance of the partition (max/mean, 1.0 = perfect).
    pub imbalance: f64,
}

impl SlabShardPlan {
    /// Build the layout, grid, and a `num_shards`-way balanced partition
    /// for `lp` under default [`BuildOptions`]. Errors when the layout is
    /// unbuildable (same condition as [`SlabCpuObjective::new`]).
    pub fn build(lp: &MatchingLp, num_shards: usize) -> Result<SlabShardPlan, String> {
        Self::build_opts(lp, num_shards, BuildOptions::default())
    }

    /// [`Self::build`] with explicit [`BuildOptions`] — the leader can
    /// fill planes with a thread pool (`opts.threads`) before scattering;
    /// the layout, grid, and partition are bit-identical at any pool
    /// width, so sharded solves stay bit-equal to single-shard ones.
    pub fn build_opts(
        lp: &MatchingLp,
        num_shards: usize,
        opts: BuildOptions,
    ) -> Result<SlabShardPlan, String> {
        let layout = Arc::new(SlabLayout::build_opts(
            &lp.a,
            &lp.cost,
            0,
            lp.num_sources(),
            &|i| lp.projection.kind_of(i),
            opts,
        )?);
        let grid = Arc::new(layout.fixed_chunk_grid());
        let ptr = layout.chunk_edge_ptr(&grid);
        let ranges = balanced_partition(&ptr, num_shards.max(1));
        let imbalance = imbalance(&ptr, &ranges);
        let shard_edges = ranges.iter().map(|&(lo, hi)| ptr[hi] - ptr[lo]).collect();
        Ok(SlabShardPlan { layout, grid, ranges, shard_edges, imbalance })
    }

    /// Record the one-time data distribution into `stats` (paper §6: rank
    /// 0 partitions on CPU and scatters): each shard receives its real
    /// edges × (index + cost + m coefficient planes). The shared `b`
    /// broadcast is recorded separately by the leader.
    pub fn record_scatter(&self, lp: &MatchingLp, stats: &CommStats) {
        for &edges in &self.shard_edges {
            stats.record_scatter((edges * (4 + 4 + 4 * lp.num_families())) as u64);
        }
    }
}

/// `ObjectiveFunction` running S slab shards in-process (see module docs).
pub struct ShardedSlabObjective<'a> {
    shards: Vec<SlabCpuObjective<'a>>,
    plan: SlabShardPlan,
    stats: Arc<CommStats>,
    /// Cumulative per-shard evaluation thread-CPU time (ms).
    shard_eval_ms: Vec<f64>,
    /// Number of `calculate` calls so far.
    evals: u64,
    full_b: Vec<f32>,
    dual_dim: usize,
    nnz: usize,
}

impl<'a> ShardedSlabObjective<'a> {
    /// Build `num_shards` shard views over `lp`'s slab layout, each with
    /// an evaluation pool of `threads_per_shard` (1 = sequential within a
    /// shard; results are bit-identical at any width). Errors when the
    /// layout is unbuildable (same condition as [`SlabCpuObjective::new`]).
    pub fn new(
        lp: &'a MatchingLp,
        num_shards: usize,
        threads_per_shard: usize,
    ) -> Result<ShardedSlabObjective<'a>, String> {
        let plan = SlabShardPlan::build(lp, num_shards)?;
        let shards: Vec<SlabCpuObjective<'a>> = plan
            .ranges
            .iter()
            .map(|&(lo, hi)| {
                SlabCpuObjective::new_shard(
                    lp,
                    plan.layout.clone(),
                    &plan.grid,
                    lo,
                    hi,
                    threads_per_shard,
                )
            })
            .collect();
        let stats = CommStats::new();
        plan.record_scatter(lp, &stats);
        stats.record_broadcast(lp.dual_dim()); // shared b (once)
        Ok(ShardedSlabObjective {
            shard_eval_ms: vec![0.0; shards.len()],
            shards,
            plan,
            stats,
            evals: 0,
            full_b: lp.full_b(),
            dual_dim: lp.dual_dim(),
            nnz: lp.nnz(),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Size of the (global) fixed chunk grid the shards partition.
    pub fn num_chunks(&self) -> usize {
        self.plan.grid.len()
    }

    /// Chunk-grid range owned by each shard.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.plan.ranges
    }

    /// Real-edge load imbalance of the partition (max/mean, 1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        self.plan.imbalance
    }

    /// Cumulative evaluation thread-CPU time per shard (ms) — what each
    /// device would have spent computing.
    pub fn shard_eval_ms(&self) -> &[f64] {
        &self.shard_eval_ms
    }

    /// Number of `calculate` calls so far.
    pub fn evals(&self) -> u64 {
        self.evals
    }

    /// Logical communication counters (broadcast / segmented-reduce /
    /// one-time scatter bytes).
    pub fn comm(&self) -> CommSnapshot {
        self.stats.snapshot()
    }

    /// Per-bucket kernel-tier counts `(batched, scalar)` of the shared
    /// layout — every shard views the same buckets, so this is counted
    /// once over the plan, not per shard.
    pub fn kernel_tier_counts(&self) -> (u64, u64) {
        let batched = self
            .plan
            .layout
            .buckets
            .iter()
            .filter(|b| b.kind.op().batched_project_rows())
            .count() as u64;
        (batched, self.plan.layout.buckets.len() as u64 - batched)
    }

    /// Family-level tier map of the shared layout's buckets.
    pub fn kernel_tiers(&self) -> super::KernelTiers {
        let mut tiers = super::KernelTiers::default();
        for b in &self.plan.layout.buckets {
            tiers.record(b.kind.op().as_ref());
        }
        tiers
    }
}

impl ObjectiveFunction for ShardedSlabObjective<'_> {
    fn dual_dim(&self) -> usize {
        self.dual_dim
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        assert_eq!(lam.len(), self.dual_dim);
        // The paper's per-iteration pattern: the leader broadcasts the
        // (λ₁, λ₂) momentum pair — counted as two |λ| payloads here even
        // though in-process shards read λ by reference.
        self.stats.record_broadcast(lam.len());
        self.stats.record_broadcast(lam.len());

        let n = self.shards.len();
        // Slots are pre-initialized to empty slices, so a rank is never
        // "missing": scoped threads write every slot before the scope
        // closes, and the borrow checker pins each slice to its shard's
        // persistent partials buffer — the merge below reads the shard
        // results in place, no per-iteration clone of the payloads.
        let mut parts: Vec<(&[ChunkPartial], f64)> = Vec::with_capacity(n);
        parts.resize(n, (&[][..], 0.0));
        if n == 1 {
            // no cross-shard concurrency to exploit; skip the spawn cost
            let t0 = thread_cpu_time_ms();
            let p = self.shards[0].eval_chunk_partials(lam, gamma);
            parts[0] = (p, thread_cpu_time_ms() - t0);
        } else {
            std::thread::scope(|scope| {
                for (slot, shard) in parts.iter_mut().zip(self.shards.iter_mut()) {
                    scope.spawn(move || {
                        let t0 = thread_cpu_time_ms();
                        let p = shard.eval_chunk_partials(lam, gamma);
                        *slot = (p, thread_cpu_time_ms() - t0);
                    });
                }
            });
        }
        let mut by_rank: Vec<&[ChunkPartial]> = Vec::with_capacity(n);
        for (rank, &(p, ms)) in parts.iter().enumerate() {
            self.shard_eval_ms[rank] += ms;
            by_rank.push(p);
        }
        let segments: usize = by_rank.iter().map(|p| p.len()).sum();
        self.stats.record_segmented_reduce(segments, self.dual_dim, 2);
        self.evals += 1;

        let (mut ax, cx, xsq) = reduce_chunk_partials(&by_rank, self.dual_dim);
        for (g, b) in ax.iter_mut().zip(&self.full_b) {
            *g -= *b;
        }
        ObjectiveResult::assemble(ax, cx, xsq, lam, gamma)
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        // Off the hot path. Shards own disjoint edge sets and write by
        // assignment, so one shared buffer reconstructs the single-shard
        // primal exactly.
        self.stats.record_broadcast(lam.len());
        let mut out = vec![0.0f32; self.nnz];
        for shard in &mut self.shards {
            shard.primal_into(lam, gamma, &mut out);
        }
        out
    }

    fn name(&self) -> &'static str {
        "cpu-sharded-slab"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};

    fn instance(seed: u64) -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 600,
            num_resources: 40,
            avg_nnz_per_row: 6.0,
            num_families: 2,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn sharded_eval_is_bit_identical_to_single_shard() {
        let lp = instance(17);
        let mut one = SlabCpuObjective::new(&lp, 1).unwrap();
        let lam = vec![0.04f32; lp.dual_dim()];
        let r1 = one.calculate(&lam, 0.1);
        let x1 = one.primal(&lam, 0.1);
        for shards in [1usize, 2, 3, 5] {
            let mut sh = ShardedSlabObjective::new(&lp, shards, 1).unwrap();
            assert_eq!(sh.num_shards(), shards);
            let rs = sh.calculate(&lam, 0.1);
            assert_eq!(r1.dual_obj.to_bits(), rs.dual_obj.to_bits(), "{shards} shards");
            assert_eq!(r1.cx.to_bits(), rs.cx.to_bits());
            assert_eq!(r1.xsq_weighted.to_bits(), rs.xsq_weighted.to_bits());
            for (a, b) in r1.grad.iter().zip(&rs.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "{shards} shards");
            }
            let xs = sh.primal(&lam, 0.1);
            for (a, b) in x1.iter().zip(&xs) {
                assert_eq!(a.to_bits(), b.to_bits(), "{shards} shards primal");
            }
        }
    }

    #[test]
    fn per_shard_threads_do_not_change_bits() {
        let lp = instance(23);
        let lam = vec![0.02f32; lp.dual_dim()];
        let mut narrow = ShardedSlabObjective::new(&lp, 3, 1).unwrap();
        let mut wide = ShardedSlabObjective::new(&lp, 3, 4).unwrap();
        let a = narrow.calculate(&lam, 0.2);
        let b = wide.calculate(&lam, 0.2);
        assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
        for (x, y) in a.grad.iter().zip(&b.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn comm_accounting_is_lambda_sized_per_iteration() {
        let lp = instance(31);
        let dual = lp.dual_dim();
        let mut sh = ShardedSlabObjective::new(&lp, 4, 1).unwrap();
        let before = sh.comm();
        assert!(before.scatter_bytes > 0, "one-time distribution counted");
        let lam = vec![0.0f32; dual];
        let iters = 6u64;
        for _ in 0..iters {
            let _ = sh.calculate(&lam, 0.1);
        }
        let after = sh.comm();
        assert_eq!(after.bcast_ops - before.bcast_ops, 2 * iters);
        assert_eq!(after.reduce_ops - before.reduce_ops, iters);
        let per_iter = (after.bcast_bytes + after.reduce_bytes
            - before.bcast_bytes
            - before.reduce_bytes) as f64
            / iters as f64;
        let expected = (2 * 4 * dual + sh.num_chunks() * (4 * dual + 16)) as f64;
        assert_eq!(per_iter, expected, "traffic must be λ/chunk-sized only");
        // scatter does not grow with iterations
        assert_eq!(after.scatter_bytes, before.scatter_bytes);
        // per-shard eval time recorded for every shard
        assert_eq!(sh.shard_eval_ms().len(), 4);
        assert_eq!(sh.evals(), iters);
    }

    #[test]
    fn repeated_calculates_reuse_buffers_bit_identically() {
        // the shard partials live in persistent buffers now — a warm
        // objective (buffers carrying a previous iteration's values) must
        // produce the same bits as a fresh one
        let lp = instance(41);
        let lam_a = vec![0.03f32; lp.dual_dim()];
        let lam_b = vec![0.07f32; lp.dual_dim()];
        let mut fresh = ShardedSlabObjective::new(&lp, 3, 1).unwrap();
        let mut reused = ShardedSlabObjective::new(&lp, 3, 1).unwrap();
        let _ = reused.calculate(&lam_b, 0.1);
        let a = fresh.calculate(&lam_a, 0.1);
        let b = reused.calculate(&lam_a, 0.1);
        assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
        assert_eq!(a.cx.to_bits(), b.cx.to_bits());
        for (x, y) in a.grad.iter().zip(&b.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn more_shards_than_chunks_is_ok() {
        let lp = generate(&SyntheticConfig {
            num_requests: 30,
            num_resources: 8,
            avg_nnz_per_row: 3.0,
            seed: 5,
            ..Default::default()
        });
        let mut one = SlabCpuObjective::new(&lp, 1).unwrap();
        let chunks = one.num_chunks();
        let mut sh = ShardedSlabObjective::new(&lp, chunks + 4, 1).unwrap();
        let lam = vec![0.01f32; lp.dual_dim()];
        let a = one.calculate(&lam, 0.1);
        let b = sh.calculate(&lam, 0.1);
        assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
    }
}
