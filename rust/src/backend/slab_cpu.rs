//! Slab-native batched CPU objective — the default serving backend.
//!
//! The reference objective (`reference::CpuObjective`) exists to be the
//! paper's §7 comparator: per-source tuple vectors, pointer-chasing
//! traversal, one projection call per block. This backend runs the same
//! math over the §6 constraint-aligned [`SlabLayout`] instead:
//!
//! - **structure-of-arrays traversal**: per bucket, the `cost` / `a[k]` /
//!   `dest_idx` planes are contiguous `[rows × width]` slabs, so the
//!   gather `u = Aᵀλ + c` and the scatter `ax += a ⊙ x` are tight
//!   width-strided sweeps instead of per-tuple hops;
//! - **batched projections**: one [`BlockProjection::project_rows`] call
//!   per (bucket, chunk) — the CPU mirror of the L1 Pallas slab kernels —
//!   replacing one dynamic `project` dispatch (and, for simplex, one sort
//!   allocation) per source;
//! - **deterministic parallelism**: rows are split into a **fixed chunk
//!   grid** that never depends on the thread count. Each chunk reduces
//!   into its own partial `ax`/`cx`/`xsq` accumulator, and partials are
//!   merged in chunk-index order — so an N-thread evaluation is
//!   bit-identical to the 1-thread evaluation (the same argument as the
//!   rank-ordered reduction in `distributed/` and the engine scheduler,
//!   applied one level down). `std::thread::scope` keeps it on borrowed
//!   data with no new crates.
//!
//! Layout-ineligible instances (a non-separable block wider than
//! `MAX_WIDTH`) are reported as a build error; `backend::CpuBackend`
//! falls back to the reference objective for those.
//!
//! **Sharding.** The chunk grid is also the unit of cross-shard
//! partitioning: [`SlabCpuObjective::new_shard`] builds a view over a
//! contiguous range of the grid, and [`eval_chunk_partials`] returns the
//! per-chunk partial reductions unmerged, so a leader (the in-process
//! [`super::ShardedSlabObjective`] or the `distributed::WorkerPool`
//! device threads) can merge all shards' partials in global chunk-index
//! order and reproduce the single-shard bit pattern exactly.
//!
//! [`eval_chunk_partials`]: SlabCpuObjective::eval_chunk_partials

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::projection::BlockProjection;
use crate::sparse::slabs::{BuildOptions, SlabChunk, SlabLayout};

/// One chunk's partial reduction — the unit payload of the deterministic
/// chunk-index-ordered allreduce (`distributed::collective`). Sized by
/// the dual dimension only (λ-sized), never by the chunk's edge count.
#[derive(Clone, Debug)]
pub struct ChunkPartial {
    /// Partial Ax accumulator over the full dual dimension.
    pub ax: Vec<f32>,
    /// Partial cᵀx.
    pub cx: f64,
    /// Partial Σ v²‖x‖².
    pub xsq: f64,
}

/// Per-chunk scratch, persistent across iterations: projected slab values
/// plus the chunk's partial reductions. Wrapped in an (uncontended)
/// `Mutex` so worker threads can fill disjoint slots through `&self`.
struct ChunkScratch {
    /// Projected primal values for the chunk's rows, `[rows × width]`.
    x: Vec<f32>,
    /// Partial Ax accumulator over the full dual dimension.
    ax: Vec<f32>,
    cx: f64,
    xsq: f64,
}

/// `ObjectiveFunction` over the slab layout (see module docs). Either the
/// full layout (`new`) or a shard view over a contiguous chunk range of
/// it (`new_shard`).
pub struct SlabCpuObjective<'a> {
    lp: &'a MatchingLp,
    layout: Arc<SlabLayout>,
    threads: usize,
    /// Projection operator per bucket, resolved from the registry once at
    /// construction so the hot loop stays lock-free.
    ops: Vec<Arc<dyn BlockProjection>>,
    /// v_i² per slab row per bucket (γ is folded in per call).
    row_v2: Vec<Vec<f32>>,
    /// This objective's slice of the fixed chunk grid (the whole grid for
    /// `new`, `grid[chunk_lo..chunk_hi]` for `new_shard`).
    tasks: Vec<SlabChunk>,
    /// Global grid index of `tasks[0]` (0 for a full objective).
    chunk_lo: usize,
    /// Whether `tasks` covers the entire grid (only then is `calculate`
    /// a complete dual evaluation).
    full_range: bool,
    scratch: Vec<Mutex<ChunkScratch>>,
    /// Persistent per-chunk partials buffer `eval_chunk_partials` copies
    /// the scratch slots into — sized once at construction so the per-
    /// iteration shard path allocates nothing.
    partials: Vec<ChunkPartial>,
    /// Precomputed rhs over all dual rows.
    full_b: Vec<f32>,
}

/// Lock a scratch slot, recovering from poison. Sound because every
/// reader runs a fill first (or reads what the last complete fill wrote)
/// and a fill overwrites the slot completely — a writer that panicked
/// mid-fill cannot leave state a later fill would not replace.
fn lock_scratch(slot: &Mutex<ChunkScratch>) -> std::sync::MutexGuard<'_, ChunkScratch> {
    slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl<'a> SlabCpuObjective<'a> {
    /// Build the slab layout and the fixed chunk grid for `lp`. `threads`
    /// is the evaluation pool width (1 = fully sequential; results are
    /// bit-identical either way) and is reused as the build's plane-fill
    /// pool width — the parallel build is bit-identical to serial at any
    /// thread count, so this is purely a setup-latency knob. Errors when
    /// the layout is unbuildable (non-separable block wider than the
    /// maximum slab width).
    pub fn new(lp: &'a MatchingLp, threads: usize) -> Result<SlabCpuObjective<'a>, String> {
        let layout = Arc::new(SlabLayout::build_opts(
            &lp.a,
            &lp.cost,
            0,
            lp.num_sources(),
            &|i| lp.projection.kind_of(i),
            BuildOptions { threads, ..BuildOptions::default() },
        )?);
        let grid = layout.fixed_chunk_grid();
        let n = grid.len();
        Ok(Self::from_parts(lp, layout, &grid, 0, n, threads))
    }

    /// Build a shard view over `grid[chunk_lo..chunk_hi]` of an already
    /// built layout. `grid` MUST be the layout's canonical
    /// `fixed_chunk_grid()` — shards that cut the grid differently from
    /// the single-shard objective forfeit bit-identity. Shard views are
    /// driven through [`Self::eval_chunk_partials`] / [`Self::primal_into`]
    /// by a leader that owns the cross-shard merge; their `calculate`
    /// panics (it would subtract the full `b` from a partial gradient).
    pub fn new_shard(
        lp: &'a MatchingLp,
        layout: Arc<SlabLayout>,
        grid: &[SlabChunk],
        chunk_lo: usize,
        chunk_hi: usize,
        threads: usize,
    ) -> SlabCpuObjective<'a> {
        Self::from_parts(lp, layout, grid, chunk_lo, chunk_hi, threads)
    }

    fn from_parts(
        lp: &'a MatchingLp,
        layout: Arc<SlabLayout>,
        grid: &[SlabChunk],
        chunk_lo: usize,
        chunk_hi: usize,
        threads: usize,
    ) -> SlabCpuObjective<'a> {
        assert!(chunk_lo <= chunk_hi && chunk_hi <= grid.len());
        let ops: Vec<Arc<dyn BlockProjection>> =
            layout.buckets.iter().map(|b| b.kind.op()).collect();
        let row_v2: Vec<Vec<f32>> = layout
            .buckets
            .iter()
            .map(|b| b.sources.iter().map(|&s| lp.gamma_scale(s as usize)).collect())
            .collect();
        let tasks: Vec<SlabChunk> = grid[chunk_lo..chunk_hi].to_vec();
        let dual = lp.dual_dim();
        let scratch = tasks
            .iter()
            .map(|_| {
                Mutex::new(ChunkScratch {
                    x: Vec::new(),
                    ax: vec![0.0f32; dual],
                    cx: 0.0,
                    xsq: 0.0,
                })
            })
            .collect();
        let partials = tasks
            .iter()
            .map(|_| ChunkPartial { ax: vec![0.0f32; dual], cx: 0.0, xsq: 0.0 })
            .collect();
        SlabCpuObjective {
            lp,
            layout,
            threads: threads.max(1),
            ops,
            row_v2,
            tasks,
            chunk_lo,
            full_range: chunk_lo == 0 && chunk_hi == grid.len(),
            scratch,
            partials,
            full_b: lp.full_b(),
        }
    }

    pub fn layout(&self) -> &SlabLayout {
        &self.layout
    }

    pub fn num_chunks(&self) -> usize {
        self.tasks.len()
    }

    /// Global grid range `[lo, hi)` this objective covers.
    pub fn chunk_range(&self) -> (usize, usize) {
        (self.chunk_lo, self.chunk_lo + self.tasks.len())
    }

    /// This objective's slice of the fixed chunk grid.
    pub fn chunks(&self) -> &[SlabChunk] {
        &self.tasks
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Per-bucket kernel-tier counts: `(buckets running a batched
    /// `project_rows` override, buckets on the scalar default)`. A
    /// nonzero scalar count means some family silently pays a per-row
    /// dynamic dispatch in the hot loop (DESIGN.md §12).
    pub fn kernel_tier_counts(&self) -> (u64, u64) {
        let batched = self.ops.iter().filter(|op| op.batched_project_rows()).count() as u64;
        (batched, self.ops.len() as u64 - batched)
    }

    /// Family-level tier map of this objective's buckets.
    pub fn kernel_tiers(&self) -> super::KernelTiers {
        let mut tiers = super::KernelTiers::default();
        for op in &self.ops {
            tiers.record(op.as_ref());
        }
        tiers
    }

    /// Run `f` over every chunk index, across the pool when it pays.
    /// Which thread runs which chunk is irrelevant to values: each chunk
    /// writes only its own scratch slot.
    ///
    /// Scoped threads are spawned per call (i.e. per solver iteration):
    /// a few tens of µs of spawn/join overhead at `threads` > 1, which
    /// only pays off on instances whose single evaluation is well into
    /// the millisecond range. That is why `threads` defaults to 1
    /// everywhere (the serving engine parallelizes across jobs instead)
    /// and why the E14 bench reports thread scaling explicitly. A
    /// persistent worker pool would amortize the spawns; not worth the
    /// complexity until a profile says otherwise.
    fn for_each_chunk<F: Fn(usize) + Sync>(&self, f: F) {
        let n = self.tasks.len();
        if self.threads <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(n) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Fill `x` with the chunk's projected primal block values:
    /// x = Π_C(−(Aᵀλ + c) / (γ v²)), batched per row.
    fn gather_project(&self, t: &SlabChunk, lam: &[f32], gamma: f32, x: &mut Vec<f32>) {
        let bk = &self.layout.buckets[t.bucket];
        let w = bk.width;
        let rows = t.row_hi - t.row_lo;
        let jj = self.lp.num_dests();
        let m = self.lp.num_families();
        let mj = self.lp.matching_dual_dim();
        x.clear();
        x.resize(rows * w, 0.0);
        for rr in 0..rows {
            let r = t.row_lo + rr;
            let base = r * w;
            let out = &mut x[rr * w..(rr + 1) * w];
            let dest = &bk.dest_idx[base..base + w];
            // u = Σ_k a_k ⊙ λ_k[dest]: one contiguous plane sweep per
            // family (padding has a = 0, so it lands on exact zero)
            for k in 0..m {
                let ak = &bk.a[k][base..base + w];
                let lk = &lam[k * jj..(k + 1) * jj];
                if k == 0 {
                    for c in 0..w {
                        out[c] = ak[c] * lk[dest[c] as usize];
                    }
                } else {
                    for c in 0..w {
                        out[c] += ak[c] * lk[dest[c] as usize];
                    }
                }
            }
            for (g_idx, g) in self.lp.global_rows.iter().enumerate() {
                let lg = lam[mj + g_idx];
                let eid = &bk.edge_id[base..base + w];
                let msk = &bk.mask[base..base + w];
                for c in 0..w {
                    if msk[c] > 0.0 {
                        out[c] += g.coeffs[eid[c] as usize] * lg;
                    }
                }
            }
            // one multiply per element instead of the reference's divide;
            // the mask factor pins padding to exact zero for the batched
            // projections
            let neg_inv = -1.0f32 / (gamma * self.row_v2[t.bucket][r]);
            let cost = &bk.cost[base..base + w];
            let msk = &bk.mask[base..base + w];
            for c in 0..w {
                out[c] = (out[c] + cost[c]) * neg_inv * msk[c];
            }
        }
        let mask = &bk.mask[t.row_lo * w..t.row_hi * w];
        self.ops[t.bucket].project_rows(x, rows, w, mask);
    }

    /// Accumulate the chunk's contribution to Ax / cᵀx / Σv²‖x‖².
    fn reduce_chunk(&self, t: &SlabChunk, x: &[f32], ax: &mut [f32]) -> (f64, f64) {
        let bk = &self.layout.buckets[t.bucket];
        let w = bk.width;
        let jj = self.lp.num_dests();
        let m = self.lp.num_families();
        let mj = self.lp.matching_dual_dim();
        let mut cx = 0.0f64;
        let mut xsq = 0.0f64;
        for rr in 0..(t.row_hi - t.row_lo) {
            let r = t.row_lo + rr;
            let base = r * w;
            let xr = &x[rr * w..(rr + 1) * w];
            let v2 = self.row_v2[t.bucket][r] as f64;
            for c in 0..w {
                let xv = xr[c];
                if xv == 0.0 {
                    continue; // padding and clamped-out coordinates
                }
                cx += bk.cost[base + c] as f64 * xv as f64;
                xsq += v2 * xv as f64 * xv as f64;
                for k in 0..m {
                    ax[k * jj + bk.dest_idx[base + c] as usize] += bk.a[k][base + c] * xv;
                }
                for (g_idx, g) in self.lp.global_rows.iter().enumerate() {
                    ax[mj + g_idx] += g.coeffs[bk.edge_id[base + c] as usize] * xv;
                }
            }
        }
        (cx, xsq)
    }

    /// Evaluate every chunk of this objective's range into its scratch
    /// slot (the parallel phase shared by `calculate` and
    /// `eval_chunk_partials`).
    fn fill_scratch(&self, lam: &[f32], gamma: f32) {
        assert_eq!(lam.len(), self.lp.dual_dim());
        let this: &Self = self;
        this.for_each_chunk(|i| {
            let t = &this.tasks[i];
            let mut guard = lock_scratch(&this.scratch[i]);
            let s = &mut *guard;
            this.gather_project(t, lam, gamma, &mut s.x);
            s.ax.fill(0.0);
            let (cx, xsq) = this.reduce_chunk(t, &s.x, &mut s.ax);
            s.cx = cx;
            s.xsq = xsq;
        });
    }

    /// Evaluate this objective's chunk range at (λ, γ) and return the
    /// per-chunk partial reductions in ascending chunk order — unmerged
    /// and with `b` NOT subtracted. This is the shard half of a
    /// distributed evaluation: the leader concatenates all shards'
    /// partials (shards own contiguous ascending chunk ranges) and merges
    /// them in global chunk-index order
    /// (`distributed::collective::reduce_chunk_partials`), which
    /// reproduces the exact f32 summation sequence of a single-shard
    /// `calculate`. Payload is `num_chunks × (|λ| + 2)` values —
    /// λ-proportional, independent of the shard's edge count.
    ///
    /// The returned slice borrows this objective's persistent partials
    /// buffer — the per-iteration shard path allocates nothing; callers
    /// that need owned payloads (channel sends) copy at the boundary.
    pub fn eval_chunk_partials(&mut self, lam: &[f32], gamma: f32) -> &[ChunkPartial] {
        self.fill_scratch(lam, gamma);
        for (p, slot) in self.partials.iter_mut().zip(&self.scratch) {
            let s = lock_scratch(slot);
            p.ax.copy_from_slice(&s.ax);
            p.cx = s.cx;
            p.xsq = s.xsq;
        }
        &self.partials
    }

    /// Write this objective's chunks' primal values into `out` (full-nnz
    /// indexing) by **assignment**. Chunks own disjoint edge sets, so a
    /// leader calling this per shard over one buffer reconstructs exactly
    /// the single-shard `primal` output, -0.0 bits included (a merge by
    /// `+=` would quietly turn −0.0 into +0.0).
    pub fn primal_into(&mut self, lam: &[f32], gamma: f32, out: &mut [f32]) {
        assert_eq!(lam.len(), self.lp.dual_dim());
        assert_eq!(out.len(), self.lp.nnz());
        // off the iteration hot path: sequential sweep, scatter by edge id
        // (split separable rows land in their own edge ranges)
        for (i, t) in self.tasks.iter().enumerate() {
            let mut guard = lock_scratch(&self.scratch[i]);
            let s = &mut *guard;
            self.gather_project(t, lam, gamma, &mut s.x);
            let bk = &self.layout.buckets[t.bucket];
            let w = bk.width;
            for rr in 0..(t.row_hi - t.row_lo) {
                let base = (t.row_lo + rr) * w;
                for c in 0..w {
                    if bk.mask[base + c] > 0.0 {
                        out[bk.edge_id[base + c] as usize] = s.x[rr * w + c];
                    }
                }
            }
        }
    }
}

impl ObjectiveFunction for SlabCpuObjective<'_> {
    fn dual_dim(&self) -> usize {
        self.lp.dual_dim()
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        assert!(
            self.full_range,
            "calculate() needs the full chunk range; shard views are driven \
             through eval_chunk_partials by their leader"
        );
        self.fill_scratch(lam, gamma);

        // Merge partials in chunk-index order — the grid is fixed, so the
        // floating-point summation order is identical at any thread count.
        // The merge target is the result's own gradient vector (it must be
        // owned by the ObjectiveResult, so this is the one per-call
        // allocation); all hot-loop scratch lives in the chunk slots.
        let mut ax = vec![0.0f32; self.lp.dual_dim()];
        let mut cx = 0.0f64;
        let mut xsq = 0.0f64;
        for slot in &self.scratch {
            let s = lock_scratch(slot);
            for (g, p) in ax.iter_mut().zip(&s.ax) {
                *g += *p;
            }
            cx += s.cx;
            xsq += s.xsq;
        }
        for (g, b) in ax.iter_mut().zip(&self.full_b) {
            *g -= *b;
        }
        ObjectiveResult::assemble(ax, cx, xsq, lam, gamma)
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        // On a shard view this fills only the shard's edges (zeros
        // elsewhere) — the distributed workers rely on exactly that.
        let mut out = vec![0.0f32; self.lp.nnz()];
        self.primal_into(lam, gamma, &mut out);
        out
    }

    fn name(&self) -> &'static str {
        "cpu-slab"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::projection::ProjectionKind;
    use crate::reference::CpuObjective;
    use crate::sparse::BlockedMatrix;

    fn tiny_lp() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 2,
            num_dests: 2,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 0, 1],
            a: vec![vec![1.0, 1.0, 1.0, 1.0]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-2.0, -1.0, -1.0, -2.0],
            vec![0.6, 0.6],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn matches_hand_computation_like_reference() {
        let lp = tiny_lp();
        let mut obj = SlabCpuObjective::new(&lp, 1).unwrap();
        let res = obj.calculate(&[0.0, 0.0], 1.0);
        assert!((res.grad[0] - 0.4).abs() < 1e-6, "{:?}", res.grad);
        assert!((res.grad[1] - 0.4).abs() < 1e-6);
        assert!((res.cx - (-4.0)).abs() < 1e-6);
        assert!((res.xsq_weighted - 2.0).abs() < 1e-6);
        assert!((res.dual_obj - (-3.0)).abs() < 1e-6);
        assert_eq!(obj.name(), "cpu-slab");
    }

    #[test]
    fn agrees_with_reference_on_generated_instance() {
        let lp = generate(&SyntheticConfig {
            num_requests: 300,
            num_resources: 24,
            avg_nnz_per_row: 5.0,
            num_families: 2,
            seed: 11,
            ..Default::default()
        });
        let mut slab = SlabCpuObjective::new(&lp, 1).unwrap();
        let mut reference = CpuObjective::new(&lp);
        let mut rng = crate::util::rng::Rng::new(3);
        let lam: Vec<f32> =
            (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.2) as f32).collect();
        let gamma = 0.2;
        let rs = slab.calculate(&lam, gamma);
        let rr = reference.calculate(&lam, gamma);
        for (r, (a, b)) in rs.grad.iter().zip(&rr.grad).enumerate() {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "row {r}: {a} vs {b}");
        }
        assert!((rs.dual_obj - rr.dual_obj).abs() < 1e-4 * (1.0 + rr.dual_obj.abs()));
        assert!((rs.cx - rr.cx).abs() < 1e-4 * (1.0 + rr.cx.abs()));
        let xs = slab.primal(&lam, gamma);
        let xr = reference.primal(&lam, gamma);
        for (e, (a, b)) in xs.iter().zip(&xr).enumerate() {
            assert!((a - b).abs() < 1e-4, "edge {e}: {a} vs {b}");
        }
    }

    #[test]
    fn multithreaded_is_bit_identical_to_single() {
        let lp = generate(&SyntheticConfig {
            num_requests: 800,
            num_resources: 40,
            avg_nnz_per_row: 6.0,
            seed: 5,
            ..Default::default()
        });
        let mut one = SlabCpuObjective::new(&lp, 1).unwrap();
        let mut many = SlabCpuObjective::new(&lp, 7).unwrap();
        assert_eq!(one.num_chunks(), many.num_chunks(), "grid must be thread-independent");
        let lam = vec![0.03f32; lp.dual_dim()];
        let r1 = one.calculate(&lam, 0.1);
        let rn = many.calculate(&lam, 0.1);
        assert_eq!(r1.dual_obj.to_bits(), rn.dual_obj.to_bits());
        assert_eq!(r1.cx.to_bits(), rn.cx.to_bits());
        for (a, b) in r1.grad.iter().zip(&rn.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_overwide_nonseparable_blocks() {
        use crate::sparse::slabs::MAX_WIDTH;
        let deg = MAX_WIDTH + 3;
        let a = BlockedMatrix {
            num_sources: 1,
            num_dests: deg,
            num_families: 1,
            src_ptr: vec![0, deg],
            dest_idx: (0..deg as u32).collect(),
            a: vec![vec![1.0; deg]],
        };
        let lp = MatchingLp::new_uniform(
            a,
            vec![-1.0; deg],
            vec![0.5; deg],
            ProjectionKind::Simplex,
        );
        assert!(SlabCpuObjective::new(&lp, 1).is_err());
    }
}
