//! `dualip-audit` CLI — run the static invariants pass (DESIGN.md §10).
//!
//! ```text
//! cargo run --release --bin audit                   # audit the crate, exit 0/1
//! cargo run --release --bin audit -- --format json  # machine-readable findings
//! cargo run --release --bin audit -- --format sarif # GitHub code scanning
//! cargo run --release --bin audit -- --baseline old.json  # fail on NEW findings only
//! cargo run --release --bin audit -- --update-ratchet
//! cargo run --release --bin audit -- --self-check   # fixtures fire exactly their rules
//! cargo run --release --bin audit -- --root <dir>   # audit another crate root
//! ```
//!
//! Exit codes: 0 clean, 1 findings (or self-check mismatch), 2 usage/IO
//! error — so CI can distinguish "invariant broken" from "auditor broken".
//! With `--baseline`, the exit code reflects *new* findings only: the
//! full report still prints, but grandfathered findings don't gate.

use std::path::PathBuf;
use std::process::ExitCode;

use dualip::analysis;

#[derive(Clone, Copy, PartialEq)]
enum Format {
    Text,
    Json,
    Sarif,
}

struct Args {
    root: PathBuf,
    format: Format,
    update_ratchet: bool,
    self_check: bool,
    baseline: Option<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    // default root: the crate this binary was built from, so plain
    // `cargo run --bin audit` audits the repo no matter the cwd.
    let mut args = Args {
        root: PathBuf::from(env!("CARGO_MANIFEST_DIR")),
        format: Format::Text,
        update_ratchet: false,
        self_check: false,
        baseline: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                args.root =
                    PathBuf::from(it.next().ok_or("--root requires a directory argument")?);
            }
            "--format" => {
                let fmt = it.next().ok_or("--format requires `text`, `json`, or `sarif`")?;
                args.format = match fmt.as_str() {
                    "json" => Format::Json,
                    "text" => Format::Text,
                    "sarif" => Format::Sarif,
                    other => return Err(format!("unknown format {other}")),
                };
            }
            "--baseline" => {
                args.baseline =
                    Some(PathBuf::from(it.next().ok_or("--baseline requires a JSON report path")?));
            }
            "--update-ratchet" => args.update_ratchet = true,
            "--self-check" => args.self_check = true,
            "--help" | "-h" => {
                return Err(
                    "usage: audit [--root DIR] [--format text|json|sarif] \
                     [--baseline REPORT.json] [--update-ratchet] [--self-check]"
                        .to_string(),
                );
            }
            other => return Err(format!("unknown argument {other} (try --help)")),
        }
    }
    Ok(args)
}

fn run() -> Result<ExitCode, String> {
    let args = parse_args()?;

    if args.self_check {
        let results = analysis::self_check(&args.root)?;
        let mut failed = 0usize;
        for r in &results {
            if r.pass() {
                println!("self-check: {} ok ({:?})", r.fixture, r.fired);
            } else {
                failed += 1;
                println!(
                    "self-check: {} FAILED — expected {:?}, fired {:?}",
                    r.fixture, r.expected, r.fired
                );
            }
        }
        println!("self-check: {} fixture(s), {} failure(s)", results.len(), failed);
        return Ok(if failed == 0 { ExitCode::SUCCESS } else { ExitCode::from(1) });
    }

    let report = analysis::audit_tree(&args.root)?;
    if args.update_ratchet {
        analysis::update_ratchet(&args.root, &report)?;
        println!(
            "wrote analysis/ratchet.toml ({} module.metric count(s))",
            report.counts.values().filter(|&&v| v > 0).count()
        );
    }
    match args.format {
        Format::Json => print!("{}", report.render_json()),
        Format::Sarif => print!("{}", report.render_sarif()),
        Format::Text => print!("{}", report.render_text()),
    }

    if let Some(path) = &args.baseline {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read baseline {}: {e}", path.display()))?;
        let base = analysis::Baseline::parse(&text)
            .map_err(|e| format!("parse baseline {}: {e}", path.display()))?;
        let new = base.new_findings(&report);
        eprintln!(
            "differential: {} finding(s) total, {} in baseline, {} new",
            report.findings.len(),
            base.len(),
            new.len()
        );
        for f in &new {
            eprintln!("differential: NEW {f}");
        }
        return Ok(if new.is_empty() { ExitCode::SUCCESS } else { ExitCode::from(1) });
    }

    Ok(if report.clean() { ExitCode::SUCCESS } else { ExitCode::from(1) })
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("audit: {e}");
            ExitCode::from(2)
        }
    }
}
