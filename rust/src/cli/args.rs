//! Minimal dependency-free argument parser: `--key value` pairs and
//! `--flag` booleans after a positional subcommand.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.kv.insert(key, v);
                }
                _ => out.flags.push(key),
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.kv.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("solve --sources 1000 --workers 4 --precondition");
        assert_eq!(a.subcommand.as_deref(), Some("solve"));
        assert_eq!(a.usize_or("sources", 0).unwrap(), 1000);
        assert_eq!(a.usize_or("workers", 1).unwrap(), 4);
        assert!(a.flag("precondition"));
        assert!(!a.flag("missing"));
        assert_eq!(a.usize_or("iters", 200).unwrap(), 200);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("solve --shift -3.5");
        // "-3.5" doesn't start with "--" so it is a value
        assert_eq!(a.f64_or("shift", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn bad_int_errors() {
        let a = parse("solve --sources abc");
        assert!(a.usize_or("sources", 0).is_err());
    }
}
