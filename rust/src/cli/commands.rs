//! CLI subcommands — each experiment driver (DESIGN.md §4 experiment
//! index) emits the CSV series behind the paper's figures plus a console
//! summary. Shared between `dualip` and the examples.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::args::Args;
use crate::backend::{CpuBackend, KernelTiers, ShardedSlabObjective, SlabCpuObjective};
use crate::distributed::{
    solve_distributed, solve_distributed_driver, DistributedSolve, ExecStrategy, LinkModel,
};
use crate::gen::{generate, workloads, SyntheticConfig};
use crate::metrics::{comm_report, shard_report, solve_report};
use crate::problem::{check_primal, jacobi_row_normalize, MatchingLp, ObjectiveFunction};
use crate::projection::{registry, ProjectionKind, ProjectionMap};
use crate::reference::CpuObjective;
use crate::runtime::{default_artifacts_dir, HloObjective};
use crate::solver::{
    maximize_with, Agd, DriverOptions, GammaSchedule, Maximizer, SolveOptions, SolveResult,
};
use crate::util::csv::CsvWriter;

pub fn usage() -> &'static str {
    "dualip — DuaLip-GPU reproduction (rust + JAX/Pallas AOT)\n\
     \n\
     USAGE: dualip <subcommand> [--flags]\n\
     \n\
     SUBCOMMANDS\n\
       solve             solve a synthetic matching LP\n\
         --sources N --dests N --nnz-per-row F --families N --seed S\n\
         --backend slab|sharded-slab|reference|hlo|dist   --iters N\n\
         --shards S         shard count: slab with S>1 runs the chunk-\n\
                            sharded objective (bit-identical to S=1);\n\
                            for --backend dist it sizes the worker pool\n\
                            (overriding --workers N, the legacy spelling,\n\
                            default 2) and --exec slab|hlo picks the\n\
                            worker execution strategy\n\
         --obj-threads N    slab objective pool width per shard (results\n\
                            are bit-identical at any width; default 1)\n\
         --gamma F | --gamma-decay init,floor,factor,every\n\
         --max-wall-ms F    wall-clock deadline enforced by the solve\n\
                            driver between iterations (stop reason\n\
                            Deadline; the anytime λ is still returned)\n\
         --record-every N   trajectory record cadence (the stopping\n\
                            iteration is always recorded)\n\
         --projection SPEC  blockwise polytope from the operator registry\n\
                            (simplex | box | capped_simplex:c:t |\n\
                             weighted_simplex:s:w1,w2,.. | box_vec:u1,u2,..;\n\
                             every family runs on the slab, sharded and\n\
                             reference CPU backends; only simplex/box have\n\
                             HLO artifacts — use --backend slab otherwise)\n\
         --count-cap M      append the global row Σx ≤ M (paper §4)\n\
         --precondition --primal-scaling --csv PATH\n\
       distributed       E15: sharded execution through the device-thread\n\
                         worker pool, with λ-only comm accounting\n\
         --shards S --exec slab|hlo --obj-threads N --iters N\n\
         --max-wall-ms F    per-solve deadline (driver-enforced)\n\
         --verify           assert the sharded solve is bit-identical to\n\
                            the single-shard slab solve (slab exec only,\n\
                            incompatible with --max-wall-ms)\n\
         (+ the solve workload/schedule/conditioning flags)\n\
       parity            E1/E2: baseline-vs-accelerated trajectories (Fig 1/2)\n\
         --sources N --iters N --out-dir results/\n\
       ablation-precond  E5: Jacobi preconditioning on/off (Fig 4)\n\
         --sources N --iters N --ref-iters N --out-dir results/\n\
       ablation-gamma    E6: γ continuation vs fixed (Fig 5)\n\
         --sources N --iters N --ref-iters N --out-dir results/\n\
       engine-batch      E12: warm-started repeated-solve engine on a\n\
                         perturbation stream (cold vs warm, matched stop);\n\
                         the warm stream runs on the cooperative executor\n\
                         (time-sliced drivers, round-robin quanta)\n\
         --sources N --dests N --nnz-per-row F --seed S\n\
         --jobs N --threads N --perturb F --warm-tail N\n\
         --backend slab|sharded-slab|reference --obj-threads N --shards S\n\
         --iters N --stall-tol F --record-every N --out-dir results/\n\
         --max-wall-ms F    per-job deadline for the warm stream (the\n\
                            engine_report line counts deadline/cancel\n\
                            stops per batch)\n\
         --quantum N        driver iterations per job per round (default\n\
                            16; results are quantum-invariant)\n\
       serve             E17: resident daemon — bounded request queue with\n\
                         admission control over the cooperative executor,\n\
                         serving a drifting instance stream through\n\
                         in-place plane deltas (zero slab rebuilds)\n\
         --sources N --dests N --nnz-per-row F --seed S\n\
         --requests N --burst N   stream length and submit burst size\n\
                            (burst > --max-queue exercises shedding)\n\
         --drift F --heavy-frac F   per-request c/b drift magnitude and\n\
                            heavy-request (drift ×4) fraction\n\
         --slo-light-ms F --slo-heavy-ms F   SLO budgets; the remaining\n\
                            budget at solve time becomes the driver\n\
                            deadline, exhausted budgets are shed\n\
         --threads N --obj-threads N --quantum N --max-queue N\n\
         --warm-tail N --cache-cap N --iters N --stall-tol F\n\
         --snapshot PATH    write the durable warm-start snapshot (dual\n\
                            cache + parked checkpoints) after the drain\n\
         --audit-parity     delta parity gate per mutation + a final\n\
                            patched-slab vs rebuild bit comparison\n\
         --out-dir results/\n\
       info              artifact + environment report\n\
     \n\
     Artifacts default to ./artifacts ($DUALIP_ARTIFACTS overrides)."
}

fn gamma_schedule(args: &Args) -> Result<GammaSchedule> {
    if let Some(spec) = args.get("gamma-decay") {
        let p: Vec<&str> = spec.split(',').collect();
        if p.len() != 4 {
            return Err(anyhow!("--gamma-decay wants init,floor,factor,every"));
        }
        Ok(GammaSchedule::Decay {
            init: p[0].parse()?,
            floor: p[1].parse()?,
            factor: p[2].parse()?,
            every: p[3].parse()?,
        })
    } else {
        Ok(GammaSchedule::Fixed(args.f64_or("gamma", 0.01)? as f32))
    }
}

fn solve_options(args: &Args) -> Result<SolveOptions> {
    Ok(SolveOptions {
        max_iters: args.usize_or("iters", 200)?,
        max_step_size: args.f64_or("max-step", 1e-3)?,
        initial_step_size: args.f64_or("init-step", 1e-5)?,
        gamma: gamma_schedule(args)?,
        record_every: args.usize_or("record-every", 1)?,
        ..Default::default()
    })
}

/// Driver policy from `--max-wall-ms` (shared by `solve`, `distributed`
/// and `engine-batch`): a wall-clock deadline enforced by the steppable
/// solve driver between iterations. Deadline-stopped solves report
/// `StopReason::Deadline` and still carry their anytime λ.
fn driver_options(args: &Args) -> Result<DriverOptions> {
    Ok(match args.get("max-wall-ms") {
        None => DriverOptions::default(),
        Some(v) => {
            let ms: f64 =
                v.parse().map_err(|_| anyhow!("--max-wall-ms: bad float {v:?}"))?;
            DriverOptions::with_deadline_ms(ms)
        }
    })
}

fn workload(args: &Args) -> Result<SyntheticConfig> {
    let mut cfg = SyntheticConfig {
        num_requests: args.usize_or("sources", 50_000)?,
        num_resources: args.usize_or("dests", 500)?,
        avg_nnz_per_row: args.f64_or("nnz-per-row", 10.0)?,
        num_families: args.usize_or("families", 1)?,
        seed: args.u64_or("seed", 0)?,
        ..SyntheticConfig::default_with(args.u64_or("seed", 0)?)
    };
    if let Some(spec) = args.get("projection") {
        cfg.kind = ProjectionKind::parse(spec).ok_or_else(|| {
            anyhow!(
                "--projection: unknown spec {spec:?} (registered families: {})",
                registry::families().join(", ")
            )
        })?;
    }
    Ok(cfg)
}

/// Worker execution strategy from `--exec slab|hlo` (shared by `solve
/// --backend dist` and the `distributed` subcommand).
fn exec_strategy(args: &Args, obj_threads: usize) -> Result<ExecStrategy> {
    match args.get_or("exec", "slab") {
        "slab" => Ok(ExecStrategy::Slab { threads: obj_threads }),
        "hlo" => Ok(ExecStrategy::Hlo { artifacts: default_artifacts_dir() }),
        other => Err(anyhow!("unknown --exec {other:?} (slab|hlo)")),
    }
}

/// Communication + per-shard + wire-time reports for a distributed solve
/// (shared by `solve --backend dist` and the `distributed` subcommand).
fn print_distributed_reports(out: &DistributedSolve, dual_dim: usize, tiers: &KernelTiers) {
    let iters = out.result.iterations as u64;
    println!("{}", comm_report(&out.comm, iters));
    println!("{}", shard_report(&out.shard_eval_ms, &out.comm, iters, tiers));
    println!(
        "estimated NCCL wire time/iter: nvlink {:.1}µs, ethernet {:.1}µs",
        LinkModel::nvlink().iter_time(dual_dim) * 1e6,
        LinkModel::ethernet().iter_time(dual_dim) * 1e6,
    );
}

fn write_trajectory(path: &str, label: &str, r: &SolveResult) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["label", "iter", "dual_obj", "grad_norm", "infeas", "gamma", "step", "wall_ms"],
    )?;
    for t in &r.trajectory {
        w.row(&[
            label.to_string(),
            t.iter.to_string(),
            format!("{:.9e}", t.dual_obj),
            format!("{:.6e}", t.grad_norm),
            format!("{:.6e}", t.infeas_pos_norm),
            format!("{}", t.gamma),
            format!("{:.6e}", t.step_size),
            format!("{:.3}", t.wall_ms),
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// `dualip solve`
pub fn cmd_solve(args: &Args) -> Result<()> {
    let cfg = workload(args)?;
    let opts = solve_options(args)?;
    let backend = args.get_or("backend", "hlo").to_string();
    let workers = args.usize_or("workers", 2)?;
    let art = default_artifacts_dir();

    eprintln!(
        "generating I={} J={} ν={} m={} seed={}…",
        cfg.num_requests, cfg.num_resources, cfg.avg_nnz_per_row, cfg.num_families, cfg.seed
    );
    let mut lp = generate(&cfg);
    // append the global row BEFORE conditioning so jacobi normalization
    // sees (and scales) it like every other dual row
    if let Some(m) = args.get("count-cap") {
        let cap: f32 = m.parse().map_err(|_| anyhow!("--count-cap: bad float {m:?}"))?;
        lp.push_global_row(vec![1.0; lp.nnz()], cap);
        eprintln!("global count row appended: Σx ≤ {cap}");
    }
    if args.flag("precondition") {
        let s = jacobi_row_normalize(&mut lp);
        eprintln!("jacobi row normalization applied ({} empty rows)", s.empty_rows);
    }
    if args.flag("primal-scaling") {
        crate::problem::apply_primal_scaling(&mut lp);
        eprintln!("primal scaling applied");
    }
    eprintln!(
        "nnz={} dual_dim={} projection={}",
        lp.nnz(),
        lp.dual_dim(),
        cfg.kind.spec()
    );

    let init = vec![0.0f32; lp.dual_dim()];
    let dopts = driver_options(args)?;
    let solve = |obj: &mut dyn ObjectiveFunction, dopts: DriverOptions| {
        maximize_with(Box::new(Agd::default().stepper()), obj, &init, &opts, dopts)
    };
    let shards = args.usize_or("shards", 1)?;
    let obj_threads = args.usize_or("obj-threads", 1)?;
    let (label, result) = match backend.as_str() {
        "slab" | "sharded-slab" => {
            // slab with --shards > 1 (or the explicit sharded-slab
            // spelling) runs the chunk-sharded objective — bit-identical
            // to the single-shard slab solve at any shard count. An
            // explicit --shards is always honored (sharded-slab merely
            // changes the DEFAULT to 2, matching engine-batch semantics).
            let shards = if backend == "sharded-slab" && args.get("shards").is_none() {
                2
            } else {
                shards
            };
            if backend == "sharded-slab" || shards > 1 {
                let mut obj = ShardedSlabObjective::new(&lp, shards, obj_threads)
                    .map_err(anyhow::Error::msg)?;
                eprintln!(
                    "sharded slab backend: {} shards over {} chunks \
                     (imbalance {:.2}), {obj_threads} threads/shard",
                    obj.num_shards(),
                    obj.num_chunks(),
                    obj.imbalance(),
                );
                let r = solve(&mut obj, dopts.clone());
                println!("{}", comm_report(&obj.comm(), r.iterations as u64));
                println!(
                    "{}",
                    shard_report(
                        obj.shard_eval_ms(),
                        &obj.comm(),
                        r.iterations as u64,
                        &obj.kernel_tiers()
                    )
                );
                ("sharded-slab", r)
            } else {
                let mut obj =
                    SlabCpuObjective::new(&lp, obj_threads).map_err(anyhow::Error::msg)?;
                eprintln!(
                    "slab backend: {} buckets, {} chunks, {} threads, padding factor {:.2}",
                    obj.layout().num_launches(),
                    obj.num_chunks(),
                    obj.threads(),
                    obj.layout().padding_factor()
                );
                ("slab", solve(&mut obj, dopts.clone()))
            }
        }
        "cpu" | "reference" => {
            let mut obj = CpuObjective::new(&lp);
            ("reference", solve(&mut obj, dopts.clone()))
        }
        "hlo" => {
            let mut obj = HloObjective::new(&lp, &art)?;
            obj.warmup()?;
            let r = solve(&mut obj, dopts.clone());
            eprintln!("phase timers: {}", obj.timers.report());
            ("hlo", r)
        }
        "dist" => {
            // device-thread worker pool; slab execution by default
            // (--exec hlo restores the artifact-gated path)
            let workers = if args.get("shards").is_some() { shards.max(1) } else { workers };
            let strategy = exec_strategy(args, obj_threads)?;
            let lp_arc = Arc::new(lp);
            let out =
                solve_distributed_driver(lp_arc.clone(), strategy, workers, &opts, dopts.clone())?;
            print_distributed_reports(&out, lp_arc.dual_dim(), &KernelTiers::of_lp(&lp_arc));
            println!("{}", solve_report("dist", &out.result));
            if let Some(csv) = args.get("csv") {
                write_trajectory(csv, "dist", &out.result)?;
            }
            return Ok(());
        }
        other => {
            return Err(anyhow!(
                "unknown backend {other:?} (slab|sharded-slab|reference|hlo|dist)"
            ))
        }
    };
    println!("{}", solve_report(label, &result));
    if let Some(csv) = args.get("csv") {
        write_trajectory(csv, label, &result)?;
    }
    Ok(())
}

/// `dualip distributed` — E15 driver: a sharded solve through the
/// device-thread `WorkerPool` (slab execution by default; `--exec hlo`
/// selects the artifact-gated path), reporting the λ-only communication
/// accounting, per-shard compute times, and — with `--verify` — asserting
/// the §6 determinism contract: the S-shard solve is bit-identical to the
/// single-shard slab solve.
pub fn cmd_distributed(args: &Args) -> Result<()> {
    let cfg = workload(args)?;
    let opts = solve_options(args)?;
    let shards = args.usize_or("shards", 4)?;
    let obj_threads = args.usize_or("obj-threads", 1)?;
    let exec = args.get_or("exec", "slab").to_string();

    let mut lp = generate(&cfg);
    if let Some(m) = args.get("count-cap") {
        let cap: f32 = m.parse().map_err(|_| anyhow!("--count-cap: bad float {m:?}"))?;
        lp.push_global_row(vec![1.0; lp.nnz()], cap);
    }
    if args.flag("precondition") {
        jacobi_row_normalize(&mut lp);
    }
    let lp = Arc::new(lp);
    eprintln!(
        "distributed: I={} J={} nnz={} dual_dim={} shards={shards} exec={exec}",
        lp.num_sources(),
        lp.num_dests(),
        lp.nnz(),
        lp.dual_dim()
    );

    let strategy = exec_strategy(args, obj_threads)?;
    let dopts = driver_options(args)?;
    let out = solve_distributed_driver(lp.clone(), strategy, shards, &opts, dopts.clone())?;
    println!("{}", solve_report(&format!("dist-{exec}-{shards}shard"), &out.result));
    print_distributed_reports(&out, lp.dual_dim(), &KernelTiers::of_lp(&lp));

    if args.flag("verify") {
        if exec != "slab" {
            return Err(anyhow!("--verify requires --exec slab (the bit-identity contract)"));
        }
        if dopts.deadline_ms.is_some() {
            return Err(anyhow!(
                "--verify is incompatible with --max-wall-ms (a wall-clock deadline \
                 stops at a timing-dependent iteration, so bit-identity is undefined)"
            ));
        }
        let mut one = SlabCpuObjective::new(&lp, obj_threads).map_err(anyhow::Error::msg)?;
        let mut agd = Agd::default();
        let r1 = agd.maximize(&mut one, &vec![0.0f32; lp.dual_dim()], &opts);
        anyhow::ensure!(
            r1.lam.len() == out.result.lam.len()
                && r1
                    .lam
                    .iter()
                    .zip(&out.result.lam)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
            "{shards}-shard solve diverged from the single-shard slab solve"
        );
        println!("verified: {shards}-shard solve bit-identical to single-shard slab");
    }
    if let Some(csv) = args.get("csv") {
        write_trajectory(csv, &format!("dist_{exec}_{shards}"), &out.result)?;
    }
    Ok(())
}

/// `dualip parity` — E1 (Fig 1) + E2 (Fig 2): run the baseline and the
/// accelerated backends on the identical instance (same seed) and emit the
/// dual-objective trajectories plus per-iteration relative error.
pub fn cmd_parity(args: &Args) -> Result<()> {
    let sources = args.usize_or("sources", 20_000)?;
    let iters = args.usize_or("iters", 150)?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let art = default_artifacts_dir();

    let cfg = SyntheticConfig {
        num_requests: sources,
        ..workloads::parity_instance(args.u64_or("seed", 0)?)
    };
    // The paper's production stack conditions first (§5.1); parity compares
    // implementations of the SAME conditioned pipeline.
    let mut lp_raw = generate(&cfg);
    jacobi_row_normalize(&mut lp_raw);
    let lp = Arc::new(lp_raw);
    let opts = SolveOptions {
        max_iters: iters,
        gamma: GammaSchedule::Fixed(0.01),
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let init = vec![0.0f32; lp.dual_dim()];

    eprintln!("parity: I={} nnz={} iters={iters}", lp.num_sources(), lp.nnz());
    let mut agd = Agd::default();
    let mut cpu = CpuObjective::new(&lp);
    let r_cpu = agd.maximize(&mut cpu, &init, &opts);
    eprintln!("{}", solve_report("baseline(cpu)", &r_cpu));

    let mut runs = vec![("baseline_cpu".to_string(), r_cpu)];
    {
        let mut hlo = HloObjective::new(&lp, &art)?;
        hlo.warmup()?;
        let r = agd.maximize(&mut hlo, &init, &opts);
        eprintln!("{}", solve_report("hlo-1dev", &r));
        runs.push(("hlo_1dev".to_string(), r));
    }
    for workers in [2usize, 4] {
        let out = solve_distributed(lp.clone(), &art, workers, &opts)?;
        eprintln!("{}", solve_report(&format!("dist-{workers}dev"), &out.result));
        runs.push((format!("dist_{workers}dev"), out.result));
    }

    // Fig 1: overlaid trajectories
    let mut w = CsvWriter::create(
        format!("{out_dir}/fig1_parity.csv"),
        &["impl", "iter", "dual_obj"],
    )?;
    for (label, r) in &runs {
        for t in &r.trajectory {
            w.row(&[label.clone(), t.iter.to_string(), format!("{:.9e}", t.dual_obj)])?;
        }
    }
    w.flush()?;

    // Fig 2: relative error vs the baseline trajectory
    let base = &runs[0].1.trajectory;
    let mut w2 = CsvWriter::create(
        format!("{out_dir}/fig2_relerr.csv"),
        &["impl", "iter", "rel_err"],
    )?;
    let mut max_tail_err = 0.0f64;
    for (label, r) in runs.iter().skip(1) {
        for (tb, tr) in base.iter().zip(&r.trajectory) {
            let rel = (tb.dual_obj - tr.dual_obj).abs() / tb.dual_obj.abs().max(1e-30);
            w2.row(&[label.clone(), tr.iter.to_string(), format!("{rel:.6e}")])?;
            if tr.iter >= 100 {
                max_tail_err = max_tail_err.max(rel);
            }
        }
    }
    w2.flush()?;
    println!(
        "parity: wrote {out_dir}/fig1_parity.csv, {out_dir}/fig2_relerr.csv; \
         max rel err after iter 100 = {max_tail_err:.3e} (paper: < 1e-2)"
    );
    Ok(())
}

/// Long high-precision solve (HLO path) to estimate the converged dual
/// optimum L̂ for the Fig 4/5 |L − L̂| series.
fn reference_optimum(
    lp: &MatchingLp,
    gamma: f32,
    iters: usize,
    art: &std::path::Path,
    precondition: bool,
) -> Result<f64> {
    // Work on a preconditioned copy for fast convergence; the optimum VALUE
    // is invariant under row scaling (same perturbed primal). The ablation
    // drivers are simplex instances, so the reference pins that polytope.
    let mut lp_ref = lp.clone();
    lp_ref.projection = ProjectionMap::Uniform(ProjectionKind::Simplex);
    if precondition {
        jacobi_row_normalize(&mut lp_ref);
    }
    let mut obj = HloObjective::new(&lp_ref, art)?;
    obj.warmup()?;
    let mut agd = Agd::default();
    let opts = SolveOptions {
        max_iters: iters,
        gamma: GammaSchedule::Fixed(gamma),
        max_step_size: if precondition { 1.0 } else { 1e-3 },
        initial_step_size: 1e-5,
        record_every: iters.max(1),
        ..Default::default()
    };
    let r = agd.maximize(&mut obj, &vec![0.0; lp_ref.dual_dim()], &opts);
    Ok(r.trajectory.iter().map(|t| t.dual_obj).fold(f64::NEG_INFINITY, f64::max))
}

/// `dualip ablation-precond` — E5 (Fig 4): log|L − L̂| with and without
/// Jacobi row normalization at fixed γ.
pub fn cmd_ablation_precond(args: &Args) -> Result<()> {
    let sources = args.usize_or("sources", 50_000)?;
    let iters = args.usize_or("iters", 300)?;
    let ref_iters = args.usize_or("ref-iters", 2000)?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let gamma = args.f64_or("gamma", 0.01)? as f32;
    let art = default_artifacts_dir();

    let cfg = SyntheticConfig {
        num_requests: sources,
        ..workloads::ablation_instance(args.u64_or("seed", 0)?)
    };
    let lp = generate(&cfg);
    eprintln!("ablation-precond: I={} nnz={}", lp.num_sources(), lp.nnz());

    let l_hat = reference_optimum(&lp, gamma, ref_iters, &art, true)?;
    eprintln!("reference optimum L̂ = {l_hat:.9e}");

    let mut runs = Vec::new();
    for precondition in [false, true] {
        let mut lp_run = lp.clone();
        lp_run.projection = ProjectionMap::Uniform(ProjectionKind::Simplex);
        lp_run.primal_scale = None;
        lp_run.global_rows = Vec::new();
        // Preconditioning rescales the dual Hessian to ~unit diagonal, so
        // the stable step cap is ~1/L(AAᵀ)≈1 instead of the paper's 1e-3.
        let max_step = if precondition {
            jacobi_row_normalize(&mut lp_run);
            1.0
        } else {
            1e-3
        };
        let mut obj = HloObjective::new(&lp_run, &art)?;
        obj.warmup()?;
        let mut agd = Agd::default();
        let opts = SolveOptions {
            max_iters: iters,
            gamma: GammaSchedule::Fixed(gamma),
            max_step_size: max_step,
            ..Default::default()
        };
        let r = agd.maximize(&mut obj, &vec![0.0; lp_run.dual_dim()], &opts);
        let label = if precondition { "jacobi" } else { "none" };
        eprintln!("{}", solve_report(label, &r));
        runs.push((label.to_string(), r));
    }

    let mut w = CsvWriter::create(
        format!("{out_dir}/fig4_precond.csv"),
        &["precond", "iter", "dual_obj", "log10_gap"],
    )?;
    for (label, r) in &runs {
        for t in &r.trajectory {
            let gap = (l_hat - t.dual_obj).abs().max(1e-300);
            w.row(&[
                label.clone(),
                t.iter.to_string(),
                format!("{:.9e}", t.dual_obj),
                format!("{:.6}", gap.log10()),
            ])?;
        }
    }
    w.flush()?;

    // headline: iterations to reach gap ≤ 1% of initial gap
    let mut summary = Vec::new();
    for (label, r) in &runs {
        let g0 = (l_hat - r.trajectory[0].dual_obj).abs();
        let hit = r
            .trajectory
            .iter()
            .find(|t| (l_hat - t.dual_obj).abs() <= 0.01 * g0)
            .map(|t| t.iter as i64)
            .unwrap_or(-1);
        summary.push(format!("{label}: iters-to-1%-gap = {hit}"));
    }
    println!("ablation-precond: wrote {out_dir}/fig4_precond.csv; {}", summary.join(", "));
    Ok(())
}

/// `dualip ablation-gamma` — E6 (Fig 5): γ continuation (0.16→0.01 halved
/// every 25) vs fixed levels.
pub fn cmd_ablation_gamma(args: &Args) -> Result<()> {
    let sources = args.usize_or("sources", 50_000)?;
    let iters = args.usize_or("iters", 300)?;
    let ref_iters = args.usize_or("ref-iters", 2000)?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let art = default_artifacts_dir();

    let cfg = SyntheticConfig {
        num_requests: sources,
        ..workloads::ablation_instance(args.u64_or("seed", 0)?)
    };
    // γ continuation is evaluated on the conditioned problem (the paper's
    // standard stack, §5.1) so that schedule effects — not raw
    // ill-conditioning — dominate the curves.
    let mut lp = generate(&cfg);
    jacobi_row_normalize(&mut lp);
    eprintln!("ablation-gamma: I={} nnz={}", lp.num_sources(), lp.nnz());

    // L̂ at the target (floor) regularization level 0.01.
    let l_hat = reference_optimum(&lp, 0.01, ref_iters, &art, false)?;
    eprintln!("reference optimum L̂(γ=0.01) = {l_hat:.9e}");

    let schedules: Vec<(&str, GammaSchedule)> = vec![
        ("fixed_0.01", GammaSchedule::Fixed(0.01)),
        ("fixed_0.16", GammaSchedule::Fixed(0.16)),
        ("decay_0.16_to_0.01", GammaSchedule::paper_fig5()),
    ];

    let mut w = CsvWriter::create(
        format!("{out_dir}/fig5_gamma.csv"),
        &["schedule", "iter", "gamma", "dual_obj", "log10_gap"],
    )?;
    let mut summaries = Vec::new();
    for (label, sched) in schedules {
        let mut obj = HloObjective::new(&lp, &art)?;
        obj.warmup()?;
        let mut agd = Agd::default();
        let opts = SolveOptions {
            max_iters: iters,
            gamma: sched,
            // conditioned Hessian ⇒ unit-scale cap; continuation rescales
            // the cap with γ automatically (step_cap_scale)
            max_step_size: 1.0,
            initial_step_size: 1e-4,
            ..Default::default()
        };
        let r = agd.maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);
        eprintln!("{}", solve_report(label, &r));
        for t in &r.trajectory {
            let gap = (l_hat - t.dual_obj).abs().max(1e-300);
            w.row(&[
                label.to_string(),
                t.iter.to_string(),
                format!("{}", t.gamma),
                format!("{:.9e}", t.dual_obj),
                format!("{:.6}", gap.log10()),
            ])?;
        }
        let final_gap = (l_hat - r.trajectory.last().unwrap().dual_obj).abs();
        summaries.push(format!("{label}: final |L−L̂| = {final_gap:.3e}"));
    }
    w.flush()?;
    println!("ablation-gamma: wrote {out_dir}/fig5_gamma.csv; {}", summaries.join(", "));
    Ok(())
}

/// `dualip engine-batch` — E12: the serving-side repeated-solve pattern.
///
/// Generates a base instance, conditions it (§5.1), derives a stream of
/// same-pattern instances with perturbed `c`/`b` (the production refresh
/// pattern), and solves the stream twice under a **matched stopping
/// criterion** (objective stall at the floor γ):
///
/// - **cold**: every instance from λ = 0 with the full γ-continuation;
/// - **warm**: through a `SolveEngine` primed on the base solve — each
///   re-solve starts from the cached dual with a short γ tail, batched
///   across the thread pool.
///
/// Reports iterations-to-stop and wall-clock per job for both, and writes
/// `BENCH_engine_warmstart.json` for cross-PR perf tracking.
pub fn cmd_engine_batch(args: &Args) -> Result<()> {
    use crate::engine::{EngineConfig, SolveEngine, SolveJob};
    use crate::gen::workloads::{perturbation_sequence, PerturbSpec};
    use crate::metrics::{coop_report, engine_report, BenchJson, JsonValue};
    use crate::solver::StoppingCriteria;

    let cfg = workload(args)?;
    let jobs = args.usize_or("jobs", 12)?;
    let threads = args.usize_or("threads", 8)?;
    let warm_tail = args.usize_or("warm-tail", 5)?;
    let perturb = args.f64_or("perturb", 0.05)?;
    let stall_tol = args.f64_or("stall-tol", 1e-7)?;
    let max_iters = args.usize_or("iters", 2_000)?;
    let record_every = args.usize_or("record-every", 1_000)?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let backend_spec = args.get_or("backend", "slab");
    let backend = CpuBackend::parse(backend_spec).ok_or_else(|| {
        anyhow!("--backend: unknown {backend_spec:?} (slab|sharded-slab|reference)")
    })?;
    let obj_threads = args.usize_or("obj-threads", 1)?;
    let shards = args.usize_or("shards", 1)?;
    let quantum = args.usize_or("quantum", 16)?;
    let deadline_ms = driver_options(args)?.deadline_ms;

    eprintln!(
        "engine-batch: I={} J={} ν={} seed={} jobs={jobs} threads={threads} perturb={perturb} \
         backend={} shards={shards}",
        cfg.num_requests,
        cfg.num_resources,
        cfg.avg_nnz_per_row,
        cfg.seed,
        backend.name()
    );
    let mut base = generate(&cfg);
    jacobi_row_normalize(&mut base);
    let base_nnz = base.nnz();

    // Matched stopping criterion for BOTH paths: objective stall at the
    // floor γ (raw ‖∇g‖ does not vanish at a constrained optimum, so a
    // gradient tolerance is not reachable on matching LPs).
    let opts = SolveOptions {
        max_iters,
        max_step_size: 1.0, // conditioned Hessian ⇒ unit-scale cap
        initial_step_size: 1e-4,
        gamma: GammaSchedule::paper_fig5(),
        stopping: StoppingCriteria {
            stall_tol: Some(stall_tol),
            stall_patience: 10,
            ..Default::default()
        },
        record_every,
    };
    let spec = PerturbSpec { c_rel: perturb, b_rel: perturb };
    let seq_seed = cfg.seed.wrapping_add(1);

    // --- cold baseline: every instance from scratch ----------------------
    // (no deadline: the cold column is the undisturbed iteration count)
    let cold_engine = SolveEngine::new(EngineConfig {
        opts: opts.clone(),
        warm_tail,
        threads: 1,
        cache_capacity: 0, // disables warm starting
        backend,
        objective_threads: obj_threads,
        shards,
        deadline_ms: None,
        quantum,
    });
    let cold_results: Vec<_> = perturbation_sequence(&base, &spec, jobs, seq_seed)
        .into_iter()
        .enumerate()
        .map(|(k, lp)| cold_engine.submit(SolveJob::new(k as u64, lp)))
        .collect();

    // --- warm engine: primed once, then the stream through the
    // cooperative executor (time-sliced drivers, per-job deadlines,
    // γ-checkpoint warm-start publication). The deadline is attached
    // per STREAM job, not to the engine config, so the priming solve is
    // exempt — a deadline-truncated primer would make iter_speedup
    // measure primer truncation instead of warm-starting. -----------------
    let warm_engine = SolveEngine::new(EngineConfig {
        opts: opts.clone(),
        warm_tail,
        threads,
        cache_capacity: 16,
        backend,
        objective_threads: obj_threads,
        shards,
        deadline_ms: None,
        quantum,
    });
    let warm_jobs: Vec<SolveJob> = perturbation_sequence(&base, &spec, jobs, seq_seed)
        .into_iter()
        .enumerate()
        .map(|(k, lp)| {
            let job = SolveJob::new(k as u64, lp);
            match deadline_ms {
                Some(ms) => job.with_deadline_ms(ms),
                None => job,
            }
        })
        .collect();
    let primer = warm_engine.submit(SolveJob::new(u64::MAX, base));
    eprintln!(
        "primed cache from base solve: {} iters, stop {:?}",
        primer.iterations, primer.stop_reason
    );
    let (warm_results, creport) = warm_engine.solve_batch_coop(warm_jobs);

    // --- report ----------------------------------------------------------
    let mut bench = BenchJson::new("engine_warmstart");
    bench
        .meta("sources", JsonValue::UInt(cfg.num_requests as u64))
        .meta("dests", JsonValue::UInt(cfg.num_resources as u64))
        .meta("nnz", JsonValue::UInt(base_nnz as u64))
        .meta("jobs", JsonValue::UInt(jobs as u64))
        .meta("threads", JsonValue::UInt(threads as u64))
        .meta("perturb", JsonValue::Num(perturb))
        .meta("stall_tol", JsonValue::Num(stall_tol))
        .meta("warm_tail", JsonValue::UInt(warm_tail as u64))
        .meta("backend", JsonValue::Str(backend.name().into()))
        .meta("objective_threads", JsonValue::UInt(obj_threads as u64))
        .meta("shards", JsonValue::UInt(shards as u64))
        .meta("quantum", JsonValue::UInt(quantum as u64))
        .meta(
            "deadline_ms",
            deadline_ms.map(JsonValue::Num).unwrap_or_else(|| JsonValue::Str("none".into())),
        )
        .meta("seed", JsonValue::UInt(cfg.seed));

    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "job", "cold iter", "warm iter", "cold ms", "warm ms", "eval ms", "Δobj rel"
    );
    let (mut cold_iter_sum, mut warm_iter_sum) = (0u64, 0u64);
    let (mut cold_ms_sum, mut warm_ms_sum) = (0.0f64, 0.0f64);
    let (mut cold_eval_sum, mut warm_eval_sum) = (0.0f64, 0.0f64);
    for (c, w) in cold_results.iter().zip(&warm_results) {
        let rel = (c.dual_obj - w.dual_obj).abs() / c.dual_obj.abs().max(1.0);
        println!(
            "{:>4} {:>10} {:>10} {:>12.1} {:>12.1} {:>10.1} {:>10.2e}",
            c.id, c.iterations, w.iterations, c.wall_ms, w.wall_ms, w.objective_eval_ms, rel
        );
        bench.row(&[
            ("job", JsonValue::UInt(c.id)),
            ("cold_iters", JsonValue::UInt(c.iterations as u64)),
            ("warm_iters", JsonValue::UInt(w.iterations as u64)),
            ("cold_wall_ms", JsonValue::Num(c.wall_ms)),
            ("warm_wall_ms", JsonValue::Num(w.wall_ms)),
            ("cold_obj_eval_ms", JsonValue::Num(c.objective_eval_ms)),
            ("warm_obj_eval_ms", JsonValue::Num(w.objective_eval_ms)),
            // actual objective name (meta "backend" is the configured
            // choice; this reflects a layout-ineligible fallback)
            ("backend_used", JsonValue::Str(w.backend.to_string())),
            ("cold_obj", JsonValue::Num(c.dual_obj)),
            ("warm_obj", JsonValue::Num(w.dual_obj)),
            ("obj_rel_diff", JsonValue::Num(rel)),
            ("cold_stop", JsonValue::Str(format!("{:?}", c.stop_reason))),
            ("warm_stop", JsonValue::Str(format!("{:?}", w.stop_reason))),
        ]);
        cold_iter_sum += c.iterations as u64;
        warm_iter_sum += w.iterations as u64;
        cold_ms_sum += c.wall_ms;
        warm_ms_sum += w.wall_ms;
        cold_eval_sum += c.objective_eval_ms;
        warm_eval_sum += w.objective_eval_ms;
    }
    let n = cold_results.len().max(1) as f64;
    let iter_speedup = cold_iter_sum as f64 / warm_iter_sum.max(1) as f64;
    bench
        .meta("mean_cold_iters", JsonValue::Num(cold_iter_sum as f64 / n))
        .meta("mean_warm_iters", JsonValue::Num(warm_iter_sum as f64 / n))
        .meta("iter_speedup", JsonValue::Num(iter_speedup))
        .meta("deadline_stops", JsonValue::UInt(creport.deadline_stops as u64))
        .meta("cancelled", JsonValue::UInt(creport.cancelled as u64))
        .meta("coop_rounds", JsonValue::UInt(creport.rounds as u64));
    let path = bench.write(&out_dir)?;

    println!(
        "mean iters: cold {:.1} vs warm {:.1} ({iter_speedup:.2}x fewer); \
         mean wall: cold {:.1}ms vs warm {:.1}ms",
        cold_iter_sum as f64 / n,
        warm_iter_sum as f64 / n,
        cold_ms_sum / n,
        warm_ms_sum / n,
    );
    if let Some(r0) = warm_results.first() {
        println!(
            "objective backend: {} — mean eval: cold {:.1}ms/job, warm {:.1}ms/job",
            r0.backend,
            cold_eval_sum / n,
            warm_eval_sum / n,
        );
    }
    println!("{}", engine_report(&warm_engine.stats()));
    println!("{}", coop_report(&creport));
    println!("wrote {}", path.display());
    Ok(())
}

/// `dualip serve` — E17: the resident serve daemon on a drifting request
/// stream.
///
/// Generates a base instance, conditions it (§5.1), derives a drifting
/// request stream (`gen::workloads::drift_stream` — per-request `c`/`b`
/// drift, occasional heavy requests, per-request SLO budgets) and plays it
/// through [`crate::serve::ServeDaemon`] in bursts. Every request after
/// the first is absorbed as an in-place plane delta against the resident
/// slab (zero rebuilds) and warm-started from the fingerprint cache.
///
/// Reports p50/p99 solve latency, the warm-hit rate and the daemon's
/// operational counters, and writes `BENCH_serve_latency.json` for
/// cross-PR perf tracking.
pub fn cmd_serve(args: &Args) -> Result<()> {
    use crate::gen::workloads::{drift_stream, DriftStreamSpec, PerturbSpec};
    use crate::metrics::{stats, BenchJson, JsonValue};
    use crate::serve::{Outcome, ServeConfig, ServeDaemon};
    use crate::solver::StoppingCriteria;

    let cfg = workload(args)?;
    let requests = args.usize_or("requests", 12)?;
    let burst = args.usize_or("burst", 4)?;
    let drift = args.f64_or("drift", 0.05)?;
    let heavy_frac = args.f64_or("heavy-frac", 0.2)?;
    let slo_light_ms = args.f64_or("slo-light-ms", 250.0)?;
    let slo_heavy_ms = args.f64_or("slo-heavy-ms", 2_000.0)?;
    let threads = args.usize_or("threads", 8)?;
    let obj_threads = args.usize_or("obj-threads", 1)?;
    let quantum = args.usize_or("quantum", 16)?;
    let max_queue = args.usize_or("max-queue", 64)?;
    let warm_tail = args.usize_or("warm-tail", 5)?;
    let cache_cap = args.usize_or("cache-cap", 64)?;
    let stall_tol = args.f64_or("stall-tol", 1e-7)?;
    let max_iters = args.usize_or("iters", 2_000)?;
    let record_every = args.usize_or("record-every", 1_000)?;
    let audit = args.flag("audit-parity");
    let out_dir = args.get_or("out-dir", "results").to_string();

    eprintln!(
        "serve: I={} J={} ν={} seed={} requests={requests} burst={burst} drift={drift} \
         heavy-frac={heavy_frac} threads={threads} max-queue={max_queue}",
        cfg.num_requests, cfg.num_resources, cfg.avg_nnz_per_row, cfg.seed
    );
    let mut base = generate(&cfg);
    jacobi_row_normalize(&mut base);
    let base_nnz = base.nnz();

    // Matched stopping criterion, as in engine-batch: objective stall at
    // the floor γ.
    let opts = SolveOptions {
        max_iters,
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        gamma: GammaSchedule::paper_fig5(),
        stopping: StoppingCriteria {
            stall_tol: Some(stall_tol),
            stall_patience: 10,
            ..Default::default()
        },
        record_every,
    };
    let spec = DriftStreamSpec {
        n: requests,
        drift: PerturbSpec { c_rel: drift, b_rel: drift },
        heavy_frac,
        slo_light_ms,
        slo_heavy_ms,
        ..Default::default()
    };
    let stream = drift_stream(&base, &spec, cfg.seed.wrapping_add(1));
    let heavy_of: std::collections::HashMap<u64, bool> =
        stream.iter().map(|r| (r.id, r.heavy)).collect();

    let mut daemon = ServeDaemon::new(ServeConfig {
        opts,
        warm_tail,
        threads,
        cache_capacity: cache_cap,
        objective_threads: obj_threads,
        quantum,
        max_queue,
        default_slo_ms: None,
        audit_parity: audit,
    });
    let outcomes = daemon.run_stream(&stream, burst);

    // --- report ----------------------------------------------------------
    let mut bench = BenchJson::new("serve_latency");
    bench
        .meta("sources", JsonValue::UInt(cfg.num_requests as u64))
        .meta("dests", JsonValue::UInt(cfg.num_resources as u64))
        .meta("nnz", JsonValue::UInt(base_nnz as u64))
        .meta("requests", JsonValue::UInt(requests as u64))
        .meta("burst", JsonValue::UInt(burst as u64))
        .meta("drift", JsonValue::Num(drift))
        .meta("heavy_frac", JsonValue::Num(heavy_frac))
        .meta("threads", JsonValue::UInt(threads as u64))
        .meta("quantum", JsonValue::UInt(quantum as u64))
        .meta("max_queue", JsonValue::UInt(max_queue as u64))
        .meta("warm_tail", JsonValue::UInt(warm_tail as u64))
        .meta("stall_tol", JsonValue::Num(stall_tol))
        .meta("seed", JsonValue::UInt(cfg.seed));

    println!(
        "{:>4} {:>6} {:>5} {:>7} {:>10} {:>14}  outcome",
        "req", "heavy", "warm", "iters", "wall ms", "stop"
    );
    let mut wall = Vec::new();
    let mut warm_solves = 0usize;
    for o in &outcomes {
        let heavy = heavy_of.get(&o.id).copied().unwrap_or(false);
        match &o.outcome {
            Outcome::Solved(r) => {
                println!(
                    "{:>4} {:>6} {:>5} {:>7} {:>10.1} {:>14}  solved",
                    o.id,
                    heavy,
                    r.warm,
                    r.iterations,
                    r.wall_ms,
                    format!("{:?}", r.stop_reason),
                );
                bench.row(&[
                    ("req", JsonValue::UInt(o.id)),
                    ("heavy", JsonValue::Bool(heavy)),
                    ("outcome", JsonValue::Str("solved".into())),
                    ("warm", JsonValue::Bool(r.warm)),
                    ("iterations", JsonValue::UInt(r.iterations as u64)),
                    ("wall_ms", JsonValue::Num(r.wall_ms)),
                    ("obj_eval_ms", JsonValue::Num(r.objective_eval_ms)),
                    ("dual_obj", JsonValue::Num(r.dual_obj)),
                    ("stop", JsonValue::Str(format!("{:?}", r.stop_reason))),
                ]);
                wall.push(r.wall_ms);
                warm_solves += r.warm as usize;
            }
            Outcome::Shed(reason) => {
                let label = format!("shed:{reason:?}");
                println!(
                    "{:>4} {:>6} {:>5} {:>7} {:>10} {:>14}  {label}",
                    o.id, heavy, "-", "-", "-", "-"
                );
                bench.row(&[
                    ("req", JsonValue::UInt(o.id)),
                    ("heavy", JsonValue::Bool(heavy)),
                    ("outcome", JsonValue::Str(label)),
                ]);
            }
            Outcome::Failed(e) => {
                println!(
                    "{:>4} {:>6} {:>5} {:>7} {:>10} {:>14}  failed: {e}",
                    o.id, heavy, "-", "-", "-", "-"
                );
                bench.row(&[
                    ("req", JsonValue::UInt(o.id)),
                    ("heavy", JsonValue::Bool(heavy)),
                    ("outcome", JsonValue::Str(format!("failed:{e}"))),
                ]);
            }
        }
    }
    if !wall.is_empty() {
        let st = stats(&wall);
        let hit_rate = warm_solves as f64 / wall.len() as f64;
        println!(
            "latency over {} solves: p50 {:.1}ms p99 {:.1}ms (mean {:.1}ms, max {:.1}ms); \
             warm-hit rate {:.0}%",
            st.n,
            st.median,
            st.p99,
            st.mean,
            st.max,
            100.0 * hit_rate,
        );
        bench
            .meta("solved", JsonValue::UInt(st.n as u64))
            .meta("p50_wall_ms", JsonValue::Num(st.median))
            .meta("p99_wall_ms", JsonValue::Num(st.p99))
            .meta("mean_wall_ms", JsonValue::Num(st.mean))
            .meta("warm_hit_rate", JsonValue::Num(hit_rate));
    }
    println!("{}", daemon.report());
    if audit {
        if let Some(r) = daemon.resident() {
            r.parity_check().map_err(|e| anyhow!("parity gate failed: {e}"))?;
            println!("parity: patched resident slab is bit-identical to a from-scratch rebuild");
        }
    }
    if let Some(path) = args.get("snapshot") {
        daemon.save_snapshot(path).map_err(|e| anyhow!("snapshot: {e}"))?;
        println!("wrote warm-start snapshot to {path}");
    }
    let path = bench.write(&out_dir)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `dualip info`
pub fn cmd_info(_args: &Args) -> Result<()> {
    let art = default_artifacts_dir();
    println!("artifacts dir: {}", art.display());
    match crate::runtime::Manifest::load(&art) {
        Ok(m) => {
            println!("  tile_rows = {}", m.tile_rows);
            println!("  widths    = {:?}", m.widths);
            println!("  artifacts = {}", m.entries.len());
        }
        Err(e) => println!("  (no artifacts: {e:#})"),
    }
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
    println!("pjrt platform: {} ({} devices)", client.platform_name(), client.device_count());
    println!("logical workers available: {}", std::thread::available_parallelism()?);
    Ok(())
}

/// Solve + validate a primal — shared tail used by examples and `solve`.
pub fn report_primal(lp: &MatchingLp, obj: &mut dyn ObjectiveFunction, lam: &[f32], gamma: f32) {
    let x = obj.primal(lam, gamma);
    let rep = check_primal(lp, &x, 1e-3);
    println!(
        "primal: cᵀx={:.6e} ‖(Ax−b)₊‖₂={:.3e} max simple viol={:.2e} active rows={:.1}%",
        rep.objective,
        rep.complex_infeas,
        rep.simple_infeas_max,
        rep.active_fraction * 100.0
    );
}
