//! Launcher: argument parsing and the experiment subcommands.

pub mod args;
pub mod commands;

pub use args::Args;

use anyhow::Result;

/// Dispatch a parsed command line.
pub fn run(args: Args) -> Result<()> {
    if args.flag("help") || args.subcommand.is_none() {
        println!("{}", commands::usage());
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "solve" => commands::cmd_solve(&args),
        "distributed" => commands::cmd_distributed(&args),
        "parity" => commands::cmd_parity(&args),
        "ablation-precond" => commands::cmd_ablation_precond(&args),
        "ablation-gamma" => commands::cmd_ablation_gamma(&args),
        "engine-batch" => commands::cmd_engine_batch(&args),
        "serve" => commands::cmd_serve(&args),
        "info" => commands::cmd_info(&args),
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            println!("{}", commands::usage());
            std::process::exit(2);
        }
    }
}
