//! Simulated collectives with byte/op accounting (DESIGN.md §5
//! Substitutions: stands in for torch.distributed + NCCL).
//!
//! The paper's claim (§6) is *structural*: per iteration the pattern is one
//! reduce (SUM, to rank 0) of the gradient (|λ| floats + 2 scalars) and two
//! broadcasts of the (λ₁, λ₂) momentum pair — independent of nnz and the
//! per-GPU column split. These collectives move the same logical payloads
//! over channels and count every byte so the benches can assert the claim
//! (experiment E10).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Byte/op counters shared between leader and workers.
#[derive(Debug, Default)]
pub struct CommStats {
    pub reduce_ops: AtomicU64,
    pub reduce_bytes: AtomicU64,
    pub bcast_ops: AtomicU64,
    pub bcast_bytes: AtomicU64,
    pub scatter_ops: AtomicU64,
    pub scatter_bytes: AtomicU64,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommSnapshot {
    pub reduce_ops: u64,
    pub reduce_bytes: u64,
    pub bcast_ops: u64,
    pub bcast_bytes: u64,
    pub scatter_ops: u64,
    pub scatter_bytes: u64,
}

impl CommStats {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record one broadcast of `n_floats` (leader → all ranks). NCCL
    /// broadcast moves ~n bytes per link regardless of fan-out; we count
    /// the logical payload once, as the paper does ("each of size |λ|").
    pub fn record_broadcast(&self, n_floats: usize) {
        self.bcast_ops.fetch_add(1, Ordering::Relaxed);
        self.bcast_bytes.fetch_add(4 * n_floats as u64, Ordering::Relaxed);
    }

    /// Record one SUM-reduce to rank 0 of `n_floats` plus `n_scalars` f64
    /// side values (objective, regularization).
    pub fn record_reduce(&self, n_floats: usize, n_scalars: usize) {
        self.reduce_ops.fetch_add(1, Ordering::Relaxed);
        self.reduce_bytes
            .fetch_add(4 * n_floats as u64 + 8 * n_scalars as u64, Ordering::Relaxed);
    }

    /// Record the one-time data distribution (paper §6: rank 0 generates
    /// and partitions on CPU, scatters column partitions).
    pub fn record_scatter(&self, bytes: u64) {
        self.scatter_ops.fetch_add(1, Ordering::Relaxed);
        self.scatter_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            reduce_ops: self.reduce_ops.load(Ordering::Relaxed),
            reduce_bytes: self.reduce_bytes.load(Ordering::Relaxed),
            bcast_ops: self.bcast_ops.load(Ordering::Relaxed),
            bcast_bytes: self.bcast_bytes.load(Ordering::Relaxed),
            scatter_ops: self.scatter_ops.load(Ordering::Relaxed),
            scatter_bytes: self.scatter_bytes.load(Ordering::Relaxed),
        }
    }
}

impl CommSnapshot {
    /// Steady-state bytes per iteration given the iteration count
    /// (excludes the one-time scatter).
    pub fn bytes_per_iter(&self, iters: u64) -> f64 {
        if iters == 0 {
            return 0.0;
        }
        (self.reduce_bytes + self.bcast_bytes) as f64 / iters as f64
    }
}

/// α–β interconnect cost model for reporting estimated wire time of a
/// collective on real hardware (bench E10's "what would NCCL move").
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// per-op latency, seconds (α)
    pub alpha: f64,
    /// seconds per byte (β = 1/bandwidth)
    pub beta: f64,
}

impl LinkModel {
    /// NVLink-class defaults: 10 µs latency, 200 GB/s effective.
    pub fn nvlink() -> Self {
        LinkModel { alpha: 10e-6, beta: 1.0 / 200e9 }
    }

    /// Datacenter Ethernet-class: 50 µs, 10 GB/s.
    pub fn ethernet() -> Self {
        LinkModel { alpha: 50e-6, beta: 1.0 / 10e9 }
    }

    /// Estimated seconds for one op of `bytes`.
    pub fn op_time(&self, bytes: u64) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// Estimated per-iteration wire time for the paper's pattern
    /// (1 reduce + 2 broadcasts of |λ| floats).
    pub fn iter_time(&self, dual_dim: usize) -> f64 {
        3.0 * self.op_time(4 * dual_dim as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = CommStats::new();
        s.record_broadcast(100);
        s.record_broadcast(100);
        s.record_reduce(100, 2);
        let snap = s.snapshot();
        assert_eq!(snap.bcast_ops, 2);
        assert_eq!(snap.bcast_bytes, 800);
        assert_eq!(snap.reduce_ops, 1);
        assert_eq!(snap.reduce_bytes, 416);
    }

    #[test]
    fn bytes_per_iter_excludes_scatter() {
        let s = CommStats::new();
        s.record_scatter(1_000_000);
        for _ in 0..10 {
            s.record_broadcast(50);
            s.record_broadcast(50);
            s.record_reduce(50, 2);
        }
        let snap = s.snapshot();
        // per iter: 2*200 + 200+16 = 616
        assert!((snap.bytes_per_iter(10) - 616.0).abs() < 1e-9);
    }

    #[test]
    fn link_model_monotone_in_size() {
        let m = LinkModel::nvlink();
        assert!(m.op_time(1000) < m.op_time(1_000_000));
        assert!(m.iter_time(10_000) > 0.0);
        // ethernet slower than nvlink for same payload
        assert!(LinkModel::ethernet().iter_time(10_000) > m.iter_time(10_000));
    }
}
