//! Simulated collectives with byte/op accounting (DESIGN.md §5
//! Substitutions: stands in for torch.distributed + NCCL).
//!
//! The paper's claim (§6) is *structural*: per iteration the pattern is one
//! reduce (SUM, to rank 0) of the gradient (|λ| floats + 2 scalars) and two
//! broadcasts of the (λ₁, λ₂) momentum pair — independent of nnz and the
//! per-GPU column split. These collectives move the same logical payloads
//! over channels and count every byte so the benches can assert the claim
//! (experiments E10/E15).
//!
//! **Deterministic chunk-ordered allreduce** (the sharded-slab reduce,
//! DESIGN.md §6): slab shards own contiguous ascending ranges of the
//! layout's fixed chunk grid and send one λ-sized partial per chunk.
//! [`reduce_chunk_partials`] merges them in global chunk-index order —
//! the exact f32 summation sequence of a single-shard slab evaluation —
//! so an S-shard solve is bit-identical to the 1-shard solve at any S.
//! The payload is `num_chunks × (|λ| + 2)` values: proportional to the
//! dual dimension and the (fixed, ≈`sparse::slabs::MAX_CHUNKS`) grid
//! size, never to shard edge counts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::backend::slab_cpu::ChunkPartial;

/// Byte/op counters shared between leader and workers.
#[derive(Debug, Default)]
pub struct CommStats {
    pub reduce_ops: AtomicU64,
    pub reduce_bytes: AtomicU64,
    pub bcast_ops: AtomicU64,
    pub bcast_bytes: AtomicU64,
    pub scatter_ops: AtomicU64,
    pub scatter_bytes: AtomicU64,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommSnapshot {
    pub reduce_ops: u64,
    pub reduce_bytes: u64,
    pub bcast_ops: u64,
    pub bcast_bytes: u64,
    pub scatter_ops: u64,
    pub scatter_bytes: u64,
}

impl CommStats {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record one broadcast of `n_floats` (leader → all ranks). NCCL
    /// broadcast moves ~n bytes per link regardless of fan-out; we count
    /// the logical payload once, as the paper does ("each of size |λ|").
    pub fn record_broadcast(&self, n_floats: usize) {
        self.bcast_ops.fetch_add(1, Ordering::Relaxed);
        self.bcast_bytes.fetch_add(4 * n_floats as u64, Ordering::Relaxed);
    }

    /// Record one SUM-reduce to rank 0 of `n_floats` plus `n_scalars` f64
    /// side values (objective, regularization).
    pub fn record_reduce(&self, n_floats: usize, n_scalars: usize) {
        self.reduce_ops.fetch_add(1, Ordering::Relaxed);
        self.reduce_bytes
            .fetch_add(4 * n_floats as u64 + 8 * n_scalars as u64, Ordering::Relaxed);
    }

    /// Record one chunk-segmented SUM-reduce to rank 0: `segments`
    /// ordered segments of `n_floats` + `n_scalars` each — the wire shape
    /// of [`reduce_chunk_partials`]. Counted as ONE op (it replaces the
    /// flat gradient reduce); its payload scales with the fixed chunk-grid
    /// size, never with shard edge counts.
    pub fn record_segmented_reduce(&self, segments: usize, n_floats: usize, n_scalars: usize) {
        self.reduce_ops.fetch_add(1, Ordering::Relaxed);
        self.reduce_bytes.fetch_add(
            segments as u64 * (4 * n_floats as u64 + 8 * n_scalars as u64),
            Ordering::Relaxed,
        );
    }

    /// Record the one-time data distribution (paper §6: rank 0 generates
    /// and partitions on CPU, scatters column partitions).
    pub fn record_scatter(&self, bytes: u64) {
        self.scatter_ops.fetch_add(1, Ordering::Relaxed);
        self.scatter_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            reduce_ops: self.reduce_ops.load(Ordering::Relaxed),
            reduce_bytes: self.reduce_bytes.load(Ordering::Relaxed),
            bcast_ops: self.bcast_ops.load(Ordering::Relaxed),
            bcast_bytes: self.bcast_bytes.load(Ordering::Relaxed),
            scatter_ops: self.scatter_ops.load(Ordering::Relaxed),
            scatter_bytes: self.scatter_bytes.load(Ordering::Relaxed),
        }
    }
}

impl CommSnapshot {
    /// Steady-state bytes per iteration given the iteration count
    /// (excludes the one-time scatter).
    pub fn bytes_per_iter(&self, iters: u64) -> f64 {
        if iters == 0 {
            return 0.0;
        }
        (self.reduce_bytes + self.bcast_bytes) as f64 / iters as f64
    }
}

/// Deterministic chunk-index-ordered allreduce (the sharded-slab reduce).
///
/// `parts_by_rank` holds each rank's per-chunk partials (borrowed — the
/// in-process sharded path reads them straight out of each shard's
/// persistent buffer without cloning) in ascending chunk order; ranks
/// own contiguous ascending chunk ranges, so iterating
/// ranks in order and chunks within each rank visits the global chunk
/// grid in index order. The elementwise f32 adds below are therefore the
/// **same summation sequence** as the single-shard
/// `backend::SlabCpuObjective::calculate` merge — bit-identical results
/// at any shard count, the sharded analogue of NCCL's order-fixed tree
/// reduction. Returns (Σ Ax, Σ cᵀx, Σ v²‖x‖²) with `b` NOT subtracted
/// (the leader owns `b`).
pub fn reduce_chunk_partials(
    parts_by_rank: &[&[ChunkPartial]],
    dual_dim: usize,
) -> (Vec<f32>, f64, f64) {
    let mut ax = vec![0.0f32; dual_dim];
    let mut cx = 0.0f64;
    let mut xsq = 0.0f64;
    for parts in parts_by_rank {
        for p in *parts {
            debug_assert_eq!(p.ax.len(), dual_dim);
            for (g, v) in ax.iter_mut().zip(&p.ax) {
                *g += *v;
            }
            cx += p.cx;
            xsq += p.xsq;
        }
    }
    (ax, cx, xsq)
}

/// α–β interconnect cost model for reporting estimated wire time of a
/// collective on real hardware (bench E10's "what would NCCL move").
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// per-op latency, seconds (α)
    pub alpha: f64,
    /// seconds per byte (β = 1/bandwidth)
    pub beta: f64,
}

impl LinkModel {
    /// NVLink-class defaults: 10 µs latency, 200 GB/s effective.
    pub fn nvlink() -> Self {
        LinkModel { alpha: 10e-6, beta: 1.0 / 200e9 }
    }

    /// Datacenter Ethernet-class: 50 µs, 10 GB/s.
    pub fn ethernet() -> Self {
        LinkModel { alpha: 50e-6, beta: 1.0 / 10e9 }
    }

    /// Estimated seconds for one op of `bytes`.
    pub fn op_time(&self, bytes: u64) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// Estimated per-iteration wire time for the paper's pattern
    /// (1 reduce + 2 broadcasts of |λ| floats).
    pub fn iter_time(&self, dual_dim: usize) -> f64 {
        3.0 * self.op_time(4 * dual_dim as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = CommStats::new();
        s.record_broadcast(100);
        s.record_broadcast(100);
        s.record_reduce(100, 2);
        let snap = s.snapshot();
        assert_eq!(snap.bcast_ops, 2);
        assert_eq!(snap.bcast_bytes, 800);
        assert_eq!(snap.reduce_ops, 1);
        assert_eq!(snap.reduce_bytes, 416);
    }

    #[test]
    fn bytes_per_iter_excludes_scatter() {
        let s = CommStats::new();
        s.record_scatter(1_000_000);
        for _ in 0..10 {
            s.record_broadcast(50);
            s.record_broadcast(50);
            s.record_reduce(50, 2);
        }
        let snap = s.snapshot();
        // per iter: 2*200 + 200+16 = 616
        assert!((snap.bytes_per_iter(10) - 616.0).abs() < 1e-9);
    }

    #[test]
    fn segmented_reduce_counts_one_op_with_per_chunk_payload() {
        let s = CommStats::new();
        s.record_segmented_reduce(7, 100, 2);
        let snap = s.snapshot();
        assert_eq!(snap.reduce_ops, 1);
        assert_eq!(snap.reduce_bytes, 7 * (4 * 100 + 16));
    }

    #[test]
    fn chunk_partial_reduce_is_rank_then_chunk_ordered() {
        // the merged sum must equal a single pass over the concatenated
        // chunk list — bit for bit (f32 addition is order-sensitive)
        let chunk = |seed: f32| ChunkPartial {
            ax: (0..5).map(|i| seed + i as f32 * 0.1).collect(),
            cx: seed as f64,
            xsq: (seed * 2.0) as f64,
        };
        let by_rank = vec![
            vec![chunk(1.0), chunk(2.0)],
            vec![],
            vec![chunk(3.0)],
        ];
        let refs: Vec<&[ChunkPartial]> = by_rank.iter().map(|p| p.as_slice()).collect();
        let (ax, cx, xsq) = reduce_chunk_partials(&refs, 5);
        let mut eax = vec![0.0f32; 5];
        let (mut ecx, mut exsq) = (0.0f64, 0.0f64);
        for p in by_rank.iter().flatten() {
            for (g, v) in eax.iter_mut().zip(&p.ax) {
                *g += *v;
            }
            ecx += p.cx;
            exsq += p.xsq;
        }
        assert!(ax.iter().zip(&eax).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(cx.to_bits(), ecx.to_bits());
        assert_eq!(xsq.to_bits(), exsq.to_bits());
    }

    #[test]
    fn link_model_monotone_in_size() {
        let m = LinkModel::nvlink();
        assert!(m.op_time(1000) < m.op_time(1_000_000));
        assert!(m.iter_time(10_000) > 0.0);
        // ethernet slower than nvlink for same payload
        assert!(LinkModel::ethernet().iter_time(10_000) > m.iter_time(10_000));
    }
}
