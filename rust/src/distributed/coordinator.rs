//! Leader-side distributed objective + solve entry point.
//!
//! `DistributedObjective` implements the `ObjectiveFunction` contract over
//! a `WorkerPool`, so the exact same `Maximizer` drives single-device and
//! multi-device solves — the paper's point that the solve loop is shared
//! while execution strategy varies. Each `calculate` performs the paper's
//! §6 iteration: two |λ|-sized broadcasts (the momentum pair), local shard
//! evaluation on every device, and one SUM-reduce of λ-sized payloads plus
//! scalars. Under the default slab strategy the reduce is the
//! chunk-index-ordered allreduce, so the distributed solve is
//! bit-identical to the single-shard slab solve; under HLO it is the
//! rank-ordered shard-gradient reduce of the artifact path.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use super::collective::CommSnapshot;
use super::worker::{ExecStrategy, WorkerPool};
use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::solver::{maximize_with, Agd, DriverOptions, SolveOptions, SolveResult};

pub struct DistributedObjective {
    pool: WorkerPool,
    b: Vec<f32>,
    /// λ₁ of the broadcast pair: the previous iterate (momentum state).
    last_query: Vec<f32>,
}

impl DistributedObjective {
    /// Spawn an HLO-strategy pool (artifact-gated). Kept as the
    /// historical entry point; `new_with` selects the strategy.
    pub fn new(lp: Arc<MatchingLp>, artifacts: impl Into<PathBuf>, num_workers: usize) -> Result<Self> {
        Self::new_with(lp, ExecStrategy::Hlo { artifacts: artifacts.into() }, num_workers)
    }

    /// Spawn a pool with an explicit [`ExecStrategy`]. The slab strategy
    /// runs everywhere (no artifacts) and is the CPU default for
    /// distributed solves.
    pub fn new_with(
        lp: Arc<MatchingLp>,
        strategy: ExecStrategy,
        num_workers: usize,
    ) -> Result<Self> {
        let b = lp.full_b();
        let dual_dim = lp.dual_dim();
        let pool = WorkerPool::spawn(lp, strategy, num_workers)?;
        Ok(DistributedObjective { pool, b, last_query: vec![0.0; dual_dim] })
    }

    pub fn comm(&self) -> CommSnapshot {
        self.pool.stats.snapshot()
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    pub fn shards(&self) -> &[(usize, usize)] {
        &self.pool.shards
    }

    /// Strategy name: "slab" | "hlo".
    pub fn strategy(&self) -> &'static str {
        self.pool.strategy
    }

    /// Size of the global fixed chunk grid (slab strategy; 0 under HLO).
    pub fn num_chunks(&self) -> usize {
        self.pool.num_chunks()
    }

    /// Per-iteration modeled parallel compute times (max over workers).
    pub fn iter_compute_max_ms(&self) -> &[f64] {
        &self.pool.iter_compute_max_ms
    }

    /// Per-iteration serialized compute times (sum over workers).
    pub fn iter_compute_sum_ms(&self) -> &[f64] {
        &self.pool.iter_compute_sum_ms
    }

    /// Cumulative per-rank shard evaluation CPU time (ms).
    pub fn shard_eval_ms(&self) -> &[f64] {
        &self.pool.shard_eval_ms
    }
}

impl ObjectiveFunction for DistributedObjective {
    fn dual_dim(&self) -> usize {
        self.b.len()
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        let momentum = std::mem::replace(&mut self.last_query, lam.to_vec());
        let (mut ax, cx, xsq) = self
            .pool
            .eval(lam, &momentum, gamma)
            .expect("distributed eval failed");
        for (g, b) in ax.iter_mut().zip(&self.b) {
            *g -= b;
        }
        ObjectiveResult::assemble(ax, cx, xsq, lam, gamma)
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        self.pool.primal(lam, gamma).expect("distributed primal failed")
    }

    fn name(&self) -> &'static str {
        match self.pool.strategy {
            "slab" => "sharded-slab",
            _ => "distributed-hlo",
        }
    }
}

/// Outcome of a distributed solve, including communication accounting and
/// the modeled-parallel timing series (see WorkerMsg::Grad::compute_ms).
pub struct DistributedSolve {
    pub result: SolveResult,
    pub comm: CommSnapshot,
    pub num_workers: usize,
    /// Execution strategy the pool ran ("slab" | "hlo").
    pub strategy: &'static str,
    /// Per-iteration max-over-workers compute ms (true-parallel model).
    pub iter_compute_max_ms: Vec<f64>,
    /// Per-iteration sum-over-workers compute ms (serialized measurement).
    pub iter_compute_sum_ms: Vec<f64>,
    /// Cumulative per-rank shard evaluation CPU time (ms).
    pub shard_eval_ms: Vec<f64>,
}

/// End-to-end distributed solve on the HLO strategy (artifact-gated) —
/// the historical entry point; see [`solve_distributed_with`].
pub fn solve_distributed(
    lp: Arc<MatchingLp>,
    artifacts: impl Into<PathBuf>,
    num_workers: usize,
    opts: &SolveOptions,
) -> Result<DistributedSolve> {
    solve_distributed_with(lp, ExecStrategy::Hlo { artifacts: artifacts.into() }, num_workers, opts)
}

/// End-to-end distributed solve with the production AGD maximizer on an
/// explicit [`ExecStrategy`]. With `ExecStrategy::Slab` the result is
/// bit-identical to the single-shard slab solve at any worker count.
pub fn solve_distributed_with(
    lp: Arc<MatchingLp>,
    strategy: ExecStrategy,
    num_workers: usize,
    opts: &SolveOptions,
) -> Result<DistributedSolve> {
    solve_distributed_driver(lp, strategy, num_workers, opts, DriverOptions::default())
}

/// Distributed solve with an explicit driver policy: the same steppable
/// `SolveDriver` the engine uses drives the worker pool, so per-job
/// wall-clock deadlines and cancellation apply to multi-shard solves too
/// (CLI: `solve --backend dist --max-wall-ms`, `distributed
/// --max-wall-ms`). A deadline-stopped distributed solve reports
/// `StopReason::Deadline` with its anytime λ.
pub fn solve_distributed_driver(
    lp: Arc<MatchingLp>,
    strategy: ExecStrategy,
    num_workers: usize,
    opts: &SolveOptions,
    dopts: DriverOptions,
) -> Result<DistributedSolve> {
    let mut obj = DistributedObjective::new_with(lp, strategy, num_workers)?;
    let init = vec![0.0f32; obj.dual_dim()];
    let result = maximize_with(Box::new(Agd::default().stepper()), &mut obj, &init, opts, dopts);
    let comm = obj.comm();
    let num_workers = obj.num_workers();
    Ok(DistributedSolve {
        result,
        comm,
        num_workers,
        strategy: obj.pool.strategy,
        iter_compute_max_ms: obj.pool.iter_compute_max_ms.clone(),
        iter_compute_sum_ms: obj.pool.iter_compute_sum_ms.clone(),
        shard_eval_ms: obj.pool.shard_eval_ms.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::runtime::HloObjective;
    use crate::solver::{GammaSchedule, Maximizer};

    fn artifacts_dir() -> std::path::PathBuf {
        crate::runtime::default_artifacts_dir()
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    fn small_lp() -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 400,
            num_resources: 50,
            avg_nnz_per_row: 6.0,
            seed: 21,
            ..Default::default()
        })
    }

    #[test]
    fn distributed_matches_single_device_gradient() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = Arc::new(small_lp());
        let mut single = HloObjective::new(&lp, artifacts_dir()).unwrap();
        let mut dist = DistributedObjective::new(lp.clone(), artifacts_dir(), 3).unwrap();
        let lam = vec![0.03f32; lp.dual_dim()];
        let rs = single.calculate(&lam, 0.05);
        let rd = dist.calculate(&lam, 0.05);
        for (a, b) in rs.grad.iter().zip(&rd.grad) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert!((rs.dual_obj - rd.dual_obj).abs() / rs.dual_obj.abs().max(1.0) < 1e-5);
    }

    #[test]
    fn comm_volume_is_dual_sized_and_iteration_linear() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = Arc::new(small_lp());
        let dual = lp.dual_dim();
        let opts = SolveOptions {
            max_iters: 10,
            gamma: GammaSchedule::Fixed(0.01),
            ..Default::default()
        };
        let out = solve_distributed(lp, artifacts_dir(), 2, &opts).unwrap();
        let c = out.comm;
        // per iter: 2 bcast + 1 reduce; plus 1 one-time b bcast at spawn
        assert_eq!(c.bcast_ops, 2 * 10 + 1, "{c:?}");
        assert_eq!(c.reduce_ops, 10);
        let expect_bytes = (2 * 4 * dual * 10 + 4 * dual) as u64 // bcasts
            + (10 * (4 * dual + 16)) as u64; // reduces
        assert_eq!(c.bcast_bytes + c.reduce_bytes, expect_bytes);
    }

    #[test]
    fn distributed_solve_converges_like_reference() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = Arc::new(small_lp());
        let opts = SolveOptions {
            max_iters: 150,
            gamma: GammaSchedule::Fixed(0.05),
            max_step_size: 1e-2,
            initial_step_size: 1e-4,
            ..Default::default()
        };
        // reference trajectory (single-threaded per-edge baseline)
        let mut cpu = crate::reference::CpuObjective::new(&lp);
        let mut agd = Agd::default();
        let r_ref = agd.maximize(&mut cpu, &vec![0.0; lp.dual_dim()], &opts);
        // distributed trajectory
        let r_dist = solve_distributed(lp.clone(), artifacts_dir(), 4, &opts).unwrap();
        let g_ref = r_ref.trajectory.last().unwrap().dual_obj;
        let g_dist = r_dist.result.trajectory.last().unwrap().dual_obj;
        // Paper Fig 2's parity criterion: relative error below 1%.
        // (Trajectories of the two backends diverge transiently through the
        // adaptive step-size branch — f32 summation-order noise — and
        // re-converge; the paper observes the same between Scala & PyTorch.)
        assert!(
            (g_ref - g_dist).abs() / g_ref.abs().max(1.0) < 1e-2,
            "ref {g_ref} vs dist {g_dist}"
        );
    }

    #[test]
    fn worker_count_exceeding_sources_is_ok() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = Arc::new(generate(&SyntheticConfig {
            num_requests: 3,
            num_resources: 8,
            avg_nnz_per_row: 2.0,
            seed: 2,
            ..Default::default()
        }));
        let mut dist = DistributedObjective::new(lp.clone(), artifacts_dir(), 5).unwrap();
        let lam = vec![0.0f32; lp.dual_dim()];
        let r = dist.calculate(&lam, 0.1);
        assert_eq!(r.grad.len(), lp.dual_dim());
    }

    #[test]
    fn distributed_solve_is_bit_deterministic() {
        // rank-ordered reduction ⇒ identical trajectories across runs even
        // though worker completion order varies with thread scheduling
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = Arc::new(small_lp());
        let opts = SolveOptions { max_iters: 20, ..Default::default() };
        let a = solve_distributed(lp.clone(), artifacts_dir(), 3, &opts).unwrap();
        let b = solve_distributed(lp.clone(), artifacts_dir(), 3, &opts).unwrap();
        assert_eq!(a.result.lam, b.result.lam);
        assert_eq!(
            a.result.trajectory.last().unwrap().dual_obj,
            b.result.trajectory.last().unwrap().dual_obj
        );
    }

    #[test]
    fn failure_injection_bad_artifacts_dir() {
        let lp = Arc::new(small_lp());
        let err = DistributedObjective::new(lp, "/nonexistent/artifacts", 2);
        assert!(err.is_err());
    }

    // ---- slab strategy: runs everywhere, no artifacts needed ----------

    #[test]
    fn slab_strategy_eval_is_bit_identical_to_single_shard() {
        let lp = Arc::new(small_lp());
        let mut single = crate::backend::SlabCpuObjective::new(&lp, 1).unwrap();
        let mut dist =
            DistributedObjective::new_with(lp.clone(), ExecStrategy::Slab { threads: 1 }, 3)
                .unwrap();
        assert_eq!(dist.strategy(), "slab");
        assert_eq!(dist.name(), "sharded-slab");
        assert!(dist.num_chunks() > 0);
        let lam = vec![0.03f32; lp.dual_dim()];
        let rs = single.calculate(&lam, 0.05);
        let rd = dist.calculate(&lam, 0.05);
        assert_eq!(rs.dual_obj.to_bits(), rd.dual_obj.to_bits());
        assert_eq!(rs.cx.to_bits(), rd.cx.to_bits());
        for (a, b) in rs.grad.iter().zip(&rd.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let xs = single.primal(&lam, 0.05);
        let xd = dist.primal(&lam, 0.05);
        for (a, b) in xs.iter().zip(&xd) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn slab_strategy_solve_is_bit_identical_to_single_shard() {
        let lp = Arc::new(small_lp());
        let opts = SolveOptions {
            max_iters: 60,
            gamma: GammaSchedule::Fixed(0.05),
            max_step_size: 1e-2,
            initial_step_size: 1e-4,
            ..Default::default()
        };
        let mut single = crate::backend::SlabCpuObjective::new(&lp, 1).unwrap();
        let mut agd = Agd::default();
        let r1 = agd.maximize(&mut single, &vec![0.0; lp.dual_dim()], &opts);
        for workers in [2usize, 4] {
            let out = solve_distributed_with(
                lp.clone(),
                ExecStrategy::Slab { threads: 1 },
                workers,
                &opts,
            )
            .unwrap();
            assert_eq!(out.strategy, "slab");
            assert_eq!(out.result.lam.len(), r1.lam.len());
            for (i, (a, b)) in out.result.lam.iter().zip(&r1.lam).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{workers}-shard λ[{i}] diverged");
            }
            assert_eq!(
                out.result.trajectory.last().unwrap().dual_obj.to_bits(),
                r1.trajectory.last().unwrap().dual_obj.to_bits()
            );
            assert_eq!(out.shard_eval_ms.len(), workers);
        }
    }

    #[test]
    fn slab_strategy_comm_is_lambda_and_chunk_sized() {
        let lp = Arc::new(small_lp());
        let dual = lp.dual_dim();
        let iters = 10usize;
        let opts = SolveOptions {
            max_iters: iters,
            gamma: GammaSchedule::Fixed(0.01),
            ..Default::default()
        };
        let out =
            solve_distributed_with(lp, ExecStrategy::Slab { threads: 1 }, 2, &opts).unwrap();
        let c = out.comm;
        // per iter: 2 bcasts + 1 segmented reduce; plus the one-time b bcast
        assert_eq!(c.bcast_ops, 2 * iters as u64 + 1, "{c:?}");
        assert_eq!(c.reduce_ops, iters as u64);
        // reduce payload = chunks × (4·dual + 16) per iteration
        assert_eq!(c.reduce_bytes % iters as u64, 0);
        let per_iter_reduce = c.reduce_bytes / iters as u64;
        assert_eq!(per_iter_reduce % (4 * dual as u64 + 16), 0);
        assert!(per_iter_reduce >= 4 * dual as u64 + 16);
    }

    #[test]
    fn slab_strategy_worker_count_exceeding_chunks_is_ok() {
        let lp = Arc::new(generate(&SyntheticConfig {
            num_requests: 12,
            num_resources: 8,
            avg_nnz_per_row: 2.0,
            seed: 2,
            ..Default::default()
        }));
        let mut dist =
            DistributedObjective::new_with(lp.clone(), ExecStrategy::Slab { threads: 1 }, 6)
                .unwrap();
        let lam = vec![0.0f32; lp.dual_dim()];
        let r = dist.calculate(&lam, 0.1);
        assert_eq!(r.grad.len(), lp.dual_dim());
    }

    #[test]
    fn slab_strategy_deadline_stops_with_anytime_dual() {
        // deadline 0 stops deterministically after exactly one iteration;
        // the distributed solve still reports a usable λ and a real
        // final evaluation
        let lp = Arc::new(small_lp());
        let opts = SolveOptions {
            max_iters: 10_000,
            gamma: GammaSchedule::Fixed(0.05),
            max_step_size: 1e-2,
            initial_step_size: 1e-4,
            ..Default::default()
        };
        let out = solve_distributed_driver(
            lp.clone(),
            ExecStrategy::Slab { threads: 1 },
            2,
            &opts,
            DriverOptions::with_deadline_ms(0.0),
        )
        .unwrap();
        assert_eq!(out.result.stop_reason, crate::solver::StopReason::Deadline);
        assert_eq!(out.result.iterations, 1);
        assert_eq!(out.result.lam.len(), lp.dual_dim());
        assert!(out.result.final_obj.dual_obj.is_finite());
    }

    #[test]
    fn slab_strategy_rejects_unbuildable_layout() {
        use crate::projection::ProjectionKind;
        use crate::sparse::slabs::MAX_WIDTH;
        use crate::sparse::BlockedMatrix;
        let deg = MAX_WIDTH + 1;
        let a = BlockedMatrix {
            num_sources: 1,
            num_dests: deg,
            num_families: 1,
            src_ptr: vec![0, deg],
            dest_idx: (0..deg as u32).collect(),
            a: vec![vec![1.0; deg]],
        };
        let lp = Arc::new(MatchingLp::new_uniform(
            a,
            vec![-1.0; deg],
            vec![0.5; deg],
            ProjectionKind::Simplex,
        ));
        let err = DistributedObjective::new_with(lp, ExecStrategy::Slab { threads: 1 }, 2);
        assert!(err.is_err(), "overwide non-separable block must error loudly");
    }
}

