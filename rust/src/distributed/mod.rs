//! Distributed execution (paper §6 "Distributed GPU communication"):
//! balanced shard partitioning, worker threads as simulated devices, and
//! λ-only collectives with full byte accounting.
//!
//! Workers run one of two execution strategies ([`ExecStrategy`]):
//!
//! - **`Slab`** (the CPU default): each worker owns a
//!   `backend::SlabCpuObjective` view over a contiguous range of the
//!   layout's fixed chunk grid, partitioned by **real-edge** count
//!   ([`balanced_partition`] over the grid's cumulative edge pointer).
//!   Per-shard gradients travel as per-chunk λ-sized partials and merge
//!   through the deterministic chunk-index-ordered allreduce
//!   ([`reduce_chunk_partials`]), so an S-shard solve is **bit-identical**
//!   to the 1-shard slab solve. Needs no artifacts; exercised by
//!   `tests/distributed_parity.rs` and experiment E15
//!   (`bench_shard_scaling`).
//! - **`Hlo`**: per-worker PJRT executables over a balanced column
//!   (source-range) split — the accelerated, artifact-gated path
//!   (experiments E4/E10).
//!
//! Either way, per-iteration traffic is λ-proportional — two |λ|
//! broadcasts (the momentum pair) and one reduce whose payload never
//! scales with shard edge counts — which is the paper's core distributed
//! claim. `collective::CommStats` counts every logical byte so benches
//! can assert it.

pub mod collective;
pub mod coordinator;
pub mod partition;
pub mod worker;

pub use collective::{reduce_chunk_partials, CommSnapshot, CommStats, LinkModel};
pub use coordinator::{
    solve_distributed, solve_distributed_driver, solve_distributed_with, DistributedObjective,
    DistributedSolve,
};
pub use partition::{balanced_partition, imbalance, shard_nnz};
pub use worker::{ExecStrategy, WorkerMsg, WorkerPool};
