//! Distributed execution (paper §6 "Distributed GPU communication"):
//! balanced column partitioning, worker threads as simulated devices, and
//! λ-only collectives with full byte accounting.

pub mod collective;
pub mod coordinator;
pub mod partition;
pub mod worker;

pub use collective::{CommSnapshot, CommStats, LinkModel};
pub use coordinator::{solve_distributed, DistributedObjective, DistributedSolve};
pub use partition::{balanced_partition, imbalance, shard_nnz};
pub use worker::{WorkerPool, WorkerMsg};
