//! Balanced contiguous-range partitioning across devices (paper §6:
//! "Columns of T (and c, consistently) are partitioned across devices in a
//! balanced column split of the CSC-format matrices").
//!
//! [`balanced_partition`] splits any cumulative-weight pointer into
//! contiguous ranges of approximately equal weight. Two callers:
//!
//! - the HLO worker pool passes the matrix's `src_ptr` — shards are
//!   source ranges balanced by nonzero count, and source blocks stay
//!   atomic (a block's simple constraint can't span devices);
//! - the slab paths (`backend::sharded`, the slab worker strategy) pass
//!   the chunk grid's cumulative **real-edge** pointer
//!   (`SlabLayout::chunk_edge_ptr`) — shards are chunk ranges balanced by
//!   real edge count, not column count, so one hot wide bucket cannot
//!   skew the split, and contiguity in chunk index is exactly what the
//!   deterministic chunk-ordered allreduce requires.

/// Partition items [0, N) — sources or slab chunks, per the pointer given
/// — into `n` contiguous shards with approximately equal cumulative
/// weight. Returns (lo, hi) pairs; every item appears in exactly one
/// shard. Empty shards are allowed when n > N.
pub fn balanced_partition(src_ptr: &[usize], n: usize) -> Vec<(usize, usize)> {
    assert!(n >= 1);
    let num_sources = src_ptr.len() - 1;
    let total = *src_ptr.last().unwrap();
    let mut shards = Vec::with_capacity(n);
    let mut lo = 0usize;
    for r in 0..n {
        let hi = if r + 1 == n {
            num_sources // last shard takes the remainder
        } else {
            // greedy boundary: advance while cumulative edges stay within
            // the ideal cumulative target for shards 0..=r
            let target = ((r + 1) as f64 / n as f64 * total as f64).round() as usize;
            let mut hi = lo;
            while hi < num_sources && src_ptr[hi + 1] <= target {
                hi += 1;
            }
            hi
        };
        shards.push((lo, hi));
        lo = hi;
    }
    shards
}

/// Edge count of a shard.
pub fn shard_nnz(src_ptr: &[usize], shard: (usize, usize)) -> usize {
    src_ptr[shard.1] - src_ptr[shard.0]
}

/// Load imbalance: max shard nnz / mean shard nnz (1.0 = perfect).
pub fn imbalance(src_ptr: &[usize], shards: &[(usize, usize)]) -> f64 {
    let nz: Vec<usize> = shards.iter().map(|&s| shard_nnz(src_ptr, s)).collect();
    let max = *nz.iter().max().unwrap_or(&0) as f64;
    let mean = nz.iter().sum::<usize>() as f64 / nz.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ptr_from_degrees(deg: &[usize]) -> Vec<usize> {
        let mut p = vec![0];
        for &d in deg {
            p.push(p.last().unwrap() + d);
        }
        p
    }

    #[test]
    fn covers_all_sources_disjointly() {
        let p = ptr_from_degrees(&[3, 1, 4, 1, 5, 9, 2, 6, 5, 3]);
        for n in 1..=6 {
            let shards = balanced_partition(&p, n);
            assert_eq!(shards.len(), n);
            assert_eq!(shards[0].0, 0);
            assert_eq!(shards.last().unwrap().1, 10);
            for w in shards.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gaps/overlap at {w:?}");
            }
        }
    }

    #[test]
    fn uniform_degrees_split_evenly() {
        let p = ptr_from_degrees(&[5; 100]);
        let shards = balanced_partition(&p, 4);
        for &(lo, hi) in &shards {
            assert_eq!(hi - lo, 25);
        }
        assert!((imbalance(&p, &shards) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn skewed_degrees_still_balanced_by_nnz() {
        // One huge source then many small: nnz balance ≠ source balance.
        let mut deg = vec![1000usize];
        deg.extend(vec![10usize; 300]);
        let p = ptr_from_degrees(&deg);
        let shards = balanced_partition(&p, 4);
        let imb = imbalance(&p, &shards);
        assert!(imb < 1.35, "imbalance {imb}");
    }

    #[test]
    fn more_workers_than_sources() {
        let p = ptr_from_degrees(&[2, 2]);
        let shards = balanced_partition(&p, 5);
        assert_eq!(shards.len(), 5);
        assert_eq!(shards.last().unwrap().1, 2);
        let covered: usize = shards.iter().map(|&(l, h)| h - l).sum();
        assert_eq!(covered, 2);
    }

    #[test]
    fn single_worker_gets_everything() {
        let p = ptr_from_degrees(&[1, 2, 3]);
        assert_eq!(balanced_partition(&p, 1), vec![(0, 3)]);
    }
}
