//! Worker pool: one OS thread per simulated device, each owning a shard
//! of the problem — the stand-in for the paper's one-process-per-GPU
//! torch.distributed setup (DESIGN.md §5).
//!
//! Two execution strategies share the pool ([`ExecStrategy`]):
//!
//! - **`Slab`** (default on CPU): the leader builds the full
//!   [`SlabLayout`] once (paper §6: rank 0 partitions on CPU), cuts its
//!   fixed chunk grid into contiguous ranges balanced by real-edge count,
//!   and each worker owns a [`SlabCpuObjective`] shard view with its own
//!   thread budget. Workers return **per-chunk** partial reductions; the
//!   leader merges them in global chunk-index order
//!   (`collective::reduce_chunk_partials`), making the S-shard evaluation
//!   bit-identical to the single-shard slab evaluation.
//! - **`Hlo`**: each worker compiles its own PJRT executables over a
//!   balanced column (source-range) split — the accelerated,
//!   artifact-gated path. Workers return one shard-summed gradient,
//!   merged in rank order.
//!
//! Protocol per iteration (paper §6), identical for both strategies:
//!   leader --2 broadcasts (λ₁, λ₂)--> workers
//!   workers: local gather → slab kernels → scatter (no cross-device deps)
//!   workers --reduce SUM (λ-sized payloads + scalars)--> leader

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::collective::{reduce_chunk_partials, CommStats};
use super::partition::balanced_partition;
use crate::backend::sharded::SlabShardPlan;
use crate::backend::slab_cpu::{ChunkPartial, SlabCpuObjective};
use crate::problem::MatchingLp;
use crate::runtime::HloObjective;
use crate::sparse::slabs::{BuildOptions, SlabChunk, SlabLayout};
use crate::util::timer::thread_cpu_time_ms;

/// How workers execute their shard (see module docs).
pub enum ExecStrategy {
    /// Slab-native CPU objective per worker over a chunk-grid range —
    /// runs everywhere, bit-identical to single-shard slab.
    Slab {
        /// Evaluation pool width inside each worker (1 = sequential;
        /// results are bit-identical at any width).
        threads: usize,
    },
    /// Per-shard PJRT/HLO executables over a source-range split
    /// (artifact-gated).
    Hlo {
        /// AOT artifact directory (`runtime::default_artifacts_dir`).
        artifacts: PathBuf,
    },
}

impl ExecStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            ExecStrategy::Slab { .. } => "slab",
            ExecStrategy::Hlo { .. } => "hlo",
        }
    }
}

/// Leader → worker commands. `momentum` is the second broadcast payload of
/// the paper's protocol (the λ₁ iterate of the momentum pair); workers use
/// `query` (= λ₂, the extrapolated point) for the gradient.
pub enum Cmd {
    Eval { query: Arc<Vec<f32>>, momentum: Arc<Vec<f32>>, gamma: f32 },
    Primal { query: Arc<Vec<f32>>, gamma: f32 },
    Shutdown,
}

/// Worker → leader messages. `compute_ms` is the worker-local **thread CPU
/// time** of the shard evaluation (CLOCK_THREAD_CPUTIME_ID) — immune to
/// time-slicing with sibling workers on this single-core testbed, so the
/// leader can model true-parallel iteration time as max_r(compute_ms) plus
/// the interconnect model (DESIGN.md §5 Substitutions).
pub enum WorkerMsg {
    Ready { rank: usize, buckets: usize, rows: usize, real_edges: usize, padded_edges: usize },
    /// HLO strategy: one shard-summed gradient per worker.
    Grad { rank: usize, ax: Vec<f32>, cx: f64, xsq: f64, compute_ms: f64 },
    /// Slab strategy: per-chunk partials in ascending chunk order — the
    /// worker's segment of the chunk-ordered allreduce.
    GradChunks { rank: usize, parts: Vec<ChunkPartial>, compute_ms: f64 },
    Primal { rank: usize, x: Vec<f32> },
    Error { rank: usize, message: String },
}

pub struct WorkerPool {
    cmd_txs: Vec<Sender<Cmd>>,
    msg_rx: Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<()>>,
    pub stats: Arc<CommStats>,
    /// Per-rank shard ranges: source ranges under `Hlo`, chunk-grid
    /// ranges under `Slab` (both contiguous and ascending by rank).
    pub shards: Vec<(usize, usize)>,
    /// Strategy name ("slab" | "hlo") for diagnostics.
    pub strategy: &'static str,
    /// Per-eval modeled parallel compute time: max over workers of the
    /// shard-local thread CPU time (what N real devices would take).
    pub iter_compute_max_ms: Vec<f64>,
    /// Per-eval sum over workers (the serialized single-core cost).
    pub iter_compute_sum_ms: Vec<f64>,
    /// Cumulative per-rank shard evaluation CPU time (ms).
    pub shard_eval_ms: Vec<f64>,
    slab: Option<SlabShardPlan>,
    dual_dim: usize,
    nnz: usize,
}

fn worker_main_hlo(
    rank: usize,
    lp: Arc<MatchingLp>,
    artifacts: PathBuf,
    shard: (usize, usize),
    cmd_rx: Receiver<Cmd>,
    msg_tx: Sender<WorkerMsg>,
) {
    let mut obj = match HloObjective::new_shard(&lp, &artifacts, shard.0, shard.1)
        .and_then(|mut o| o.warmup().map(|_| o))
    {
        Ok(o) => o,
        Err(e) => {
            let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
            return;
        }
    };
    let _ = msg_tx.send(WorkerMsg::Ready {
        rank,
        buckets: obj.layout().num_launches(),
        rows: obj.layout().total_rows(),
        real_edges: obj.layout().total_real_edges(),
        padded_edges: obj.layout().total_padded_edges(),
    });
    let dual_dim = lp.dual_dim();
    for cmd in cmd_rx {
        match cmd {
            Cmd::Eval { query, momentum, gamma } => {
                let _ = &momentum; // momentum pair received (traffic parity)
                let mut ax = vec![0.0f32; dual_dim];
                let t0 = thread_cpu_time_ms();
                match obj.eval_shard(&query, gamma, &mut ax, None) {
                    Ok((cx, xsq)) => {
                        let compute_ms = thread_cpu_time_ms() - t0;
                        let _ = msg_tx.send(WorkerMsg::Grad { rank, ax, cx, xsq, compute_ms });
                    }
                    Err(e) => {
                        let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
                        return;
                    }
                }
            }
            Cmd::Primal { query, gamma } => {
                let mut ax = vec![0.0f32; dual_dim];
                let mut x = vec![0.0f32; lp.nnz()];
                match obj.eval_shard(&query, gamma, &mut ax, Some(&mut x)) {
                    Ok(_) => {
                        let _ = msg_tx.send(WorkerMsg::Primal { rank, x });
                    }
                    Err(e) => {
                        let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
                        return;
                    }
                }
            }
            Cmd::Shutdown => return,
        }
    }
}

fn worker_main_slab(
    rank: usize,
    lp: Arc<MatchingLp>,
    layout: Arc<SlabLayout>,
    grid: Arc<Vec<SlabChunk>>,
    range: (usize, usize),
    threads: usize,
    cmd_rx: Receiver<Cmd>,
    msg_tx: Sender<WorkerMsg>,
) {
    let mut obj =
        SlabCpuObjective::new_shard(&lp, layout.clone(), &grid, range.0, range.1, threads);
    let chunks = &grid[range.0..range.1];
    let mut buckets: Vec<usize> = chunks.iter().map(|c| c.bucket).collect();
    buckets.dedup();
    let _ = msg_tx.send(WorkerMsg::Ready {
        rank,
        buckets: buckets.len(),
        rows: chunks.iter().map(|c| c.rows()).sum(),
        real_edges: chunks.iter().map(|c| layout.chunk_real_edges(c)).sum(),
        padded_edges: chunks.iter().map(|c| c.rows() * layout.buckets[c.bucket].width).sum(),
    });
    for cmd in cmd_rx {
        match cmd {
            Cmd::Eval { query, momentum, gamma } => {
                let _ = &momentum; // momentum pair received (traffic parity)
                let t0 = thread_cpu_time_ms();
                // owned copy at the channel boundary — the shard's own
                // partials buffer is reused next iteration
                let parts = obj.eval_chunk_partials(&query, gamma).to_vec();
                let compute_ms = thread_cpu_time_ms() - t0;
                let _ = msg_tx.send(WorkerMsg::GradChunks { rank, parts, compute_ms });
            }
            Cmd::Primal { query, gamma } => {
                // full-nnz buffer with only this shard's edges populated;
                // the leader copies the owned slots by assignment
                let mut x = vec![0.0f32; lp.nnz()];
                obj.primal_into(&query, gamma, &mut x);
                let _ = msg_tx.send(WorkerMsg::Primal { rank, x });
            }
            Cmd::Shutdown => return,
        }
    }
}

impl WorkerPool {
    /// Spawn `num_workers` device threads over a balanced shard split for
    /// `strategy`, blocking until every worker has built (and, for HLO,
    /// compiled) its shard.
    pub fn spawn(
        lp: Arc<MatchingLp>,
        strategy: ExecStrategy,
        num_workers: usize,
    ) -> Result<WorkerPool> {
        assert!(num_workers >= 1);
        let stats = CommStats::new();
        let (msg_tx, msg_rx) = channel::<WorkerMsg>();
        let mut cmd_txs = Vec::with_capacity(num_workers);
        let mut handles = Vec::with_capacity(num_workers);

        let (shards, slab) = match &strategy {
            ExecStrategy::Hlo { artifacts } => {
                let shards = balanced_partition(&lp.a.src_ptr, num_workers);
                for (rank, &shard) in shards.iter().enumerate() {
                    let (tx, rx) = channel::<Cmd>();
                    cmd_txs.push(tx);
                    let lp2 = lp.clone();
                    let art = artifacts.clone();
                    let mtx = msg_tx.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("dualip-worker-{rank}"))
                            .spawn(move || worker_main_hlo(rank, lp2, art, shard, rx, mtx))?,
                    );
                    // one-time data distribution accounting (edges × (idx +
                    // cost + m coefficient planes) + shared b broadcast)
                    let edges = lp.a.src_ptr[shard.1] - lp.a.src_ptr[shard.0];
                    stats.record_scatter((edges * (4 + 4 + 4 * lp.num_families())) as u64);
                }
                (shards, None)
            }
            ExecStrategy::Slab { threads } => {
                // Rank 0 builds the canonical layout + grid and cuts
                // contiguous chunk ranges balanced by real edge count —
                // the SAME plan construction the in-process sharded
                // objective uses, so the two paths stay bit-equal by
                // construction. The leader fills planes with one thread
                // per worker: the parallel build is bit-identical to
                // serial, so this only shortens scatter setup.
                let plan = SlabShardPlan::build_opts(
                    &lp,
                    num_workers,
                    BuildOptions { threads: num_workers, ..BuildOptions::default() },
                )
                .map_err(anyhow::Error::msg)?;
                let threads = *threads;
                for (rank, &range) in plan.ranges.iter().enumerate() {
                    let (tx, rx) = channel::<Cmd>();
                    cmd_txs.push(tx);
                    let lp2 = lp.clone();
                    let lay = plan.layout.clone();
                    let gr = plan.grid.clone();
                    let mtx = msg_tx.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("dualip-worker-{rank}"))
                            .spawn(move || {
                                worker_main_slab(rank, lp2, lay, gr, range, threads, rx, mtx)
                            })?,
                    );
                }
                plan.record_scatter(&lp, &stats);
                (plan.ranges.clone(), Some(plan))
            }
        };
        stats.record_broadcast(lp.dual_dim()); // b broadcast (once)

        // wait for readiness
        let mut ready = 0usize;
        while ready < num_workers {
            match msg_rx.recv().map_err(|_| anyhow!("worker channel closed during spawn"))? {
                WorkerMsg::Ready { .. } => ready += 1,
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed to start: {message}"));
                }
                _ => return Err(anyhow!("unexpected message during spawn")),
            }
        }

        Ok(WorkerPool {
            cmd_txs,
            msg_rx,
            handles,
            stats,
            shards,
            strategy: strategy.name(),
            iter_compute_max_ms: Vec::new(),
            iter_compute_sum_ms: Vec::new(),
            shard_eval_ms: vec![0.0; num_workers],
            slab,
            dual_dim: lp.dual_dim(),
            nnz: lp.nnz(),
        })
    }

    pub fn num_workers(&self) -> usize {
        self.cmd_txs.len()
    }

    /// Size of the global fixed chunk grid (slab strategy; 0 under HLO).
    pub fn num_chunks(&self) -> usize {
        self.slab.as_ref().map_or(0, |p| p.grid.len())
    }

    /// One distributed dual evaluation: 2 broadcasts + compute + 1 reduce.
    /// Returns (Σ_r A_r x_r, Σ cx, Σ xsq) — b is NOT subtracted (leader's
    /// job, it owns b).
    pub fn eval(&mut self, query: &[f32], momentum: &[f32], gamma: f32) -> Result<(Vec<f32>, f64, f64)> {
        let q = Arc::new(query.to_vec());
        let mo = Arc::new(momentum.to_vec());
        self.stats.record_broadcast(q.len());
        self.stats.record_broadcast(mo.len());
        for tx in &self.cmd_txs {
            tx.send(Cmd::Eval { query: q.clone(), momentum: mo.clone(), gamma })
                .map_err(|_| anyhow!("worker died"))?;
        }
        // Collect per-rank, then reduce in a FIXED order: rank order for
        // shard-summed HLO gradients, global chunk-index order for slab
        // chunk partials (ranks own contiguous ascending chunk ranges).
        // A fixed reduction order keeps the f32 sum — and therefore the
        // whole AGD trajectory — bit-deterministic regardless of thread
        // scheduling (NCCL's tree reduction is likewise order-fixed).
        let n = self.num_workers();
        let mut sums: Vec<Option<(Vec<f32>, f64, f64)>> = (0..n).map(|_| None).collect();
        let mut chunked: Vec<Option<Vec<ChunkPartial>>> = (0..n).map(|_| None).collect();
        let mut times = vec![0.0f64; n];
        for _ in 0..n {
            match self.msg_rx.recv().map_err(|_| anyhow!("worker channel closed"))? {
                WorkerMsg::Grad { rank, ax, cx, xsq, compute_ms } => {
                    sums[rank] = Some((ax, cx, xsq));
                    times[rank] = compute_ms;
                }
                WorkerMsg::GradChunks { rank, parts, compute_ms } => {
                    chunked[rank] = Some(parts);
                    times[rank] = compute_ms;
                }
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed: {message}"));
                }
                _ => return Err(anyhow!("unexpected worker message")),
            }
        }
        let (ax, cx, xsq) = if self.slab.is_some() {
            let by_rank: Vec<Vec<ChunkPartial>> = chunked
                .into_iter()
                .map(|p| p.expect("missing rank result"))
                .collect();
            let refs: Vec<&[ChunkPartial]> = by_rank.iter().map(|p| p.as_slice()).collect();
            let segments: usize = refs.iter().map(|p| p.len()).sum();
            self.stats.record_segmented_reduce(segments, self.dual_dim, 2);
            reduce_chunk_partials(&refs, self.dual_dim)
        } else {
            let mut ax = vec![0.0f32; self.dual_dim];
            let (mut cx, mut xsq) = (0.0f64, 0.0f64);
            for part in sums.into_iter() {
                let (g, c, s) = part.expect("missing rank result");
                crate::util::mathvec::add_assign(&mut ax, &g);
                cx += c;
                xsq += s;
            }
            self.stats.record_reduce(self.dual_dim, 2);
            (ax, cx, xsq)
        };
        let (mut t_max, mut t_sum) = (0.0f64, 0.0f64);
        for (rank, &ms) in times.iter().enumerate() {
            self.shard_eval_ms[rank] += ms;
            t_max = t_max.max(ms);
            t_sum += ms;
        }
        self.iter_compute_max_ms.push(t_max);
        self.iter_compute_sum_ms.push(t_sum);
        Ok((ax, cx, xsq))
    }

    /// Recover the full per-edge primal (merges shard contributions).
    pub fn primal(&mut self, query: &[f32], gamma: f32) -> Result<Vec<f32>> {
        let q = Arc::new(query.to_vec());
        self.stats.record_broadcast(q.len());
        for tx in &self.cmd_txs {
            tx.send(Cmd::Primal { query: q.clone(), gamma })
                .map_err(|_| anyhow!("worker died"))?;
        }
        let n = self.num_workers();
        let mut by_rank: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match self.msg_rx.recv().map_err(|_| anyhow!("worker channel closed"))? {
                WorkerMsg::Primal { rank, x: xs } => by_rank[rank] = Some(xs),
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed: {message}"));
                }
                _ => return Err(anyhow!("unexpected worker message")),
            }
        }
        let mut x = vec![0.0f32; self.nnz];
        if let Some(plan) = &self.slab {
            // copy each rank's OWNED edges by assignment — shards hold
            // disjoint edge sets, and assignment (unlike `+=`) preserves
            // the single-shard bit pattern for signed zeros
            for (rank, &(lo, hi)) in plan.ranges.iter().enumerate() {
                let xr = by_rank[rank].as_ref().expect("missing rank result");
                for c in &plan.grid[lo..hi] {
                    let bk = &plan.layout.buckets[c.bucket];
                    let w = bk.width;
                    for idx in c.row_lo * w..c.row_hi * w {
                        if bk.mask[idx] > 0.0 {
                            let e = bk.edge_id[idx] as usize;
                            x[e] = xr[e];
                        }
                    }
                }
            }
        } else {
            // HLO shards write disjoint source ranges; summing zeros
            // elsewhere reconstructs the full vector
            for xs in by_rank.into_iter() {
                crate::util::mathvec::add_assign(&mut x, &xs.expect("missing rank result"));
            }
        }
        Ok(x)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
