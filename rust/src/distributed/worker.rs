//! Worker pool: one OS thread per simulated device, each owning a column
//! (source-range) shard and its own PJRT engine + compiled executables —
//! the stand-in for the paper's one-process-per-GPU torch.distributed
//! setup (DESIGN.md §5).
//!
//! Protocol per iteration (paper §6):
//!   leader --2 broadcasts (λ₁, λ₂)--> workers
//!   workers: local gather → slab kernels → scatter (no cross-device deps)
//!   workers --reduce SUM (grad, 2 scalars)--> leader

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::collective::CommStats;
use super::partition::balanced_partition;
use crate::problem::MatchingLp;
use crate::runtime::HloObjective;

/// Leader → worker commands. `momentum` is the second broadcast payload of
/// the paper's protocol (the λ₁ iterate of the momentum pair); workers use
/// `query` (= λ₂, the extrapolated point) for the gradient.
pub enum Cmd {
    Eval { query: Arc<Vec<f32>>, momentum: Arc<Vec<f32>>, gamma: f32 },
    Primal { query: Arc<Vec<f32>>, gamma: f32 },
    Shutdown,
}

/// Worker → leader messages. `compute_ms` is the worker-local **thread CPU
/// time** of the shard evaluation (CLOCK_THREAD_CPUTIME_ID) — immune to
/// time-slicing with sibling workers on this single-core testbed, so the
/// leader can model true-parallel iteration time as max_r(compute_ms) plus
/// the interconnect model (DESIGN.md §5 Substitutions).
pub enum WorkerMsg {
    Ready { rank: usize, buckets: usize, rows: usize, real_edges: usize, padded_edges: usize },
    Grad { rank: usize, ax: Vec<f32>, cx: f64, xsq: f64, compute_ms: f64 },
    Primal { rank: usize, x: Vec<f32> },
    Error { rank: usize, message: String },
}

/// Per-thread CPU time in milliseconds (contention-immune; used for the
/// modeled-parallel device time).
fn thread_cpu_time_ms() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 * 1e3 + ts.tv_nsec as f64 / 1e6
}

pub struct WorkerPool {
    cmd_txs: Vec<Sender<Cmd>>,
    msg_rx: Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<()>>,
    pub stats: Arc<CommStats>,
    pub shards: Vec<(usize, usize)>,
    /// Per-eval modeled parallel compute time: max over workers of the
    /// shard-local wall time (what N real devices would take).
    pub iter_compute_max_ms: Vec<f64>,
    /// Per-eval sum over workers (the serialized single-core cost).
    pub iter_compute_sum_ms: Vec<f64>,
    dual_dim: usize,
    nnz: usize,
}

fn worker_main(
    rank: usize,
    lp: Arc<MatchingLp>,
    artifacts: PathBuf,
    shard: (usize, usize),
    cmd_rx: Receiver<Cmd>,
    msg_tx: Sender<WorkerMsg>,
) {
    let mut obj = match HloObjective::new_shard(&lp, &artifacts, shard.0, shard.1)
        .and_then(|mut o| o.warmup().map(|_| o))
    {
        Ok(o) => o,
        Err(e) => {
            let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
            return;
        }
    };
    let _ = msg_tx.send(WorkerMsg::Ready {
        rank,
        buckets: obj.layout().num_launches(),
        rows: obj.layout().total_rows(),
        real_edges: obj.layout().total_real_edges(),
        padded_edges: obj.layout().total_padded_edges(),
    });
    let dual_dim = lp.dual_dim();
    for cmd in cmd_rx {
        match cmd {
            Cmd::Eval { query, momentum, gamma } => {
                let _ = &momentum; // momentum pair received (traffic parity)
                let mut ax = vec![0.0f32; dual_dim];
                let t0 = thread_cpu_time_ms();
                match obj.eval_shard(&query, gamma, &mut ax, None) {
                    Ok((cx, xsq)) => {
                        let compute_ms = thread_cpu_time_ms() - t0;
                        let _ = msg_tx.send(WorkerMsg::Grad { rank, ax, cx, xsq, compute_ms });
                    }
                    Err(e) => {
                        let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
                        return;
                    }
                }
            }
            Cmd::Primal { query, gamma } => {
                let mut ax = vec![0.0f32; dual_dim];
                let mut x = vec![0.0f32; lp.nnz()];
                match obj.eval_shard(&query, gamma, &mut ax, Some(&mut x)) {
                    Ok(_) => {
                        let _ = msg_tx.send(WorkerMsg::Primal { rank, x });
                    }
                    Err(e) => {
                        let _ = msg_tx.send(WorkerMsg::Error { rank, message: format!("{e:#}") });
                        return;
                    }
                }
            }
            Cmd::Shutdown => return,
        }
    }
}

impl WorkerPool {
    /// Spawn `num_workers` device threads over a balanced column split,
    /// blocking until every worker has built + compiled its shard.
    pub fn spawn(
        lp: Arc<MatchingLp>,
        artifacts: impl Into<PathBuf>,
        num_workers: usize,
    ) -> Result<WorkerPool> {
        assert!(num_workers >= 1);
        let artifacts = artifacts.into();
        let shards = balanced_partition(&lp.a.src_ptr, num_workers);
        let stats = CommStats::new();
        let (msg_tx, msg_rx) = channel::<WorkerMsg>();
        let mut cmd_txs = Vec::with_capacity(num_workers);
        let mut handles = Vec::with_capacity(num_workers);

        for (rank, &shard) in shards.iter().enumerate() {
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let lp2 = lp.clone();
            let art = artifacts.clone();
            let mtx = msg_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dualip-worker-{rank}"))
                    .spawn(move || worker_main(rank, lp2, art, shard, rx, mtx))?,
            );
            // one-time data distribution accounting (edges × (idx + cost +
            // m coefficient planes) + shared b broadcast)
            let edges = lp.a.src_ptr[shard.1] - lp.a.src_ptr[shard.0];
            stats.record_scatter((edges * (4 + 4 + 4 * lp.num_families())) as u64);
        }
        stats.record_broadcast(lp.dual_dim()); // b broadcast (once)

        // wait for readiness
        let mut ready = 0usize;
        while ready < num_workers {
            match msg_rx.recv().map_err(|_| anyhow!("worker channel closed during spawn"))? {
                WorkerMsg::Ready { .. } => ready += 1,
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed to start: {message}"));
                }
                _ => return Err(anyhow!("unexpected message during spawn")),
            }
        }

        Ok(WorkerPool {
            cmd_txs,
            msg_rx,
            handles,
            stats,
            shards,
            iter_compute_max_ms: Vec::new(),
            iter_compute_sum_ms: Vec::new(),
            dual_dim: lp.dual_dim(),
            nnz: lp.nnz(),
        })
    }

    pub fn num_workers(&self) -> usize {
        self.cmd_txs.len()
    }

    /// One distributed dual evaluation: 2 broadcasts + compute + 1 reduce.
    /// Returns (Σ_r A_r x_r, Σ cx, Σ xsq) — b is NOT subtracted (leader's
    /// job, it owns b).
    pub fn eval(&mut self, query: &[f32], momentum: &[f32], gamma: f32) -> Result<(Vec<f32>, f64, f64)> {
        let q = Arc::new(query.to_vec());
        let mo = Arc::new(momentum.to_vec());
        self.stats.record_broadcast(q.len());
        self.stats.record_broadcast(mo.len());
        for tx in &self.cmd_txs {
            tx.send(Cmd::Eval { query: q.clone(), momentum: mo.clone(), gamma })
                .map_err(|_| anyhow!("worker died"))?;
        }
        // Collect per-rank, then reduce in RANK order: a fixed reduction
        // order keeps the f32 sum — and therefore the whole AGD trajectory
        // — bit-deterministic regardless of thread scheduling (NCCL's tree
        // reduction is likewise order-fixed).
        let mut parts: Vec<Option<(Vec<f32>, f64, f64, f64)>> = (0..self.num_workers()).map(|_| None).collect();
        for _ in 0..self.num_workers() {
            match self.msg_rx.recv().map_err(|_| anyhow!("worker channel closed"))? {
                WorkerMsg::Grad { rank, ax: g, cx: c, xsq: s, compute_ms } => {
                    parts[rank] = Some((g, c, s, compute_ms));
                }
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed: {message}"));
                }
                _ => return Err(anyhow!("unexpected worker message")),
            }
        }
        let mut ax = vec![0.0f32; self.dual_dim];
        let (mut cx, mut xsq) = (0.0f64, 0.0f64);
        let (mut t_max, mut t_sum) = (0.0f64, 0.0f64);
        for part in parts.into_iter() {
            let (g, c, s, compute_ms) = part.expect("missing rank result");
            crate::util::mathvec::add_assign(&mut ax, &g);
            cx += c;
            xsq += s;
            t_max = t_max.max(compute_ms);
            t_sum += compute_ms;
        }
        self.stats.record_reduce(self.dual_dim, 2);
        self.iter_compute_max_ms.push(t_max);
        self.iter_compute_sum_ms.push(t_sum);
        Ok((ax, cx, xsq))
    }

    /// Recover the full per-edge primal (merges shard contributions).
    pub fn primal(&mut self, query: &[f32], gamma: f32) -> Result<Vec<f32>> {
        let q = Arc::new(query.to_vec());
        self.stats.record_broadcast(q.len());
        for tx in &self.cmd_txs {
            tx.send(Cmd::Primal { query: q.clone(), gamma })
                .map_err(|_| anyhow!("worker died"))?;
        }
        // shards write disjoint edges, so arrival order is immaterial here
        let mut x = vec![0.0f32; self.nnz];
        for _ in 0..self.num_workers() {
            match self.msg_rx.recv().map_err(|_| anyhow!("worker channel closed"))? {
                WorkerMsg::Primal { x: xs, .. } => {
                    crate::util::mathvec::add_assign(&mut x, &xs);
                }
                WorkerMsg::Error { rank, message } => {
                    return Err(anyhow!("worker {rank} failed: {message}"));
                }
                _ => return Err(anyhow!("unexpected worker message")),
            }
        }
        Ok(x)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
