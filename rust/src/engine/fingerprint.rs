//! Structural fingerprint of a `MatchingLp` (DESIGN.md §3).
//!
//! Production traffic re-solves *perturbed* instances: the eligibility
//! graph (which (source, destination) pairs carry variables) changes
//! slowly, while objective coefficients `c` and budgets `b` refresh every
//! cycle. The fingerprint captures exactly the slow part — dimensions,
//! family count, global-row count, and a hash of the sparsity pattern
//! (`src_ptr` + `dest_idx`) — and deliberately ignores the numeric planes,
//! so a (same-pattern, new-`c`/`b`) instance maps to the same key and the
//! warm-start cache recognizes it as a re-solve.

use std::fmt;

use crate::problem::MatchingLp;

/// 64-bit FNV-1a over a little-endian byte stream — dependency-free,
/// deterministic across runs and platforms (same requirement as the
/// workload RNG: identical instances must key identically everywhere).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv64 {
        Fnv64(Self::OFFSET)
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Structural identity of a matching LP. Two instances with equal
/// fingerprints share dims and the exact `A` sparsity pattern; their
/// dual spaces are therefore identical and a final λ of one is a valid
/// (and, under small `c`/`b` perturbation, near-optimal) start for the
/// other.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    pub num_sources: usize,
    pub num_dests: usize,
    pub num_families: usize,
    pub num_global_rows: usize,
    pub nnz: usize,
    /// FNV-1a over (src_ptr, dest_idx).
    pub pattern_hash: u64,
}

impl Fingerprint {
    pub fn of(lp: &MatchingLp) -> Fingerprint {
        let mut h = Fnv64::new();
        for &p in &lp.a.src_ptr {
            h.write_u64(p as u64);
        }
        for &j in &lp.a.dest_idx {
            h.write_u32(j);
        }
        Fingerprint {
            num_sources: lp.num_sources(),
            num_dests: lp.num_dests(),
            num_families: lp.num_families(),
            num_global_rows: lp.global_rows.len(),
            nnz: lp.nnz(),
            pattern_hash: h.finish(),
        }
    }

    /// Dual dimension implied by the fingerprint (mJ + G) — used to reject
    /// stale cache entries whose λ no longer matches.
    pub fn dual_dim(&self) -> usize {
        self.num_families * self.num_dests + self.num_global_rows
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{} m={} g={} nnz={} #{:016x}",
            self.num_sources,
            self.num_dests,
            self.num_families,
            self.num_global_rows,
            self.nnz,
            self.pattern_hash
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, workloads, SyntheticConfig};

    fn small(seed: u64) -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 300,
            num_resources: 24,
            avg_nnz_per_row: 5.0,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn identical_instances_share_fingerprint() {
        let a = Fingerprint::of(&small(3));
        let b = Fingerprint::of(&small(3));
        assert_eq!(a, b);
        assert_eq!(a.dual_dim(), 24);
    }

    #[test]
    fn perturbed_cost_and_rhs_keep_fingerprint() {
        let base = small(4);
        let spec = workloads::PerturbSpec::default();
        let re = workloads::perturb_instance(&base, &spec, 99);
        assert_ne!(base.cost, re.cost);
        assert_eq!(Fingerprint::of(&base), Fingerprint::of(&re));
    }

    #[test]
    fn different_pattern_changes_hash() {
        let a = Fingerprint::of(&small(5));
        let b = Fingerprint::of(&small(6));
        assert_ne!(a, b, "different seeds draw different graphs");
    }

    #[test]
    fn global_rows_count_into_identity() {
        let mut lp = small(7);
        let a = Fingerprint::of(&lp);
        lp.push_global_row(vec![1.0; lp.nnz()], 10.0);
        let b = Fingerprint::of(&lp);
        assert_ne!(a, b);
        assert_eq!(b.dual_dim(), a.dual_dim() + 1);
    }

    #[test]
    fn fnv_is_order_sensitive() {
        let mut h1 = Fnv64::new();
        h1.write_u32(1);
        h1.write_u32(2);
        let mut h2 = Fnv64::new();
        h2.write_u32(2);
        h2.write_u32(1);
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn display_is_compact() {
        let s = format!("{}", Fingerprint::of(&small(8)));
        assert!(s.contains("300x24"));
        assert!(s.contains('#'));
    }
}
