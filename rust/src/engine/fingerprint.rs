//! Structural fingerprint of a `MatchingLp` (DESIGN.md §3).
//!
//! Production traffic re-solves *perturbed* instances: the eligibility
//! graph (which (source, destination) pairs carry variables) changes
//! slowly, while objective coefficients `c` and budgets `b` refresh every
//! cycle. The fingerprint captures exactly the slow part — dimensions,
//! family count, a hash of the sparsity pattern (`src_ptr` + `dest_idx`),
//! the per-block projection specs (polytope identity), the constraint
//! coefficient planes (matching families and global rows) and the
//! primal-scale vector — and deliberately ignores the numeric
//! `c`/`b`/global-rhs planes, so a (same-structure, new-`c`/`b`)
//! instance maps to the same key and the warm-start cache recognizes it
//! as a re-solve. Polytopes and coefficients are part of identity
//! because two instances sharing a sparsity pattern but projecting onto
//! different sets (or weighting `A` differently) have different duals —
//! colliding them would warm-start from a wrong λ.

use std::collections::BTreeMap;
use std::fmt;

use crate::problem::MatchingLp;
use crate::projection::{ProjectionKind, ProjectionMap};

/// 64-bit FNV-1a over a little-endian byte stream — dependency-free,
/// deterministic across runs and platforms (same requirement as the
/// workload RNG: identical instances must key identically everywhere).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv64 {
        Fnv64(Self::OFFSET)
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    #[inline]
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Structural identity of a matching LP. Two instances with equal
/// fingerprints share dims and the exact `A` sparsity pattern; their
/// dual spaces are therefore identical and a final λ of one is a valid
/// (and, under small `c`/`b` perturbation, near-optimal) start for the
/// other.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    pub num_sources: usize,
    pub num_dests: usize,
    pub num_families: usize,
    pub num_global_rows: usize,
    pub nnz: usize,
    /// FNV-1a over (src_ptr, dest_idx).
    pub pattern_hash: u64,
    /// FNV-1a over each block's projection spec string, in block order —
    /// the polytope side of identity. Instances with identical sparsity
    /// but different projection operators must not share warm starts.
    pub projection_hash: u64,
    /// FNV-1a over the global rows' coefficient planes (their rhs is a
    /// numeric plane and stays excluded, like `b`).
    pub global_coeff_hash: u64,
    /// FNV-1a over the matching-family coefficient planes (`A`'s values)
    /// and the primal-scale vector. Like the polytopes, these shape the
    /// dual optimum; only `c`/`b`/global-rhs drift between re-solves.
    pub coeff_hash: u64,
}

/// Hash of one operator's canonical spec string.
fn spec_hash(k: ProjectionKind) -> u64 {
    let mut h = Fnv64::new();
    h.write_bytes(k.spec().as_bytes());
    h.finish()
}

impl Fingerprint {
    pub fn of(lp: &MatchingLp) -> Fingerprint {
        let mut h = Fnv64::new();
        for &p in &lp.a.src_ptr {
            h.write_u64(p as u64);
        }
        for &j in &lp.a.dest_idx {
            h.write_u32(j);
        }
        // Polytope identity: one spec hash per block, written in block
        // order so a uniform map and its materialized per-block equivalent
        // fingerprint identically. Distinct kinds are memoized — spec
        // strings are only rendered once per operator.
        let mut ph = Fnv64::new();
        match &lp.projection {
            ProjectionMap::Uniform(k) => {
                let hk = spec_hash(*k);
                for _ in 0..lp.num_sources() {
                    ph.write_u64(hk);
                }
            }
            ProjectionMap::PerBlock(_) => {
                let mut memo: BTreeMap<ProjectionKind, u64> = BTreeMap::new();
                for i in 0..lp.num_sources() {
                    let k = lp.projection.kind_of(i);
                    let hk = *memo.entry(k).or_insert_with(|| spec_hash(k));
                    ph.write_u64(hk);
                }
            }
        }
        let mut gh = Fnv64::new();
        for g in &lp.global_rows {
            for &c in &g.coeffs {
                gh.write_u32(c.to_bits());
            }
            // row separator so plane boundaries are order-sensitive
            gh.write_u64(0x9E37_79B9_7F4A_7C15);
        }
        // Coefficient identity: the family planes and primal scaling shape
        // the dual optimum exactly like the global-row coefficients do, so
        // same-pattern instances with different `A` values must not share
        // warm starts. Held fixed across a perturbation stream (only c/b
        // and global rhs drift), so re-solves still key identically.
        let mut ch = Fnv64::new();
        for ak in &lp.a.a {
            for &c in ak {
                ch.write_u32(c.to_bits());
            }
            ch.write_u64(0x9E37_79B9_7F4A_7C15);
        }
        match &lp.primal_scale {
            None => ch.write_u64(0),
            Some(v) => {
                ch.write_u64(1);
                for &s in v {
                    ch.write_u32(s.to_bits());
                }
            }
        }
        Fingerprint {
            num_sources: lp.num_sources(),
            num_dests: lp.num_dests(),
            num_families: lp.num_families(),
            num_global_rows: lp.global_rows.len(),
            nnz: lp.nnz(),
            pattern_hash: h.finish(),
            projection_hash: ph.finish(),
            global_coeff_hash: gh.finish(),
            coeff_hash: ch.finish(),
        }
    }

    /// Dual dimension implied by the fingerprint (mJ + G) — used to reject
    /// stale cache entries whose λ no longer matches.
    pub fn dual_dim(&self) -> usize {
        self.num_families * self.num_dests + self.num_global_rows
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{} m={} g={} nnz={} #{:016x}",
            self.num_sources,
            self.num_dests,
            self.num_families,
            self.num_global_rows,
            self.nnz,
            self.pattern_hash
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, workloads, SyntheticConfig};

    fn small(seed: u64) -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 300,
            num_resources: 24,
            avg_nnz_per_row: 5.0,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn identical_instances_share_fingerprint() {
        let a = Fingerprint::of(&small(3));
        let b = Fingerprint::of(&small(3));
        assert_eq!(a, b);
        assert_eq!(a.dual_dim(), 24);
    }

    #[test]
    fn perturbed_cost_and_rhs_keep_fingerprint() {
        let base = small(4);
        let spec = workloads::PerturbSpec::default();
        let re = workloads::perturb_instance(&base, &spec, 99);
        assert_ne!(base.cost, re.cost);
        assert_eq!(Fingerprint::of(&base), Fingerprint::of(&re));
    }

    #[test]
    fn different_pattern_changes_hash() {
        let a = Fingerprint::of(&small(5));
        let b = Fingerprint::of(&small(6));
        assert_ne!(a, b, "different seeds draw different graphs");
    }

    #[test]
    fn global_rows_count_into_identity() {
        let mut lp = small(7);
        let a = Fingerprint::of(&lp);
        lp.push_global_row(vec![1.0; lp.nnz()], 10.0);
        let b = Fingerprint::of(&lp);
        assert_ne!(a, b);
        assert_eq!(b.dual_dim(), a.dual_dim() + 1);
    }

    #[test]
    fn projection_spec_is_part_of_identity() {
        use crate::projection::{ProjectionKind, ProjectionMap};
        let base = small(11);
        let mut capped = base.clone();
        capped.projection =
            ProjectionMap::Uniform(ProjectionKind::capped_simplex(0.5, 1.0));
        let a = Fingerprint::of(&base);
        let b = Fingerprint::of(&capped);
        assert_eq!(a.pattern_hash, b.pattern_hash, "same sparsity");
        assert_ne!(a, b, "different polytopes must not collide");
        // different parameters of the same family differ too
        let mut capped2 = base.clone();
        capped2.projection =
            ProjectionMap::Uniform(ProjectionKind::capped_simplex(0.5, 2.0));
        assert_ne!(Fingerprint::of(&capped2), b);
    }

    #[test]
    fn uniform_and_materialized_per_block_maps_agree() {
        use crate::projection::{ProjectionKind, ProjectionMap};
        let uniform = small(12);
        let mut per_block = uniform.clone();
        per_block.projection = ProjectionMap::per_block(|_| ProjectionKind::Simplex);
        assert_eq!(Fingerprint::of(&uniform), Fingerprint::of(&per_block));
        // ...but a genuinely mixed map differs
        let mut mixed = uniform.clone();
        mixed.projection = ProjectionMap::per_block(|i| {
            if i % 2 == 0 {
                ProjectionKind::Simplex
            } else {
                ProjectionKind::Box
            }
        });
        assert_ne!(Fingerprint::of(&uniform), Fingerprint::of(&mixed));
    }

    #[test]
    fn global_row_coeffs_count_rhs_does_not() {
        let base = small(13);
        let mut ones = base.clone();
        ones.push_global_row(vec![1.0; ones.nnz()], 10.0);
        let mut ones_other_rhs = base.clone();
        ones_other_rhs.push_global_row(vec![1.0; ones_other_rhs.nnz()], 99.0);
        let mut twos = base.clone();
        twos.push_global_row(vec![2.0; twos.nnz()], 10.0);
        // rhs is a numeric plane (perturbs between re-solves): excluded
        assert_eq!(Fingerprint::of(&ones), Fingerprint::of(&ones_other_rhs));
        // the coefficient plane is structural: included
        assert_ne!(Fingerprint::of(&ones), Fingerprint::of(&twos));
    }

    #[test]
    fn family_coeff_planes_and_primal_scale_count() {
        let base = small(14);
        let mut fam1 = base.clone();
        fam1.push_family(vec![1.0; fam1.nnz()], vec![0.5; fam1.num_dests()]);
        let mut fam5 = base.clone();
        fam5.push_family(vec![5.0; fam5.nnz()], vec![0.5; fam5.num_dests()]);
        // same pattern + family count, different A values ⇒ distinct keys
        assert_ne!(Fingerprint::of(&fam1), Fingerprint::of(&fam5));
        // primal scaling changes the effective objective ⇒ distinct keys
        let mut scaled = base.clone();
        scaled.primal_scale = Some(vec![2.0; scaled.num_sources()]);
        assert_ne!(Fingerprint::of(&base), Fingerprint::of(&scaled));
    }

    #[test]
    fn fnv_is_order_sensitive() {
        let mut h1 = Fnv64::new();
        h1.write_u32(1);
        h1.write_u32(2);
        let mut h2 = Fnv64::new();
        h2.write_u32(2);
        h2.write_u32(1);
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn display_is_compact() {
        let s = format!("{}", Fingerprint::of(&small(8)));
        assert!(s.contains("300x24"));
        assert!(s.contains('#'));
    }
}
