//! Serving layer (DESIGN.md §3): a persistent multi-problem solve engine
//! for the production pattern the paper opens with — LPs that "must be
//! solved repeatedly at massive scale" as ranking/allocation inputs refresh
//! under traffic.
//!
//! The seed stack solved one cold instance per process. This layer sits
//! above `solver/` and `problem/` and adds what repeated solving needs:
//!
//! - [`fingerprint`] — a structural fingerprint of a `MatchingLp` (dims,
//!   family count, sparsity-pattern hash) so instances that share an `A`
//!   pattern but carry perturbed `c`/`b` are recognized as re-solves;
//! - [`warmstart`] — a dual warm-start cache mapping fingerprint → final
//!   (λ, γ), so a re-solve starts AGD from the cached dual with a short
//!   γ-continuation tail instead of from zero. First-order LP solvers are
//!   iteration-count bound (D-PDLP, cuPDLP.jl report the same), which is
//!   exactly what dual warm-starting attacks;
//! - [`scheduler`] — the fixed-width thread pool, in two modes: the
//!   run-to-completion batch scheduler and the **cooperative executor**
//!   that time-slices steppable solve drivers in round-robin quanta —
//!   both deterministic (results are bit-identical to sequential
//!   execution at any pool width);
//! - [`session`] — the [`SolveEngine`] API: `submit`, `solve_batch`,
//!   `solve_batch_coop` (deadlines, cancellation, mid-solve warm-start
//!   checkpoints), `stats`.
//!
//! Driven end-to-end by the `engine-batch` CLI subcommand and the
//! `bench_engine_warmstart` / `bench_driver_overhead` benches
//! (experiments E12, E16).

pub mod fingerprint;
pub mod scheduler;
pub mod session;
pub mod warmstart;

pub use fingerprint::Fingerprint;
pub use scheduler::{BatchReport, CoopReport, Scheduler};
pub use session::{EngineConfig, EngineStats, JobResult, SolveEngine, SolveJob};
pub use warmstart::{warm_options, WarmStart, WarmStartCache};
