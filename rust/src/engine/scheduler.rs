//! Bounded-concurrency batch scheduler (DESIGN.md §3).
//!
//! Runs N independent jobs across at most `num_threads` OS threads via a
//! shared atomic work queue. Two properties matter for serving:
//!
//! - **determinism**: results are returned in submission order, and each
//!   job's computation sees only its own inputs — so a batch run is
//!   bit-identical to the same jobs executed sequentially (`num_threads`
//!   = 1). Thread scheduling affects wall-clock only, never values. This
//!   mirrors the rank-ordered reduction the distributed layer uses for
//!   the same reason.
//! - **bounded concurrency**: at most `num_threads` jobs are in flight;
//!   per-job memory (objective scratch, trajectories) is bounded by the
//!   pool width, not the batch length.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::timer::Stopwatch;

/// Aggregate facts about one batch execution.
#[derive(Clone, Copy, Debug)]
pub struct BatchReport {
    pub jobs: usize,
    pub threads: usize,
    /// Max jobs observed simultaneously in flight (≤ threads; equals the
    /// pool width whenever jobs outlast the pickup phase).
    pub peak_in_flight: usize,
    pub wall_ms: f64,
}

impl BatchReport {
    /// Jobs per second over the batch wall-clock.
    pub fn throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.jobs as f64 / (self.wall_ms / 1e3)
    }
}

/// Fixed-width thread-pool scheduler.
pub struct Scheduler {
    num_threads: usize,
}

impl Scheduler {
    pub fn new(num_threads: usize) -> Scheduler {
        assert!(num_threads >= 1, "scheduler needs at least one thread");
        Scheduler { num_threads }
    }

    pub fn num_threads(&self) -> usize {
        self.num_threads
    }

    /// Run `f(0..n)` with bounded concurrency; returns results in index
    /// order plus a batch report. `f` must be a pure function of its index
    /// for the determinism guarantee to hold (the engine passes a closure
    /// over an immutable resolved-jobs slice).
    pub fn run<T, F>(&self, n: usize, f: F) -> (Vec<T>, BatchReport)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let sw = Stopwatch::start();
        let next = AtomicUsize::new(0);
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let workers = self.num_threads.min(n.max(1));

        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    let out = f(i);
                    *slots[i].lock().unwrap() = Some(out);
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });

        let results: Vec<T> = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("scheduler: job slot unfilled"))
            .collect();
        let report = BatchReport {
            jobs: n,
            threads: workers,
            peak_in_flight: peak.load(Ordering::SeqCst),
            wall_ms: sw.elapsed_ms(),
        };
        (results, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_submission_order() {
        let s = Scheduler::new(4);
        let (out, report) = s.run(32, |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(report.jobs, 32);
        assert!(report.threads <= 4);
        assert!(report.peak_in_flight >= 1);
    }

    #[test]
    fn batch_equals_sequential() {
        // deterministic per-index computation → identical results at any width
        let work = |i: usize| {
            let mut acc = i as u64 + 1;
            for _ in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            acc
        };
        let (par, _) = Scheduler::new(8).run(24, work);
        let (seq, _) = Scheduler::new(1).run(24, work);
        assert_eq!(par, seq);
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let count = AtomicU64::new(0);
        let (out, _) = Scheduler::new(3).run(50, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(out.len(), 50);
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn zero_jobs_is_fine() {
        let (out, report) = Scheduler::new(4).run(0, |i| i);
        assert!(out.is_empty());
        assert_eq!(report.jobs, 0);
        assert_eq!(report.peak_in_flight, 0);
    }

    #[test]
    fn more_threads_than_jobs_clamps() {
        let (out, report) = Scheduler::new(16).run(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(report.threads, 3);
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        let _ = Scheduler::new(0);
    }
}
