//! Bounded-concurrency batch scheduler + cooperative executor
//! (DESIGN.md §3, §8).
//!
//! Two execution modes over one fixed-width thread pool:
//!
//! - [`Scheduler::run`] — run-to-completion: N independent jobs, each
//!   owned by one worker from pickup to finish.
//! - [`Scheduler::run_coop`] — cooperative: N steppable tasks time-sliced
//!   in fixed round-robin quanta. Every live task gets exactly one
//!   quantum per round; a barrier closes the round and task events are
//!   applied **in task-index order** before the next round starts. This
//!   is what lets one pool interleave many in-flight solve drivers,
//!   enforce per-job deadlines, and publish warm-start checkpoints
//!   mid-solve.
//!
//! Determinism, both modes: each task's computation sees only its own
//! inputs, and cross-task effects (returned results, round events) are
//! applied in task-index order — so results are bit-identical to
//! sequential execution at any pool width. Thread scheduling affects
//! wall-clock only, never values. This mirrors the rank-ordered reduction
//! the distributed layer uses for the same reason.
//!
//! Bounded concurrency: at most `num_threads` jobs are in flight;
//! per-job memory (objective scratch, trajectories) is bounded by the
//! pool width in run-to-completion mode. (Cooperative mode keeps every
//! task's state alive for the whole batch — that is the price of
//! interleaving — but at most `num_threads` are *executing*.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::solver::StopReason;
use crate::util::timer::Stopwatch;

/// Aggregate facts about one batch execution.
#[derive(Clone, Copy, Debug)]
pub struct BatchReport {
    pub jobs: usize,
    pub threads: usize,
    /// Max jobs observed simultaneously in flight (≤ threads; equals the
    /// pool width whenever jobs outlast the pickup phase).
    pub peak_in_flight: usize,
    pub wall_ms: f64,
}

impl BatchReport {
    /// Jobs per second over the batch wall-clock.
    pub fn throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.jobs as f64 / (self.wall_ms / 1e3)
    }
}

/// Fixed-width thread-pool scheduler.
pub struct Scheduler {
    num_threads: usize,
}

impl Scheduler {
    pub fn new(num_threads: usize) -> Scheduler {
        assert!(num_threads >= 1, "scheduler needs at least one thread");
        Scheduler { num_threads }
    }

    pub fn num_threads(&self) -> usize {
        self.num_threads
    }

    /// Run `f(0..n)` with bounded concurrency; returns results in index
    /// order plus a batch report. `f` must be a pure function of its index
    /// for the determinism guarantee to hold (the engine passes a closure
    /// over an immutable resolved-jobs slice).
    pub fn run<T, F>(&self, n: usize, f: F) -> (Vec<T>, BatchReport)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let sw = Stopwatch::start();
        let next = AtomicUsize::new(0);
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let workers = self.num_threads.min(n.max(1));

        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    let out = f(i);
                    *slots[i].lock().unwrap() = Some(out);
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });

        let results: Vec<T> = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("scheduler: job slot unfilled"))
            .collect();
        let report = BatchReport {
            jobs: n,
            threads: workers,
            peak_in_flight: peak.load(Ordering::SeqCst),
            wall_ms: sw.elapsed_ms(),
        };
        (results, report)
    }
}

/// Aggregate facts about one cooperative execution.
#[derive(Clone, Copy, Debug)]
pub struct CoopReport {
    pub jobs: usize,
    pub threads: usize,
    /// Round-robin rounds until every task finished.
    pub rounds: usize,
    pub deadline_stops: usize,
    pub cancelled: usize,
    pub wall_ms: f64,
}

impl CoopReport {
    /// Jobs per second over the cooperative batch wall-clock.
    pub fn throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.jobs as f64 / (self.wall_ms / 1e3)
    }
}

impl Scheduler {
    /// Time-slice N cooperative tasks in fixed round-robin quanta.
    ///
    /// Per round, every unfinished task gets exactly one `quantum_fn`
    /// call (which should advance it by a fixed quantum of work — e.g.
    /// `k` driver steps) on some pool thread; the round then barriers and
    /// `apply` consumes each task's emitted events **in task-index
    /// order** on the calling thread. `quantum_fn` returns
    /// `Some(StopReason)` when its task reached a terminal state; the
    /// task is then never called again. Loops until every task finishes —
    /// `quantum_fn` must guarantee termination (solve drivers do, via
    /// `max_iters`).
    ///
    /// Determinism: values may not depend on pool width. Tasks are
    /// independent; cross-task effects flow only through `apply`, which
    /// runs single-threaded in (round, task-index) order.
    pub fn run_coop<J, E, F, P>(
        &self,
        jobs: Vec<J>,
        quantum_fn: F,
        mut apply: P,
    ) -> (Vec<J>, Vec<StopReason>, CoopReport)
    where
        J: Send,
        E: Send,
        F: Fn(usize, &mut J) -> (Vec<E>, Option<StopReason>) + Sync,
        P: FnMut(usize, Vec<E>),
    {
        let sw = Stopwatch::start();
        let n = jobs.len();
        let slots: Vec<Mutex<J>> = jobs.into_iter().map(Mutex::new).collect();
        let mut finished: Vec<Option<StopReason>> = (0..n).map(|_| None).collect();
        let mut rounds = 0usize;

        while finished.iter().any(|f| f.is_none()) {
            let live: Vec<usize> = (0..n).filter(|&i| finished[i].is_none()).collect();
            rounds += 1;
            let workers = self.num_threads.min(live.len());
            let next = AtomicUsize::new(0);
            let round_out: Vec<Mutex<Option<(Vec<E>, Option<StopReason>)>>> =
                live.iter().map(|_| Mutex::new(None)).collect();

            if workers == 1 {
                // inline fast path: no thread churn for the sequential case
                for (k, &i) in live.iter().enumerate() {
                    let mut job = slots[i].lock().unwrap();
                    let out = quantum_fn(i, &mut job);
                    *round_out[k].lock().unwrap() = Some(out);
                }
            } else {
                // NOTE: workers are (re)spawned per round — simple and
                // deterministic, but it prices each round at `workers`
                // thread spawns, so tiny quanta pay real overhead (visible
                // in bench_driver_overhead's throughput ratio). Keep the
                // quantum ≥ ~8 iterations, or move to a parked persistent
                // pool if small quanta ever matter.
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| loop {
                            let k = next.fetch_add(1, Ordering::SeqCst);
                            if k >= live.len() {
                                break;
                            }
                            let i = live[k];
                            let mut job = slots[i].lock().unwrap();
                            let out = quantum_fn(i, &mut job);
                            *round_out[k].lock().unwrap() = Some(out);
                        });
                    }
                });
            }

            for (k, cell) in round_out.into_iter().enumerate() {
                let i = live[k];
                let (events, stop) =
                    cell.into_inner().unwrap().expect("coop: quantum slot unfilled");
                apply(i, events);
                if stop.is_some() {
                    finished[i] = stop;
                }
            }
        }

        let jobs: Vec<J> = slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
        let reasons: Vec<StopReason> =
            finished.into_iter().map(|f| f.expect("coop: unfinished task")).collect();
        let report = CoopReport {
            jobs: n,
            threads: self.num_threads.min(n.max(1)),
            rounds,
            deadline_stops: reasons.iter().filter(|&&r| r == StopReason::Deadline).count(),
            cancelled: reasons.iter().filter(|&&r| r == StopReason::Cancelled).count(),
            wall_ms: sw.elapsed_ms(),
        };
        (jobs, reasons, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_submission_order() {
        let s = Scheduler::new(4);
        let (out, report) = s.run(32, |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(report.jobs, 32);
        assert!(report.threads <= 4);
        assert!(report.peak_in_flight >= 1);
    }

    #[test]
    fn batch_equals_sequential() {
        // deterministic per-index computation → identical results at any width
        let work = |i: usize| {
            let mut acc = i as u64 + 1;
            for _ in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            acc
        };
        let (par, _) = Scheduler::new(8).run(24, work);
        let (seq, _) = Scheduler::new(1).run(24, work);
        assert_eq!(par, seq);
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let count = AtomicU64::new(0);
        let (out, _) = Scheduler::new(3).run(50, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(out.len(), 50);
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn zero_jobs_is_fine() {
        let (out, report) = Scheduler::new(4).run(0, |i| i);
        assert!(out.is_empty());
        assert_eq!(report.jobs, 0);
        assert_eq!(report.peak_in_flight, 0);
    }

    #[test]
    fn more_threads_than_jobs_clamps() {
        let (out, report) = Scheduler::new(16).run(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(report.threads, 3);
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        let _ = Scheduler::new(0);
    }

    // ---- cooperative executor --------------------------------------------

    /// Run heterogeneous counter tasks cooperatively; return the event
    /// stream (task, value) in applied order plus the stop reasons.
    fn coop_counters(threads: usize, targets: &[usize]) -> (Vec<(usize, usize)>, Vec<StopReason>) {
        let jobs: Vec<(usize, usize)> = targets.iter().map(|&t| (0usize, t)).collect();
        let mut stream = Vec::new();
        let (_jobs, reasons, report) = Scheduler::new(threads).run_coop(
            jobs,
            |i, job: &mut (usize, usize)| {
                // one quantum = one unit of work, emitting one event
                job.0 += 1;
                let done = if job.0 >= job.1 { Some(StopReason::MaxIters) } else { None };
                (vec![(i, job.0)], done)
            },
            |_i, events| stream.extend(events),
        );
        assert_eq!(report.jobs, targets.len());
        assert!(report.rounds >= targets.iter().copied().max().unwrap_or(0));
        (stream, reasons)
    }

    #[test]
    fn coop_event_order_is_pool_width_invariant() {
        let targets = [5usize, 1, 3, 7, 2, 7, 4, 1];
        let (s1, r1) = coop_counters(1, &targets);
        for threads in [2usize, 4, 8] {
            let (st, rt) = coop_counters(threads, &targets);
            assert_eq!(s1, st, "event stream differs at {threads} threads");
            assert_eq!(r1, rt);
        }
        // round-robin fairness: round 1 applies one event per task in
        // task-index order
        assert_eq!(&s1[..8], &[(0, 1), (1, 1), (2, 1), (3, 1), (4, 1), (5, 1), (6, 1), (7, 1)]);
        // finished tasks drop out of later rounds
        assert_eq!(s1.len(), targets.iter().sum::<usize>());
        assert_eq!(s1.last(), Some(&(5, 7)), "longest task finishes last");
    }

    #[test]
    fn coop_counts_deadline_and_cancel_stops() {
        let reasons_in = [
            StopReason::MaxIters,
            StopReason::Deadline,
            StopReason::Cancelled,
            StopReason::Deadline,
        ];
        let (_jobs, reasons, report) = Scheduler::new(2).run_coop(
            (0..reasons_in.len()).collect::<Vec<usize>>(),
            |i, _job: &mut usize| (Vec::<()>::new(), Some(reasons_in[i])),
            |_, _| {},
        );
        assert_eq!(reasons, reasons_in);
        assert_eq!(report.rounds, 1);
        assert_eq!(report.deadline_stops, 2);
        assert_eq!(report.cancelled, 1);
    }

    #[test]
    fn coop_zero_jobs_is_fine() {
        let (jobs, reasons, report) = Scheduler::new(4).run_coop(
            Vec::<usize>::new(),
            |_i, _j: &mut usize| (Vec::<()>::new(), Some(StopReason::MaxIters)),
            |_, _| {},
        );
        assert!(jobs.is_empty() && reasons.is_empty());
        assert_eq!(report.rounds, 0);
    }
}
