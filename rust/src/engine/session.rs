//! The `SolveEngine` API (DESIGN.md §3): a persistent engine that accepts
//! solve jobs, recognizes structural re-solves via fingerprints, starts
//! them from cached duals, and runs batches across a bounded thread pool.
//!
//! Semantics chosen for serving determinism:
//!
//! - `submit` — solve one job now: cache lookup → solve → cache update.
//! - `solve_batch` — resolve every job's warm start against the cache
//!   state **at batch entry**, run the jobs through the scheduler, then
//!   apply cache updates in job order. Lookup-then-update at batch
//!   granularity makes the batch bit-identical to running the same jobs
//!   through a 1-thread scheduler — no dependence on completion order.
//! - `solve_batch_coop` — same snapshot semantics, but jobs run as
//!   steppable drivers time-sliced over the pool in round-robin quanta
//!   (DESIGN.md §8): per-job deadlines and cancellation are enforced
//!   between iterations, and anytime duals are published to the
//!   warm-start cache at every γ-decay checkpoint — not just at
//!   completion — so deadline-killed solves still warm their successors.
//!
//! Every path solves through the steppable `SolveDriver`
//! (`solver::driver`), so a `submit` is bit-identical to the same job
//! stepped manually or cooperatively.
//!
//! Jobs are solved on a named CPU backend (`backend::CpuBackend`) — the
//! slab-native batched objective by default, promoted to the chunk-sharded
//! flavor when `EngineConfig::shards > 1` (bit-identical results, so the
//! promotion and the warm-start cache are shard-count-agnostic), with the
//! per-source reference baseline selectable per engine. All are always
//! available and deterministic, and the `Maximizer`/`ObjectiveFunction`
//! contract is backend-agnostic, so swapping in the PJRT objective stays a
//! local change once artifacts exist. Each job's objective is wrapped in a
//! `TimedObjective`, so results attribute their wall-clock to objective
//! evaluation.

use std::sync::Mutex;

use super::fingerprint::Fingerprint;
use super::scheduler::{BatchReport, CoopReport, Scheduler};
use super::warmstart::{warm_options, WarmStart, WarmStartCache};
use crate::backend::{AnyObjective, CpuBackend, TimedObjective};
use crate::problem::{LpSpec, MatchingLp, ObjectiveFunction};
use crate::solver::{
    Agd, CancelToken, DriverOptions, SolveDriver, SolveOptions, StepEvent, StopReason,
};

/// One unit of work: an instance plus optional per-job overrides — solve
/// options (defaults to the engine's cold-solve template), a wall-clock
/// deadline, and a cancellation token.
pub struct SolveJob {
    /// Caller-chosen id, echoed in the result.
    pub id: u64,
    pub lp: MatchingLp,
    pub opts: Option<SolveOptions>,
    /// Per-job wall-clock deadline in ms (overrides
    /// `EngineConfig::deadline_ms`). A deadline-stopped job still runs at
    /// least one iteration and publishes its anytime λ to the warm-start
    /// cache, so killed solves warm their successors.
    pub deadline_ms: Option<f64>,
    /// Cooperative cancellation: keep a clone, `cancel()` any time.
    pub cancel: Option<CancelToken>,
}

impl SolveJob {
    pub fn new(id: u64, lp: MatchingLp) -> SolveJob {
        SolveJob { id, lp, opts: None, deadline_ms: None, cancel: None }
    }

    /// Builder: per-job wall-clock deadline in milliseconds.
    pub fn with_deadline_ms(mut self, ms: f64) -> SolveJob {
        self.deadline_ms = Some(ms);
        self
    }

    /// Builder: attach a cancellation token (keep a clone to fire it).
    pub fn with_cancel(mut self, token: CancelToken) -> SolveJob {
        self.cancel = Some(token);
        self
    }

    /// Build the job's instance from a declarative [`LpSpec`] — the
    /// formulation-API entry into the serving layer. Any registered
    /// projection family is accepted; the compiled instance is validated
    /// before it reaches the scheduler.
    pub fn from_spec(id: u64, spec: LpSpec) -> Result<SolveJob, String> {
        Ok(SolveJob::new(id, spec.build()?))
    }
}

/// Outcome of one engine solve.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub fingerprint: Fingerprint,
    /// Whether the solve started from a cached dual.
    pub warm: bool,
    pub iterations: usize,
    pub stop_reason: StopReason,
    pub dual_obj: f64,
    pub cx: f64,
    pub infeas_pos_norm: f64,
    pub final_gamma: f32,
    pub wall_ms: f64,
    /// Objective backend the job actually ran on (e.g. `cpu-slab`,
    /// `cpu-sharded-slab`; a slab request that could not build its layout
    /// reports `cpu-reference`).
    pub backend: &'static str,
    /// Shard count the job's objective ran with. Stats-only: shard count
    /// is NOT part of the fingerprint, because sharded results are
    /// bit-equal to single-shard results — warm starts are freely shared
    /// across shard configurations.
    pub shards: usize,
    /// Wall-clock spent inside objective evaluation (the per-iteration
    /// hot path), a subset of `wall_ms`.
    pub objective_eval_ms: f64,
    /// Slab buckets that ran a batched `project_rows` kernel (0 on the
    /// reference backend, which has no buckets).
    pub batched_kernel_buckets: u64,
    /// Slab buckets that fell back to the scalar per-row default — a
    /// nonzero count flags a family without its batched override
    /// (DESIGN.md §12).
    pub scalar_kernel_buckets: u64,
    /// Final dual iterate (feeds the cache and downstream primal recovery).
    pub lam: Vec<f32>,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Cold-solve options template (schedule, caps, stopping).
    pub opts: SolveOptions,
    /// Warm-start γ-tail length (iterations per halving; see
    /// `warmstart::warm_options`). 0 = restart directly at the floor γ.
    pub warm_tail: usize,
    /// Thread-pool width for `solve_batch`.
    pub threads: usize,
    /// Warm-start cache capacity (distinct fingerprints); 0 disables
    /// warm starting entirely (cold-baseline engine).
    pub cache_capacity: usize,
    /// Objective backend jobs solve on (slab by default).
    pub backend: CpuBackend,
    /// Thread-pool width *inside* one objective evaluation (slab backend
    /// only). Defaults to 1: batches already parallelize across jobs, and
    /// slab results are bit-identical at any width, so this is purely a
    /// latency knob for wide single jobs.
    pub objective_threads: usize,
    /// Shard count per objective (slab backends only). 1 = unsharded; a
    /// slab backend with `shards > 1` runs the chunk-sharded objective
    /// (`backend::ShardedSlabObjective`). Results are bit-identical at
    /// any shard count, so this — like `objective_threads` — is purely an
    /// execution knob: it is folded into stats (`JobResult::shards`), not
    /// into the fingerprint, and warm starts cross shard configurations.
    pub shards: usize,
    /// Default per-job wall-clock deadline in ms (None = unbounded);
    /// `SolveJob::deadline_ms` overrides per job. Enforced by the solve
    /// driver on every execution path (`submit`, `solve_batch`,
    /// `solve_batch_coop`).
    pub deadline_ms: Option<f64>,
    /// Cooperative-executor time slice: driver iterations per job per
    /// round-robin round (`solve_batch_coop`). Purely an execution knob —
    /// results are bit-identical at any quantum and any pool width.
    pub quantum: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            opts: SolveOptions::default(),
            warm_tail: 5,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            cache_capacity: 64,
            backend: CpuBackend::Slab,
            objective_threads: 1,
            shards: 1,
            deadline_ms: None,
            quantum: 16,
        }
    }
}

/// Aggregate engine counters (snapshot via `SolveEngine::stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub submitted: u64,
    pub cold_solves: u64,
    pub warm_solves: u64,
    pub cold_iters: u64,
    pub warm_iters: u64,
    pub total_wall_ms: f64,
    /// Wall-clock spent inside objective evaluation across all solves —
    /// attributes engine time to the backend hot path.
    pub objective_eval_ms: f64,
    pub batches: u64,
    pub peak_in_flight: usize,
    /// Solves stopped by the wall-clock deadline (`StopReason::Deadline`).
    pub deadline_stops: u64,
    /// Solves stopped by a cancellation token (`StopReason::Cancelled`).
    pub cancelled: u64,
    /// Warm-start cache hits (merged from the cache at snapshot time).
    pub cache_hits: u64,
    /// Warm-start cache misses (merged from the cache at snapshot time).
    pub cache_misses: u64,
    /// Warm-start cache LRU evictions — a nonzero rate means the cache is
    /// undersized for the fingerprint working set and re-solves that
    /// should run warm are running cold.
    pub cache_evictions: u64,
    /// Slab buckets across all solves that ran a batched kernel.
    pub batched_kernel_buckets: u64,
    /// Slab buckets across all solves that ran the scalar fallback —
    /// nonzero means some family is quietly on the slow path.
    pub scalar_kernel_buckets: u64,
}

impl EngineStats {
    pub fn mean_cold_iters(&self) -> f64 {
        if self.cold_solves == 0 {
            return f64::NAN;
        }
        self.cold_iters as f64 / self.cold_solves as f64
    }

    pub fn mean_warm_iters(&self) -> f64 {
        if self.warm_solves == 0 {
            return f64::NAN;
        }
        self.warm_iters as f64 / self.warm_solves as f64
    }

    /// Warm-start cache hit rate in [0, 1] (NaN before any lookup).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            return f64::NAN;
        }
        self.cache_hits as f64 / total as f64
    }

    /// Evictions per insert-causing solve — rough pressure signal
    /// (evictions over all completed solves).
    pub fn cache_evict_rate(&self) -> f64 {
        if self.submitted == 0 {
            return f64::NAN;
        }
        self.cache_evictions as f64 / self.submitted as f64
    }
}

/// Persistent multi-problem solve engine.
pub struct SolveEngine {
    cfg: EngineConfig,
    cache: Mutex<WarmStartCache>,
    stats: Mutex<EngineStats>,
}

impl SolveEngine {
    pub fn new(cfg: EngineConfig) -> SolveEngine {
        assert!(cfg.threads >= 1, "engine needs at least one thread");
        let cache = WarmStartCache::new(cfg.cache_capacity);
        SolveEngine {
            cfg,
            cache: Mutex::new(cache),
            stats: Mutex::new(EngineStats::default()),
        }
    }

    /// The cold-solve options for a job: the job override or the engine
    /// template, with `min_iters` pushed past the continuation descent so
    /// the stopping criterion is only evaluated at the floor γ — the
    /// "matched stopping criterion" warm and cold runs share.
    fn cold_options(&self, job: &SolveJob) -> SolveOptions {
        let mut opts = job.opts.clone().unwrap_or_else(|| self.cfg.opts.clone());
        opts.stopping.min_iters = opts
            .stopping
            .min_iters
            .max(opts.gamma.iters_to_floor() + 1);
        opts
    }

    /// Resolve a job's driver inputs: initial dual + options (warm or
    /// cold) and the driver policy (deadline, cancellation).
    fn driver_inputs(
        job: &SolveJob,
        cold: &SolveOptions,
        warm: Option<&WarmStart>,
        tail: usize,
        default_deadline_ms: Option<f64>,
    ) -> (Vec<f32>, SolveOptions, bool, DriverOptions) {
        let (init, opts, is_warm) = match warm {
            Some(ws) => (ws.lam.clone(), warm_options(cold, tail), true),
            None => (vec![0.0f32; job.lp.dual_dim()], cold.clone(), false),
        };
        let dopts = DriverOptions {
            deadline_ms: job.deadline_ms.or(default_deadline_ms),
            cancel: job.cancel.clone(),
        };
        (init, opts, is_warm, dopts)
    }

    /// Solve one resolved job through the driver. Pure function of its
    /// inputs — the scheduler fans this out without affecting values.
    /// `fp` is the job's fingerprint, computed once at resolution time
    /// (hashing the full sparsity pattern is not free on serving-sized
    /// instances).
    fn solve_resolved(
        job: &SolveJob,
        fp: Fingerprint,
        cold: &SolveOptions,
        warm: Option<&WarmStart>,
        tail: usize,
        backend: CpuBackend,
        objective_threads: usize,
        shards: usize,
        default_deadline_ms: Option<f64>,
    ) -> JobResult {
        let (init, opts, is_warm, dopts) =
            Self::driver_inputs(job, cold, warm, tail, default_deadline_ms);
        let mut obj =
            TimedObjective::new(backend.objective_with(&job.lp, objective_threads, shards));
        // actual, not requested: a layout-ineligible instance falls back
        // to the (unsharded) reference objective
        let ran_shards = obj.inner.shards();
        let (batched_kernel_buckets, scalar_kernel_buckets) = obj.inner.kernel_tier_counts();
        let mut driver = SolveDriver::new(Box::new(Agd::default().stepper()), &init, opts, dopts);
        let r = driver.run(&mut obj);
        JobResult {
            id: job.id,
            fingerprint: fp,
            warm: is_warm,
            iterations: r.iterations,
            stop_reason: r.stop_reason,
            dual_obj: r.final_obj.dual_obj,
            cx: r.final_obj.cx,
            infeas_pos_norm: r.final_obj.infeas_pos_norm,
            final_gamma: r.final_gamma,
            wall_ms: r.total_wall_ms,
            backend: obj.name(),
            shards: ran_shards,
            objective_eval_ms: obj.eval_ms,
            batched_kernel_buckets,
            scalar_kernel_buckets,
            lam: r.lam,
        }
    }

    fn record(&self, r: &JobResult) {
        let mut s = self.stats.lock().unwrap();
        s.submitted += 1;
        s.total_wall_ms += r.wall_ms;
        s.objective_eval_ms += r.objective_eval_ms;
        s.batched_kernel_buckets += r.batched_kernel_buckets;
        s.scalar_kernel_buckets += r.scalar_kernel_buckets;
        if r.warm {
            s.warm_solves += 1;
            s.warm_iters += r.iterations as u64;
        } else {
            s.cold_solves += 1;
            s.cold_iters += r.iterations as u64;
        }
        match r.stop_reason {
            StopReason::Deadline => s.deadline_stops += 1,
            StopReason::Cancelled => s.cancelled += 1,
            _ => {}
        }
    }

    /// Solve one job immediately (lookup → solve → cache update).
    pub fn submit(&self, job: SolveJob) -> JobResult {
        let fp = Fingerprint::of(&job.lp);
        let warm = self.cache.lock().unwrap().lookup(&fp);
        let cold = self.cold_options(&job);
        let r = Self::solve_resolved(
            &job,
            fp,
            &cold,
            warm.as_ref(),
            self.cfg.warm_tail,
            self.cfg.backend,
            self.cfg.objective_threads,
            self.cfg.shards,
            self.cfg.deadline_ms,
        );
        // zero-iteration λ is just the initial value (cancelled before the
        // first step, or a zero budget) — never cache it
        if r.iterations > 0 {
            self.cache
                .lock()
                .unwrap()
                .insert(fp, r.lam.clone(), r.final_gamma);
        }
        self.record(&r);
        r
    }

    /// Solve a batch across the thread pool. Warm starts resolve against
    /// the cache snapshot at entry; updates apply in job order afterwards,
    /// so results are independent of scheduling (see module docs).
    pub fn solve_batch(&self, jobs: Vec<SolveJob>) -> (Vec<JobResult>, BatchReport) {
        let tail = self.cfg.warm_tail;
        let resolved: Vec<(SolveJob, Fingerprint, SolveOptions, Option<WarmStart>)> = {
            let mut cache = self.cache.lock().unwrap();
            jobs.into_iter()
                .map(|job| {
                    let fp = Fingerprint::of(&job.lp);
                    let warm = cache.lookup(&fp);
                    let cold = self.cold_options(&job);
                    (job, fp, cold, warm)
                })
                .collect()
        };

        let backend = self.cfg.backend;
        let obj_threads = self.cfg.objective_threads;
        let shards = self.cfg.shards;
        let deadline = self.cfg.deadline_ms;
        let sched = Scheduler::new(self.cfg.threads);
        let (results, report) = sched.run(resolved.len(), |i| {
            let (job, fp, cold, warm) = &resolved[i];
            Self::solve_resolved(
                job,
                *fp,
                cold,
                warm.as_ref(),
                tail,
                backend,
                obj_threads,
                shards,
                deadline,
            )
        });

        {
            let mut cache = self.cache.lock().unwrap();
            for r in &results {
                // same guard as the coop path: a zero-iteration λ is just
                // the initial value and must not poison the cache
                if r.iterations > 0 {
                    cache.insert(r.fingerprint, r.lam.clone(), r.final_gamma);
                }
            }
        }
        for r in &results {
            self.record(r);
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.batches += 1;
            s.peak_in_flight = s.peak_in_flight.max(report.peak_in_flight);
        }
        (results, report)
    }

    /// Solve a batch on the **cooperative executor**: all jobs' drivers
    /// are time-sliced over the thread pool in fixed round-robin quanta
    /// (`EngineConfig::quantum` iterations per job per round), instead of
    /// each job monopolizing a worker to completion.
    ///
    /// Semantics vs [`Self::solve_batch`]:
    /// - warm starts still resolve against the cache snapshot at batch
    ///   entry, and per-job results are **bit-identical** to `solve_batch`
    ///   (same driver math) at any pool width and any quantum;
    /// - per-job deadlines/cancellation are enforced between iterations,
    ///   with latency bounded by one quantum rather than a full solve;
    /// - each job's anytime λ is published to the warm-start cache at
    ///   **every γ-decay checkpoint** (the last one is the γ-floor
    ///   arrival) — applied at round barriers in job order — and again at
    ///   completion, so even a deadline-killed or cancelled job warms its
    ///   successors. Zero-iteration jobs publish nothing (their λ is just
    ///   the initial value).
    pub fn solve_batch_coop(&self, jobs: Vec<SolveJob>) -> (Vec<JobResult>, CoopReport) {
        let tail = self.cfg.warm_tail;
        let resolved: Vec<(SolveJob, Fingerprint, SolveOptions, Option<WarmStart>)> = {
            let mut cache = self.cache.lock().unwrap();
            jobs.into_iter()
                .map(|job| {
                    let fp = Fingerprint::of(&job.lp);
                    let warm = cache.lookup(&fp);
                    let cold = self.cold_options(&job);
                    (job, fp, cold, warm)
                })
                .collect()
        };

        struct CoopTask<'a> {
            driver: SolveDriver<'static>,
            obj: TimedObjective<AnyObjective<'a>>,
            ran_shards: usize,
            kernel_tiers: (u64, u64),
        }

        let quantum = self.cfg.quantum.max(1);
        let tasks: Vec<CoopTask> = resolved
            .iter()
            .map(|(job, _fp, cold, warm)| {
                let (init, opts, _is_warm, dopts) =
                    Self::driver_inputs(job, cold, warm.as_ref(), tail, self.cfg.deadline_ms);
                let obj = TimedObjective::new(self.cfg.backend.objective_with(
                    &job.lp,
                    self.cfg.objective_threads,
                    self.cfg.shards,
                ));
                let ran_shards = obj.inner.shards();
                let kernel_tiers = obj.inner.kernel_tier_counts();
                let driver =
                    SolveDriver::new(Box::new(Agd::default().stepper()), &init, opts, dopts);
                CoopTask { driver, obj, ran_shards, kernel_tiers }
            })
            .collect();

        let sched = Scheduler::new(self.cfg.threads);
        let (tasks, _reasons, report) = sched.run_coop(
            tasks,
            |i, task: &mut CoopTask<'_>| {
                let mut events: Vec<(Fingerprint, Vec<f32>, f32)> = Vec::new();
                for _ in 0..quantum {
                    match task.driver.step(&mut task.obj) {
                        StepEvent::Stopped { reason } => return (events, Some(reason)),
                        StepEvent::GammaDecayed { record, .. } => {
                            // γ checkpoint: publish the λ optimized at the
                            // γ that just ended (record.gamma)
                            events.push((
                                resolved[i].1,
                                task.driver.current_lam().to_vec(),
                                record.gamma,
                            ));
                        }
                        StepEvent::Continue { .. } => {}
                    }
                }
                (events, None)
            },
            |_i, events| {
                let mut cache = self.cache.lock().unwrap();
                for (fp, lam, gamma) in events {
                    cache.insert(fp, lam, gamma);
                }
            },
        );

        let mut results = Vec::with_capacity(tasks.len());
        for (k, mut task) in tasks.into_iter().enumerate() {
            let (job, fp, _cold, warm) = &resolved[k];
            let r = task.driver.result(&mut task.obj);
            results.push(JobResult {
                id: job.id,
                fingerprint: *fp,
                warm: warm.is_some(),
                iterations: r.iterations,
                stop_reason: r.stop_reason,
                dual_obj: r.final_obj.dual_obj,
                cx: r.final_obj.cx,
                infeas_pos_norm: r.final_obj.infeas_pos_norm,
                final_gamma: r.final_gamma,
                wall_ms: r.total_wall_ms,
                backend: task.obj.name(),
                shards: task.ran_shards,
                objective_eval_ms: task.obj.eval_ms,
                batched_kernel_buckets: task.kernel_tiers.0,
                scalar_kernel_buckets: task.kernel_tiers.1,
                lam: r.lam,
            });
        }

        {
            let mut cache = self.cache.lock().unwrap();
            for r in &results {
                // zero-iteration λ is just the initial value — never
                // publish it (a cancelled cold job would poison the cache
                // with zeros)
                if r.iterations > 0 {
                    cache.insert(r.fingerprint, r.lam.clone(), r.final_gamma);
                }
            }
        }
        for r in &results {
            self.record(r);
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.batches += 1;
            s.peak_in_flight = s.peak_in_flight.max(report.threads);
        }
        (results, report)
    }

    /// Counter snapshot. Warm-start cache counters (hits/misses/evictions)
    /// are merged in from the cache at snapshot time — they live on the
    /// cache itself so every lookup path (including future direct cache
    /// users) is counted.
    pub fn stats(&self) -> EngineStats {
        let mut s = *self.stats.lock().unwrap();
        let c = self.cache.lock().unwrap();
        s.cache_hits = c.hits;
        s.cache_misses = c.misses;
        s.cache_evictions = c.evictions;
        s
    }

    /// Non-mutating view of the cached warm start for a fingerprint
    /// (diagnostics; no LRU or hit-counter effects).
    pub fn peek_warm(&self, fp: &Fingerprint) -> Option<WarmStart> {
        self.cache.lock().unwrap().peek(fp).cloned()
    }

    /// (hits, misses) of the warm-start cache.
    pub fn cache_counters(&self) -> (u64, u64) {
        let c = self.cache.lock().unwrap();
        (c.hits, c.misses)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::problem::jacobi_row_normalize;
    use crate::solver::{GammaSchedule, StoppingCriteria};

    fn instance(seed: u64) -> MatchingLp {
        let mut lp = generate(&SyntheticConfig {
            num_requests: 400,
            num_resources: 32,
            avg_nnz_per_row: 5.0,
            seed,
            ..Default::default()
        });
        jacobi_row_normalize(&mut lp);
        lp
    }

    fn test_config(threads: usize) -> EngineConfig {
        // Matched stopping: objective stall at the floor γ. (The RAW
        // gradient norm does not vanish at a constrained optimum — slack
        // rows keep λ = 0 against a negative gradient — so grad_norm_tol
        // is not a reachable criterion for matching LPs.)
        EngineConfig {
            opts: SolveOptions {
                max_iters: 1500,
                max_step_size: 1.0,
                initial_step_size: 1e-4,
                gamma: GammaSchedule::Decay {
                    init: 0.08,
                    floor: 0.02,
                    factor: 0.5,
                    every: 10,
                },
                stopping: StoppingCriteria {
                    stall_tol: Some(1e-6),
                    stall_patience: 10,
                    ..Default::default()
                },
                record_every: 50,
            },
            warm_tail: 4,
            threads,
            cache_capacity: 8,
            backend: CpuBackend::Slab,
            objective_threads: 1,
            shards: 1,
            deadline_ms: None,
            quantum: 8,
        }
    }

    #[test]
    fn submit_cold_then_warm_on_same_pattern() {
        let engine = SolveEngine::new(test_config(1));
        let a = engine.submit(SolveJob::new(0, instance(1)));
        assert!(!a.warm);
        // same seed → same instance → same fingerprint → warm
        let b = engine.submit(SolveJob::new(1, instance(1)));
        assert!(b.warm);
        assert_eq!(a.fingerprint, b.fingerprint);
        let s = engine.stats();
        assert_eq!((s.cold_solves, s.warm_solves), (1, 1));
        assert_eq!(engine.cache_counters(), (1, 1));
        // cache counters surface in the stats snapshot too
        assert_eq!((s.cache_hits, s.cache_misses, s.cache_evictions), (1, 1, 0));
        assert_eq!(s.cache_hit_rate(), 0.5);
        // warm restart of the SAME instance finishes almost immediately
        assert!(
            b.iterations < a.iterations,
            "warm {} vs cold {}",
            b.iterations,
            a.iterations
        );
    }

    #[test]
    fn distinct_patterns_do_not_cross_warm() {
        let engine = SolveEngine::new(test_config(1));
        let a = engine.submit(SolveJob::new(0, instance(1)));
        let b = engine.submit(SolveJob::new(1, instance(2)));
        assert!(!a.warm && !b.warm);
        assert_eq!(engine.cache_len(), 2);
    }

    #[test]
    fn polytope_change_misses_the_cache() {
        use crate::projection::{ProjectionKind, ProjectionMap};
        // same sparsity pattern, different blockwise polytope: the
        // fingerprints must differ, so no cross-polytope warm start (a λ
        // optimized for one feasible set is wrong for the other)
        let engine = SolveEngine::new(test_config(1));
        let a = engine.submit(SolveJob::new(0, instance(1)));
        let mut lp2 = instance(1);
        lp2.projection = ProjectionMap::Uniform(ProjectionKind::capped_simplex(0.5, 1.0));
        let b = engine.submit(SolveJob::new(1, lp2));
        assert_ne!(a.fingerprint, b.fingerprint);
        assert!(!b.warm, "different polytope must solve cold");
        assert_eq!(engine.cache_len(), 2);
    }

    #[test]
    fn jobs_build_from_lpspec_with_registry_operator() {
        let base = instance(9);
        let spec = LpSpec::new(base.a.clone(), base.cost.clone(), base.b.clone())
            .projection("weighted_simplex:1:1,0.5");
        let engine = SolveEngine::new(test_config(1));
        let r = engine.submit(SolveJob::from_spec(3, spec).unwrap());
        assert_eq!(r.id, 3);
        assert!(r.dual_obj.is_finite());
        // malformed specs surface as errors, not panics
        let bad = LpSpec::new(base.a.clone(), vec![0.0; 1], base.b.clone());
        assert!(SolveJob::from_spec(4, bad).is_err());
    }

    #[test]
    fn job_results_surface_backend_and_eval_time() {
        // default engine runs slab; reference stays selectable and both
        // report where the wall-clock went
        let slab_engine = SolveEngine::new(test_config(1));
        let a = slab_engine.submit(SolveJob::new(0, instance(4)));
        assert_eq!(a.backend, "cpu-slab");
        assert!(a.objective_eval_ms > 0.0 && a.objective_eval_ms <= a.wall_ms);
        assert!(slab_engine.stats().objective_eval_ms >= a.objective_eval_ms);

        let mut cfg = test_config(1);
        cfg.backend = CpuBackend::Reference;
        let ref_engine = SolveEngine::new(cfg);
        let b = ref_engine.submit(SolveJob::new(1, instance(4)));
        assert_eq!(b.backend, "cpu-reference");
        // both backends agree on the solve up to float noise
        assert!(
            (a.dual_obj - b.dual_obj).abs() < 1e-3 * (1.0 + b.dual_obj.abs()),
            "slab {} vs reference {}",
            a.dual_obj,
            b.dual_obj
        );
    }

    #[test]
    fn sharded_engine_is_bit_identical_and_shares_warm_starts() {
        // shard count is an execution knob, not identity: a sharded solve
        // must reproduce the unsharded bits, and a λ cached by a sharded
        // engine config must warm-start an unsharded re-solve (and vice
        // versa) because the fingerprint ignores shard count
        let plain = SolveEngine::new(test_config(1));
        let mut cfg = test_config(1);
        cfg.shards = 3;
        let sharded = SolveEngine::new(cfg);

        let a = plain.submit(SolveJob::new(0, instance(6)));
        let b = sharded.submit(SolveJob::new(0, instance(6)));
        assert_eq!(a.backend, "cpu-slab");
        assert_eq!(b.backend, "cpu-sharded-slab");
        assert_eq!((a.shards, b.shards), (1, 3));
        assert_eq!(a.fingerprint, b.fingerprint, "shards must not change identity");
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
        assert_eq!(a.lam.len(), b.lam.len());
        for (x, y) in a.lam.iter().zip(&b.lam) {
            assert_eq!(x.to_bits(), y.to_bits(), "sharded λ diverged");
        }
        // cross-config warm start: the sharded engine's cache was primed
        // by its own (bit-identical) solve, so a re-submit of the same
        // pattern under shards=3 must run warm
        let c = sharded.submit(SolveJob::new(1, instance(6)));
        assert!(c.warm, "same fingerprint must warm-start across shard configs");
    }

    #[test]
    fn coop_batch_is_bit_identical_to_run_to_completion_batch() {
        // same jobs, same primed cache: the cooperative executor must
        // reproduce solve_batch exactly, at any pool width and quantum
        let a_engine = SolveEngine::new(test_config(4));
        let mut cfg = test_config(1);
        cfg.quantum = 3;
        let b_engine = SolveEngine::new(cfg);
        let _ = a_engine.submit(SolveJob::new(99, instance(3)));
        let _ = b_engine.submit(SolveJob::new(99, instance(3)));

        let jobs = |off: u64| -> Vec<SolveJob> {
            (0..5).map(|k| SolveJob::new(off + k, instance(3))).collect()
        };
        let (a, _) = a_engine.solve_batch(jobs(0));
        let (b, creport) = b_engine.solve_batch_coop(jobs(0));
        assert_eq!(creport.jobs, 5);
        assert!(creport.rounds >= 1);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.iterations, rb.iterations, "job {}", ra.id);
            assert_eq!(ra.stop_reason, rb.stop_reason);
            assert_eq!(ra.dual_obj.to_bits(), rb.dual_obj.to_bits(), "job {}", ra.id);
            for (x, y) in ra.lam.iter().zip(&rb.lam) {
                assert_eq!(x.to_bits(), y.to_bits(), "job {} λ diverged", ra.id);
            }
        }
    }

    #[test]
    fn deadline_stops_are_reported_and_still_warm_the_cache() {
        let mut cfg = test_config(2);
        cfg.quantum = 4;
        let engine = SolveEngine::new(cfg);
        // deadline 0: stops deterministically after exactly one iteration
        let job = SolveJob::new(0, instance(5)).with_deadline_ms(0.0);
        let (results, report) = engine.solve_batch_coop(vec![job]);
        assert_eq!(results[0].stop_reason, StopReason::Deadline);
        assert_eq!(results[0].iterations, 1);
        assert!(results[0].dual_obj.is_finite());
        assert_eq!(report.deadline_stops, 1);
        let s = engine.stats();
        assert_eq!(s.deadline_stops, 1);
        // the killed solve still published its anytime λ
        assert_eq!(engine.cache_len(), 1);
        let again = engine.submit(SolveJob::new(1, instance(5)));
        assert!(again.warm, "deadline-killed solve must warm its successor");
    }

    #[test]
    fn cancelled_job_reports_cancelled_and_publishes_nothing() {
        use crate::solver::CancelToken;
        let engine = SolveEngine::new(test_config(2));
        let token = CancelToken::new();
        token.cancel(); // cancelled before the batch even starts
        let job = SolveJob::new(0, instance(7)).with_cancel(token);
        let (results, report) = engine.solve_batch_coop(vec![job]);
        assert_eq!(results[0].stop_reason, StopReason::Cancelled);
        assert_eq!(results[0].iterations, 0);
        // satellite guarantee: even a zero-iteration solve reports a real
        // evaluation, not a −∞ placeholder
        assert!(results[0].dual_obj.is_finite());
        assert_eq!(report.cancelled, 1);
        assert_eq!(engine.stats().cancelled, 1);
        assert_eq!(engine.cache_len(), 0, "zero-iteration λ must not be cached");
    }

    #[test]
    fn coop_mid_solve_gamma_checkpoints_reach_the_cache() {
        // one decay solve: γ checkpoints publish BEFORE the job completes.
        // The test schedule (0.08→0.02, halved every 10) has exactly 2
        // decay transitions, so the cache entry must show 2 checkpoint
        // inserts + 1 completion insert = 3 refreshes.
        let engine = SolveEngine::new(test_config(1));
        let (results, _) = engine.solve_batch_coop(vec![SolveJob::new(0, instance(8))]);
        assert!(!results[0].warm);
        let ws = engine.peek_warm(&results[0].fingerprint).expect("cached");
        assert!(
            ws.refreshes >= 2,
            "γ checkpoints must publish before the completion insert (refreshes {})",
            ws.refreshes
        );
        assert_eq!(ws.gamma, results[0].final_gamma);
        for (a, b) in ws.lam.iter().zip(&results[0].lam) {
            assert_eq!(a.to_bits(), b.to_bits(), "final insert wins");
        }
    }

    #[test]
    fn zero_capacity_engine_always_cold() {
        let mut cfg = test_config(1);
        cfg.cache_capacity = 0;
        let engine = SolveEngine::new(cfg);
        let _ = engine.submit(SolveJob::new(0, instance(1)));
        let b = engine.submit(SolveJob::new(1, instance(1)));
        assert!(!b.warm);
        assert_eq!(engine.stats().cold_solves, 2);
    }

    #[test]
    fn cache_evictions_surface_in_stats() {
        let mut cfg = test_config(1);
        cfg.cache_capacity = 1;
        let engine = SolveEngine::new(cfg);
        let _ = engine.submit(SolveJob::new(0, instance(1)));
        let _ = engine.submit(SolveJob::new(1, instance(2))); // evicts seed-1 entry
        let s = engine.stats();
        assert_eq!(s.cache_evictions, 1);
        assert_eq!(s.cache_evict_rate(), 0.5);
    }

    #[test]
    fn batch_snapshot_semantics_and_stats() {
        let engine = SolveEngine::new(test_config(4));
        // prime the cache with the pattern
        let primer = engine.submit(SolveJob::new(0, instance(3)));
        assert!(!primer.warm);
        let jobs: Vec<SolveJob> =
            (0..6).map(|k| SolveJob::new(10 + k, instance(3))).collect();
        let (results, report) = engine.solve_batch(jobs);
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.warm), "all jobs share the primed pattern");
        // ids echoed in order
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (10..16).collect::<Vec<u64>>());
        assert_eq!(report.jobs, 6);
        let s = engine.stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.submitted, 7);
    }
}
