//! Dual warm-start cache (DESIGN.md §3).
//!
//! First-order LP solve time is dominated by iteration count, not
//! per-iteration cost — so the serving win for re-solves is to start the
//! dual ascent from the previous instance's final λ instead of zero. The
//! cache maps a structural [`Fingerprint`] to the latest final (λ, γ) with
//! LRU eviction, and [`warm_options`] derives the re-solve options: the
//! full γ-continuation schedule is replaced by a **short tail** (a couple
//! of halvings into the same floor), because the cached λ is already a
//! near-optimal dual for the floor-γ problem and only needs a brief
//! re-smoothing window to absorb the `c`/`b` perturbation.

use std::collections::BTreeMap;

use super::fingerprint::Fingerprint;
use crate::solver::{GammaSchedule, SolveOptions};

/// Cached dual state from a completed solve — or from a mid-solve
/// γ-decay checkpoint: the cooperative executor publishes each job's
/// anytime λ at every continuation transition, so a deadline-killed or
/// cancelled solve still leaves a usable entry behind.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Latest published dual iterate λ (in the solved system's row
    /// scaling).
    pub lam: Vec<f32>,
    /// γ the cached λ was optimized at (the producing schedule's floor,
    /// or the pre-decay γ of a mid-solve checkpoint).
    pub gamma: f32,
    /// How many inserts have touched this entry (checkpoint publications
    /// count).
    pub refreshes: u64,
}

/// Fingerprint → warm-start map with LRU eviction and hit accounting.
pub struct WarmStartCache {
    // BTreeMap, not HashMap: `insert`'s eviction scan and
    // `export_entries` iterate this map, and LRU-tick ties (impossible
    // today, but one refactor away) would otherwise break on hash order —
    // snapshots and eviction sequences must be byte-stable across runs.
    entries: BTreeMap<Fingerprint, (WarmStart, u64)>,
    capacity: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// LRU evictions performed by `insert` (capacity pressure). Silent
    /// evictions would mask an undersized cache — or an undersized
    /// snapshot after a daemon restart — so the engine and serve layers
    /// surface this in their reports.
    pub evictions: u64,
}

impl WarmStartCache {
    /// `capacity` 0 disables warm starting (every lookup misses) — the
    /// engine's cold-baseline mode.
    pub fn new(capacity: usize) -> WarmStartCache {
        WarmStartCache {
            entries: BTreeMap::new(),
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Monotonic LRU clock (bumped by every `lookup` and `insert`).
    pub fn tick(&self) -> u64 {
        self.tick
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up a warm start, bumping LRU recency and hit counters. Entries
    /// whose λ length no longer matches the fingerprint's dual dimension
    /// are treated as misses (defensive; cannot happen through `insert`).
    pub fn lookup(&mut self, fp: &Fingerprint) -> Option<WarmStart> {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(fp) {
            Some((ws, last_used)) if ws.lam.len() == fp.dual_dim() => {
                *last_used = tick;
                self.hits += 1;
                Some(ws.clone())
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Non-mutating peek (no LRU/counter effects).
    pub fn peek(&self, fp: &Fingerprint) -> Option<&WarmStart> {
        self.entries.get(fp).map(|(ws, _)| ws)
    }

    /// Insert or refresh the entry for `fp`, evicting the least recently
    /// used entry when at capacity. No-op when capacity is 0.
    pub fn insert(&mut self, fp: Fingerprint, lam: Vec<f32>, gamma: f32) {
        if self.capacity == 0 {
            return;
        }
        debug_assert_eq!(lam.len(), fp.dual_dim());
        self.tick += 1;
        let tick = self.tick;
        if let Some((ws, last_used)) = self.entries.get_mut(&fp) {
            ws.lam = lam;
            ws.gamma = gamma;
            ws.refreshes += 1;
            *last_used = tick;
            return;
        }
        if self.entries.len() >= self.capacity {
            if let Some(oldest) = self
                .entries
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| *k)
            {
                self.entries.remove(&oldest);
                self.evictions += 1;
            }
        }
        self.entries
            .insert(fp, (WarmStart { lam, gamma, refreshes: 1 }, tick));
    }

    /// Snapshot view of every entry with its LRU tick, ordered oldest →
    /// newest. Ticks are unique (every `lookup`/`insert` consumes one), so
    /// the order is total and a restored cache evicts in exactly the same
    /// sequence the live one would have.
    pub fn export_entries(&self) -> Vec<(Fingerprint, WarmStart, u64)> {
        let mut out: Vec<(Fingerprint, WarmStart, u64)> = self
            .entries
            .iter()
            .map(|(fp, (ws, used))| (*fp, ws.clone(), *used))
            .collect();
        out.sort_by_key(|(_, _, used)| *used);
        out
    }

    /// Rebuild a cache from snapshot parts (inverse of `export_entries`
    /// plus the counters), preserving exact LRU ticks so eviction order
    /// and hit accounting continue bit-identically after a restart.
    pub fn from_parts(
        capacity: usize,
        tick: u64,
        hits: u64,
        misses: u64,
        evictions: u64,
        entries: Vec<(Fingerprint, WarmStart, u64)>,
    ) -> WarmStartCache {
        WarmStartCache {
            entries: entries
                .into_iter()
                .map(|(fp, ws, used)| (fp, (ws, used)))
                .collect(),
            capacity,
            tick,
            hits,
            misses,
            evictions,
        }
    }
}

/// Derive warm-start solve options from the cold-solve template.
///
/// - γ: a short continuation tail `2·floor → floor` (one halving after
///   `tail` iterations) instead of the cold schedule's full descent; with
///   `tail == 0`, fixed at the floor.
/// - step cap: the cold run ends with cap `max_step_size · floor/γ₀`
///   (continuation rescales the cap with γ); the warm run starts from
///   an already-converged dual, so it gets that *end-state* cap. The
///   tail's own `step_cap_scale` then halves it once more at the
///   transition, staying on the stable side.
/// - stopping: same criteria, but `min_iters` is **replaced** by the
///   tail-based gate (`tail + 1`) so the matched criterion is evaluated
///   as soon as the tail reaches the floor γ. The cold path's own
///   `min_iters` is an artifact of the cold schedule's descent length
///   (the engine bumps it to `iters_to_floor + 1`); inheriting it would
///   floor every warm solve at the cold descent length and erase the
///   warm-start win.
pub fn warm_options(cold: &SolveOptions, tail: usize) -> SolveOptions {
    let floor = cold.gamma.final_gamma();
    let g0 = cold.gamma.gamma_at(0);
    let end_cap_scale = if g0 > 0.0 { (floor / g0) as f64 } else { 1.0 };
    let gamma = if tail == 0 {
        GammaSchedule::Fixed(floor)
    } else {
        GammaSchedule::Decay {
            init: floor * 2.0,
            floor,
            factor: 0.5,
            every: tail,
        }
    };
    let mut stopping = cold.stopping.clone();
    stopping.min_iters = gamma.iters_to_floor() + 1;
    SolveOptions {
        max_iters: cold.max_iters,
        max_step_size: cold.max_step_size * end_cap_scale.min(1.0),
        initial_step_size: cold.initial_step_size,
        gamma,
        stopping,
        record_every: cold.record_every,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::StoppingCriteria;

    fn fp(n: usize) -> Fingerprint {
        Fingerprint {
            num_sources: n,
            num_dests: 4,
            num_families: 1,
            num_global_rows: 0,
            nnz: 4 * n,
            pattern_hash: n as u64,
            projection_hash: 0,
            global_coeff_hash: 0,
            coeff_hash: 0,
        }
    }

    #[test]
    fn miss_then_hit() {
        let mut c = WarmStartCache::new(4);
        assert!(c.lookup(&fp(1)).is_none());
        c.insert(fp(1), vec![0.5; 4], 0.01);
        let ws = c.lookup(&fp(1)).expect("hit");
        assert_eq!(ws.lam, vec![0.5; 4]);
        assert_eq!(ws.gamma, 0.01);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn refresh_updates_in_place() {
        let mut c = WarmStartCache::new(2);
        c.insert(fp(1), vec![0.1; 4], 0.04);
        c.insert(fp(1), vec![0.2; 4], 0.01);
        assert_eq!(c.len(), 1);
        let ws = c.peek(&fp(1)).unwrap();
        assert_eq!(ws.lam, vec![0.2; 4]);
        assert_eq!(ws.refreshes, 2);
    }

    #[test]
    fn lru_eviction_spares_recently_used() {
        let mut c = WarmStartCache::new(2);
        c.insert(fp(1), vec![0.0; 4], 0.01);
        c.insert(fp(2), vec![0.0; 4], 0.01);
        let _ = c.lookup(&fp(1)); // 1 newer than 2
        c.insert(fp(3), vec![0.0; 4], 0.01); // evicts 2
        assert!(c.peek(&fp(1)).is_some());
        assert!(c.peek(&fp(2)).is_none());
        assert!(c.peek(&fp(3)).is_some());
    }

    #[test]
    fn eviction_counter_tallies() {
        let mut c = WarmStartCache::new(2);
        c.insert(fp(1), vec![0.0; 4], 0.01);
        c.insert(fp(2), vec![0.0; 4], 0.01);
        assert_eq!(c.evictions, 0);
        c.insert(fp(3), vec![0.0; 4], 0.01);
        c.insert(fp(4), vec![0.0; 4], 0.01);
        assert_eq!(c.evictions, 2);
        c.insert(fp(4), vec![1.0; 4], 0.01); // refresh, not an eviction
        assert_eq!(c.evictions, 2);
    }

    #[test]
    fn export_and_from_parts_round_trip_preserves_lru() {
        let mut c = WarmStartCache::new(2);
        c.insert(fp(1), vec![0.1; 4], 0.04);
        c.insert(fp(2), vec![0.2; 4], 0.02);
        let _ = c.lookup(&fp(1)); // 1 newer than 2
        let _ = c.lookup(&fp(9)); // miss
        let entries = c.export_entries();
        assert_eq!(entries.len(), 2);
        // oldest → newest: fp(2) then fp(1)
        assert_eq!(entries[0].0, fp(2));
        assert_eq!(entries[1].0, fp(1));
        assert!(entries[0].2 < entries[1].2, "ticks strictly ordered");

        let mut r = WarmStartCache::from_parts(
            c.capacity(),
            c.tick(),
            c.hits,
            c.misses,
            c.evictions,
            entries,
        );
        assert_eq!((r.hits, r.misses, r.evictions), (1, 1, 0));
        assert_eq!(r.tick(), c.tick());
        // same next eviction victim as the live cache: fp(2)
        r.insert(fp(3), vec![0.3; 4], 0.01);
        c.insert(fp(3), vec![0.3; 4], 0.01);
        for cache in [&r, &c] {
            assert!(cache.peek(&fp(1)).is_some());
            assert!(cache.peek(&fp(2)).is_none());
            assert!(cache.peek(&fp(3)).is_some());
        }
        assert_eq!(r.evictions, 1);
        assert_eq!(r.tick(), c.tick());
    }

    #[test]
    fn zero_capacity_disables_cache() {
        let mut c = WarmStartCache::new(0);
        c.insert(fp(1), vec![0.0; 4], 0.01);
        assert!(c.is_empty());
        assert!(c.lookup(&fp(1)).is_none());
    }

    #[test]
    fn warm_options_short_tail_and_scaled_cap() {
        let cold = SolveOptions {
            max_iters: 500,
            max_step_size: 1.0,
            initial_step_size: 1e-4,
            gamma: GammaSchedule::paper_fig5(), // 0.16 → 0.01
            stopping: StoppingCriteria {
                grad_norm_tol: Some(1e-3),
                ..Default::default()
            },
            record_every: 1,
        };
        let warm = warm_options(&cold, 5);
        // tail: 0.02 → 0.01 after 5 iterations
        assert_eq!(warm.gamma.gamma_at(0), 0.02);
        assert_eq!(warm.gamma.gamma_at(5), 0.01);
        assert_eq!(warm.gamma.final_gamma(), 0.01);
        // cap matches the cold run's end-state cap (1.0 · 0.01/0.16,
        // computed in f32 like the schedule itself)
        let expect = (0.01f32 / 0.16f32) as f64;
        assert!((warm.max_step_size - expect).abs() < 1e-12);
        // criterion only evaluated at the floor — and the tail gate
        // REPLACES the cold min_iters (a cold-descent artifact) rather
        // than maxing with it, or every warm solve would be floored at
        // the cold schedule's length
        assert_eq!(warm.stopping.min_iters, 6);
        let mut bumped = cold.clone();
        bumped.stopping.min_iters = 101; // what the engine's cold path sets
        assert_eq!(warm_options(&bumped, 5).stopping.min_iters, 6);
        assert_eq!(warm.stopping.grad_norm_tol, Some(1e-3));
        // tail 0 → fixed floor
        let warm0 = warm_options(&cold, 0);
        assert!(matches!(warm0.gamma, GammaSchedule::Fixed(f) if f == 0.01));
    }
}
