//! Synthetic workload generation (paper Appendix B) and named workload
//! presets used by the experiment drivers.

pub mod synthetic;
pub mod workloads;

pub use synthetic::{generate, SyntheticConfig};
pub use workloads::{power_law_instance, PowerLawConfig};
