//! Appendix-B synthetic matching LP generator — implemented exactly as the
//! paper describes:
//!
//! 1. Per resource j draw a lognormal "breadth", normalize to probabilities
//!    p_j, sample K_j ~ Poisson(p_j · I · ν) truncated at I (ν = target
//!    average nonzeros per row), and pick K_j distinct requests → edges.
//! 2. Per edge: value c_ij = min(v_j · u_i · ε_ij, c_max) from a
//!    resource-scale v_j, request-responsiveness u_i, and multiplicative
//!    noise ε_ij; constraint coefficient a_ij = s_j · c_ij with lognormal
//!    per-resource scale s_j.
//! 3. RHS: greedy load ℓ_j = Σ over requests of their max incident a_ij
//!    assigned to the argmax resource; b_j = ρ_j (ℓ_j + ε), ρ_j ~ U[0.5, 1]
//!    — so some constraints bind and others stay slack.
//!
//! Values are generated as *positive* and signs flipped to match the
//! minimization convention (paper: "signs adjusted").

use crate::problem::{LpSpec, MatchingLp};
use crate::projection::ProjectionKind;
use crate::sparse::slabs::MAX_WIDTH;
use crate::sparse::BlockedMatrix;
use crate::util::rng::Rng;

/// Generator parameters (defaults follow Appendix B / §7's workloads).
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// I — number of requests (sources).
    pub num_requests: usize,
    /// J — number of resources (destinations).
    pub num_resources: usize,
    /// ν — target average nonzeros per constraint row; paper's "sparsity"
    /// is ν/I (e.g. 0.001 with I=25M → ν = 25k; scaled runs keep ν/I).
    pub avg_nnz_per_row: f64,
    /// Lognormal σ of resource breadth.
    pub breadth_sigma: f64,
    /// Lognormal σ of the per-resource value scale v_j.
    pub value_sigma: f64,
    /// Lognormal σ of request responsiveness u_i.
    pub responsiveness_sigma: f64,
    /// Lognormal σ of edge noise ε_ij.
    pub noise_sigma: f64,
    /// Lognormal σ of the constraint scale s_j.
    pub constraint_scale_sigma: f64,
    /// Value cap c_max.
    pub c_max: f64,
    /// Small additive slack ε in b_j = ρ_j(ℓ_j + ε).
    pub rhs_eps: f64,
    /// Number of matching constraint families m (paper Def. 1). Families
    /// beyond the first reuse the same eligibility pattern with fresh
    /// per-resource scales, as in a_kij = s_jk · c_ij.
    pub num_families: usize,
    /// Simple-constraint polytope per source.
    pub kind: ProjectionKind,
    pub seed: u64,
}

impl SyntheticConfig {
    /// Paper §7 Table-2 shape at a scale factor: J=10k, sparsity 1e-3.
    /// `scale=1.0` ⇒ 25M sources (paper row 1); we typically run 0.01.
    pub fn table2(sources: usize, seed: u64) -> Self {
        SyntheticConfig {
            num_requests: sources,
            num_resources: 10_000.min(sources / 10).max(16),
            avg_nnz_per_row: 0.001 * sources as f64,
            ..SyntheticConfig::default_with(seed)
        }
    }

    pub fn default_with(seed: u64) -> Self {
        SyntheticConfig {
            num_requests: 10_000,
            num_resources: 500,
            avg_nnz_per_row: 10.0,
            breadth_sigma: 1.0,
            value_sigma: 0.6,
            responsiveness_sigma: 0.5,
            noise_sigma: 0.3,
            constraint_scale_sigma: 1.0,
            c_max: 10.0,
            rhs_eps: 1e-3,
            num_families: 1,
            kind: ProjectionKind::Simplex,
            seed,
        }
    }
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        Self::default_with(0)
    }
}

/// Generate a matching LP per Appendix B.
pub fn generate(cfg: &SyntheticConfig) -> MatchingLp {
    let (i_n, j_n) = (cfg.num_requests, cfg.num_resources);
    assert!(i_n > 0 && j_n > 0);
    let mut rng = Rng::new(cfg.seed);

    // --- 1. bipartite graph ----------------------------------------------
    // breadth → probabilities
    let breadth: Vec<f64> = (0..j_n).map(|_| rng.lognormal(0.0, cfg.breadth_sigma)).collect();
    let total_breadth: f64 = breadth.iter().sum();
    // per-resource request lists (edges grouped by resource first)
    let mut incident: Vec<Vec<u32>> = Vec::with_capacity(j_n);
    for j in 0..j_n {
        let p = breadth[j] / total_breadth;
        // E[K_j] = p_j · I · ν ... with Σ_j E[K_j] = I·ν ⇒ ν = avg edges per
        // *source*; paper says "per row" (constraint rows are resources in
        // the single-family matching form) — we follow Σ nnz ≈ I·ν.
        let mean = p * i_n as f64 * cfg.avg_nnz_per_row;
        let k = (rng.poisson(mean) as usize).min(i_n);
        incident.push(rng.sample_distinct(i_n, k));
    }

    // --- 2. values and coefficients --------------------------------------
    let u: Vec<f64> = (0..i_n).map(|_| rng.lognormal(0.0, cfg.responsiveness_sigma)).collect();
    let vj: Vec<f64> = (0..j_n).map(|_| rng.lognormal(0.0, cfg.value_sigma)).collect();
    // per-family constraint scales s_jk
    let s: Vec<Vec<f64>> = (0..cfg.num_families)
        .map(|_| (0..j_n).map(|_| rng.lognormal(0.0, cfg.constraint_scale_sigma)).collect())
        .collect();

    // Regroup edges by source (the blocked layout) while drawing values.
    // First count degrees; drop duplicate (i,j) pairs (sample_distinct makes
    // them distinct within a resource already).
    let mut degree = vec![0u32; i_n];
    for js in incident.iter() {
        for &i in js {
            degree[i as usize] += 1;
        }
    }
    // Cap degrees at MAX_WIDTH for non-separable polytopes by dropping
    // excess edges (rare under the paper's sparsity; counted below).
    let cap = if cfg.kind.separable() { u32::MAX } else { MAX_WIDTH as u32 };

    let mut src_ptr = vec![0usize; i_n + 1];
    for i in 0..i_n {
        src_ptr[i + 1] = src_ptr[i] + degree[i].min(cap) as usize;
    }
    let nnz = src_ptr[i_n];
    let mut dest_idx = vec![0u32; nnz];
    let mut cost = vec![0.0f32; nnz];
    let mut a: Vec<Vec<f32>> = vec![vec![0.0f32; nnz]; cfg.num_families];
    let mut fill = vec![0u32; i_n];
    let mut dropped = 0usize;
    for (j, js) in incident.iter().enumerate() {
        for &i in js {
            let iu = i as usize;
            if fill[iu] >= degree[iu].min(cap) {
                dropped += 1;
                continue;
            }
            let e = src_ptr[iu] + fill[iu] as usize;
            fill[iu] += 1;
            dest_idx[e] = j as u32;
            let eps = rng.lognormal(0.0, cfg.noise_sigma);
            let c = (vj[j] * u[iu] * eps).min(cfg.c_max);
            cost[e] = -(c as f32); // minimization convention: value → -cost
            for (k, ak) in a.iter_mut().enumerate() {
                ak[e] = (s[k][j] * c) as f32;
            }
        }
    }
    let _ = dropped;

    let matrix = BlockedMatrix {
        num_sources: i_n,
        num_dests: j_n,
        num_families: cfg.num_families,
        src_ptr,
        dest_idx,
        a,
    };

    // --- 3. right-hand side ----------------------------------------------
    // Greedy load: each request sends its largest family-0 coefficient to
    // that argmax resource (per-request simplex: at most one unit).
    let mut load = vec![0.0f64; j_n];
    for i in 0..i_n {
        let (e0, e1) = (matrix.src_ptr[i], matrix.src_ptr[i + 1]);
        if e0 == e1 {
            continue;
        }
        let mut best_e = e0;
        for e in e0 + 1..e1 {
            if matrix.a[0][e] > matrix.a[0][best_e] {
                best_e = e;
            }
        }
        load[matrix.dest_idx[best_e] as usize] += matrix.a[0][best_e] as f64;
    }
    let mut b = Vec::with_capacity(cfg.num_families * j_n);
    for k in 0..cfg.num_families {
        for j in 0..j_n {
            let rho = rng.uniform_range(0.5, 1.0);
            // family k scales with its own s_jk relative to family 0
            let scale = if k == 0 { 1.0 } else { s[k][j] / s[0][j].max(1e-12) };
            b.push((rho * (load[j] * scale + cfg.rhs_eps)) as f32);
        }
    }

    // Assemble through the declarative builder (the §4 formulation API);
    // `build` validates, replacing the old debug-only assertion.
    LpSpec::new(matrix, cost, b)
        .projection_kind(cfg.kind)
        .build()
        .expect("Appendix-B generator produced an invalid LP")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_lp() {
        let lp = generate(&SyntheticConfig {
            num_requests: 2000,
            num_resources: 100,
            avg_nnz_per_row: 8.0,
            ..Default::default()
        });
        lp.validate().unwrap();
        assert_eq!(lp.num_sources(), 2000);
        assert_eq!(lp.num_dests(), 100);
        assert!(lp.nnz() > 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SyntheticConfig { num_requests: 500, num_resources: 50, seed: 7, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.a.dest_idx, b.a.dest_idx);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.b, b.b);
        let c = generate(&SyntheticConfig { seed: 8, ..cfg });
        assert_ne!(a.a.dest_idx, c.a.dest_idx);
    }

    #[test]
    fn target_density_roughly_met() {
        let cfg = SyntheticConfig {
            num_requests: 20_000,
            num_resources: 200,
            avg_nnz_per_row: 6.0,
            ..Default::default()
        };
        let lp = generate(&cfg);
        let avg = lp.nnz() as f64 / cfg.num_requests as f64;
        assert!(
            (avg - 6.0).abs() / 6.0 < 0.35,
            "avg degree {avg} too far from target 6"
        );
    }

    #[test]
    fn costs_negative_and_capped() {
        let lp = generate(&SyntheticConfig::default());
        assert!(lp.cost.iter().all(|&c| c <= 0.0));
        assert!(lp.cost.iter().all(|&c| c >= -10.0 - 1e-5));
        // a coefficients positive wherever cost nonzero
        for (e, &c) in lp.cost.iter().enumerate() {
            if c < 0.0 {
                assert!(lp.a.a[0][e] > 0.0);
            }
        }
    }

    #[test]
    fn rhs_makes_some_constraints_bindable() {
        // greedy load vs rhs: b_j < ℓ_j for at least a decent fraction
        // (ρ_j < 1), so the LP is not trivially unconstrained.
        let lp = generate(&SyntheticConfig {
            num_requests: 5000,
            num_resources: 100,
            avg_nnz_per_row: 10.0,
            ..Default::default()
        });
        let nonzero_b = lp.b.iter().filter(|&&b| b > 0.0).count();
        assert!(nonzero_b > 50, "most resources should have positive capacity");
    }

    #[test]
    fn multi_family_shapes() {
        let lp = generate(&SyntheticConfig {
            num_requests: 1000,
            num_resources: 64,
            num_families: 3,
            ..Default::default()
        });
        lp.validate().unwrap();
        assert_eq!(lp.num_families(), 3);
        assert_eq!(lp.dual_dim(), 3 * 64);
        assert_eq!(lp.b.len(), 3 * 64);
    }

    #[test]
    fn degrees_capped_for_simplex() {
        let lp = generate(&SyntheticConfig {
            num_requests: 200,
            num_resources: 1200,
            avg_nnz_per_row: 700.0, // would exceed MAX_WIDTH without cap
            kind: ProjectionKind::Simplex,
            ..Default::default()
        });
        assert!(lp.a.max_degree() <= MAX_WIDTH);
    }
}
