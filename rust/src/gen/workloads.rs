//! Named workload presets mapping the paper's experiment settings (§7,
//! Appendix B) to concrete generator configs at this testbed's scale.
//!
//! Paper scale: 25M–100M sources, J = 10 000, sparsity 0.001, on A100s.
//! CPU-PJRT scale: divide sources by SCALE_DIV (default 100), keep J
//! proportionally sized and preserve ν/I (density), so bucket
//! distributions, padding factors and comm/compute ratios stay
//! representative (DESIGN.md §5 Substitutions).

use super::synthetic::SyntheticConfig;
use crate::problem::{LpSpec, MatchingLp};
use crate::projection::ProjectionKind;
use crate::sparse::slabs::MAX_WIDTH;
use crate::sparse::BlockedMatrix;
use crate::util::rng::Rng;

/// Source-count divisor vs. the paper's instances.
pub const SCALE_DIV: usize = 100;

/// Table 2 rows: paper sources ∈ {25M, 50M, 75M, 100M}, J = 10k,
/// sparsity = 0.001 (⇒ ν = 10 per source at J = 10k).
pub fn table2_row(paper_sources_m: usize, seed: u64) -> SyntheticConfig {
    let sources = paper_sources_m * 1_000_000 / SCALE_DIV;
    SyntheticConfig {
        num_requests: sources,
        num_resources: 10_000 / SCALE_DIV.min(10), // keep J = 1000 at /100
        avg_nnz_per_row: 10.0,                     // = J · 0.001 at paper scale
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Fig 4/5 ablation instance: paper 25M sources, 10k dests, 0.1% sparsity.
pub fn ablation_instance(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 250_000,
        num_resources: 1_000,
        avg_nnz_per_row: 10.0,
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Parity (Fig 1/2) instance: small enough that the reference path is fast,
/// structured like the production workloads.
pub fn parity_instance(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 50_000,
        num_resources: 500,
        avg_nnz_per_row: 10.0,
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Quick smoke workload for examples/tests.
pub fn smoke(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 2_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Relative perturbation magnitudes for a production re-solve stream: the
/// eligibility graph (A's pattern AND coefficients) is held fixed while
/// objective coefficients and budgets drift — the refresh pattern the
/// paper's "solved repeatedly at massive scale" serving setting produces
/// (bids/value models re-scored, budgets re-paced between solves).
#[derive(Clone, Copy, Debug)]
pub struct PerturbSpec {
    /// Std-dev of the multiplicative cost noise: c ← c·(1 + c_rel·N(0,1)).
    pub c_rel: f64,
    /// Std-dev of the multiplicative rhs noise: b ← b·max(0, 1 + b_rel·N).
    pub b_rel: f64,
}

impl Default for PerturbSpec {
    fn default() -> Self {
        PerturbSpec { c_rel: 0.05, b_rel: 0.05 }
    }
}

/// A same-pattern instance with perturbed `c`/`b`. The constraint matrix is
/// cloned verbatim, so `engine::Fingerprint` recognizes the result as a
/// re-solve of `base`. Deterministic per (base, spec, seed).
pub fn perturb_instance(base: &MatchingLp, spec: &PerturbSpec, seed: u64) -> MatchingLp {
    let mut rng = Rng::new(seed ^ 0xA5A5_5A5A_C3C3_3C3C);
    let cost: Vec<f32> = base
        .cost
        .iter()
        .map(|&c| (c as f64 * (1.0 + spec.c_rel * rng.normal())) as f32)
        .collect();
    let b: Vec<f32> = base
        .b
        .iter()
        .map(|&v| (v as f64 * (1.0 + spec.b_rel * rng.normal()).max(0.0)) as f32)
        .collect();
    let global_rows = base
        .global_rows
        .iter()
        .map(|g| {
            let mut g2 = g.clone();
            g2.rhs = (g.rhs as f64 * (1.0 + spec.b_rel * rng.normal()).max(0.0)) as f32;
            g2
        })
        .collect();
    MatchingLp {
        a: base.a.clone(),
        cost,
        b,
        // shallow Arc clone — same polytopes, same fingerprint
        projection: base.projection.clone(),
        primal_scale: base.primal_scale.clone(),
        global_rows,
    }
}

/// One request in a drifting serve stream: a perturbed instance plus its
/// arrival offset and SLO budget — the input shape `serve::ServeDaemon`
/// and `bench_serve_latency` consume.
#[derive(Clone)]
pub struct StreamRequest {
    pub id: u64,
    pub lp: MatchingLp,
    /// Arrival offset from stream start (ms), non-decreasing.
    pub arrival_ms: f64,
    /// SLO budget from arrival (ms): tight for light refreshes, loose for
    /// heavy campaign refreshes.
    pub slo_ms: f64,
    /// Heavy campaign refresh (larger perturbation, loose SLO).
    pub heavy: bool,
}

/// Drifting request-stream shape: per-step drift magnitude, skewed
/// (lognormal) inter-arrival gaps, and a light/heavy request mix.
#[derive(Clone, Copy, Debug)]
pub struct DriftStreamSpec {
    /// Number of requests.
    pub n: usize,
    /// Per-step drift (applied cumulatively — see [`drift_stream`]).
    pub drift: PerturbSpec,
    /// Heavy requests scale the per-step drift by this factor.
    pub heavy_drift_mult: f64,
    /// Fraction of requests that are heavy campaign refreshes.
    pub heavy_frac: f64,
    /// Median inter-arrival gap (ms).
    pub median_gap_ms: f64,
    /// Lognormal σ of the gap skew (0 = uniform spacing).
    pub gap_sigma: f64,
    /// SLO budget for light requests (ms).
    pub slo_light_ms: f64,
    /// SLO budget for heavy requests (ms).
    pub slo_heavy_ms: f64,
}

impl Default for DriftStreamSpec {
    fn default() -> Self {
        DriftStreamSpec {
            n: 32,
            drift: PerturbSpec { c_rel: 0.02, b_rel: 0.02 },
            heavy_drift_mult: 4.0,
            heavy_frac: 0.2,
            median_gap_ms: 5.0,
            gap_sigma: 1.0,
            slo_light_ms: 250.0,
            slo_heavy_ms: 2000.0,
        }
    }
}

/// A drifting request stream off a base instance. Unlike
/// [`perturbation_sequence`] (iid jitter around the base), each request
/// perturbs the *previous* instance, so `c`/`b` random-walk away from the
/// base over time — the serving regime where yesterday's λ slowly stops
/// being a good start. The sparsity pattern is untouched, so every
/// request keeps the base fingerprint and exercises the warm-start path.
/// Inter-arrival gaps are lognormal (bursts + long tails) and a
/// `heavy_frac` of requests are heavy campaign refreshes with
/// `heavy_drift_mult`× the drift and a looser SLO. Deterministic per
/// (base, spec, seed).
pub fn drift_stream(base: &MatchingLp, spec: &DriftStreamSpec, seed: u64) -> Vec<StreamRequest> {
    let mut arrivals = Rng::new(seed ^ 0x7D31_F7_5E4E_5EED);
    let mut current = base.clone();
    let mut clock = 0.0f64;
    (0..spec.n as u64)
        .map(|k| {
            let heavy = arrivals.uniform() < spec.heavy_frac;
            let step = if heavy {
                PerturbSpec {
                    c_rel: spec.drift.c_rel * spec.heavy_drift_mult,
                    b_rel: spec.drift.b_rel * spec.heavy_drift_mult,
                }
            } else {
                spec.drift
            };
            current = perturb_instance(&current, &step, seed.wrapping_add(k));
            clock += spec.median_gap_ms * arrivals.lognormal(0.0, spec.gap_sigma);
            StreamRequest {
                id: k,
                lp: current.clone(),
                arrival_ms: clock,
                slo_ms: if heavy { spec.slo_heavy_ms } else { spec.slo_light_ms },
                heavy,
            }
        })
        .collect()
}

/// A length-`n` re-solve stream off a base instance; element k is
/// `perturb_instance(base, spec, seed + k)`.
pub fn perturbation_sequence(
    base: &MatchingLp,
    spec: &PerturbSpec,
    n: usize,
    seed: u64,
) -> Vec<MatchingLp> {
    (0..n)
        .map(|k| perturb_instance(base, spec, seed.wrapping_add(k as u64)))
        .collect()
}


/// Power-law (bounded-Pareto) degree workload — the workload-zoo member
/// whose skewed degrees are the adversarial case for width bucketing:
/// most sources sit at the minimum degree while a heavy tail pins the
/// wide buckets, so pow2 padding overshoots and `bench_slab_build` uses
/// it to measure what the quarter-step [`WidthPolicy`] buys back.
///
/// [`WidthPolicy`]: crate::sparse::WidthPolicy
#[derive(Clone, Debug)]
pub struct PowerLawConfig {
    pub num_sources: usize,
    pub num_dests: usize,
    /// Pareto tail exponent (`deg ∝ u^{-1/(alpha-1)}`); smaller = heavier
    /// tail. Typical web-graph range: 1.8–2.5.
    pub alpha: f64,
    pub min_degree: usize,
    /// Degree ceiling before the structural caps (destination count; the
    /// slab width for non-separable kinds, which cannot split rows).
    pub max_degree: usize,
    pub num_families: usize,
    pub kind: ProjectionKind,
    pub seed: u64,
}

impl Default for PowerLawConfig {
    fn default() -> PowerLawConfig {
        PowerLawConfig {
            num_sources: 10_000,
            num_dests: 2_000,
            alpha: 2.2,
            min_degree: 2,
            max_degree: MAX_WIDTH,
            num_families: 1,
            kind: ProjectionKind::Simplex,
            seed: 0,
        }
    }
}

/// Generate a matching LP with bounded-Pareto source degrees (see
/// [`PowerLawConfig`]). Deterministic per seed. Costs are negated
/// lognormal utilities; budgets follow the Appendix-B greedy-load recipe
/// so the duals bind without starving destinations.
pub fn power_law_instance(cfg: &PowerLawConfig) -> MatchingLp {
    let mut rng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
    let mut cap = cfg.max_degree.min(cfg.num_dests);
    if !cfg.kind.separable() {
        cap = cap.min(MAX_WIDTH);
    }
    let min_deg = cfg.min_degree.clamp(1, cap);
    let tail = -1.0 / (cfg.alpha - 1.0);
    let mut src_ptr = vec![0usize];
    let mut dest_idx: Vec<u32> = Vec::new();
    for _ in 0..cfg.num_sources {
        let u = rng.uniform().max(1e-12);
        let deg = ((min_deg as f64) * u.powf(tail)) as usize;
        let deg = deg.clamp(min_deg, cap);
        let mut dests = rng.sample_distinct(cfg.num_dests, deg);
        dests.sort_unstable();
        dest_idx.extend_from_slice(&dests);
        src_ptr.push(dest_idx.len());
    }
    let nnz = dest_idx.len();
    let mut a = Vec::with_capacity(cfg.num_families);
    for k in 0..cfg.num_families {
        let mut fr = rng.fork(k as u64 + 1);
        let plane: Vec<f32> = (0..nnz).map(|_| (0.2 + fr.uniform() * 1.8) as f32).collect();
        a.push(plane);
    }
    let cost: Vec<f32> = (0..nnz)
        .map(|_| -(rng.lognormal(0.0, 0.6).min(10.0) as f32))
        .collect();
    let matrix = BlockedMatrix {
        num_sources: cfg.num_sources,
        num_dests: cfg.num_dests,
        num_families: cfg.num_families,
        src_ptr,
        dest_idx,
        a,
    };
    let mut load = vec![0.0f64; cfg.num_families * cfg.num_dests];
    for k in 0..cfg.num_families {
        for (e, &j) in matrix.dest_idx.iter().enumerate() {
            load[k * cfg.num_dests + j as usize] += matrix.a[k][e] as f64;
        }
    }
    let b: Vec<f32> = load
        .iter()
        .map(|&lj| {
            let rho = rng.uniform_range(0.5, 1.0);
            (rho * (lj * 0.5 + 1e-3)) as f32
        })
        .collect();
    LpSpec::new(matrix, cost, b)
        .projection_kind(cfg.kind)
        .build()
        .expect("power-law generator produced an invalid LP")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_scaling() {
        let c = table2_row(25, 0);
        assert_eq!(c.num_requests, 250_000);
        assert_eq!(c.num_resources, 1000);
        let c100 = table2_row(100, 0);
        assert_eq!(c100.num_requests, 1_000_000);
    }

    #[test]
    fn presets_generate() {
        let lp = crate::gen::generate(&smoke(1));
        lp.validate().unwrap();
    }

    #[test]
    fn perturbation_keeps_pattern_changes_values() {
        let base = crate::gen::generate(&smoke(2));
        let spec = PerturbSpec::default();
        let p = perturb_instance(&base, &spec, 7);
        p.validate().unwrap();
        // identical structure
        assert_eq!(base.a.src_ptr, p.a.src_ptr);
        assert_eq!(base.a.dest_idx, p.a.dest_idx);
        assert_eq!(base.a.a, p.a.a);
        // perturbed planes
        assert_ne!(base.cost, p.cost);
        assert_ne!(base.b, p.b);
        // rhs stays nonnegative under clamped noise
        assert!(p.b.iter().all(|&v| v >= 0.0));
        // 5% relative noise stays small in aggregate
        let rel: f64 = base
            .cost
            .iter()
            .zip(&p.cost)
            .map(|(a, b)| ((a - b).abs() as f64) / (a.abs() as f64).max(1e-9))
            .sum::<f64>()
            / base.cost.len() as f64;
        assert!(rel < 0.2, "mean relative cost drift {rel}");
    }

    #[test]
    fn perturbation_is_deterministic_per_seed() {
        let base = crate::gen::generate(&smoke(3));
        let spec = PerturbSpec::default();
        let a = perturb_instance(&base, &spec, 11);
        let b = perturb_instance(&base, &spec, 11);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.b, b.b);
        let c = perturb_instance(&base, &spec, 12);
        assert_ne!(a.cost, c.cost);
    }

    #[test]
    fn drift_stream_random_walks_with_fixed_pattern() {
        use crate::engine::Fingerprint;
        let base = crate::gen::generate(&smoke(5));
        let spec = DriftStreamSpec { n: 24, ..Default::default() };
        let stream = drift_stream(&base, &spec, 42);
        assert_eq!(stream.len(), 24);
        let base_fp = Fingerprint::of(&base);
        let mut prev_arrival = 0.0;
        for r in &stream {
            // drift never touches structure: every request is a warm
            // re-solve of the base fingerprint
            assert_eq!(Fingerprint::of(&r.lp), base_fp, "request {}", r.id);
            assert!(r.arrival_ms > prev_arrival, "arrivals strictly increase");
            prev_arrival = r.arrival_ms;
            assert_eq!(r.slo_ms, if r.heavy { spec.slo_heavy_ms } else { spec.slo_light_ms });
        }
        // cumulative drift: later instances sit farther from base than
        // early ones (random walk, not iid jitter around base)
        let dist = |lp: &MatchingLp| -> f64 {
            lp.cost
                .iter()
                .zip(&base.cost)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(
            dist(&stream[23].lp) > dist(&stream[0].lp),
            "drift must accumulate: d0={} d23={}",
            dist(&stream[0].lp),
            dist(&stream[23].lp)
        );
        // mix contains both classes at 20% heavy over 24 draws (seed-stable)
        assert!(stream.iter().any(|r| r.heavy) && stream.iter().any(|r| !r.heavy));
        // deterministic per seed
        let again = drift_stream(&base, &spec, 42);
        for (a, b) in stream.iter().zip(&again) {
            assert_eq!(a.lp.cost, b.lp.cost);
            assert_eq!(a.arrival_ms, b.arrival_ms);
        }
        assert_ne!(drift_stream(&base, &spec, 43)[0].lp.cost, stream[0].lp.cost);
    }

    #[test]
    fn sequence_elements_differ() {
        let base = crate::gen::generate(&smoke(4));
        let seq = perturbation_sequence(&base, &PerturbSpec::default(), 3, 100);
        assert_eq!(seq.len(), 3);
        assert_ne!(seq[0].cost, seq[1].cost);
        assert_ne!(seq[1].cost, seq[2].cost);
        for lp in &seq {
            assert_eq!(lp.a.dest_idx, base.a.dest_idx);
        }
    }

    #[test]
    fn power_law_degrees_are_heavy_tailed_and_valid() {
        let cfg = PowerLawConfig { num_sources: 4000, num_dests: 1000, ..Default::default() };
        let lp = power_law_instance(&cfg);
        lp.validate().unwrap();
        let degs: Vec<usize> = (0..lp.num_sources()).map(|s| lp.a.degree(s)).collect();
        assert!(degs.iter().all(|&d| d >= cfg.min_degree && d <= MAX_WIDTH));
        let thin = degs.iter().filter(|&&d| d <= 2 * cfg.min_degree).count();
        let wide = degs.iter().filter(|&&d| d >= 16 * cfg.min_degree).count();
        // bounded Pareto: most mass at the minimum, a real tail far above
        assert!(thin > lp.num_sources() / 3, "thin sources: {thin}");
        assert!(wide > 0, "no tail reached {} edges", 16 * cfg.min_degree);
        // budgets are positive and sized per (family, dest)
        assert_eq!(lp.b.len(), cfg.num_families * cfg.num_dests);
        assert!(lp.b.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn power_law_is_deterministic_per_seed() {
        let cfg = PowerLawConfig { num_sources: 500, num_dests: 200, ..Default::default() };
        let a = power_law_instance(&cfg);
        let b = power_law_instance(&cfg);
        assert_eq!(a.a.dest_idx, b.a.dest_idx);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.b, b.b);
        let c = power_law_instance(&PowerLawConfig { seed: 1, ..cfg });
        assert_ne!(a.a.dest_idx, c.a.dest_idx);
    }

    #[test]
    fn quarter_step_tames_power_law_padding() {
        use crate::sparse::slabs::{BuildOptions, SlabLayout, WidthPolicy};
        let lp = power_law_instance(&PowerLawConfig {
            num_sources: 3000,
            num_dests: 800,
            seed: 9,
            ..Default::default()
        });
        let kind_of = |i: usize| lp.projection.kind_of(i);
        let pow2 = SlabLayout::build_opts(
            &lp.a,
            &lp.cost,
            0,
            lp.num_sources(),
            &kind_of,
            BuildOptions::default(),
        )
        .unwrap();
        let quarter = SlabLayout::build_opts(
            &lp.a,
            &lp.cost,
            0,
            lp.num_sources(),
            &kind_of,
            BuildOptions { policy: WidthPolicy::QuarterStep, threads: 0 },
        )
        .unwrap();
        assert_eq!(quarter.total_real_edges(), pow2.total_real_edges());
        assert!(
            quarter.padding_factor() < pow2.padding_factor(),
            "quarter {} !< pow2 {}",
            quarter.padding_factor(),
            pow2.padding_factor()
        );
    }
}
