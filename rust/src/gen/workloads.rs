//! Named workload presets mapping the paper's experiment settings (§7,
//! Appendix B) to concrete generator configs at this testbed's scale.
//!
//! Paper scale: 25M–100M sources, J = 10 000, sparsity 0.001, on A100s.
//! CPU-PJRT scale: divide sources by SCALE_DIV (default 100), keep J
//! proportionally sized and preserve ν/I (density), so bucket
//! distributions, padding factors and comm/compute ratios stay
//! representative (DESIGN.md §5 Substitutions).

use super::synthetic::SyntheticConfig;
use crate::projection::ProjectionKind;

/// Source-count divisor vs. the paper's instances.
pub const SCALE_DIV: usize = 100;

/// Table 2 rows: paper sources ∈ {25M, 50M, 75M, 100M}, J = 10k,
/// sparsity = 0.001 (⇒ ν = 10 per source at J = 10k).
pub fn table2_row(paper_sources_m: usize, seed: u64) -> SyntheticConfig {
    let sources = paper_sources_m * 1_000_000 / SCALE_DIV;
    SyntheticConfig {
        num_requests: sources,
        num_resources: 10_000 / SCALE_DIV.min(10), // keep J = 1000 at /100
        avg_nnz_per_row: 10.0,                     // = J · 0.001 at paper scale
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Fig 4/5 ablation instance: paper 25M sources, 10k dests, 0.1% sparsity.
pub fn ablation_instance(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 250_000,
        num_resources: 1_000,
        avg_nnz_per_row: 10.0,
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Parity (Fig 1/2) instance: small enough that the reference path is fast,
/// structured like the production workloads.
pub fn parity_instance(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 50_000,
        num_resources: 500,
        avg_nnz_per_row: 10.0,
        num_families: 1,
        kind: ProjectionKind::Simplex,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

/// Quick smoke workload for examples/tests.
pub fn smoke(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        num_requests: 2_000,
        num_resources: 100,
        avg_nnz_per_row: 8.0,
        seed,
        ..SyntheticConfig::default_with(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_scaling() {
        let c = table2_row(25, 0);
        assert_eq!(c.num_requests, 250_000);
        assert_eq!(c.num_resources, 1000);
        let c100 = table2_row(100, 0);
        assert_eq!(c100.num_requests, 1_000_000);
    }

    #[test]
    fn presets_generate() {
        let lp = crate::gen::generate(&smoke(1));
        lp.validate().unwrap();
    }
}
