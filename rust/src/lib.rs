//! # dualip — DuaLip-GPU reproduction
//!
//! Extreme-scale ridge-regularized dual-ascent LP solver for matching
//! problems (DuaLip-GPU Technical Report, LinkedIn 2026), rebuilt on the
//! three-layer rust + JAX/Pallas architecture:
//!
//! - **L3 (this crate)**: coordinator — problem model, AGD optimizer with
//!   γ-continuation, Jacobi/primal conditioning, sharded workers and
//!   λ-only collectives, diagnostics, CLI; plus the serving layer
//!   (`engine/`): fingerprinted warm-start cache and batch scheduler for
//!   the production repeated-solve pattern, running on the slab-native
//!   batched CPU objective (`backend/`) by default — chunk-sharded
//!   across workers on request (`--shards`, `EngineConfig::shards`),
//!   with S-shard solves bit-identical to 1-shard solves; and the
//!   resident serving layer (`serve/`): a request queue with admission
//!   control over the cooperative executor, in-place instance deltas
//!   against a hot slab, and durable warm-start snapshots.
//! - **L2/L1 (python/compile, build-time only)**: the batched slab dual
//!   step (scale → blockwise projection → reduce) as a Pallas kernel inside
//!   a JAX graph, AOT-lowered to HLO text artifacts.
//! - **runtime**: loads the artifacts through PJRT (`xla` crate) and runs
//!   them from the solve hot path — Python is never on the request path.
//!
//! See README.md for the architecture map and quickstart, DESIGN.md for
//! the system inventory and experiment index.
//!
//! New LP formulations are added *locally* through the operator registry
//! (`projection::registry`) and the declarative `problem::LpSpec` builder
//! — see DESIGN.md "Adding a constraint family".

// The audit pass (U1, `analysis/`) requires every unsafe block to carry a
// SAFETY comment; the compiler half of that contract is a crate-wide deny
// so new unsafe code needs a scoped, reviewable opt-in. The single current
// exception is the libc CPU-clock read in `util::timer`.
#![deny(unsafe_code)]
// CI denies all warnings (`cargo clippy -- -D warnings`). These
// crate-wide allowances cover long-standing internal idioms — multi-plane
// index loops over parallel slices, wide kernel-call signatures, resolved
// job tuples, and entry-map patterns with fallible value construction —
// so the deny-wall stays meaningful for everything else.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::type_complexity,
    clippy::map_entry,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain
)]

pub mod analysis;
pub mod backend;
pub mod cli;
pub mod distributed;
pub mod engine;
pub mod gen;
pub mod metrics;
pub mod problem;
pub mod runtime;
pub mod projection;
pub mod reference;
pub mod serve;
pub mod solver;
pub mod sparse;
pub mod util;
