//! `dualip` — leader entrypoint. See `dualip --help`.

fn main() -> anyhow::Result<()> {
    let args = dualip::cli::Args::parse(std::env::args().skip(1))?;
    dualip::cli::run(args)
}
