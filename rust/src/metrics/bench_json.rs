//! Minimal machine-readable bench emission — no serde offline, so this is
//! a small hand-rolled JSON writer for the flat shape the bench harnesses
//! need:
//!
//! ```json
//! {"bench": "engine_warmstart", "schema_version": 1, "meta": {...}, "rows": [{...}, ...]}
//! ```
//!
//! Emitted files are named `BENCH_<name>.json` so the PR driver can diff
//! perf trajectories across commits; every document carries a top-level
//! `schema_version` ([`SCHEMA_VERSION`]) so downstream tooling can detect
//! shape changes instead of silently misparsing old artifacts. Values are
//! numbers, strings or bools; non-finite floats serialize as `null`
//! (valid JSON, unlike `NaN`).

use std::io::Write;
use std::path::Path;

/// Version of the `BENCH_*.json` document shape. Bump when the top-level
/// layout (not the per-bench row fields) changes incompatibly.
pub const SCHEMA_VERSION: u32 = 1;

/// One JSON scalar.
#[derive(Clone, Debug)]
pub enum JsonValue {
    Int(i64),
    UInt(u64),
    Num(f64),
    Str(String),
    Bool(bool),
}

impl JsonValue {
    fn render(&self) -> String {
        match self {
            JsonValue::Int(v) => v.to_string(),
            JsonValue::UInt(v) => v.to_string(),
            JsonValue::Num(v) => {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    "null".to_string()
                }
            }
            JsonValue::Str(s) => escape(s),
            JsonValue::Bool(b) => b.to_string(),
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn object(fields: &[(&str, JsonValue)]) -> String {
    let body: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("{}: {}", escape(k), v.render()))
        .collect();
    format!("{{{}}}", body.join(", "))
}

/// Accumulates one bench document: metadata fields + a row list.
pub struct BenchJson {
    name: String,
    meta: Vec<(String, JsonValue)>,
    rows: Vec<String>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        BenchJson { name: name.to_string(), meta: Vec::new(), rows: Vec::new() }
    }

    /// Attach a top-level metadata field (instance dims, config, …).
    pub fn meta(&mut self, key: &str, value: JsonValue) -> &mut Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Append one data row.
    pub fn row(&mut self, fields: &[(&str, JsonValue)]) -> &mut Self {
        self.rows.push(object(fields));
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render the full document.
    pub fn render(&self) -> String {
        let meta_fields: Vec<String> = self
            .meta
            .iter()
            .map(|(k, v)| format!("{}: {}", escape(k), v.render()))
            .collect();
        format!(
            "{{\"bench\": {}, \"schema_version\": {SCHEMA_VERSION}, \"meta\": {{{}}}, \"rows\": [\n  {}\n]}}\n",
            escape(&self.name),
            meta_fields.join(", "),
            self.rows.join(",\n  "),
        )
    }

    /// Write to `dir/BENCH_<name>.json` (creating `dir`), returning the
    /// path written.
    pub fn write(&self, dir: impl AsRef<Path>) -> std::io::Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_shape() {
        let mut b = BenchJson::new("engine_warmstart");
        b.meta("sources", JsonValue::UInt(1000));
        b.row(&[
            ("job", JsonValue::Int(0)),
            ("mode", JsonValue::Str("cold".into())),
            ("iters", JsonValue::UInt(120)),
            ("wall_ms", JsonValue::Num(12.5)),
            ("warm", JsonValue::Bool(false)),
        ]);
        let s = b.render();
        assert!(s.starts_with("{\"bench\": \"engine_warmstart\", \"schema_version\": 1"));
        assert!(s.contains("\"meta\": {\"sources\": 1000}"));
        assert!(s.contains("\"mode\": \"cold\""));
        assert!(s.contains("\"warm\": false"));
        assert_eq!(b.num_rows(), 1);
    }

    #[test]
    fn escapes_and_nonfinite() {
        assert_eq!(JsonValue::Num(f64::NAN).render(), "null");
        assert_eq!(JsonValue::Num(f64::INFINITY).render(), "null");
        assert_eq!(escape("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("dualip_bench_json_test");
        let mut b = BenchJson::new("t");
        b.row(&[("x", JsonValue::Int(1))]);
        let path = b.write(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_t.json");
        assert!(text.contains("\"x\": 1"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
