//! Diagnostics & reporting: solve summaries, simple sample statistics for
//! the bench harnesses, human-readable reports (the "structured
//! diagnostics" hooks of paper §4), and machine-readable bench emission
//! (`BENCH_*.json`) so the perf trajectory is trackable across PRs.

pub mod bench_json;

pub use bench_json::{BenchJson, JsonValue, SCHEMA_VERSION};

use crate::backend::KernelTiers;
use crate::distributed::CommSnapshot;
use crate::engine::{BatchReport, CoopReport, EngineStats};
use crate::solver::SolveResult;

/// Sample statistics for bench timing series.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub p99: f64,
    pub stddev: f64,
}

/// Compute stats over a sample (NaNs rejected by assertion).
pub fn stats(samples: &[f64]) -> Stats {
    stats_into(samples, &mut Vec::new())
}

/// [`stats`] with a caller-owned sort buffer — harnesses computing stats
/// per iteration (e.g. a bench's rolling report) reuse one scratch
/// allocation across calls. Identical results to [`stats`].
pub fn stats_into(samples: &[f64], scratch: &mut Vec<f64>) -> Stats {
    assert!(!samples.is_empty());
    assert!(samples.iter().all(|v| v.is_finite()));
    scratch.clear();
    scratch.extend_from_slice(samples);
    let s = &mut scratch[..];
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    let mean = s.iter().sum::<f64>() / n as f64;
    let var = s.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let q = |p: f64| -> f64 {
        let idx = (p * (n - 1) as f64).round() as usize;
        s[idx.min(n - 1)]
    };
    Stats {
        n,
        mean,
        median: q(0.5),
        min: s[0],
        max: s[n - 1],
        p95: q(0.95),
        p99: q(0.99),
        stddev: var.sqrt(),
    }
}

/// One-paragraph human-readable solve report.
pub fn solve_report(label: &str, r: &SolveResult) -> String {
    let last = r.trajectory.last();
    format!(
        "[{label}] iters={} wall={:.1}ms stop={:?} γ_final={} g={:.6e} ‖∇g‖={:.3e} ‖(Ax−b)₊‖={:.3e} cᵀx={:.6e}",
        r.iterations,
        r.total_wall_ms,
        r.stop_reason,
        r.final_gamma,
        last.map_or(f64::NAN, |t| t.dual_obj),
        last.map_or(f64::NAN, |t| t.grad_norm),
        last.map_or(f64::NAN, |t| t.infeas_pos_norm),
        last.map_or(f64::NAN, |t| t.cx),
    )
}

/// One-paragraph engine report: warm/cold solve mix, mean iterations per
/// class, objective-eval share of wall-clock, batch concurrency,
/// warm-start cache behavior (hit rate + evictions — a nonzero eviction
/// rate flags an undersized cache), and the projection kernel-tier mix
/// (how many slab buckets ran the batched override vs the scalar
/// fallback — a nonzero scalar count flags a family missing its
/// `project_rows` kernel, see DESIGN.md §12).
pub fn engine_report(s: &EngineStats) -> String {
    let eval_share = if s.total_wall_ms > 0.0 {
        100.0 * s.objective_eval_ms / s.total_wall_ms
    } else {
        0.0
    };
    let hit_pct = if s.cache_hits + s.cache_misses > 0 {
        100.0 * s.cache_hit_rate()
    } else {
        0.0
    };
    format!(
        "engine: {} solves ({} cold / {} warm), mean iters cold={:.1} warm={:.1}, \
         {:.1}ms total ({:.1}ms / {eval_share:.0}% in objective eval), \
         {} batches (peak {} in flight), {} deadline-stopped, {} cancelled, \
         cache {hit_pct:.0}% hit ({}/{} lookups, {} evictions), \
         kernels {}/{} buckets batched",
        s.submitted,
        s.cold_solves,
        s.warm_solves,
        s.mean_cold_iters(),
        s.mean_warm_iters(),
        s.total_wall_ms,
        s.objective_eval_ms,
        s.batches,
        s.peak_in_flight,
        s.deadline_stops,
        s.cancelled,
        s.cache_hits,
        s.cache_hits + s.cache_misses,
        s.cache_evictions,
        s.batched_kernel_buckets,
        s.batched_kernel_buckets + s.scalar_kernel_buckets,
    )
}

/// One-line cooperative-executor report: round-robin rounds, throughput,
/// and the deadline/cancel mix of the batch.
pub fn coop_report(r: &CoopReport) -> String {
    format!(
        "coop: {} jobs time-sliced on {} threads, {} rounds in {:.1}ms \
         ({:.1} jobs/s), {} deadline-stopped, {} cancelled",
        r.jobs,
        r.threads,
        r.rounds,
        r.wall_ms,
        r.throughput(),
        r.deadline_stops,
        r.cancelled,
    )
}

/// One-line batch report (throughput over the batch wall-clock).
pub fn batch_report(r: &BatchReport) -> String {
    format!(
        "batch: {} jobs on {} threads in {:.1}ms ({:.1} jobs/s, peak {} in flight)",
        r.jobs,
        r.threads,
        r.wall_ms,
        r.throughput(),
        r.peak_in_flight,
    )
}

/// Per-shard execution report for sharded solves: each shard's cumulative
/// evaluation CPU time (what its device would have spent computing), the
/// λ-only wire traffic per iteration — the §6 accounting pair the E15
/// bench tracks — and the per-family kernel-tier split (batched slab
/// override vs scalar fallback, DESIGN.md §12).
pub fn shard_report(
    shard_eval_ms: &[f64],
    c: &CommSnapshot,
    iters: u64,
    tiers: &KernelTiers,
) -> String {
    use std::fmt::Write as _;
    // one output string, written through — no intermediate per-rank
    // Vec<String>; the rendered bytes are identical to the old join(" ")
    let mut per = String::new();
    for (r, ms) in shard_eval_ms.iter().enumerate() {
        if r > 0 {
            per.push(' ');
        }
        let _ = write!(per, "r{r}={ms:.1}ms");
    }
    let max = shard_eval_ms.iter().cloned().fold(0.0f64, f64::max);
    format!(
        "shards: {} workers, eval [{per}] (max {max:.1}ms) | λ-traffic {:.1} B/iter | kernels {}",
        shard_eval_ms.len(),
        c.bytes_per_iter(iters),
        tiers.summary(),
    )
}

/// Communication report (per-iteration steady state).
pub fn comm_report(c: &CommSnapshot, iters: u64) -> String {
    format!(
        "comm: {} bcasts ({} B), {} reduces ({} B), one-time scatter {} B; {:.1} B/iter steady-state",
        c.bcast_ops,
        c.bcast_bytes,
        c.reduce_ops,
        c.reduce_bytes,
        c.scatter_bytes,
        c.bytes_per_iter(iters),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn stats_single_sample() {
        let s = stats(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn p99_sits_between_p95_and_max() {
        let samples: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = stats(&samples);
        assert_eq!(s.p95, 949.0);
        assert_eq!(s.p99, 989.0);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    #[should_panic]
    fn stats_rejects_empty() {
        stats(&[]);
    }

    #[test]
    fn stats_into_matches_stats_with_a_reused_scratch() {
        let mut scratch = Vec::new();
        // a warm (previously longer) scratch must not leak stale samples
        for samples in [&[3.0, 1.0, 2.0, 5.0, 4.0, 9.0][..], &[7.5][..], &[2.0, 1.0][..]] {
            let a = stats(samples);
            let b = stats_into(samples, &mut scratch);
            assert_eq!(a.n, b.n);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.median.to_bits(), b.median.to_bits());
            assert_eq!(a.min.to_bits(), b.min.to_bits());
            assert_eq!(a.max.to_bits(), b.max.to_bits());
            assert_eq!(a.p95.to_bits(), b.p95.to_bits());
            assert_eq!(a.p99.to_bits(), b.p99.to_bits());
            assert_eq!(a.stddev.to_bits(), b.stddev.to_bits());
        }
    }

    #[test]
    fn engine_and_coop_reports_name_deadline_and_cancel_counts() {
        let s = EngineStats {
            deadline_stops: 3,
            cancelled: 1,
            cache_hits: 3,
            cache_misses: 1,
            cache_evictions: 2,
            batched_kernel_buckets: 5,
            scalar_kernel_buckets: 1,
            ..Default::default()
        };
        let rep = engine_report(&s);
        assert!(rep.contains("3 deadline-stopped") && rep.contains("1 cancelled"), "{rep}");
        assert!(
            rep.contains("cache 75% hit (3/4 lookups, 2 evictions)"),
            "{rep}"
        );
        assert!(rep.contains("kernels 5/6 buckets batched"), "{rep}");
        let c = CoopReport {
            jobs: 4,
            threads: 2,
            rounds: 9,
            deadline_stops: 2,
            cancelled: 1,
            wall_ms: 10.0,
        };
        let rep = coop_report(&c);
        assert!(
            rep.contains("4 jobs") && rep.contains("9 rounds") && rep.contains("2 deadline-stopped"),
            "{rep}"
        );
    }

    #[test]
    fn shard_report_names_every_rank() {
        let s = crate::distributed::CommStats::new();
        s.record_broadcast(10);
        s.record_segmented_reduce(3, 10, 2);
        let mut tiers = KernelTiers::default();
        tiers.batched.insert("simplex".to_string());
        tiers.scalar.insert("half_line".to_string());
        let rep = shard_report(&[1.0, 2.5], &s.snapshot(), 1, &tiers);
        assert!(rep.contains("2 workers"), "{rep}");
        assert!(rep.contains("r0=1.0ms") && rep.contains("r1=2.5ms"), "{rep}");
        assert!(rep.contains("B/iter"), "{rep}");
        assert!(
            rep.contains("kernels batched[simplex] scalar[half_line]"),
            "{rep}"
        );
    }
}
