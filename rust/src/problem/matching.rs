//! The matching LP instance type (paper §3.2, Definition 1).
//!
//! min cᵀx  s.t.  A x ≤ b (m matching constraint families, dualized),
//!                x_i ∈ C_i (per-source simple polytope, projected).
//!
//! Variables exist only on eligible (source, destination) edges; `A` is the
//! blocked matching matrix, `c` lives on the same edge set, and `b` has one
//! entry per (family, destination).

use crate::projection::{ProjectionKind, ProjectionMap};
use crate::sparse::BlockedMatrix;

/// An arbitrary extra linear constraint row `Σ_e coeffs[e]·x[e] ≤ rhs`
/// outside the matching-family structure — e.g. the paper's §4 global count
/// constraint Σ_ij x_ij ≤ M. `Ax` and `Aᵀλ` for such a row are trivial, and
/// because gather/scatter live in the coordinator (not the kernels), adding
/// one requires no solver or artifact change — the extensibility claim the
/// Scala stack failed (experiment E11).
#[derive(Clone, Debug)]
pub struct GlobalRow {
    /// Dense per-edge coefficients (len = nnz; use 0 for uninvolved edges).
    pub coeffs: Vec<f32>,
    pub rhs: f32,
}

/// A matching LP instance (Definition 1). `Clone` is cheap on the
/// projection side (`ProjectionMap` clones shallowly via `Arc`), so engine
/// jobs can share one instance across scheduler threads or derive
/// variants without rebuilding per-block metadata.
#[derive(Clone)]
pub struct MatchingLp {
    /// The complex-constraint matrix A (Definition 1).
    pub a: BlockedMatrix,
    /// Objective coefficients per edge (minimization convention — negative
    /// entries are "value").
    pub cost: Vec<f32>,
    /// Right-hand side per dual row (k*J + j). len = mJ.
    pub b: Vec<f32>,
    /// Simple-constraint polytope per source block (paper Table 1's
    /// ProjectionMap role).
    pub projection: ProjectionMap,
    /// Optional per-source primal scale factors v_i (paper §5.1 "Primal
    /// scaling"): the ridge term becomes γ/2 Σ_i v_i²‖x_i‖². None = all 1.
    pub primal_scale: Option<Vec<f32>>,
    /// Extra constraint rows appended after the mJ matching rows; dual rows
    /// mJ..mJ+G.
    pub global_rows: Vec<GlobalRow>,
}

impl MatchingLp {
    pub fn num_sources(&self) -> usize {
        self.a.num_sources
    }

    pub fn num_dests(&self) -> usize {
        self.a.num_dests
    }

    pub fn num_families(&self) -> usize {
        self.a.num_families
    }

    /// Total dual dimension: mJ matching rows + G global rows.
    pub fn dual_dim(&self) -> usize {
        self.a.dual_dim() + self.global_rows.len()
    }

    /// Dual dimension of the matching block only (mJ).
    pub fn matching_dual_dim(&self) -> usize {
        self.a.dual_dim()
    }

    /// Full rhs vector over all dual rows (matching b then global rhs).
    pub fn full_b(&self) -> Vec<f32> {
        let mut b = self.b.clone();
        b.extend(self.global_rows.iter().map(|g| g.rhs));
        b
    }

    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// Uniform-kind convenience constructor.
    pub fn new_uniform(
        a: BlockedMatrix,
        cost: Vec<f32>,
        b: Vec<f32>,
        kind: ProjectionKind,
    ) -> Self {
        assert_eq!(cost.len(), a.nnz());
        assert_eq!(b.len(), a.dual_dim());
        MatchingLp {
            a,
            cost,
            b,
            projection: ProjectionMap::Uniform(kind),
            primal_scale: None,
            global_rows: Vec::new(),
        }
    }

    /// Append a global constraint row (paper §4's Σ_ij x_ij ≤ M example:
    /// `coeffs = vec![1.0; nnz]`, `rhs = M`).
    pub fn push_global_row(&mut self, coeffs: Vec<f32>, rhs: f32) {
        assert_eq!(coeffs.len(), self.a.nnz());
        self.global_rows.push(GlobalRow { coeffs, rhs });
    }

    /// Effective ridge multiplier for source i: γ_i = γ · v_i².
    #[inline]
    pub fn gamma_scale(&self, i: usize) -> f32 {
        match &self.primal_scale {
            Some(v) => v[i] * v[i],
            None => 1.0,
        }
    }

    /// Splice one edge into the CSR at the end of `source`'s range (all
    /// planes: matrix coefficients, cost, and global-row coefficients,
    /// which get 0). Returns the new edge's global position — the input
    /// the slab delta path (`SlabLayout::patch_edge`) needs. Errors leave
    /// the instance untouched.
    pub fn insert_edge(
        &mut self,
        source: usize,
        dest: u32,
        a: &[f32],
        cost: f32,
    ) -> Result<usize, String> {
        if source >= self.num_sources() {
            return Err(format!("source {source} out of range"));
        }
        if dest as usize >= self.num_dests() {
            return Err(format!("dest {dest} out of range"));
        }
        if a.len() != self.num_families() {
            return Err(format!(
                "{} family coefficients for {} families",
                a.len(),
                self.num_families()
            ));
        }
        let (e0, e1) = (self.a.src_ptr[source], self.a.src_ptr[source + 1]);
        if self.a.dest_idx[e0..e1].contains(&dest) {
            return Err(format!("source {source} already has an edge to dest {dest}"));
        }
        let p = e1;
        self.a.dest_idx.insert(p, dest);
        for (k, plane) in self.a.a.iter_mut().enumerate() {
            plane.insert(p, a[k]);
        }
        self.cost.insert(p, cost);
        for g in &mut self.global_rows {
            g.coeffs.insert(p, 0.0);
        }
        for ptr in &mut self.a.src_ptr[source + 1..] {
            *ptr += 1;
        }
        Ok(p)
    }

    /// Remove the edge `(source, dest)` from every plane, returning its
    /// old global position. Errors leave the instance untouched.
    pub fn remove_edge(&mut self, source: usize, dest: u32) -> Result<usize, String> {
        if source >= self.num_sources() {
            return Err(format!("source {source} out of range"));
        }
        let (e0, e1) = (self.a.src_ptr[source], self.a.src_ptr[source + 1]);
        let p = self.a.dest_idx[e0..e1]
            .iter()
            .position(|&d| d == dest)
            .map(|off| e0 + off)
            .ok_or_else(|| format!("source {source} has no edge to dest {dest}"))?;
        self.a.dest_idx.remove(p);
        for plane in &mut self.a.a {
            plane.remove(p);
        }
        self.cost.remove(p);
        for g in &mut self.global_rows {
            g.coeffs.remove(p);
        }
        for ptr in &mut self.a.src_ptr[source + 1..] {
            *ptr -= 1;
        }
        Ok(p)
    }

    /// Structural sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        self.a.validate()?;
        if self.cost.len() != self.a.nnz() {
            return Err("cost length != nnz".into());
        }
        if self.b.len() != self.a.dual_dim() {
            return Err("b length != mJ".into());
        }
        if let Some(v) = &self.primal_scale {
            if v.len() != self.a.num_sources {
                return Err("primal_scale length != I".into());
            }
            if v.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
                return Err("primal_scale must be positive finite".into());
            }
        }
        for (r, g) in self.global_rows.iter().enumerate() {
            if g.coeffs.len() != self.a.nnz() {
                return Err(format!("global row {r} coeffs length != nnz"));
            }
        }
        Ok(())
    }

    /// Append one extra constraint family with the given per-edge
    /// coefficients and per-destination rhs — the paper's extensibility
    /// story (§4: a global count constraint Σx ≤ m is "trivial to compute
    /// Ax and Aᵀλ for" yet required extensive changes in the Scala stack;
    /// here it is purely local composition).
    pub fn push_family(&mut self, coeffs: Vec<f32>, rhs: Vec<f32>) {
        assert_eq!(coeffs.len(), self.a.nnz());
        assert_eq!(rhs.len(), self.a.num_dests);
        self.a.a.push(coeffs);
        self.a.num_families += 1;
        self.b.extend(rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 2,
            num_dests: 3,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 1, 2],
            a: vec![vec![1.0, 2.0, 3.0, 4.0]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-1.0, -2.0, -3.0, -4.0],
            vec![1.0, 1.0, 1.0],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn dims() {
        let lp = tiny();
        assert_eq!(lp.num_sources(), 2);
        assert_eq!(lp.dual_dim(), 3);
        assert_eq!(lp.nnz(), 4);
        lp.validate().unwrap();
    }

    #[test]
    fn push_family_extends_dual() {
        let mut lp = tiny();
        // global count constraint: coefficient 1 on every edge
        lp.push_family(vec![1.0; 4], vec![0.5, 0.5, 0.5]);
        assert_eq!(lp.num_families(), 2);
        assert_eq!(lp.dual_dim(), 6);
        lp.validate().unwrap();
    }

    #[test]
    fn gamma_scale_defaults_to_one() {
        let mut lp = tiny();
        assert_eq!(lp.gamma_scale(0), 1.0);
        lp.primal_scale = Some(vec![2.0, 0.5]);
        assert_eq!(lp.gamma_scale(0), 4.0);
        assert_eq!(lp.gamma_scale(1), 0.25);
        lp.validate().unwrap();
    }

    #[test]
    fn insert_and_remove_edge_round_trip() {
        let mut lp = tiny();
        lp.push_global_row(vec![1.0; 4], 2.0);
        let before = lp.clone();
        let p = lp.insert_edge(0, 2, &[7.0], -9.0).unwrap();
        assert_eq!(p, 2, "inserted at the end of source 0's range");
        assert_eq!(lp.nnz(), 5);
        assert_eq!(lp.a.src_ptr, vec![0, 3, 5]);
        assert_eq!(lp.cost[2], -9.0);
        assert_eq!(lp.a.a[0][2], 7.0);
        assert_eq!(lp.global_rows[0].coeffs[2], 0.0);
        lp.validate().unwrap();
        let q = lp.remove_edge(0, 2).unwrap();
        assert_eq!(q, 2);
        assert_eq!(lp.a.src_ptr, before.a.src_ptr);
        assert_eq!(lp.a.dest_idx, before.a.dest_idx);
        assert_eq!(lp.cost, before.cost);
        lp.validate().unwrap();
    }

    #[test]
    fn edge_edits_reject_bad_input_untouched() {
        let mut lp = tiny();
        let before_nnz = lp.nnz();
        assert!(lp.insert_edge(9, 0, &[1.0], 0.0).is_err(), "source range");
        assert!(lp.insert_edge(0, 9, &[1.0], 0.0).is_err(), "dest range");
        assert!(lp.insert_edge(0, 2, &[1.0, 2.0], 0.0).is_err(), "family arity");
        assert!(lp.insert_edge(0, 1, &[1.0], 0.0).is_err(), "duplicate dest");
        assert!(lp.remove_edge(0, 2).is_err(), "no such edge");
        assert_eq!(lp.nnz(), before_nnz);
        lp.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_scale() {
        let mut lp = tiny();
        lp.primal_scale = Some(vec![1.0, 0.0]);
        assert!(lp.validate().is_err());
        lp.primal_scale = Some(vec![1.0]);
        assert!(lp.validate().is_err());
    }
}
