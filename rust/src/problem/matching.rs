//! The matching LP instance type (paper §3.2, Definition 1).
//!
//! min cᵀx  s.t.  A x ≤ b (m matching constraint families, dualized),
//!                x_i ∈ C_i (per-source simple polytope, projected).
//!
//! Variables exist only on eligible (source, destination) edges; `A` is the
//! blocked matching matrix, `c` lives on the same edge set, and `b` has one
//! entry per (family, destination).

use crate::projection::{ProjectionKind, ProjectionMap};
use crate::sparse::BlockedMatrix;

/// An arbitrary extra linear constraint row `Σ_e coeffs[e]·x[e] ≤ rhs`
/// outside the matching-family structure — e.g. the paper's §4 global count
/// constraint Σ_ij x_ij ≤ M. `Ax` and `Aᵀλ` for such a row are trivial, and
/// because gather/scatter live in the coordinator (not the kernels), adding
/// one requires no solver or artifact change — the extensibility claim the
/// Scala stack failed (experiment E11).
#[derive(Clone, Debug)]
pub struct GlobalRow {
    /// Dense per-edge coefficients (len = nnz; use 0 for uninvolved edges).
    pub coeffs: Vec<f32>,
    pub rhs: f32,
}

/// A matching LP instance (Definition 1). `Clone` is cheap on the
/// projection side (`ProjectionMap` clones shallowly via `Arc`), so engine
/// jobs can share one instance across scheduler threads or derive
/// variants without rebuilding per-block metadata.
#[derive(Clone)]
pub struct MatchingLp {
    /// The complex-constraint matrix A (Definition 1).
    pub a: BlockedMatrix,
    /// Objective coefficients per edge (minimization convention — negative
    /// entries are "value").
    pub cost: Vec<f32>,
    /// Right-hand side per dual row (k*J + j). len = mJ.
    pub b: Vec<f32>,
    /// Simple-constraint polytope per source block (paper Table 1's
    /// ProjectionMap role).
    pub projection: ProjectionMap,
    /// Optional per-source primal scale factors v_i (paper §5.1 "Primal
    /// scaling"): the ridge term becomes γ/2 Σ_i v_i²‖x_i‖². None = all 1.
    pub primal_scale: Option<Vec<f32>>,
    /// Extra constraint rows appended after the mJ matching rows; dual rows
    /// mJ..mJ+G.
    pub global_rows: Vec<GlobalRow>,
}

impl MatchingLp {
    pub fn num_sources(&self) -> usize {
        self.a.num_sources
    }

    pub fn num_dests(&self) -> usize {
        self.a.num_dests
    }

    pub fn num_families(&self) -> usize {
        self.a.num_families
    }

    /// Total dual dimension: mJ matching rows + G global rows.
    pub fn dual_dim(&self) -> usize {
        self.a.dual_dim() + self.global_rows.len()
    }

    /// Dual dimension of the matching block only (mJ).
    pub fn matching_dual_dim(&self) -> usize {
        self.a.dual_dim()
    }

    /// Full rhs vector over all dual rows (matching b then global rhs).
    pub fn full_b(&self) -> Vec<f32> {
        let mut b = self.b.clone();
        b.extend(self.global_rows.iter().map(|g| g.rhs));
        b
    }

    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// Uniform-kind convenience constructor.
    pub fn new_uniform(
        a: BlockedMatrix,
        cost: Vec<f32>,
        b: Vec<f32>,
        kind: ProjectionKind,
    ) -> Self {
        assert_eq!(cost.len(), a.nnz());
        assert_eq!(b.len(), a.dual_dim());
        MatchingLp {
            a,
            cost,
            b,
            projection: ProjectionMap::Uniform(kind),
            primal_scale: None,
            global_rows: Vec::new(),
        }
    }

    /// Append a global constraint row (paper §4's Σ_ij x_ij ≤ M example:
    /// `coeffs = vec![1.0; nnz]`, `rhs = M`).
    pub fn push_global_row(&mut self, coeffs: Vec<f32>, rhs: f32) {
        assert_eq!(coeffs.len(), self.a.nnz());
        self.global_rows.push(GlobalRow { coeffs, rhs });
    }

    /// Effective ridge multiplier for source i: γ_i = γ · v_i².
    #[inline]
    pub fn gamma_scale(&self, i: usize) -> f32 {
        match &self.primal_scale {
            Some(v) => v[i] * v[i],
            None => 1.0,
        }
    }

    /// Structural sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        self.a.validate()?;
        if self.cost.len() != self.a.nnz() {
            return Err("cost length != nnz".into());
        }
        if self.b.len() != self.a.dual_dim() {
            return Err("b length != mJ".into());
        }
        if let Some(v) = &self.primal_scale {
            if v.len() != self.a.num_sources {
                return Err("primal_scale length != I".into());
            }
            if v.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
                return Err("primal_scale must be positive finite".into());
            }
        }
        for (r, g) in self.global_rows.iter().enumerate() {
            if g.coeffs.len() != self.a.nnz() {
                return Err(format!("global row {r} coeffs length != nnz"));
            }
        }
        Ok(())
    }

    /// Append one extra constraint family with the given per-edge
    /// coefficients and per-destination rhs — the paper's extensibility
    /// story (§4: a global count constraint Σx ≤ m is "trivial to compute
    /// Ax and Aᵀλ for" yet required extensive changes in the Scala stack;
    /// here it is purely local composition).
    pub fn push_family(&mut self, coeffs: Vec<f32>, rhs: Vec<f32>) {
        assert_eq!(coeffs.len(), self.a.nnz());
        assert_eq!(rhs.len(), self.a.num_dests);
        self.a.a.push(coeffs);
        self.a.num_families += 1;
        self.b.extend(rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 2,
            num_dests: 3,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 1, 2],
            a: vec![vec![1.0, 2.0, 3.0, 4.0]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-1.0, -2.0, -3.0, -4.0],
            vec![1.0, 1.0, 1.0],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn dims() {
        let lp = tiny();
        assert_eq!(lp.num_sources(), 2);
        assert_eq!(lp.dual_dim(), 3);
        assert_eq!(lp.nnz(), 4);
        lp.validate().unwrap();
    }

    #[test]
    fn push_family_extends_dual() {
        let mut lp = tiny();
        // global count constraint: coefficient 1 on every edge
        lp.push_family(vec![1.0; 4], vec![0.5, 0.5, 0.5]);
        assert_eq!(lp.num_families(), 2);
        assert_eq!(lp.dual_dim(), 6);
        lp.validate().unwrap();
    }

    #[test]
    fn gamma_scale_defaults_to_one() {
        let mut lp = tiny();
        assert_eq!(lp.gamma_scale(0), 1.0);
        lp.primal_scale = Some(vec![2.0, 0.5]);
        assert_eq!(lp.gamma_scale(0), 4.0);
        assert_eq!(lp.gamma_scale(1), 0.25);
        lp.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_scale() {
        let mut lp = tiny();
        lp.primal_scale = Some(vec![1.0, 0.0]);
        assert!(lp.validate().is_err());
        lp.primal_scale = Some(vec![1.0]);
        assert!(lp.validate().is_err());
    }
}
