//! Problem layer: the matching LP instance type (Definition 1), the
//! declarative `LpSpec` builder (§4 formulation API), the
//! `ObjectiveFunction` contract (paper Table 1), conditioning transforms
//! (§5.1) and primal validation.

pub mod matching;
pub mod objective;
pub mod scaling;
pub mod spec;
pub mod validate;

pub use matching::{GlobalRow, MatchingLp};
pub use objective::{ObjectiveFunction, ObjectiveResult};
pub use scaling::{apply_primal_scaling, jacobi_row_normalize, unscale_dual, RowScaling};
pub use spec::LpSpec;
pub use validate::{check_primal, PrimalReport};
