//! The `ObjectiveFunction` contract (paper Table 1): everything the
//! Maximizer needs from a problem is `calculate(λ, γ) → ObjectiveResult`.
//!
//! Implementations in this repo:
//! - `reference::CpuObjective` — single-threaded per-edge loop (the
//!   Scala-equivalent baseline),
//! - `backend::SlabCpuObjective` — slab-native batched CPU objective
//!   (the serving default),
//! - `backend::ShardedSlabObjective` — the slab objective chunk-sharded
//!   in-process (bit-identical to the unsharded slab),
//! - `runtime::HloObjective` — batched slab kernels through PJRT,
//! - `distributed::DistributedObjective` — sharded workers + collectives
//!   (slab or HLO execution strategy).

/// Result of one dual evaluation at (λ, γ).
#[derive(Clone, Debug)]
pub struct ObjectiveResult {
    /// ∇g(λ) = A x*γ(λ) − b. len = mJ.
    pub grad: Vec<f32>,
    /// g(λ) = cᵀx + γ/2 Σ v_i²‖x_i‖² + λᵀ(Ax − b).
    pub dual_obj: f64,
    /// cᵀx — primal objective of the current (infeasible-in-A) primal.
    pub cx: f64,
    /// Σ_i v_i² ‖x_i‖² — ridge penalty without the γ/2 factor.
    pub xsq_weighted: f64,
    /// ‖(Ax − b)₊‖₂ — the Lemma A.1 primal infeasibility measure.
    pub infeas_pos_norm: f64,
}

impl ObjectiveResult {
    /// Assemble dual_obj and infeasibility from the parts every backend
    /// produces (grad must already be Ax − b).
    pub fn assemble(grad: Vec<f32>, cx: f64, xsq_weighted: f64, lam: &[f32], gamma: f32) -> Self {
        let lam_ax_b = crate::util::mathvec::dot(lam, &grad);
        let infeas = crate::util::mathvec::pos_norm2(&grad);
        ObjectiveResult {
            dual_obj: cx + 0.5 * gamma as f64 * xsq_weighted + lam_ax_b,
            grad,
            cx,
            xsq_weighted,
            infeas_pos_norm: infeas,
        }
    }
}

/// Paper Table 1, row "ObjectiveFunction": single required method.
pub trait ObjectiveFunction {
    /// Dual dimension mJ.
    fn dual_dim(&self) -> usize;

    /// Evaluate g(λ) and ∇g(λ) at ridge parameter γ.
    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult;

    /// Recover the full per-edge primal x*γ(λ) (used by validation,
    /// rounding and the E2E drivers; not on the iteration hot path).
    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32>;

    /// Backend label for diagnostics.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_math() {
        let grad = vec![1.0, -2.0];
        let lam = vec![0.5, 1.0];
        let r = ObjectiveResult::assemble(grad, 3.0, 4.0, &lam, 0.5);
        // dual = cx + γ/2 xsq + λ·grad = 3 + 1 + (0.5 - 2.0) = 2.5
        assert!((r.dual_obj - 2.5).abs() < 1e-12);
        // infeas = ‖(1, 0)₊‖ = 1
        assert!((r.infeas_pos_norm - 1.0).abs() < 1e-12);
    }
}
