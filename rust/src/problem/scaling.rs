//! Conditioning transforms (paper §5.1).
//!
//! **Jacobi row normalization**: A' = D A, b' = D b with
//! D = diag(‖A_r*‖₂⁻¹) — exactly Jacobi preconditioning of the dual
//! Hessian −∇²g = AAᵀ/γ. Feasible set preserved; duals map λ = D λ'.
//!
//! **Primal scaling**: per-source scale v_i turns the ridge into
//! γ/2 Σ v_i²‖x_i‖² (equivalently rescales primal coordinates). With a
//! uniform v per block, the block subproblem stays a Euclidean projection
//! with effective ridge γ·v_i², so the kernels are unchanged.

use super::matching::MatchingLp;

/// Report of a Jacobi row-normalization application.
#[derive(Clone, Debug)]
pub struct RowScaling {
    /// d[r] = 1/‖A_r*‖₂ (1.0 for empty rows). λ_original = d ⊙ λ_scaled.
    pub d: Vec<f32>,
    /// Number of empty (all-zero) rows left unscaled.
    pub empty_rows: usize,
}

/// Apply Jacobi row normalization in place (paper §5.1). Returns the
/// scaling so callers can map duals back to the original system.
pub fn jacobi_row_normalize(lp: &mut MatchingLp) -> RowScaling {
    let mut norms = lp.a.row_sq_norms();
    norms.extend(lp.global_rows.iter().map(|g| {
        g.coeffs.iter().map(|&c| c as f64 * c as f64).sum::<f64>()
    }));
    let mut empty = 0usize;
    let d: Vec<f32> = norms
        .iter()
        .map(|&n| {
            if n > 0.0 {
                (1.0 / n.sqrt()) as f32
            } else {
                empty += 1;
                1.0
            }
        })
        .collect();
    let mj = lp.matching_dual_dim();
    lp.a.scale_rows(&d[..mj]);
    for (bi, di) in lp.b.iter_mut().zip(&d[..mj]) {
        *bi *= di;
    }
    for (r, g) in lp.global_rows.iter_mut().enumerate() {
        let dr = d[mj + r];
        for c in g.coeffs.iter_mut() {
            *c *= dr;
        }
        g.rhs *= dr;
    }
    RowScaling { d, empty_rows: empty }
}

/// Map a dual vector of the row-normalized system back to the original
/// system: λ = D λ'.
pub fn unscale_dual(scaling: &RowScaling, lam_scaled: &[f32]) -> Vec<f32> {
    lam_scaled.iter().zip(&scaling.d).map(|(l, d)| l * d).collect()
}

/// Choose per-source primal scales from the column geometry: v_i = the
/// root-mean-square magnitude of A's columns in block i (falling back to
/// 1.0 for empty blocks), normalized to geometric mean 1 so the global γ
/// keeps its meaning. (Paper: "choosing v according to typical magnitudes
/// of the primal coordinates or the column norms of A".)
pub fn primal_scales_from_columns(lp: &MatchingLp) -> Vec<f32> {
    let m = &lp.a;
    let mut v = vec![1.0f32; m.num_sources];
    let mut log_sum = 0.0f64;
    let mut nz_blocks = 0usize;
    for i in 0..m.num_sources {
        let (e0, e1) = (m.src_ptr[i], m.src_ptr[i + 1]);
        if e0 == e1 {
            continue;
        }
        let mut sq = 0.0f64;
        for e in e0..e1 {
            for ak in &m.a {
                sq += (ak[e] as f64) * (ak[e] as f64);
            }
        }
        let rms = (sq / (e1 - e0) as f64).sqrt();
        if rms > 0.0 {
            v[i] = rms as f32;
            log_sum += rms.ln();
            nz_blocks += 1;
        }
    }
    if nz_blocks > 0 {
        let gm = (log_sum / nz_blocks as f64).exp() as f32;
        for x in v.iter_mut() {
            *x /= gm;
        }
    }
    v
}

/// Install column-derived primal scaling on the problem.
pub fn apply_primal_scaling(lp: &mut MatchingLp) {
    let v = primal_scales_from_columns(lp);
    lp.primal_scale = Some(v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionKind;
    use crate::sparse::BlockedMatrix;

    fn lp() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 3,
            num_dests: 2,
            num_families: 1,
            src_ptr: vec![0, 2, 3, 5],
            dest_idx: vec![0, 1, 0, 0, 1],
            a: vec![vec![3.0, 1.0, 4.0, 0.5, 8.0]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-1.0; 5],
            vec![2.0, 4.0],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn rows_normalized_to_unit() {
        let mut p = lp();
        let s = jacobi_row_normalize(&mut p);
        assert_eq!(s.empty_rows, 0);
        for n in p.a.row_sq_norms() {
            assert!((n - 1.0).abs() < 1e-6);
        }
        // b scaled consistently: b'[0] = 2 / sqrt(9+16+0.25)
        let expect = 2.0 / (9.0f32 + 16.0 + 0.25).sqrt();
        assert!((p.b[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn feasible_set_preserved() {
        // For any x, Ax ≤ b  ⟺  A'x ≤ b' (d > 0).
        let mut p = lp();
        let orig = (p.a.clone(), p.b.clone());
        let _ = jacobi_row_normalize(&mut p);
        let x = vec![0.1, 0.4, 0.2, 0.05, 0.3];
        let mut ax0 = vec![0.0; 2];
        orig.0.scatter_ax(&x, &mut ax0);
        let slack0: Vec<f32> = ax0.iter().zip(&orig.1).map(|(a, b)| b - a).collect();
        let mut ax1 = vec![0.0; 2];
        p.a.scatter_ax(&x, &mut ax1);
        let slack1: Vec<f32> = ax1.iter().zip(&p.b).map(|(a, b)| b - a).collect();
        for (s0, s1) in slack0.iter().zip(&slack1) {
            assert_eq!(s0.signum(), s1.signum(), "feasibility flipped");
        }
    }

    #[test]
    fn empty_rows_left_alone() {
        let mut p = lp();
        p.b = vec![2.0, 4.0, 1.0, 1.0];
        p.a.num_families = 2;
        p.a.a.push(vec![0.0; 5]); // family 2 entirely zero
        let s = jacobi_row_normalize(&mut p);
        assert_eq!(s.empty_rows, 2);
        assert_eq!(&s.d[2..4], &[1.0, 1.0]);
        assert_eq!(&p.b[2..4], &[1.0, 1.0]);
    }

    #[test]
    fn unscale_dual_roundtrip() {
        let mut p = lp();
        let s = jacobi_row_normalize(&mut p);
        let lam_scaled = vec![0.7, 0.2];
        let lam = unscale_dual(&s, &lam_scaled);
        for ((l, ls), d) in lam.iter().zip(&lam_scaled).zip(&s.d) {
            assert_eq!(*l, ls * d);
        }
    }

    #[test]
    fn primal_scales_geometric_mean_one() {
        let p = lp();
        let v = primal_scales_from_columns(&p);
        assert_eq!(v.len(), 3);
        let gm: f64 = v.iter().map(|&x| (x as f64).ln()).sum::<f64>() / 3.0;
        assert!(gm.abs() < 1e-5, "geometric mean must be ~1, got e^{gm}");
        // block with the large 8.0 coefficient gets the largest scale
        assert!(v[2] > v[1] && v[2] > v[0]);
    }

    #[test]
    fn apply_primal_scaling_installs_valid_scales() {
        let mut p = lp();
        apply_primal_scaling(&mut p);
        p.validate().unwrap();
        assert!(p.gamma_scale(2) > p.gamma_scale(1));
    }
}
