//! `LpSpec` — the declarative problem builder of the operator-centric
//! formulation API (paper §4).
//!
//! A formulation is data planes (matrix, cost, rhs) plus *composable
//! declarations*: a projection spec per block (resolved through the
//! operator registry), extra matching constraint families, global rows,
//! and primal scaling. `build` compiles the declarations into a validated
//! [`MatchingLp`], so callers — `gen/workloads`, the CLI, `engine`
//! sessions — never hand-assemble the struct, and a new constraint family
//! becomes usable everywhere the moment its spec string parses.

use crate::problem::matching::{GlobalRow, MatchingLp};
use crate::projection::{ProjectionKind, ProjectionMap};
use crate::sparse::BlockedMatrix;

/// Declarative matching-LP specification. Consume-and-return builder:
/// chain declarations, then `build()`.
pub struct LpSpec {
    matrix: BlockedMatrix,
    cost: Vec<f32>,
    b: Vec<f32>,
    projection: ProjectionMap,
    extra_families: Vec<(Vec<f32>, Vec<f32>)>,
    global_rows: Vec<GlobalRow>,
    primal_scale: Option<Vec<f32>>,
    /// First declaration error, surfaced by `build` (keeps the chain fluent).
    deferred_err: Option<String>,
}

impl LpSpec {
    /// Start from the data planes of Definition 1: the blocked matrix
    /// (first constraint family included), per-edge costs, and the
    /// per-(family, destination) rhs. Projection defaults to the uniform
    /// simplex.
    pub fn new(matrix: BlockedMatrix, cost: Vec<f32>, b: Vec<f32>) -> LpSpec {
        LpSpec {
            matrix,
            cost,
            b,
            projection: ProjectionMap::Uniform(ProjectionKind::Simplex),
            extra_families: Vec::new(),
            global_rows: Vec::new(),
            primal_scale: None,
            deferred_err: None,
        }
    }

    /// Uniform blockwise projection from a registry spec string, e.g.
    /// `"simplex"`, `"capped_simplex:0.5:2"`, `"weighted_simplex:1:1,2"`.
    /// An unknown spec surfaces as an error from `build` (like every other
    /// declaration problem), so the chain stays fluent.
    pub fn projection(mut self, spec: &str) -> LpSpec {
        match ProjectionKind::parse(spec) {
            Some(kind) => self.projection = ProjectionMap::Uniform(kind),
            None => {
                let msg = format!("unknown projection spec {spec:?}");
                self.deferred_err.get_or_insert(msg);
            }
        }
        self
    }

    /// Uniform blockwise projection from an operator handle.
    pub fn projection_kind(mut self, kind: ProjectionKind) -> LpSpec {
        self.projection = ProjectionMap::Uniform(kind);
        self
    }

    /// Heterogeneous projection from a block-id closure.
    pub fn per_block_projection<F>(mut self, f: F) -> LpSpec
    where
        F: Fn(usize) -> ProjectionKind + Send + Sync + 'static,
    {
        self.projection = ProjectionMap::per_block(f);
        self
    }

    /// Heterogeneous projection from materialized per-block kinds
    /// (length must be `num_sources`; checked at `build`).
    pub fn block_projections(mut self, kinds: Vec<ProjectionKind>) -> LpSpec {
        if kinds.len() != self.matrix.num_sources {
            self.deferred_err.get_or_insert_with(|| {
                format!(
                    "block_projections: {} kinds for {} sources",
                    kinds.len(),
                    self.matrix.num_sources
                )
            });
        }
        self.projection = ProjectionMap::per_block(move |i| kinds[i]);
        self
    }

    /// Append a matching constraint family: per-edge coefficients on the
    /// shared eligibility pattern plus a per-destination rhs (adds J dual
    /// rows).
    pub fn family(mut self, coeffs: Vec<f32>, rhs: Vec<f32>) -> LpSpec {
        self.extra_families.push((coeffs, rhs));
        self
    }

    /// Append an arbitrary global constraint row Σ coeffs·x ≤ rhs (adds
    /// one dual row after the matching block).
    pub fn global_row(mut self, coeffs: Vec<f32>, rhs: f32) -> LpSpec {
        self.global_rows.push(GlobalRow { coeffs, rhs });
        self
    }

    /// The paper §4 global count constraint Σ_ij x_ij ≤ m.
    pub fn count_cap(self, m: f32) -> LpSpec {
        let coeffs = vec![1.0; self.matrix.nnz()];
        self.global_row(coeffs, m)
    }

    /// Per-source primal scale factors v_i (§5.1): the ridge becomes
    /// γ/2 Σ_i v_i²‖x_i‖².
    pub fn primal_scale(mut self, v: Vec<f32>) -> LpSpec {
        self.primal_scale = Some(v);
        self
    }

    /// Compile the declarations into a validated `MatchingLp`.
    pub fn build(self) -> Result<MatchingLp, String> {
        if let Some(e) = self.deferred_err {
            return Err(e);
        }
        if self.cost.len() != self.matrix.nnz() {
            return Err(format!(
                "cost length {} != nnz {}",
                self.cost.len(),
                self.matrix.nnz()
            ));
        }
        if self.b.len() != self.matrix.dual_dim() {
            return Err(format!(
                "b length {} != mJ {}",
                self.b.len(),
                self.matrix.dual_dim()
            ));
        }
        let mut lp = MatchingLp {
            a: self.matrix,
            cost: self.cost,
            b: self.b,
            projection: self.projection,
            primal_scale: self.primal_scale,
            global_rows: Vec::new(),
        };
        for (k, (coeffs, rhs)) in self.extra_families.into_iter().enumerate() {
            if coeffs.len() != lp.a.nnz() {
                return Err(format!("extra family {k}: coeffs length != nnz"));
            }
            if rhs.len() != lp.a.num_dests {
                return Err(format!("extra family {k}: rhs length != J"));
            }
            lp.push_family(coeffs, rhs);
        }
        for (r, g) in self.global_rows.into_iter().enumerate() {
            if g.coeffs.len() != lp.a.nnz() {
                return Err(format!("global row {r}: coeffs length != nnz"));
            }
            lp.global_rows.push(g);
        }
        lp.validate()?;
        Ok(lp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_matrix() -> (BlockedMatrix, Vec<f32>, Vec<f32>) {
        let m = BlockedMatrix {
            num_sources: 2,
            num_dests: 3,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 1, 2],
            a: vec![vec![1.0, 2.0, 3.0, 4.0]],
        };
        let cost = vec![-1.0, -2.0, -3.0, -4.0];
        let b = vec![1.0, 1.0, 1.0];
        (m, cost, b)
    }

    #[test]
    fn minimal_spec_builds_uniform_simplex() {
        let (m, cost, b) = tiny_matrix();
        let lp = LpSpec::new(m, cost, b).build().unwrap();
        assert_eq!(lp.projection.uniform_kind(), Some(ProjectionKind::Simplex));
        assert_eq!(lp.dual_dim(), 3);
    }

    #[test]
    fn full_composition_builds_and_validates() {
        let (m, cost, b) = tiny_matrix();
        let lp = LpSpec::new(m, cost, b)
            .projection("weighted_simplex:2:1,2")
            .family(vec![1.0; 4], vec![0.5, 0.5, 0.5])
            .count_cap(3.0)
            .global_row(vec![0.0, 1.0, 1.0, 0.0], 0.7)
            .primal_scale(vec![1.0, 2.0])
            .build()
            .unwrap();
        assert_eq!(lp.num_families(), 2);
        assert_eq!(lp.global_rows.len(), 2);
        assert_eq!(lp.dual_dim(), 2 * 3 + 2);
        assert_eq!(lp.gamma_scale(1), 4.0);
        assert_eq!(
            lp.projection.uniform_kind().map(|k| k.spec()),
            Some("weighted_simplex:2:1,2".to_string())
        );
    }

    #[test]
    fn per_block_specs_compose() {
        let (m, cost, b) = tiny_matrix();
        let box_half = ProjectionKind::parse("box_vec:0.5").unwrap();
        let lp = LpSpec::new(m, cost, b)
            .block_projections(vec![ProjectionKind::Simplex, box_half])
            .build()
            .unwrap();
        assert_eq!(lp.projection.kind_of(0), ProjectionKind::Simplex);
        assert_eq!(lp.projection.kind_of(1), box_half);
        // the LP (including its Arc'd per-block map) clones shallowly
        let lp2 = lp.clone();
        assert_eq!(lp2.projection.kind_of(1), box_half);
    }

    #[test]
    fn bad_specs_are_rejected() {
        let (m, cost, b) = tiny_matrix();
        let r = LpSpec::new(m.clone(), cost.clone(), b.clone())
            .projection("no_such_family:1")
            .build();
        assert!(r.is_err(), "unknown spec must surface at build");
        // wrong plane lengths
        assert!(LpSpec::new(m.clone(), vec![0.0; 3], b.clone()).build().is_err());
        assert!(LpSpec::new(m.clone(), cost.clone(), vec![0.0; 2]).build().is_err());
        assert!(LpSpec::new(m.clone(), cost.clone(), b.clone())
            .family(vec![1.0; 2], vec![0.5; 3])
            .build()
            .is_err());
        assert!(LpSpec::new(m.clone(), cost.clone(), b.clone())
            .global_row(vec![1.0; 3], 1.0)
            .build()
            .is_err());
        assert!(LpSpec::new(m, cost, b)
            .primal_scale(vec![1.0, -1.0])
            .build()
            .is_err());
    }

    #[test]
    fn mismatched_block_projection_length_fails_build() {
        let (m, cost, b) = tiny_matrix();
        let r = LpSpec::new(m, cost, b)
            .block_projections(vec![ProjectionKind::Simplex]) // 1 kind, 2 sources
            .build();
        assert!(r.is_err());
    }
}
