//! Primal solution validation and reporting: feasibility w.r.t. both
//! constraint classes, objective value, and the quantities EXPERIMENTS.md
//! reports for the E2E drivers.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::matching::MatchingLp;
use crate::projection::{BlockProjection, ProjectionKind};

/// Summary of a primal candidate x (per-edge).
#[derive(Clone, Debug)]
pub struct PrimalReport {
    /// cᵀx.
    pub objective: f64,
    /// ‖(Ax − b)₊‖₂ — complex-constraint violation.
    pub complex_infeas: f64,
    /// max over complex rows of (Ax − b)₊.
    pub complex_infeas_max: f64,
    /// Max violation of the simple constraints across blocks.
    pub simple_infeas_max: f64,
    /// Fraction of complex constraints that are (nearly) tight.
    pub active_fraction: f64,
}

/// Evaluate a per-edge primal vector against the LP.
pub fn check_primal(lp: &MatchingLp, x: &[f32], tol: f32) -> PrimalReport {
    assert_eq!(x.len(), lp.nnz());
    let mut ax = vec![0.0f32; lp.dual_dim()];
    lp.a.scatter_ax(x, &mut ax[..lp.matching_dual_dim()]);
    let mj = lp.matching_dual_dim();
    for (r, g) in lp.global_rows.iter().enumerate() {
        ax[mj + r] = g.coeffs.iter().zip(x).map(|(c, xe)| c * xe).sum();
    }
    let b = lp.full_b();

    let mut sq = 0.0f64;
    let mut mx = 0.0f64;
    let mut active = 0usize;
    for (r, (&axr, &br)) in ax.iter().zip(&b).enumerate() {
        let _ = r;
        let viol = (axr - br).max(0.0) as f64;
        sq += viol * viol;
        mx = mx.max(viol);
        if (axr - br).abs() <= tol * br.abs().max(1.0) {
            active += 1;
        }
    }

    // Simple-constraint violations come from each block's registered
    // operator (the `violation` oracle of `BlockProjection`), so custom
    // families are validated with no edits here. Operators are memoized
    // per distinct kind — one registry lookup per kind, not per block.
    let mut ops: BTreeMap<ProjectionKind, Arc<dyn BlockProjection>> = BTreeMap::new();
    let mut simple_mx = 0.0f64;
    for i in 0..lp.num_sources() {
        let (e0, e1) = (lp.a.src_ptr[i], lp.a.src_ptr[i + 1]);
        let kind = lp.projection.kind_of(i);
        let op = ops.entry(kind).or_insert_with(|| kind.op());
        simple_mx = simple_mx.max(op.violation(&x[e0..e1]));
    }

    let objective = lp
        .cost
        .iter()
        .zip(x)
        .map(|(c, xe)| *c as f64 * *xe as f64)
        .sum();

    PrimalReport {
        objective,
        complex_infeas: sq.sqrt(),
        complex_infeas_max: mx,
        simple_infeas_max: simple_mx,
        active_fraction: active as f64 / lp.dual_dim().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionKind;
    use crate::sparse::BlockedMatrix;

    fn lp() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 2,
            num_dests: 2,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 0, 1],
            a: vec![vec![1.0; 4]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-1.0, -2.0, -3.0, -4.0],
            vec![1.0, 1.0],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn feasible_point_clean_report() {
        let p = lp();
        let x = vec![0.5, 0.5, 0.5, 0.5];
        let r = check_primal(&p, &x, 1e-6);
        assert_eq!(r.complex_infeas, 0.0);
        assert_eq!(r.simple_infeas_max, 0.0);
        assert!((r.objective - (-0.5 - 1.0 - 1.5 - 2.0)).abs() < 1e-9);
        assert_eq!(r.active_fraction, 1.0); // both rows exactly tight
    }

    #[test]
    fn detects_complex_violation() {
        let p = lp();
        let x = vec![1.0, 0.0, 1.0, 0.0]; // Ax = (2, 0), b = (1, 1)
        let r = check_primal(&p, &x, 1e-6);
        assert!((r.complex_infeas - 1.0).abs() < 1e-6);
        assert!((r.complex_infeas_max - 1.0).abs() < 1e-6);
        // simple: block sums are 1 → fine
        assert_eq!(r.simple_infeas_max, 0.0);
    }

    #[test]
    fn detects_simple_violation() {
        let p = lp();
        let x = vec![0.9, 0.9, -0.1, 0.0];
        let r = check_primal(&p, &x, 1e-6);
        assert!(r.simple_infeas_max >= 0.8 - 1e-6); // sum 1.8 > 1
    }

    #[test]
    fn capped_simplex_violations_detected() {
        let mut p = lp();
        p.projection = crate::projection::ProjectionMap::Uniform(
            ProjectionKind::capped_simplex(0.5, 0.8),
        );
        // feasible: within cap and cut
        let ok = check_primal(&p, &[0.4, 0.4, 0.3, 0.5], 1e-6);
        assert_eq!(ok.simple_infeas_max, 0.0);
        // coordinate cap violated by 0.2
        let r1 = check_primal(&p, &[0.7, 0.0, 0.0, 0.0], 1e-6);
        assert!((r1.simple_infeas_max - 0.2).abs() < 1e-6);
        // cut violated: block sum 0.5+0.45 = 0.95 > 0.8 by 0.15
        let r2 = check_primal(&p, &[0.5, 0.45, 0.0, 0.0], 1e-6);
        assert!((r2.simple_infeas_max - 0.15).abs() < 1e-6);
    }
}
