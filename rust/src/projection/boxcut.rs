//! Box-cut / capped-simplex projection: Π onto {0 ≤ x ≤ u, Σx ≤ s} — the
//! "box-cut" polytope of [6] (per-user capacity with per-item caps);
//! `u = 1` is the classic box-cut, general `u` the capped simplex.
//!
//! Solved by bisection on the Lagrange multiplier μ of the cut constraint:
//! x(μ) = clamp(v − μ, 0, u) is monotone nonincreasing in μ, so the μ* with
//! Σ x(μ*) = s (when the clamp alone exceeds s) is found to tolerance in
//! ~60 iterations.

use std::any::Any;

use super::hlo::{emit_for, HloProjection};
use super::registry::BlockProjection;

/// Registry operator for {0 ≤ x ≤ cap, Σx ≤ total}, kernelized on every
/// tier: batched `project_rows` on the slab backends and a bisection HLO
/// emission for the PJRT path (DESIGN.md §12).
pub struct CappedSimplexOp {
    pub cap: f32,
    pub total: f32,
}

impl CappedSimplexOp {
    pub(crate) const SAMPLES: &'static [&'static str] = &[
        "capped_simplex:1:1",
        "capped_simplex:0.5:1",
        "capped_simplex:0.4:2",
    ];

    /// Family parser: bare args default to (cap=1, total=1);
    /// `<cap>:<total>` parses explicit positive finite parameters.
    pub(crate) fn parse_args(args: &str) -> Option<Box<dyn BlockProjection>> {
        let (cap, total) = if args.is_empty() {
            (1.0f32, 1.0f32)
        } else {
            let (c, t) = args.split_once(':')?;
            (c.parse().ok()?, t.parse().ok()?)
        };
        (cap > 0.0 && cap.is_finite() && total > 0.0 && total.is_finite())
            .then(|| Box::new(CappedSimplexOp { cap, total }) as Box<dyn BlockProjection>)
    }
}

impl BlockProjection for CappedSimplexOp {
    fn family(&self) -> &str {
        "capped_simplex"
    }

    fn spec(&self) -> String {
        format!("capped_simplex:{}:{}", self.cap, self.total)
    }

    fn project(&self, v: &mut [f32]) {
        project_capped_simplex(v, self.cap, self.total)
    }

    /// Width-strided batched bisection, bit-identical to looping the
    /// scalar `project` over each row's real prefix: gathered padding is
    /// exactly ±0.0, μ ≥ 0 throughout, and `clamp(±0.0 - μ, 0, cap)`
    /// contributes an exact zero to every f64 accumulation, so sweeping
    /// the full padded width reproduces the prefix sums term for term.
    /// The hoisted f64 `cap`/`total` and the branch-free full-width sweeps
    /// (no per-element mask reads inside the 64 bisection iterations) are
    /// the batching win; a final tail fill pins padding to +0.0.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        debug_assert_eq!(mask.len(), rows * width);
        let cap = self.cap as f64;
        let total = self.total as f64;
        for r in 0..rows {
            let row = &mut slab[r * width..(r + 1) * width];
            let real =
                mask[r * width..(r + 1) * width].iter().take_while(|&&m| m > 0.0).count();
            let mut clamped_sum = 0.0f64;
            for &x in row.iter() {
                clamped_sum += (x as f64).clamp(0.0, cap);
            }
            if clamped_sum <= total {
                for x in row.iter_mut() {
                    *x = (*x as f64).clamp(0.0, cap) as f32;
                }
                row[real..].fill(0.0);
                continue;
            }
            let mut max = f32::NEG_INFINITY;
            for &x in row.iter() {
                max = max.max(x);
            }
            let mut hi = max as f64;
            if hi <= 0.0 {
                // mirror the scalar dead-end: everything clamps to 0
                row.fill(0.0);
                continue;
            }
            let mut lo = 0.0f64;
            for _ in 0..64 {
                let mu = 0.5 * (lo + hi);
                let mut s = 0.0f64;
                for &x in row.iter() {
                    s += ((x as f64) - mu).clamp(0.0, cap);
                }
                if s > total {
                    lo = mu;
                } else {
                    hi = mu;
                }
            }
            let mu = 0.5 * (lo + hi);
            for x in row.iter_mut() {
                *x = ((*x as f64) - mu).clamp(0.0, cap) as f32;
            }
            row[real..].fill(0.0);
        }
    }

    fn batched_project_rows(&self) -> bool {
        true
    }

    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        emit_for(
            self.family(),
            &HloProjection::Capped { cap: self.cap, total: self.total },
            rows,
            width,
        )
    }

    fn violation(&self, v: &[f32]) -> f64 {
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        let coord = v
            .iter()
            .map(|&x| ((x - self.cap) as f64).max((-x) as f64).max(0.0))
            .fold(0.0, f64::max);
        (s - self.total as f64).max(0.0).max(coord)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// In-place projection of `v` onto {0 ≤ x ≤ cap, Σx ≤ total}.
pub fn project_capped_simplex(v: &mut [f32], cap: f32, total: f32) {
    debug_assert!(cap > 0.0);
    debug_assert!(total >= 0.0);
    let cap = cap as f64;
    let clamped_sum: f64 = v.iter().map(|&x| (x as f64).clamp(0.0, cap)).sum();
    if clamped_sum <= total as f64 {
        for x in v.iter_mut() {
            *x = (*x as f64).clamp(0.0, cap) as f32;
        }
        return;
    }
    let mut lo = 0.0f64;
    let mut hi = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    if hi <= 0.0 {
        // everything clamps to 0; Σ=0 ≤ total
        for x in v.iter_mut() {
            *x = 0.0;
        }
        return;
    }
    for _ in 0..64 {
        let mu = 0.5 * (lo + hi);
        let s: f64 = v.iter().map(|&x| ((x as f64) - mu).clamp(0.0, cap)).sum();
        if s > total as f64 {
            lo = mu;
        } else {
            hi = mu;
        }
    }
    let mu = 0.5 * (lo + hi);
    for x in v.iter_mut() {
        *x = ((*x as f64) - mu).clamp(0.0, cap) as f32;
    }
}

/// In-place projection of `v` onto {0 ≤ x ≤ 1, Σx ≤ r}.
pub fn project_box_cut(v: &mut [f32], r: f32) {
    project_capped_simplex(v, 1.0, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn feasible_point_only_clamped() {
        let mut v = vec![0.2, 0.3, -0.5];
        project_box_cut(&mut v, 2.0);
        assert_eq!(v, vec![0.2, 0.3, 0.0]);
    }

    #[test]
    fn cut_binds() {
        let mut v = vec![0.9, 0.9, 0.9];
        project_box_cut(&mut v, 1.5);
        assert!((sum(&v) - 1.5).abs() < 1e-4, "sum={}", sum(&v));
        // symmetric input → symmetric output
        assert!((v[0] - v[1]).abs() < 1e-5 && (v[1] - v[2]).abs() < 1e-5);
    }

    #[test]
    fn box_binds_before_cut() {
        let mut v = vec![5.0, -5.0];
        project_box_cut(&mut v, 1.0);
        assert!((v[0] - 1.0).abs() < 1e-5);
        assert_eq!(v[1], 0.0);
    }

    #[test]
    fn all_negative() {
        let mut v = vec![-1.0, -2.0];
        project_box_cut(&mut v, 1.0);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    fn reduces_to_simplex_when_r1_and_small_entries() {
        // With entries ≤ 1 post-shift, box-cut(r=1) == simplex-ineq.
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..100 {
            let n = 2 + rng.below(6);
            let v: Vec<f32> = (0..n).map(|_| (rng.uniform() * 0.8) as f32).collect();
            let mut a = v.clone();
            let mut b = v.clone();
            project_box_cut(&mut a, 1.0);
            crate::projection::project_simplex_ineq(&mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn capped_simplex_general_cap_binds() {
        // cap 0.4, total 1.0: symmetric large input hits the cut at
        // x_i = 1/3 each (below the cap), not 0.4.
        let mut v = vec![5.0, 5.0, 5.0];
        project_capped_simplex(&mut v, 0.4, 1.0);
        assert!((sum(&v) - 1.0).abs() < 1e-4);
        for &x in &v {
            assert!((x - 1.0 / 3.0).abs() < 1e-4, "{v:?}");
        }
        // total 2.0: now the cap binds first (3 × 0.4 = 1.2 ≤ 2.0)
        let mut w = vec![5.0, 5.0, 5.0];
        project_capped_simplex(&mut w, 0.4, 2.0);
        for &x in &w {
            assert!((x - 0.4).abs() < 1e-5, "{w:?}");
        }
    }

    #[test]
    fn capped_simplex_reduces_to_box_cut_at_cap_one() {
        let mut rng = crate::util::rng::Rng::new(21);
        for _ in 0..50 {
            let n = 2 + rng.below(6);
            let r = 0.5 + rng.uniform() as f32 * 2.0;
            let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 1.5) as f32).collect();
            let mut a = v.clone();
            let mut b = v.clone();
            project_capped_simplex(&mut a, 1.0, r);
            project_box_cut(&mut b, r);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn optimality_vs_random_feasible_points() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..30 {
            let n = 3 + rng.below(5);
            let r = 1.0 + rng.uniform() as f32;
            let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 1.5) as f32).collect();
            let mut p = v.clone();
            project_box_cut(&mut p, r);
            assert!(sum(&p) <= r as f64 + 1e-4);
            assert!(p.iter().all(|&x| (-1e-6..=1.0 + 1e-6).contains(&x)));
            let d_star: f64 = v.iter().zip(&p).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            for _ in 0..40 {
                let mut y: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
                let s: f64 = y.iter().sum();
                if s > r as f64 {
                    y.iter_mut().for_each(|x| *x *= r as f64 / s);
                }
                let d: f64 = v.iter().zip(&y).map(|(a, b)| (*a as f64 - b).powi(2)).sum();
                assert!(d_star <= d + 1e-5);
            }
        }
    }
}
