//! Unit-box projection [0, 1]^n — the "box" simple constraint of [6].

use std::any::Any;

use super::hlo::{emit_for, HloProjection};
use super::registry::BlockProjection;

/// Registry operator for [0, 1]^n.
pub struct UnitBoxOp;

impl BlockProjection for UnitBoxOp {
    fn family(&self) -> &str {
        "box"
    }

    fn spec(&self) -> String {
        "box".to_string()
    }

    fn project(&self, v: &mut [f32]) {
        project_unit_box(v)
    }

    /// Width-strided batched projection (the CPU mirror of the L1 box slab
    /// kernel): the clamp is separable, so one branch-free sweep over the
    /// whole slab does the math; a cheap tail pass then pins padding to
    /// exactly +0.0 (gathered padding can carry -0.0, which `clamp`
    /// preserves), keeping the override bit-identical to the scalar
    /// default on padded rows.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        debug_assert_eq!(mask.len(), rows * width);
        for x in slab.iter_mut() {
            *x = x.clamp(0.0, 1.0);
        }
        for r in 0..rows {
            let base = r * width;
            let real = mask[base..base + width].iter().take_while(|&&m| m > 0.0).count();
            slab[base + real..base + width].fill(0.0);
        }
    }

    fn batched_project_rows(&self) -> bool {
        true
    }

    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        emit_for(self.family(), &HloProjection::UnitBox, rows, width)
    }

    fn violation(&self, v: &[f32]) -> f64 {
        v.iter()
            .map(|&x| ((x as f64) - 1.0).max((-x) as f64).max(0.0))
            .fold(0.0, f64::max)
    }

    /// The box factors per coordinate with no positional parameters, so
    /// slab rows may be split freely.
    fn separable(&self) -> bool {
        true
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// In-place projection onto [0, 1]^n.
pub fn project_unit_box(v: &mut [f32]) {
    for x in v.iter_mut() {
        *x = x.clamp(0.0, 1.0);
    }
}

/// In-place projection onto a general box [lo, hi]^n.
pub fn project_box(v: &mut [f32], lo: f32, hi: f32) {
    debug_assert!(lo <= hi);
    for x in v.iter_mut() {
        *x = x.clamp(lo, hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_both_sides() {
        let mut v = vec![-1.0, 0.5, 2.0];
        project_unit_box(&mut v);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn general_box() {
        let mut v = vec![-1.0, 0.5, 2.0];
        project_box(&mut v, 0.25, 0.75);
        assert_eq!(v, vec![0.25, 0.5, 0.75]);
    }

    #[test]
    fn project_rows_clamps_whole_slab() {
        use crate::projection::BlockProjection;
        let op = UnitBoxOp;
        let mut slab = vec![-1.0f32, 0.5, 2.0, 0.0, 0.25, 3.0, -0.5, 0.0];
        let mask = vec![1.0f32, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0];
        op.project_rows(&mut slab, 2, 4, &mask);
        assert_eq!(slab, vec![0.0, 0.5, 1.0, 0.0, 0.25, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn project_rows_pins_negative_zero_padding() {
        use crate::projection::BlockProjection;
        let op = UnitBoxOp;
        // gather_project can hand the kernel -0.0 in padded lanes; the
        // batched override must still match the scalar default's +0.0 tail
        let mut slab = vec![0.5f32, -0.0, -0.0, -0.0];
        let mask = vec![1.0f32, 0.0, 0.0, 0.0];
        op.project_rows(&mut slab, 1, 4, &mask);
        for &x in &slab[1..] {
            assert_eq!(x.to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn idempotent() {
        let mut v = vec![-3.0, 0.1, 7.0];
        project_unit_box(&mut v);
        let once = v.clone();
        project_unit_box(&mut v);
        assert_eq!(v, once);
    }
}
