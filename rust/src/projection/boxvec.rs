//! Vector-box projection: Π onto {0 ≤ xᵢ ≤ uᵢ} — per-coordinate upper
//! bounds (e.g. per-edge frequency caps that differ by destination).
//!
//! The first operator whose parameter is a non-`Copy` payload: the bound
//! vector lives in the registry's interned table and the slab/bucket maps
//! keep keying by the compact `OpId` handle. Registered purely inside
//! `projection/` — no solver, sparse-layout, or runtime edits (paper §4
//! locality). The bound vector cycles over block coordinates
//! (`u[i % len]`), so `box_vec:0.5` is a uniform [0, 0.5] box and a
//! full-width vector is per-edge. Kernelized on every tier: a batched
//! `project_rows` override with a hoisted per-column bound table on the
//! slab backends, and a clamp HLO emission with a constant bound plane
//! for the PJRT path (DESIGN.md §12).

use std::any::Any;

use super::hlo::{emit_for, HloProjection};
use super::registry::BlockProjection;
use super::ProjectionKind;

/// Registry operator for {0 ≤ xᵢ ≤ uᵢ} with cycling bounds.
pub struct BoxVecOp {
    pub upper: Vec<f32>,
}

/// Intern {0 ≤ xᵢ ≤ uᵢ} with cycling per-coordinate bounds.
pub fn box_vec(upper: &[f32]) -> ProjectionKind {
    assert!(
        !upper.is_empty() && upper.iter().all(|&u| u > 0.0 && u.is_finite()),
        "bounds must be a nonempty positive finite vector"
    );
    ProjectionKind::intern(Box::new(BoxVecOp {
        upper: upper.to_vec(),
    }))
}

impl BoxVecOp {
    pub(crate) const SAMPLES: &'static [&'static str] =
        &["box_vec:1", "box_vec:0.5,1.5", "box_vec:0.25,2,1"];

    /// Family parser: bare args default to u = [1] ≡ the unit box;
    /// `<u1>,<u2>,…` sets explicit cycling bounds.
    pub(crate) fn parse_args(args: &str) -> Option<Box<dyn BlockProjection>> {
        let upper: Vec<f32> = if args.is_empty() {
            vec![1.0]
        } else {
            args.split(',')
                .map(|s| s.parse().ok())
                .collect::<Option<Vec<f32>>>()?
        };
        let ok = !upper.is_empty() && upper.iter().all(|&u| u > 0.0 && u.is_finite());
        ok.then(|| Box::new(BoxVecOp { upper }) as Box<dyn BlockProjection>)
    }

    #[inline]
    fn bound(&self, i: usize) -> f32 {
        self.upper[i % self.upper.len()]
    }
}

impl BlockProjection for BoxVecOp {
    fn family(&self) -> &str {
        "box_vec"
    }

    fn spec(&self) -> String {
        let us: Vec<String> = self.upper.iter().map(|u| u.to_string()).collect();
        format!("box_vec:{}", us.join(","))
    }

    fn project(&self, v: &mut [f32]) {
        for (i, x) in v.iter_mut().enumerate() {
            *x = x.clamp(0.0, self.bound(i));
        }
    }

    /// Width-strided batched clamp. Bounds are positional with period
    /// `upper.len()`, so a per-row cycled iterator reproduces the scalar
    /// `bound(c) = upper[c % len]` modulo-free and without a per-call
    /// bound table — this override runs inside the solver's hot loop and
    /// must not allocate. Real entries occupy the row head, so column
    /// bounds line up with scalar indices; the clamp itself is identical
    /// per element, and a tail fill pins padding to +0.0 (gathered
    /// padding can carry -0.0), so the override is bit-identical to the
    /// scalar default.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        debug_assert_eq!(mask.len(), rows * width);
        for r in 0..rows {
            let row = &mut slab[r * width..(r + 1) * width];
            for (x, &u) in row.iter_mut().zip(self.upper.iter().cycle()) {
                *x = x.clamp(0.0, u);
            }
            let real =
                mask[r * width..(r + 1) * width].iter().take_while(|&&m| m > 0.0).count();
            row[real..].fill(0.0);
        }
    }

    fn batched_project_rows(&self) -> bool {
        true
    }

    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        emit_for(self.family(), &HloProjection::BoxVec { upper: &self.upper }, rows, width)
    }

    fn violation(&self, v: &[f32]) -> f64 {
        v.iter()
            .enumerate()
            .map(|(i, &x)| ((x - self.bound(i)) as f64).max((-x) as f64).max(0.0))
            .fold(0.0, f64::max)
    }

    /// Coordinatewise math, but the bounds are positional: splitting a
    /// block across slab rows would re-index `i` and misalign `u[i %
    /// len]`. Conservatively non-separable until the slab kernel carries
    /// its own parameter-offset plane.
    fn separable(&self) -> bool {
        false
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_per_coordinate_with_cycling() {
        let op = BoxVecOp {
            upper: vec![0.5, 2.0],
        };
        let mut v = vec![1.0, 1.0, -3.0, 3.0, 0.25];
        op.project(&mut v);
        // bounds cycle: 0.5, 2, 0.5, 2, 0.5
        assert_eq!(v, vec![0.5, 1.0, 0.0, 2.0, 0.25]);
        assert!(op.feasible(&v, 0.0));
        assert!(op.violation(&[0.6, 0.0]) > 0.0);
        assert!((op.violation(&[0.75, 0.0]) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn uniform_bound_one_matches_unit_box() {
        let op = BoxVecOp { upper: vec![1.0] };
        let mut a = vec![-0.5, 0.5, 2.0];
        let mut b = a.clone();
        op.project(&mut a);
        crate::projection::project_unit_box(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn spec_roundtrip_and_constructor() {
        let k = box_vec(&[0.5, 1.5]);
        assert_eq!(k.spec(), "box_vec:0.5,1.5");
        assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
        assert_eq!(k.name(), "box_vec");
        assert!(!k.separable());
        let bare = ProjectionKind::parse("box_vec").map(|b| b.spec());
        assert_eq!(bare, Some("box_vec:1".to_string()));
        // malformed / invalid parameters rejected
        assert_eq!(ProjectionKind::parse("box_vec:0"), None);
        assert_eq!(ProjectionKind::parse("box_vec:-1,1"), None);
        assert_eq!(ProjectionKind::parse("box_vec:1,"), None);
        assert_eq!(ProjectionKind::parse("box_vec:a"), None);
    }
}
