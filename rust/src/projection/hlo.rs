//! Shared HLO-text emitter behind
//! [`emit_hlo`](super::registry::BlockProjection::emit_hlo) (DESIGN.md §12).
//!
//! Every slab kernel follows the same contract as the AOT artifacts under
//! `python/compile/`: parameters `u: f32[T,w]`, `c: f32[T,w]`,
//! `mask: f32[T,w]`, `g: f32[1]`, root tuple `(x, cx, xsq)` where
//! `v = -(u + c) / g * mask`, `x = proj(v) * mask`, `cx = sum(c * x)` and
//! `xsq = sum(x * x)`. The family-specific piece is only the projection
//! section mapping `v` to `x`; everything around it is shared here so a
//! new family gets the whole module for the price of a
//! [`HloProjection`] variant.
//!
//! Simplex-like families use a row-wise 64-step bisection on the
//! Lagrange multiplier, expressed as an HLO `while` loop over the state
//! tuple `(v, lo, hi, i)` — the same fixed trip count as the scalar CPU
//! paths, so the emitted kernels match the CPU tier to f32 accuracy.
//! The text is deterministic (fixed instruction names, no counters):
//! golden snapshots under `tests/snapshots/` pin it byte for byte.

use std::fmt::Write as _;

/// Family-specific projection section of a slab kernel.
pub(crate) enum HloProjection<'a> {
    /// `x = clamp(v, 0, 1)`.
    UnitBox,
    /// `x = clamp(v, 0, upper[c % upper.len()])` per column `c`.
    BoxVec { upper: &'a [f32] },
    /// Bisection: `x = max(v - mu, 0)` with `sum(x) <= total`.
    Simplex { total: f32 },
    /// Bisection: `x = clamp(v - mu, 0, cap)` with `sum(x) <= total`.
    Capped { cap: f32, total: f32 },
    /// Bisection: `x = max(v - mu*w, 0)` with `sum(w*x) <= total`,
    /// weights cycled per column like the scalar operator.
    Weighted { total: f32, weights: &'a [f32] },
}

impl HloProjection<'_> {
    fn bisects(&self) -> bool {
        matches!(
            self,
            HloProjection::Simplex { .. }
                | HloProjection::Capped { .. }
                | HloProjection::Weighted { .. }
        )
    }
}

/// HLO text constants must parse back to the same f32; Rust's shortest
/// round-trip `Display` is exactly that. Kernel parameters are validated
/// positive and finite at registration, so `nan`/`inf` never reach here.
fn fmt_f32(v: f32) -> String {
    debug_assert!(v.is_finite() || v == f32::NEG_INFINITY);
    format!("{v}")
}

/// `{a, b, a, b, ...}` — a per-column table cycling `vals` out to `width`,
/// mirroring the `params[i % params.len()]` convention of the scalar ops.
fn const_list(vals: &[f32], width: usize) -> String {
    let mut out = String::new();
    for c in 0..width {
        if c > 0 {
            out.push_str(", ");
        }
        out.push_str(&fmt_f32(vals[c % vals.len()]));
    }
    out
}

fn state_ty(t: usize, w: usize) -> String {
    format!("(f32[{t},{w}], f32[{t}], f32[{t}], s32[])")
}

fn push_add_f32(s: &mut String) {
    let _ = writeln!(s, "%add_f32 (a: f32[], b: f32[]) -> f32[] {{");
    let _ = writeln!(s, "  %a = f32[] parameter(0)");
    let _ = writeln!(s, "  %b = f32[] parameter(1)");
    let _ = writeln!(s, "  ROOT %add = f32[] add(%a, %b)");
    let _ = writeln!(s, "}}");
}

fn push_max_f32(s: &mut String) {
    let _ = writeln!(s, "%max_f32 (a: f32[], b: f32[]) -> f32[] {{");
    let _ = writeln!(s, "  %a = f32[] parameter(0)");
    let _ = writeln!(s, "  %b = f32[] parameter(1)");
    let _ = writeln!(s, "  ROOT %max = f32[] maximum(%a, %b)");
    let _ = writeln!(s, "}}");
}

fn push_bisect_cond(s: &mut String, t: usize, w: usize) {
    let st = state_ty(t, w);
    let _ = writeln!(s, "%bisect_cond (state: {st}) -> pred[] {{");
    let _ = writeln!(s, "  %state = {st} parameter(0)");
    let _ = writeln!(s, "  %i = s32[] get-tuple-element(%state), index=3");
    let _ = writeln!(s, "  %iters = s32[] constant(64)");
    let _ = writeln!(s, "  ROOT %continue = pred[] compare(%i, %iters), direction=LT");
    let _ = writeln!(s, "}}");
}

fn push_bisect_body(s: &mut String, t: usize, w: usize, proj: &HloProjection) {
    let st = state_ty(t, w);
    let _ = writeln!(s, "%bisect_body (state: {st}) -> {st} {{");
    let _ = writeln!(s, "  %state = {st} parameter(0)");
    let _ = writeln!(s, "  %v = f32[{t},{w}] get-tuple-element(%state), index=0");
    let _ = writeln!(s, "  %lo = f32[{t}] get-tuple-element(%state), index=1");
    let _ = writeln!(s, "  %hi = f32[{t}] get-tuple-element(%state), index=2");
    let _ = writeln!(s, "  %i = s32[] get-tuple-element(%state), index=3");
    let _ = writeln!(s, "  %half = f32[] constant(0.5)");
    let _ = writeln!(s, "  %halfb = f32[{t}] broadcast(%half), dimensions={{}}");
    let _ = writeln!(s, "  %losum = f32[{t}] add(%lo, %hi)");
    let _ = writeln!(s, "  %mu = f32[{t}] multiply(%losum, %halfb)");
    let _ = writeln!(s, "  %mub = f32[{t},{w}] broadcast(%mu), dimensions={{0}}");
    let _ = writeln!(s, "  %zero = f32[] constant(0)");
    let _ = writeln!(s, "  %zerob = f32[{t},{w}] broadcast(%zero), dimensions={{}}");
    let total = match proj {
        HloProjection::Weighted { total, weights } => {
            let _ = writeln!(s, "  %wcol = f32[{w}] constant({{{}}})", const_list(weights, w));
            let _ = writeln!(s, "  %wb = f32[{t},{w}] broadcast(%wcol), dimensions={{1}}");
            let _ = writeln!(s, "  %muw = f32[{t},{w}] multiply(%mub, %wb)");
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %muw)");
            let _ = writeln!(s, "  %xmu = f32[{t},{w}] maximum(%shift, %zerob)");
            let _ = writeln!(s, "  %wx = f32[{t},{w}] multiply(%wb, %xmu)");
            let _ = writeln!(
                s,
                "  %mass = f32[{t}] reduce(%wx, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        HloProjection::Capped { cap, total } => {
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %mub)");
            let _ = writeln!(s, "  %cap = f32[] constant({})", fmt_f32(*cap));
            let _ = writeln!(s, "  %capb = f32[{t},{w}] broadcast(%cap), dimensions={{}}");
            let _ = writeln!(s, "  %xmu = f32[{t},{w}] clamp(%zerob, %shift, %capb)");
            let _ = writeln!(
                s,
                "  %mass = f32[{t}] reduce(%xmu, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        HloProjection::Simplex { total } => {
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %mub)");
            let _ = writeln!(s, "  %xmu = f32[{t},{w}] maximum(%shift, %zerob)");
            let _ = writeln!(
                s,
                "  %mass = f32[{t}] reduce(%xmu, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        // Callers only build a bisection body for bisecting variants.
        HloProjection::UnitBox | HloProjection::BoxVec { .. } => return,
    };
    let _ = writeln!(s, "  %total = f32[] constant({})", fmt_f32(total));
    let _ = writeln!(s, "  %totalb = f32[{t}] broadcast(%total), dimensions={{}}");
    let _ = writeln!(s, "  %over = pred[{t}] compare(%mass, %totalb), direction=GT");
    let _ = writeln!(s, "  %lo2 = f32[{t}] select(%over, %mu, %lo)");
    let _ = writeln!(s, "  %hi2 = f32[{t}] select(%over, %hi, %mu)");
    let _ = writeln!(s, "  %one = s32[] constant(1)");
    let _ = writeln!(s, "  %i2 = s32[] add(%i, %one)");
    let _ = writeln!(s, "  ROOT %next = {st} tuple(%v, %lo2, %hi2, %i2)");
    let _ = writeln!(s, "}}");
}

fn push_entry_prefix(s: &mut String, t: usize, w: usize) {
    let _ = writeln!(
        s,
        "ENTRY %main (u: f32[{t},{w}], c: f32[{t},{w}], mask: f32[{t},{w}], g: f32[1]) -> (f32[{t},{w}], f32[1], f32[1]) {{"
    );
    let _ = writeln!(s, "  %u = f32[{t},{w}] parameter(0)");
    let _ = writeln!(s, "  %c = f32[{t},{w}] parameter(1)");
    let _ = writeln!(s, "  %mask = f32[{t},{w}] parameter(2)");
    let _ = writeln!(s, "  %g = f32[1] parameter(3)");
    let _ = writeln!(s, "  %gs = f32[] reshape(%g)");
    let _ = writeln!(s, "  %gb = f32[{t},{w}] broadcast(%gs), dimensions={{}}");
    let _ = writeln!(s, "  %uc = f32[{t},{w}] add(%u, %c)");
    let _ = writeln!(s, "  %nuc = f32[{t},{w}] negate(%uc)");
    let _ = writeln!(s, "  %vraw = f32[{t},{w}] divide(%nuc, %gb)");
    let _ = writeln!(s, "  %v = f32[{t},{w}] multiply(%vraw, %mask)");
    let _ = writeln!(s, "  %zero = f32[] constant(0)");
    let _ = writeln!(s, "  %zerob = f32[{t},{w}] broadcast(%zero), dimensions={{}}");
}

fn push_entry_suffix(s: &mut String, t: usize, w: usize) {
    let _ = writeln!(s, "  %x = f32[{t},{w}] multiply(%xproj, %mask)");
    let _ = writeln!(s, "  %cxe = f32[{t},{w}] multiply(%c, %x)");
    let _ = writeln!(
        s,
        "  %cxs = f32[] reduce(%cxe, %zero), dimensions={{0,1}}, to_apply=%add_f32"
    );
    let _ = writeln!(s, "  %cx = f32[1] reshape(%cxs)");
    let _ = writeln!(s, "  %xx = f32[{t},{w}] multiply(%x, %x)");
    let _ = writeln!(
        s,
        "  %xsqs = f32[] reduce(%xx, %zero), dimensions={{0,1}}, to_apply=%add_f32"
    );
    let _ = writeln!(s, "  %xsq = f32[1] reshape(%xsqs)");
    let _ = writeln!(s, "  ROOT %out = (f32[{t},{w}], f32[1], f32[1]) tuple(%x, %cx, %xsq)");
    let _ = writeln!(s, "}}");
}

fn push_bisect_entry_section(s: &mut String, t: usize, w: usize, proj: &HloProjection) {
    let total = match proj {
        HloProjection::Weighted { total, weights } => {
            let _ = writeln!(s, "  %wcol = f32[{w}] constant({{{}}})", const_list(weights, w));
            let _ = writeln!(s, "  %wb = f32[{t},{w}] broadcast(%wcol), dimensions={{1}}");
            let _ = writeln!(s, "  %clamped = f32[{t},{w}] maximum(%v, %zerob)");
            let _ = writeln!(s, "  %wx0 = f32[{t},{w}] multiply(%wb, %clamped)");
            let _ = writeln!(
                s,
                "  %mass0 = f32[{t}] reduce(%wx0, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        HloProjection::Capped { cap, total } => {
            let _ = writeln!(s, "  %cap = f32[] constant({})", fmt_f32(*cap));
            let _ = writeln!(s, "  %capb = f32[{t},{w}] broadcast(%cap), dimensions={{}}");
            let _ = writeln!(s, "  %clamped = f32[{t},{w}] clamp(%zerob, %v, %capb)");
            let _ = writeln!(
                s,
                "  %mass0 = f32[{t}] reduce(%clamped, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        HloProjection::Simplex { total } => {
            let _ = writeln!(s, "  %clamped = f32[{t},{w}] maximum(%v, %zerob)");
            let _ = writeln!(
                s,
                "  %mass0 = f32[{t}] reduce(%clamped, %zero), dimensions={{1}}, to_apply=%add_f32"
            );
            *total
        }
        // Callers only build a bisection section for bisecting variants.
        HloProjection::UnitBox | HloProjection::BoxVec { .. } => return,
    };
    let _ = writeln!(s, "  %total = f32[] constant({})", fmt_f32(total));
    let _ = writeln!(s, "  %totalb = f32[{t}] broadcast(%total), dimensions={{}}");
    let _ = writeln!(s, "  %feas = pred[{t}] compare(%mass0, %totalb), direction=LE");
    let _ = writeln!(s, "  %ninf = f32[] constant(-inf)");
    if matches!(proj, HloProjection::Weighted { .. }) {
        let _ = writeln!(s, "  %ratio = f32[{t},{w}] divide(%clamped, %wb)");
        let _ = writeln!(
            s,
            "  %hiraw = f32[{t}] reduce(%ratio, %ninf), dimensions={{1}}, to_apply=%max_f32"
        );
    } else {
        let _ = writeln!(
            s,
            "  %hiraw = f32[{t}] reduce(%v, %ninf), dimensions={{1}}, to_apply=%max_f32"
        );
    }
    let st = state_ty(t, w);
    let _ = writeln!(s, "  %lo0 = f32[{t}] broadcast(%zero), dimensions={{}}");
    let _ = writeln!(s, "  %hi0 = f32[{t}] maximum(%hiraw, %lo0)");
    let _ = writeln!(s, "  %izero = s32[] constant(0)");
    let _ = writeln!(s, "  %init = {st} tuple(%v, %lo0, %hi0, %izero)");
    let _ = writeln!(s, "  %bisect = {st} while(%init), condition=%bisect_cond, body=%bisect_body");
    let _ = writeln!(s, "  %lof = f32[{t}] get-tuple-element(%bisect), index=1");
    let _ = writeln!(s, "  %hif = f32[{t}] get-tuple-element(%bisect), index=2");
    let _ = writeln!(s, "  %half = f32[] constant(0.5)");
    let _ = writeln!(s, "  %halfb = f32[{t}] broadcast(%half), dimensions={{}}");
    let _ = writeln!(s, "  %losum = f32[{t}] add(%lof, %hif)");
    let _ = writeln!(s, "  %mu = f32[{t}] multiply(%losum, %halfb)");
    let _ = writeln!(s, "  %mub = f32[{t},{w}] broadcast(%mu), dimensions={{0}}");
    match proj {
        HloProjection::Weighted { .. } => {
            let _ = writeln!(s, "  %muw = f32[{t},{w}] multiply(%mub, %wb)");
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %muw)");
            let _ = writeln!(s, "  %xbis = f32[{t},{w}] maximum(%shift, %zerob)");
        }
        HloProjection::Capped { .. } => {
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %mub)");
            let _ = writeln!(s, "  %xbis = f32[{t},{w}] clamp(%zerob, %shift, %capb)");
        }
        _ => {
            let _ = writeln!(s, "  %shift = f32[{t},{w}] subtract(%v, %mub)");
            let _ = writeln!(s, "  %xbis = f32[{t},{w}] maximum(%shift, %zerob)");
        }
    }
    let _ = writeln!(s, "  %feasb = pred[{t},{w}] broadcast(%feas), dimensions={{0}}");
    let _ = writeln!(s, "  %xproj = f32[{t},{w}] select(%feasb, %clamped, %xbis)");
}

/// Emit a complete slab-kernel module for one `(family, rows, width)`
/// tile. `tag` becomes part of the module name (`slab_{tag}_t{T}_w{w}`)
/// and must be a valid HLO identifier fragment — family names are.
pub(crate) fn emit_slab_module(
    tag: &str,
    rows: usize,
    width: usize,
    proj: &HloProjection,
) -> String {
    debug_assert!(rows > 0 && width > 0);
    let (t, w) = (rows, width);
    let mut s = String::new();
    let _ = writeln!(s, "HloModule slab_{tag}_t{t}_w{w}");
    let _ = writeln!(s);
    push_add_f32(&mut s);
    if proj.bisects() {
        let _ = writeln!(s);
        push_max_f32(&mut s);
        let _ = writeln!(s);
        push_bisect_cond(&mut s, t, w);
        let _ = writeln!(s);
        push_bisect_body(&mut s, t, w, proj);
    }
    let _ = writeln!(s);
    push_entry_prefix(&mut s, t, w);
    match proj {
        HloProjection::UnitBox => {
            let _ = writeln!(s, "  %one = f32[] constant(1)");
            let _ = writeln!(s, "  %oneb = f32[{t},{w}] broadcast(%one), dimensions={{}}");
            let _ = writeln!(s, "  %xproj = f32[{t},{w}] clamp(%zerob, %v, %oneb)");
        }
        HloProjection::BoxVec { upper } => {
            let _ = writeln!(s, "  %ucol = f32[{w}] constant({{{}}})", const_list(upper, w));
            let _ = writeln!(s, "  %ub = f32[{t},{w}] broadcast(%ucol), dimensions={{1}}");
            let _ = writeln!(s, "  %xproj = f32[{t},{w}] clamp(%zerob, %v, %ub)");
        }
        _ => push_bisect_entry_section(&mut s, t, w, proj),
    }
    push_entry_suffix(&mut s, t, w);
    s
}

/// Structural sanity of an emission, shared by the conformance matrix and
/// the runtime fallback: the module must carry the slab contract shapes.
/// Cheap string checks only — the real gate is compiling the text.
pub fn emission_is_well_formed(text: &str, rows: usize, width: usize) -> bool {
    let tile = format!("f32[{rows},{width}]");
    text.starts_with("HloModule slab_")
        && text.contains("ENTRY %main")
        && text.contains(&format!("ROOT %out = ({tile}, f32[1], f32[1]) tuple(%x, %cx, %xsq)"))
        && text.contains(&format!("%mask = {tile} parameter(2)"))
}

/// Convenience used by operator `emit_hlo` impls: emit for a family that
/// maps 1:1 onto an [`HloProjection`] variant, declining degenerate tiles.
pub(crate) fn emit_for(
    family: &str,
    proj: &HloProjection,
    rows: usize,
    width: usize,
) -> Option<String> {
    if rows == 0 || width == 0 {
        return None;
    }
    Some(emit_slab_module(family, rows, width, proj))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_box_module_is_well_formed_and_loop_free() {
        let txt = emit_slab_module("box", 4, 8, &HloProjection::UnitBox);
        assert!(emission_is_well_formed(&txt, 4, 8), "{txt}");
        assert!(!txt.contains("while"), "box must not emit a bisection loop");
        assert!(txt.starts_with("HloModule slab_box_t4_w8\n"));
    }

    #[test]
    fn bisection_families_carry_while_loop_and_guards() {
        for proj in [
            HloProjection::Simplex { total: 1.0 },
            HloProjection::Capped { cap: 0.5, total: 1.0 },
            HloProjection::Weighted { total: 2.0, weights: &[1.0, 2.0] },
        ] {
            let txt = emit_slab_module("fam", 4, 4, &proj);
            assert!(emission_is_well_formed(&txt, 4, 4), "{txt}");
            assert!(txt.contains("condition=%bisect_cond, body=%bisect_body"));
            assert!(txt.contains("%iters = s32[] constant(64)"));
            assert!(txt.contains("direction=LE"), "feasible-row guard missing");
        }
    }

    #[test]
    fn cyclic_parameter_tables_expand_to_width() {
        let txt = emit_slab_module(
            "box_vec",
            2,
            5,
            &HloProjection::BoxVec { upper: &[0.5, 1.5] },
        );
        assert!(txt.contains("%ucol = f32[5] constant({0.5, 1.5, 0.5, 1.5, 0.5})"), "{txt}");
    }

    #[test]
    fn float_constants_render_shortest_roundtrip() {
        assert_eq!(fmt_f32(1.0), "1");
        assert_eq!(fmt_f32(0.5), "0.5");
        assert_eq!(fmt_f32(1.5), "1.5");
        assert_eq!(fmt_f32(0.25), "0.25");
    }
}
