//! Blockwise projection operators onto the "simple constraint" polytopes
//! (paper §3.2 and Table 1's `ProjectionMap` role).
//!
//! Every operator projects one source's variable block in place. These CPU
//! implementations back the reference ("Scala-equivalent") objective, the
//! primal rounding/validation path, and the oracles the property tests
//! compare the Pallas kernels against. The accelerated path runs the same
//! math inside the AOT slab kernels (python/compile/kernels/slab.py).

mod boxcut;
mod boxp;
mod simplex;

pub use boxcut::project_box_cut;
pub use boxp::{project_box, project_unit_box};
pub use simplex::{project_simplex_eq, project_simplex_ineq};

/// Projection kinds available to slab buckets (must stay in sync with the
/// AOT artifact family in python/compile/aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ProjectionKind {
    /// {x ≥ 0, Σx ≤ 1} — per-source impression capacity (paper Eq. 4–5).
    Simplex,
    /// [0, 1]^w unit box.
    Box,
}

impl ProjectionKind {
    pub fn name(self) -> &'static str {
        match self {
            ProjectionKind::Simplex => "simplex",
            ProjectionKind::Box => "box",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "simplex" => Some(ProjectionKind::Simplex),
            "box" => Some(ProjectionKind::Box),
            _ => None,
        }
    }

    /// Apply this projection to one block in place.
    pub fn apply(self, v: &mut [f32]) {
        match self {
            ProjectionKind::Simplex => project_simplex_ineq(v),
            ProjectionKind::Box => project_unit_box(v),
        }
    }

    /// Whether the polytope is separable per coordinate (allows slab rows
    /// to be split when a block exceeds the maximum slab width).
    pub fn separable(self) -> bool {
        matches!(self, ProjectionKind::Box)
    }
}

/// The `ProjectionMap` of paper Table 1: maps a block id to its projection
/// operator. A uniform map is one allocation; heterogeneous maps are a
/// closure over per-block metadata.
pub enum ProjectionMap {
    Uniform(ProjectionKind),
    PerBlock(Box<dyn Fn(usize) -> ProjectionKind + Send + Sync>),
}

impl ProjectionMap {
    pub fn kind_of(&self, block: usize) -> ProjectionKind {
        match self {
            ProjectionMap::Uniform(k) => *k,
            ProjectionMap::PerBlock(f) => f(block),
        }
    }

    /// `project(block_id, v)` — the single required method (paper Table 1).
    pub fn project(&self, block: usize, v: &mut [f32]) {
        self.kind_of(block).apply(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [ProjectionKind::Simplex, ProjectionKind::Box] {
            assert_eq!(ProjectionKind::parse(k.name()), Some(k));
        }
        assert_eq!(ProjectionKind::parse("nope"), None);
    }

    #[test]
    fn uniform_map_projects() {
        let m = ProjectionMap::Uniform(ProjectionKind::Box);
        let mut v = vec![-0.5, 0.5, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn per_block_map_dispatches() {
        let m = ProjectionMap::PerBlock(Box::new(|i| {
            if i == 0 { ProjectionKind::Box } else { ProjectionKind::Simplex }
        }));
        let mut v = vec![2.0, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![1.0, 1.0]); // box clamp
        let mut w = vec![2.0, 2.0];
        m.project(1, &mut w);
        let s: f32 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-6); // simplex cap
    }
}
