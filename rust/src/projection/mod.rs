//! Blockwise projection operators onto the "simple constraint" polytopes
//! (paper §3.2 and Table 1's `ProjectionMap` role).
//!
//! Every operator projects one source's variable block in place. These CPU
//! implementations back the reference ("Scala-equivalent") objective, the
//! primal rounding/validation path, and the oracles the property tests
//! compare the Pallas kernels against. The accelerated path runs the same
//! math inside the AOT slab kernels (python/compile/kernels/slab.py).

mod boxcut;
mod boxp;
mod simplex;

pub use boxcut::{project_box_cut, project_capped_simplex};
pub use boxp::{project_box, project_unit_box};
pub use simplex::{project_simplex_eq, project_simplex_ineq};

/// Projection kinds available to slab buckets (must stay in sync with the
/// AOT artifact family in python/compile/aot.py; `CappedSimplex` is
/// CPU-reference-only until its slab kernel lands there).
///
/// Parameterized kinds store their f32 parameters as bit patterns so the
/// enum stays `Copy + Eq + Ord + Hash` — it keys the bucket map in
/// `sparse::slabs` and the artifact map in `runtime::pjrt`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ProjectionKind {
    /// {x ≥ 0, Σx ≤ 1} — per-source impression capacity (paper Eq. 4–5).
    Simplex,
    /// [0, 1]^w unit box.
    Box,
    /// {0 ≤ x ≤ u, Σx ≤ s} — per-edge caps plus a per-source total
    /// capacity (the "box-cut" family of [6] with a general cap/total).
    /// Construct via [`ProjectionKind::capped_simplex`].
    CappedSimplex { cap_bits: u32, total_bits: u32 },
}

impl ProjectionKind {
    /// {0 ≤ x ≤ cap, Σx ≤ total}. Both parameters must be positive finite.
    pub fn capped_simplex(cap: f32, total: f32) -> Self {
        assert!(cap > 0.0 && cap.is_finite(), "cap must be positive finite");
        assert!(total > 0.0 && total.is_finite(), "total must be positive finite");
        ProjectionKind::CappedSimplex {
            cap_bits: cap.to_bits(),
            total_bits: total.to_bits(),
        }
    }

    /// (cap, total) of a `CappedSimplex`, None otherwise.
    pub fn capped_params(self) -> Option<(f32, f32)> {
        match self {
            ProjectionKind::CappedSimplex { cap_bits, total_bits } => {
                Some((f32::from_bits(cap_bits), f32::from_bits(total_bits)))
            }
            _ => None,
        }
    }

    /// Family name (parameter-free; see [`ProjectionKind::spec`] for the
    /// round-trippable form).
    pub fn name(self) -> &'static str {
        match self {
            ProjectionKind::Simplex => "simplex",
            ProjectionKind::Box => "box",
            ProjectionKind::CappedSimplex { .. } => "capped_simplex",
        }
    }

    /// Full round-trippable spec string: `parse(k.spec()) == Some(k)`.
    /// (f32 `Display` is the shortest exact representation in Rust, so the
    /// parameter round-trip is lossless.)
    pub fn spec(self) -> String {
        match self.capped_params() {
            Some((cap, total)) => format!("capped_simplex:{cap}:{total}"),
            None => self.name().to_string(),
        }
    }

    /// Parse a name or spec string. Bare `capped_simplex` gets the
    /// (cap=1, total=1) defaults; `capped_simplex:<cap>:<total>` parses
    /// explicit parameters.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "simplex" => return Some(ProjectionKind::Simplex),
            "box" => return Some(ProjectionKind::Box),
            "capped_simplex" => return Some(ProjectionKind::capped_simplex(1.0, 1.0)),
            _ => {}
        }
        let rest = s.strip_prefix("capped_simplex:")?;
        let (cap_s, total_s) = rest.split_once(':')?;
        let cap: f32 = cap_s.parse().ok()?;
        let total: f32 = total_s.parse().ok()?;
        if cap > 0.0 && cap.is_finite() && total > 0.0 && total.is_finite() {
            Some(ProjectionKind::capped_simplex(cap, total))
        } else {
            None
        }
    }

    /// Apply this projection to one block in place.
    pub fn apply(self, v: &mut [f32]) {
        match self {
            ProjectionKind::Simplex => project_simplex_ineq(v),
            ProjectionKind::Box => project_unit_box(v),
            ProjectionKind::CappedSimplex { cap_bits, total_bits } => project_capped_simplex(
                v,
                f32::from_bits(cap_bits),
                f32::from_bits(total_bits),
            ),
        }
    }

    /// Whether the polytope is separable per coordinate (allows slab rows
    /// to be split when a block exceeds the maximum slab width). The sum
    /// cut couples coordinates, so `CappedSimplex` is non-separable like
    /// `Simplex`.
    pub fn separable(self) -> bool {
        matches!(self, ProjectionKind::Box)
    }
}

/// The `ProjectionMap` of paper Table 1: maps a block id to its projection
/// operator. A uniform map is one allocation; heterogeneous maps are a
/// closure over per-block metadata.
pub enum ProjectionMap {
    Uniform(ProjectionKind),
    PerBlock(Box<dyn Fn(usize) -> ProjectionKind + Send + Sync>),
}

impl ProjectionMap {
    pub fn kind_of(&self, block: usize) -> ProjectionKind {
        match self {
            ProjectionMap::Uniform(k) => *k,
            ProjectionMap::PerBlock(f) => f(block),
        }
    }

    /// `project(block_id, v)` — the single required method (paper Table 1).
    pub fn project(&self, block: usize, v: &mut [f32]) {
        self.kind_of(block).apply(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [ProjectionKind::Simplex, ProjectionKind::Box] {
            assert_eq!(ProjectionKind::parse(k.name()), Some(k));
            assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
        }
        assert_eq!(ProjectionKind::parse("nope"), None);
    }

    #[test]
    fn capped_simplex_spec_roundtrip() {
        for (cap, total) in [(1.0f32, 1.0f32), (0.5, 2.5), (0.3333333, 7.0), (1e-3, 1e3)] {
            let k = ProjectionKind::capped_simplex(cap, total);
            let spec = k.spec();
            assert_eq!(ProjectionKind::parse(&spec), Some(k), "spec {spec}");
            assert_eq!(k.name(), "capped_simplex");
            assert_eq!(k.capped_params(), Some((cap, total)));
        }
        // bare family name gets defaults
        assert_eq!(
            ProjectionKind::parse("capped_simplex"),
            Some(ProjectionKind::capped_simplex(1.0, 1.0))
        );
        // malformed / invalid parameters rejected
        assert_eq!(ProjectionKind::parse("capped_simplex:1.0"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:0:1"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:1:-2"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:a:b"), None);
    }

    #[test]
    fn capped_simplex_applies_and_is_nonseparable() {
        let k = ProjectionKind::capped_simplex(0.5, 1.0);
        assert!(!k.separable());
        let mut v = vec![2.0, 2.0, 2.0, -1.0];
        k.apply(&mut v);
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        assert!(s <= 1.0 + 1e-4, "sum {s}");
        assert!(v.iter().all(|&x| (-1e-6..=0.5 + 1e-6).contains(&x)));
    }

    #[test]
    fn uniform_map_projects() {
        let m = ProjectionMap::Uniform(ProjectionKind::Box);
        let mut v = vec![-0.5, 0.5, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn per_block_map_dispatches() {
        let m = ProjectionMap::PerBlock(Box::new(|i| {
            if i == 0 { ProjectionKind::Box } else { ProjectionKind::Simplex }
        }));
        let mut v = vec![2.0, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![1.0, 1.0]); // box clamp
        let mut w = vec![2.0, 2.0];
        m.project(1, &mut w);
        let s: f32 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-6); // simplex cap
    }
}
