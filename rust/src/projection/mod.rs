//! Blockwise projection operators onto the "simple constraint" polytopes
//! (paper §3.2 and Table 1's `ProjectionMap` role), organized as the §4
//! operator model: a [`BlockProjection`] trait, a process-wide
//! [`registry`] of composable families, and a compact [`ProjectionKind`]
//! handle over interned operator instances.
//!
//! Every operator projects one source's variable block in place. These CPU
//! implementations back the reference ("Scala-equivalent") objective, the
//! primal rounding/validation path, and the oracles the property tests
//! compare the Pallas kernels against. The registry is the source of
//! truth for all three execution tiers (DESIGN.md §12): the scalar
//! `project`, the batched `project_rows` slab kernels (every builtin
//! family carries a hand-vectorized override), and the `emit_hlo` hook
//! the PJRT runtime falls back to when an AOT artifact
//! (python/compile/kernels/slab.py) is absent for a kind — shared
//! emission lives in [`hlo`].
//!
//! New constraint families are added *locally*: implement the trait,
//! register a parser + conformance samples (one line in
//! `registry::with_builtins`, or `registry::register_family` at runtime
//! from any crate), and every consumer picks the family up through the
//! spec-string surface — see `weighted` and `boxvec` for the template and
//! DESIGN.md "Adding a constraint family" for the recipe covering all
//! three tiers.

mod boxcut;
mod boxp;
mod boxvec;
pub mod hlo;
pub mod registry;
mod simplex;
mod weighted;

use std::fmt;
use std::sync::Arc;

pub use boxcut::{project_box_cut, project_capped_simplex, CappedSimplexOp};
pub use boxp::{project_box, project_unit_box, UnitBoxOp};
pub use boxvec::{box_vec, BoxVecOp};
pub use registry::{BlockProjection, OpId};
pub use simplex::{project_simplex_eq, project_simplex_ineq, SimplexOp};
pub use weighted::{weighted_simplex, WeightedSimplexOp};

/// Handle of one interned projection operator — the open successor of the
/// former closed enum. Stays `Copy + Eq + Ord + Hash` (it keys the bucket
/// map in `sparse::slabs` and the artifact map in `runtime::pjrt`) while
/// arbitrary operator parameters live in the registry's interned table.
///
/// Equality is interning identity: operators with the same canonical spec
/// string share a handle, so `parse(k.spec()) == Some(k)` for every kind.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ProjectionKind(OpId);

impl ProjectionKind {
    /// {x ≥ 0, Σx ≤ 1} — per-source impression capacity (paper Eq. 4–5).
    #[allow(non_upper_case_globals)]
    pub const Simplex: ProjectionKind = ProjectionKind(registry::OPID_SIMPLEX);

    /// [0, 1]^w unit box.
    #[allow(non_upper_case_globals)]
    pub const Box: ProjectionKind = ProjectionKind(registry::OPID_BOX);

    /// Intern an operator instance and return its handle. The registry
    /// deduplicates by canonical spec, so equal parameterizations compare
    /// equal.
    pub fn intern(op: Box<dyn BlockProjection>) -> ProjectionKind {
        ProjectionKind(registry::intern(op))
    }

    /// {0 ≤ x ≤ cap, Σx ≤ total}. Both parameters must be positive finite.
    pub fn capped_simplex(cap: f32, total: f32) -> Self {
        assert!(cap > 0.0 && cap.is_finite(), "cap must be positive finite");
        assert!(total > 0.0 && total.is_finite(), "total must be positive finite");
        Self::intern(Box::new(CappedSimplexOp { cap, total }))
    }

    /// Parse a family name or spec string through the registry. Bare
    /// family names get that family's default parameters.
    pub fn parse(s: &str) -> Option<Self> {
        registry::parse(s).map(ProjectionKind)
    }

    /// The interned operator behind this handle.
    pub fn op(self) -> Arc<dyn BlockProjection> {
        registry::get(self.0)
    }

    /// Raw registry handle.
    pub fn id(self) -> OpId {
        self.0
    }

    /// Family name (parameter-free; see [`ProjectionKind::spec`] for the
    /// round-trippable form).
    pub fn name(self) -> String {
        self.op().family().to_string()
    }

    /// Full round-trippable spec string: `parse(k.spec()) == Some(k)`.
    pub fn spec(self) -> String {
        self.op().spec()
    }

    /// Apply this projection to one block in place.
    pub fn apply(self, v: &mut [f32]) {
        self.op().project(v)
    }

    /// Whether the polytope is separable per coordinate (allows slab rows
    /// to be split when a block exceeds the maximum slab width).
    pub fn separable(self) -> bool {
        self.op().separable()
    }

    /// Maximum constraint violation of `v` (0 when feasible).
    pub fn violation(self, v: &[f32]) -> f64 {
        self.op().violation(v)
    }

    /// Feasibility oracle: violation within `tol`.
    pub fn feasible(self, v: &[f32], tol: f64) -> bool {
        self.op().feasible(v, tol)
    }

    /// (cap, total) when this handle is a `capped_simplex`, None otherwise.
    pub fn capped_params(self) -> Option<(f32, f32)> {
        let op = self.op();
        op.as_any().downcast_ref::<CappedSimplexOp>().map(|c| (c.cap, c.total))
    }
}

impl fmt::Debug for ProjectionKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ProjectionKind({})", self.spec())
    }
}

/// The `ProjectionMap` of paper Table 1: maps a block id to its projection
/// operator. A uniform map is one handle; heterogeneous maps are a shared
/// closure over per-block metadata. `Clone` is shallow (`Arc`), so one
/// `MatchingLp` can fan out across scheduler threads without rebuilding.
#[derive(Clone)]
pub enum ProjectionMap {
    Uniform(ProjectionKind),
    PerBlock(Arc<dyn Fn(usize) -> ProjectionKind + Send + Sync>),
}

impl ProjectionMap {
    /// Heterogeneous map from a block-id closure.
    pub fn per_block<F>(f: F) -> ProjectionMap
    where
        F: Fn(usize) -> ProjectionKind + Send + Sync + 'static,
    {
        ProjectionMap::PerBlock(Arc::new(f))
    }

    pub fn kind_of(&self, block: usize) -> ProjectionKind {
        match self {
            ProjectionMap::Uniform(k) => *k,
            ProjectionMap::PerBlock(f) => f(block),
        }
    }

    /// The single kind of a uniform map, None for per-block maps.
    pub fn uniform_kind(&self) -> Option<ProjectionKind> {
        match self {
            ProjectionMap::Uniform(k) => Some(*k),
            ProjectionMap::PerBlock(_) => None,
        }
    }

    /// `project(block_id, v)` — the single required method (paper Table 1).
    pub fn project(&self, block: usize, v: &mut [f32]) {
        self.kind_of(block).apply(v)
    }
}

impl fmt::Debug for ProjectionMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProjectionMap::Uniform(k) => write!(f, "Uniform({})", k.spec()),
            ProjectionMap::PerBlock(_) => write!(f, "PerBlock(..)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [ProjectionKind::Simplex, ProjectionKind::Box] {
            assert_eq!(ProjectionKind::parse(&k.name()), Some(k));
            assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
        }
        assert_eq!(ProjectionKind::parse("nope"), None);
    }

    #[test]
    fn capped_simplex_spec_roundtrip() {
        for (cap, total) in [(1.0f32, 1.0f32), (0.5, 2.5), (0.3333333, 7.0), (1e-3, 1e3)] {
            let k = ProjectionKind::capped_simplex(cap, total);
            let spec = k.spec();
            assert_eq!(ProjectionKind::parse(&spec), Some(k), "spec {spec}");
            assert_eq!(k.name(), "capped_simplex");
            assert_eq!(k.capped_params(), Some((cap, total)));
        }
        // bare family name gets defaults
        assert_eq!(
            ProjectionKind::parse("capped_simplex"),
            Some(ProjectionKind::capped_simplex(1.0, 1.0))
        );
        // malformed / invalid parameters rejected
        assert_eq!(ProjectionKind::parse("capped_simplex:1.0"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:0:1"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:1:-2"), None);
        assert_eq!(ProjectionKind::parse("capped_simplex:a:b"), None);
    }

    #[test]
    fn capped_simplex_applies_and_is_nonseparable() {
        let k = ProjectionKind::capped_simplex(0.5, 1.0);
        assert!(!k.separable());
        let mut v = vec![2.0, 2.0, 2.0, -1.0];
        k.apply(&mut v);
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        assert!(s <= 1.0 + 1e-4, "sum {s}");
        assert!(v.iter().all(|&x| (-1e-6..=0.5 + 1e-6).contains(&x)));
        assert!(k.feasible(&v, 1e-4));
    }

    #[test]
    fn handles_are_interning_identity() {
        let a = ProjectionKind::capped_simplex(0.5, 1.5);
        let b = ProjectionKind::parse("capped_simplex:0.5:1.5").unwrap();
        assert_eq!(a, b);
        assert_ne!(a, ProjectionKind::capped_simplex(0.5, 1.25));
        // non-capped kinds have no capped params
        assert_eq!(ProjectionKind::Simplex.capped_params(), None);
        assert_eq!(ProjectionKind::Box.capped_params(), None);
    }

    #[test]
    fn violation_oracle_matches_polytopes() {
        assert_eq!(ProjectionKind::Simplex.violation(&[0.5, 0.4]), 0.0);
        assert!((ProjectionKind::Simplex.violation(&[0.9, 0.6]) - 0.5).abs() < 1e-6);
        assert!((ProjectionKind::Box.violation(&[1.25, -0.5]) - 0.5).abs() < 1e-6);
        let k = ProjectionKind::capped_simplex(0.5, 0.8);
        assert_eq!(k.violation(&[0.4, 0.4]), 0.0);
        assert!((k.violation(&[0.7, 0.0]) - 0.2).abs() < 1e-6);
    }

    #[test]
    fn debug_prints_spec() {
        let k = ProjectionKind::capped_simplex(0.5, 1.0);
        assert_eq!(format!("{k:?}"), "ProjectionKind(capped_simplex:0.5:1)");
    }

    #[test]
    fn uniform_map_projects() {
        let m = ProjectionMap::Uniform(ProjectionKind::Box);
        assert_eq!(m.uniform_kind(), Some(ProjectionKind::Box));
        let mut v = vec![-0.5, 0.5, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn per_block_map_dispatches_and_clones_shallowly() {
        let m = ProjectionMap::per_block(|i| {
            if i == 0 {
                ProjectionKind::Box
            } else {
                ProjectionKind::Simplex
            }
        });
        assert_eq!(m.uniform_kind(), None);
        let m2 = m.clone();
        let mut v = vec![2.0, 2.0];
        m.project(0, &mut v);
        assert_eq!(v, vec![1.0, 1.0]); // box clamp
        let mut w = vec![2.0, 2.0];
        m2.project(1, &mut w);
        let s: f32 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-6); // simplex cap
    }
}
