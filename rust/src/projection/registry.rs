//! Process-wide registry of blockwise projection operators — the paper's
//! §4 operator-centric extensibility surface.
//!
//! A *family* (e.g. `simplex`, `capped_simplex`, `weighted_simplex`) is a
//! named parser from spec strings to operator instances; an *operator* is
//! one parameterization of a family implementing [`BlockProjection`].
//! Interning gives every operator — including arbitrary-parameter ones
//! like per-coordinate bound vectors — a compact `Copy + Eq + Ord + Hash`
//! [`OpId`] handle, so the slab bucket map (`sparse::slabs`) and the PJRT
//! artifact cache (`runtime::pjrt`) keep keying by value while the
//! parameter payload lives here. Interned entries are deduplicated by
//! their canonical spec string and retained for the process lifetime (the
//! table only grows; it is the identity space for cache keys). Operator
//! parameters are therefore *identity*, not data: keep drifting numeric
//! planes (costs, budgets, rhs) in `c`/`b`/global-row rhs — a
//! parameterization that changes every re-solve would intern one
//! permanent entry per cycle in a long-running engine process.
//!
//! Adding a constraint family is local to `projection/`: implement the
//! trait, register the family with a parser and conformance samples, and
//! every consumer — CPU objective, slab bucketing, primal validation, the
//! `LpSpec` builder, the CLI `--projection` flag, and the generic
//! conformance proptests — picks it up with zero further edits (DESIGN.md
//! "Adding a constraint family"). The registry is also the source of
//! truth for the *accelerated* tiers: `project_rows` is the batched slab
//! entry point and `emit_hlo` the PJRT kernel emission, and the
//! cross-backend conformance matrix (`tests/kernel_matrix.rs`) holds
//! every registered family to the same bit-consistency bar across all of
//! them (DESIGN.md §12).

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use super::boxcut::CappedSimplexOp;
use super::boxp::UnitBoxOp;
use super::boxvec::BoxVecOp;
use super::simplex::SimplexOp;
use super::weighted::WeightedSimplexOp;

/// One blockwise projection operator Π onto a simple-constraint polytope
/// C (paper Table 1's `ProjectionMap` role, opened into a trait).
///
/// Implementations must be pure (no interior mutability observable through
/// `project`) and deterministic: the engine layer relies on bit-identical
/// re-execution, and `spec` round-tripping is the interning identity.
pub trait BlockProjection: Send + Sync + 'static {
    /// Registry family name, e.g. `"capped_simplex"`. Must equal the name
    /// the family was registered under.
    fn family(&self) -> &str;

    /// Canonical round-trippable spec string: `parse(op.spec())` must
    /// resolve to this exact operator (f32 `Display` is shortest-exact in
    /// Rust, so numeric parameters round-trip losslessly).
    fn spec(&self) -> String;

    /// Project one variable block onto C in place (Euclidean projection).
    fn project(&self, v: &mut [f32]);

    /// Batched slab entry point: project `rows` rows of `width` stored
    /// contiguously row-major in `slab`, honoring the validity `mask`
    /// (1 real, 0 padding; padding is always a contiguous per-row tail,
    /// as `sparse::slabs` builds it). This is the CPU mirror of the L1
    /// Pallas slab kernels: one call per bucket instead of one `project`
    /// per source. The default loops the scalar `project` over each
    /// row's real prefix (so every registered family is slab-correct
    /// with zero edits — positional parameters keep their coordinate
    /// indices because real entries occupy the row head) and zeroes the
    /// padding tail; layout-aware operators override with width-strided
    /// sweeps over the full slab.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        debug_assert_eq!(mask.len(), rows * width);
        for r in 0..rows {
            let base = r * width;
            let real = mask[base..base + width].iter().take_while(|&&m| m > 0.0).count();
            self.project(&mut slab[base..base + real]);
            slab[base + real..base + width].fill(0.0);
        }
    }

    /// Whether [`BlockProjection::project_rows`] is a hand-vectorized
    /// batched override rather than the scalar-loop default. Informational
    /// only — the slab backend records per-bucket which tier ran so a
    /// family quietly falling back to the scalar path shows up in
    /// `engine_report`/`shard_report` instead of just running slow
    /// (DESIGN.md §12). An override MUST flip this to `true`.
    fn batched_project_rows(&self) -> bool {
        false
    }

    /// Emit the HLO slab-kernel text for a `rows`×`width` tile, or `None`
    /// when the family has no accelerated emission. The module must follow
    /// the slab contract (DESIGN.md §12): parameters `u`/`c`/`mask` of
    /// shape `f32[rows,width]` plus `g: f32[1]`, root tuple
    /// `(x, cx, xsq)` with `v = -(u + c) / g * mask`, `x = Π_C(v) * mask`.
    /// The PJRT runtime resolves kernels manifest-first and falls back to
    /// this hook, so a family that emits is accelerated on every tier
    /// without touching `runtime/`. Builtins delegate to
    /// `projection::hlo::emit_slab_module`; text must be deterministic —
    /// golden snapshots under `tests/snapshots/` pin it byte for byte.
    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        let _ = (rows, width);
        None
    }

    /// Maximum constraint violation of `v` (0 when feasible) — the oracle
    /// behind primal validation and the conformance proptests.
    fn violation(&self, v: &[f32]) -> f64;

    /// Whether the polytope factors per coordinate, allowing slab rows to
    /// be split when a block exceeds the maximum slab width. Operators
    /// with positional parameters should stay non-separable even when the
    /// math factors, because chunk splitting re-indexes coordinates.
    fn separable(&self) -> bool {
        false
    }

    /// Feasibility oracle: violation within `tol`.
    fn feasible(&self, v: &[f32], tol: f64) -> bool {
        self.violation(v) <= tol
    }

    /// Downcast support (e.g. `ProjectionKind::capped_params`).
    fn as_any(&self) -> &dyn Any;
}

/// Compact handle of an interned operator. `Copy + Eq + Ord + Hash` so the
/// types wrapping it can keep keying bucket/artifact maps by value. Ids
/// are assigned in interning order and are only meaningful within the
/// process — cross-process identity is the spec string.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpId(u32);

impl OpId {
    /// Position in the interned-operator table.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Reserved ids for the two slab-kernel builtins; `ProjectionKind::Simplex`
/// and `ProjectionKind::Box` are compile-time constants over these.
pub(crate) const OPID_SIMPLEX: OpId = OpId(0);
pub(crate) const OPID_BOX: OpId = OpId(1);

type Parser = Arc<dyn Fn(&str) -> Option<Box<dyn BlockProjection>> + Send + Sync>;

struct Family {
    parser: Parser,
    samples: Vec<String>,
}

struct Registry {
    families: BTreeMap<String, Family>,
    ops: Vec<Arc<dyn BlockProjection>>,
    // BTreeMap, not HashMap: interned ids are assigned in call order, but
    // any future iteration over this map (spec dumps, manifest exports)
    // must already be order-stable — D1 in the audit pass keeps it that way.
    by_spec: BTreeMap<String, OpId>,
}

impl Registry {
    fn with_builtins() -> Registry {
        let mut r = Registry {
            families: BTreeMap::new(),
            ops: Vec::new(),
            by_spec: BTreeMap::new(),
        };
        // Builtins claim the reserved ids (interning order fixes them).
        let simplex: Box<dyn BlockProjection> = Box::new(SimplexOp);
        let id = r.intern_op(simplex.spec(), simplex);
        assert_eq!(id, OPID_SIMPLEX);
        let unit_box: Box<dyn BlockProjection> = Box::new(UnitBoxOp);
        let id = r.intern_op(unit_box.spec(), unit_box);
        assert_eq!(id, OPID_BOX);
        r.add_family("simplex", &["simplex"], |args: &str| {
            args.is_empty().then(|| Box::new(SimplexOp) as Box<dyn BlockProjection>)
        });
        r.add_family("box", &["box"], |args: &str| {
            args.is_empty().then(|| Box::new(UnitBoxOp) as Box<dyn BlockProjection>)
        });
        r.add_family("capped_simplex", CappedSimplexOp::SAMPLES, CappedSimplexOp::parse_args);
        r.add_family("weighted_simplex", WeightedSimplexOp::SAMPLES, WeightedSimplexOp::parse_args);
        r.add_family("box_vec", BoxVecOp::SAMPLES, BoxVecOp::parse_args);
        r
    }

    fn intern_op(&mut self, spec: String, op: Box<dyn BlockProjection>) -> OpId {
        if let Some(&id) = self.by_spec.get(&spec) {
            return id;
        }
        let id = OpId(u32::try_from(self.ops.len()).expect("operator table overflow"));
        self.ops.push(Arc::from(op));
        self.by_spec.insert(spec, id);
        id
    }

    fn add_family<F>(&mut self, name: &str, samples: &[&str], parser: F) -> bool
    where
        F: Fn(&str) -> Option<Box<dyn BlockProjection>> + Send + Sync + 'static,
    {
        let entry = Family {
            parser: Arc::new(parser),
            samples: samples.iter().map(|s| s.to_string()).collect(),
        };
        self.families.insert(name.to_string(), entry).is_none()
    }
}

static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();

fn global() -> &'static RwLock<Registry> {
    REGISTRY.get_or_init(|| RwLock::new(Registry::with_builtins()))
}

/// Intern an operator instance, returning its handle. Deduplicates by the
/// canonical spec string, so equal parameterizations share one id.
pub fn intern(op: Box<dyn BlockProjection>) -> OpId {
    // Render the canonical spec BEFORE taking the write lock: a composed
    // operator's `spec()` may consult the registry (e.g. an inner kind's
    // spec), and the lock is not reentrant.
    let spec = op.spec();
    global().write().unwrap().intern_op(spec, op)
}

/// Resolve a handle to its operator (panics on a foreign `OpId`, which
/// cannot be constructed through the public API).
pub fn get(id: OpId) -> Arc<dyn BlockProjection> {
    global().read().unwrap().ops[id.index()].clone()
}

/// Parse `family` or `family:args` into an interned operator. Unknown
/// families, malformed arguments, and parsers answering for a different
/// family all return `None`.
pub fn parse(spec: &str) -> Option<OpId> {
    {
        // fast path: canonical specs of already-interned operators
        let r = global().read().unwrap();
        if let Some(&id) = r.by_spec.get(spec) {
            return Some(id);
        }
    }
    let (family, args) = match spec.split_once(':') {
        Some((f, a)) => (f, a),
        None => (spec, ""),
    };
    // clone the parser out so user parsers never run under the lock
    let parser = global().read().unwrap().families.get(family)?.parser.clone();
    let op = parser(args)?;
    if op.family() != family {
        return None;
    }
    Some(intern(op))
}

/// Register a constraint family. `samples` are spec strings exercising
/// representative parameterizations — the generic conformance proptests
/// run every registered family through them, so new operators get
/// idempotence/feasibility/optimality coverage for free. Returns whether
/// the name was new (an existing family is replaced either way; interned
/// operators are unaffected).
pub fn register_family<F>(name: &str, samples: &[&str], parser: F) -> bool
where
    F: Fn(&str) -> Option<Box<dyn BlockProjection>> + Send + Sync + 'static,
{
    global().write().unwrap().add_family(name, samples, parser)
}

/// Names of all registered families, sorted.
pub fn families() -> Vec<String> {
    global().read().unwrap().families.keys().cloned().collect()
}

/// Conformance sample specs of one family (empty for unknown names).
pub fn family_samples(name: &str) -> Vec<String> {
    let r = global().read().unwrap();
    r.families.get(name).map(|f| f.samples.clone()).unwrap_or_default()
}

/// Current size of the interned-operator table (diagnostics).
pub fn num_interned() -> usize {
    global().read().unwrap().ops.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_hold_reserved_ids() {
        assert_eq!(parse("simplex"), Some(OPID_SIMPLEX));
        assert_eq!(parse("box"), Some(OPID_BOX));
        assert_eq!(get(OPID_SIMPLEX).spec(), "simplex");
        assert_eq!(get(OPID_BOX).spec(), "box");
    }

    #[test]
    fn builtin_families_reject_arguments() {
        assert_eq!(parse("simplex:1"), None);
        assert_eq!(parse("box:0.5"), None);
        assert_eq!(parse("no_such_family"), None);
        assert_eq!(parse("no_such_family:1:2"), None);
    }

    #[test]
    fn interning_dedups_by_canonical_spec() {
        let a = parse("capped_simplex:0.5:2").unwrap();
        let b = parse("capped_simplex:0.50:2.0").unwrap(); // non-canonical
        let c = parse("capped_simplex:0.5:3").unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(get(a).spec(), "capped_simplex:0.5:2");
    }

    #[test]
    fn every_family_sample_parses_and_roundtrips() {
        for fam in families() {
            let samples = family_samples(&fam);
            assert!(!samples.is_empty(), "family {fam} has no samples");
            for s in samples {
                let id = parse(&s).unwrap_or_else(|| panic!("sample {s} must parse"));
                let op = get(id);
                assert_eq!(op.family(), fam, "sample {s}");
                assert_eq!(parse(&op.spec()), Some(id), "spec of {s} must round-trip");
            }
        }
    }

    #[test]
    fn runtime_family_registration_is_picked_up() {
        // a toy half-line family {x ≥ 0}: the extension path user crates take
        struct HalfLine;
        impl BlockProjection for HalfLine {
            fn family(&self) -> &str {
                "halfline_test"
            }
            fn spec(&self) -> String {
                "halfline_test".to_string()
            }
            fn project(&self, v: &mut [f32]) {
                for x in v.iter_mut() {
                    *x = x.max(0.0);
                }
            }
            fn violation(&self, v: &[f32]) -> f64 {
                v.iter().map(|&x| (-x).max(0.0) as f64).fold(0.0, f64::max)
            }
            fn separable(&self) -> bool {
                true
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        register_family("halfline_test", &["halfline_test"], |args: &str| {
            args.is_empty().then(|| Box::new(HalfLine) as Box<dyn BlockProjection>)
        });
        let id = parse("halfline_test").expect("registered family parses");
        let mut v = vec![-1.0, 2.0];
        get(id).project(&mut v);
        assert_eq!(v, vec![0.0, 2.0]);
        assert!(get(id).feasible(&v, 1e-9));
        assert!(families().contains(&"halfline_test".to_string()));
    }

    #[test]
    fn default_project_rows_matches_scalar_on_real_prefixes() {
        // Every registered family's samples: the default batched entry
        // point must agree with the scalar `project` on each row's real
        // prefix and leave the padding tail exactly zero.
        for fam in families() {
            for sample in family_samples(&fam) {
                let op = get(parse(&sample).unwrap());
                let width = 8usize;
                let reals = [3usize, 8, 1, 5];
                let mut slab = vec![0.0f32; reals.len() * width];
                let mut mask = vec![0.0f32; reals.len() * width];
                for (r, &real) in reals.iter().enumerate() {
                    for c in 0..real {
                        slab[r * width + c] = (r as f32 + 1.0) * 0.7 - c as f32 * 0.9;
                        mask[r * width + c] = 1.0;
                    }
                }
                let mut expect = slab.clone();
                op.project_rows(&mut slab, reals.len(), width, &mask);
                for (r, &real) in reals.iter().enumerate() {
                    let base = r * width;
                    op.project(&mut expect[base..base + real]);
                    for c in 0..width {
                        if c < real {
                            assert_eq!(
                                slab[base + c].to_bits(),
                                expect[base + c].to_bits(),
                                "{sample} row {r} col {c}"
                            );
                        } else {
                            assert_eq!(slab[base + c], 0.0, "{sample} padding row {r} col {c}");
                        }
                    }
                }
            }
        }
    }
}
