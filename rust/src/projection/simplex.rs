//! Projection onto the simplex polytopes.
//!
//! `project_simplex_ineq`: Π onto {x ≥ 0, Σx ≤ 1} — if the nonnegative
//! clamp already satisfies the capacity the clamp is the projection,
//! otherwise project onto the equality simplex.
//!
//! `project_simplex_eq`: Π onto {x ≥ 0, Σx = r} via the sort-threshold
//! method (Held/Wolfe/Crowder; Michelot): with v sorted descending, find
//! ρ = max{k : v_(k) > (Σ_{l≤k} v_(l) − r)/k}, θ = (Σ_{l≤ρ} v_(l) − r)/ρ,
//! x = max(v − θ, 0). O(n log n).

use std::any::Any;

use super::hlo::{emit_for, HloProjection};
use super::registry::BlockProjection;

/// Registry operator for {x ≥ 0, Σx ≤ 1} (paper Eq. 4–5).
pub struct SimplexOp;

impl BlockProjection for SimplexOp {
    fn family(&self) -> &str {
        "simplex"
    }

    fn spec(&self) -> String {
        "simplex".to_string()
    }

    fn project(&self, v: &mut [f32]) {
        project_simplex_ineq(v)
    }

    /// Width-strided batched projection (the CPU mirror of the L1 simplex
    /// slab kernel). Padding entries are zero on input and a zero tail is
    /// transparent to this polytope: when the cap binds, θ > 0 and zeros
    /// never enter the support, so the sort-threshold over the padded row
    /// computes the exact same θ as over the real prefix. One sort scratch
    /// is reused across all rows, replacing the per-block `Vec` the scalar
    /// path allocates inside `project_simplex_eq`.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        let mut sorted: Vec<f32> = Vec::with_capacity(width);
        for r in 0..rows {
            let row = &mut slab[r * width..(r + 1) * width];
            let mrow = &mask[r * width..(r + 1) * width];
            let real = mrow.iter().take_while(|&&m| m > 0.0).count();
            let mut s = 0.0f64;
            for x in row.iter_mut() {
                if *x < 0.0 {
                    *x = 0.0;
                }
                s += *x as f64;
            }
            if s <= 1.0 {
                // the clamp is the projection; pin the tail to +0.0 like
                // the scalar default (gathered padding can carry -0.0)
                row[real..].fill(0.0);
                continue;
            }
            if real == 1 {
                // mirror `project_simplex_eq`'s single-coordinate case
                row[0] = 1.0;
                row[1..].fill(0.0);
                continue;
            }
            sorted.clear();
            sorted.extend_from_slice(row);
            sorted.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            let mut cumsum = 0.0f64;
            let mut theta = 0.0f64;
            for (k, &val) in sorted.iter().enumerate() {
                cumsum += val as f64;
                let t = (cumsum - 1.0) / (k + 1) as f64;
                if (val as f64) > t {
                    theta = t;
                }
            }
            for x in row[..real].iter_mut() {
                *x = (*x as f64 - theta).max(0.0) as f32;
            }
            // padding stays exactly zero even on borderline rows where θ
            // rounds to ≤ 0
            row[real..].fill(0.0);
        }
    }

    fn batched_project_rows(&self) -> bool {
        true
    }

    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        emit_for(self.family(), &HloProjection::Simplex { total: 1.0 }, rows, width)
    }

    fn violation(&self, v: &[f32]) -> f64 {
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        let neg = v.iter().map(|&x| (-x).max(0.0) as f64).fold(0.0, f64::max);
        (s - 1.0).max(0.0).max(neg)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// In-place projection onto {x ≥ 0, Σ x = r}.
pub fn project_simplex_eq(v: &mut [f32], r: f32) {
    debug_assert!(r >= 0.0);
    let n = v.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        v[0] = r;
        return;
    }
    let mut sorted: Vec<f32> = v.to_vec();
    sorted.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cumsum = 0.0f64;
    let mut theta = 0.0f64;
    let mut rho = 0usize;
    for (k, &val) in sorted.iter().enumerate() {
        cumsum += val as f64;
        let t = (cumsum - r as f64) / (k + 1) as f64;
        if (val as f64) > t {
            theta = t;
            rho = k + 1;
        }
    }
    debug_assert!(rho >= 1);
    for x in v.iter_mut() {
        *x = (*x as f64 - theta).max(0.0) as f32;
    }
}

/// In-place projection onto {x ≥ 0, Σ x ≤ 1} (paper Eq. 4–5).
pub fn project_simplex_ineq(v: &mut [f32]) {
    let mut s = 0.0f64;
    for x in v.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
        s += *x as f64;
    }
    if s <= 1.0 {
        return; // clamp is already the projection
    }
    project_simplex_eq(v, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum(v: &[f32]) -> f64 {
        v.iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn inside_point_unchanged() {
        let mut v = vec![0.2, 0.3, 0.1];
        let orig = v.clone();
        project_simplex_ineq(&mut v);
        assert_eq!(v, orig);
    }

    #[test]
    fn negative_clamped_when_feasible() {
        let mut v = vec![-0.5, 0.3, 0.2];
        project_simplex_ineq(&mut v);
        assert_eq!(v, vec![0.0, 0.3, 0.2]);
    }

    #[test]
    fn oversum_projects_to_boundary() {
        let mut v = vec![1.0, 1.0];
        project_simplex_ineq(&mut v);
        assert!((sum(&v) - 1.0).abs() < 1e-6);
        assert!((v[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn eq_projection_known_case() {
        // Π_{Σ=1}([0.5, 0.5, 1.5]) : θ = (2.5-1)/3 = 0.5 → [0,0,1]
        let mut v = vec![0.5, 0.5, 1.5];
        project_simplex_eq(&mut v, 1.0);
        assert!((v[0] - 0.0).abs() < 1e-6);
        assert!((v[1] - 0.0).abs() < 1e-6);
        assert!((v[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn eq_projection_radius_r() {
        let mut v = vec![3.0, 1.0];
        project_simplex_eq(&mut v, 2.0);
        assert!((sum(&v) - 2.0).abs() < 1e-6);
        assert!((v[0] - 2.0).abs() < 1e-6);
        assert!((v[1] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn single_element() {
        let mut v = vec![5.0];
        project_simplex_ineq(&mut v);
        assert_eq!(v, vec![1.0]);
        let mut w = vec![-3.0];
        project_simplex_ineq(&mut w);
        assert_eq!(w, vec![0.0]);
    }

    #[test]
    fn empty_block_noop() {
        let mut v: Vec<f32> = vec![];
        project_simplex_ineq(&mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn projection_is_idempotent() {
        let mut v = vec![2.0, -1.0, 0.7, 0.4];
        project_simplex_ineq(&mut v);
        let once = v.clone();
        project_simplex_ineq(&mut v);
        for (a, b) in v.iter().zip(&once) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn project_rows_matches_scalar_rowwise_including_padding() {
        use crate::projection::BlockProjection;
        let op = SimplexOp;
        let mut rng = crate::util::rng::Rng::new(19);
        for _ in 0..50 {
            let width = 1 << (2 + rng.below(4)); // 4..32
            let rows = 1 + rng.below(6);
            let mut slab = vec![0.0f32; rows * width];
            let mut mask = vec![0.0f32; rows * width];
            let mut reals = Vec::new();
            for r in 0..rows {
                let real = 1 + rng.below(width);
                reals.push(real);
                for c in 0..real {
                    slab[r * width + c] = (rng.normal() * 2.0) as f32;
                    mask[r * width + c] = 1.0;
                }
            }
            let mut expect = slab.clone();
            op.project_rows(&mut slab, rows, width, &mask);
            for (r, &real) in reals.iter().enumerate() {
                let base = r * width;
                project_simplex_ineq(&mut expect[base..base + real]);
                for c in 0..real {
                    assert_eq!(
                        slab[base + c].to_bits(),
                        expect[base + c].to_bits(),
                        "row {r} col {c}: {} vs {}",
                        slab[base + c],
                        expect[base + c]
                    );
                }
                for c in real..width {
                    assert_eq!(slab[base + c], 0.0, "padding row {r} col {c}");
                }
            }
        }
    }

    #[test]
    fn optimality_vs_random_feasible_points() {
        // Π(v) minimizes ‖x−v‖ over the polytope: check against probes.
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..50 {
            let n = 2 + rng.below(8);
            let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
            let mut p = v.clone();
            project_simplex_ineq(&mut p);
            let d_star: f64 = v
                .iter()
                .zip(&p)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            for _ in 0..50 {
                // random feasible y
                let mut y: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
                let s: f64 = y.iter().sum();
                if s > 1.0 {
                    y.iter_mut().for_each(|x| *x /= s);
                }
                let d: f64 = v
                    .iter()
                    .zip(&y)
                    .map(|(a, b)| (*a as f64 - b).powi(2))
                    .sum();
                assert!(d_star <= d + 1e-6, "probe beat projection");
            }
        }
    }
}
