//! Weighted-simplex projection: Π onto {x ≥ 0, Σ wᵢxᵢ ≤ s} — per-edge
//! resource weights under one block capacity (e.g. impression slots that
//! consume different inventory amounts).
//!
//! Registered as the `weighted_simplex` family purely inside
//! `projection/` — no solver, sparse-layout, or runtime edits — which is
//! the paper's §4 locality claim for new formulations. Solved by
//! bisection on the cut multiplier μ: x(μ) = max(v − μw, 0) makes
//! wᵀx(μ) monotone nonincreasing, so the binding μ* is found to
//! tolerance in 64 halvings, mirroring `boxcut`.
//!
//! The weight vector cycles over block coordinates (`w[i % len]`), so one
//! operator serves blocks of any width: a single weight is a uniform
//! weighting, a pair alternates, a full-width vector is per-edge.
//! Kernelized on every tier: a batched `project_rows` override with a
//! hoisted per-column weight table on the slab backends, and a bisection
//! HLO emission for the PJRT path (DESIGN.md §12).

use std::any::Any;

use super::hlo::{emit_for, HloProjection};
use super::registry::BlockProjection;
use super::ProjectionKind;

/// Registry operator for {x ≥ 0, Σ wᵢxᵢ ≤ total}.
pub struct WeightedSimplexOp {
    pub total: f32,
    pub weights: Vec<f32>,
}

/// Intern {x ≥ 0, Σ wᵢxᵢ ≤ total} with cycling weights.
pub fn weighted_simplex(total: f32, weights: &[f32]) -> ProjectionKind {
    assert!(
        total > 0.0 && total.is_finite(),
        "total must be positive finite"
    );
    assert!(
        !weights.is_empty() && weights.iter().all(|&w| w > 0.0 && w.is_finite()),
        "weights must be a nonempty positive finite vector"
    );
    ProjectionKind::intern(Box::new(WeightedSimplexOp {
        total,
        weights: weights.to_vec(),
    }))
}

impl WeightedSimplexOp {
    pub(crate) const SAMPLES: &'static [&'static str] = &[
        "weighted_simplex:1:1",
        "weighted_simplex:2:1,2",
        "weighted_simplex:0.8:0.5,1.5,1",
    ];

    /// Family parser: bare args default to (total=1, w=[1]) ≡ the plain
    /// simplex polytope; `<total>` sets the capacity with unit weights;
    /// `<total>:<w1>,<w2>,…` sets explicit cycling weights.
    pub(crate) fn parse_args(args: &str) -> Option<Box<dyn BlockProjection>> {
        if args.is_empty() {
            return Some(Box::new(WeightedSimplexOp {
                total: 1.0,
                weights: vec![1.0],
            }));
        }
        let (total_s, weights_s) = match args.split_once(':') {
            Some((t, w)) => (t, Some(w)),
            None => (args, None),
        };
        let total: f32 = total_s.parse().ok()?;
        let weights: Vec<f32> = match weights_s {
            None => vec![1.0],
            Some(w) => w
                .split(',')
                .map(|s| s.parse().ok())
                .collect::<Option<Vec<f32>>>()?,
        };
        let ok = total > 0.0
            && total.is_finite()
            && !weights.is_empty()
            && weights.iter().all(|&w| w > 0.0 && w.is_finite());
        ok.then(|| Box::new(WeightedSimplexOp { total, weights }) as Box<dyn BlockProjection>)
    }

    #[inline]
    fn weight(&self, i: usize) -> f64 {
        self.weights[i % self.weights.len()] as f64
    }
}

impl BlockProjection for WeightedSimplexOp {
    fn family(&self) -> &str {
        "weighted_simplex"
    }

    fn spec(&self) -> String {
        let ws: Vec<String> = self.weights.iter().map(|w| w.to_string()).collect();
        format!("weighted_simplex:{}:{}", self.total, ws.join(","))
    }

    fn project(&self, v: &mut [f32]) {
        // Clamping negatives first is exact: for v_i ≤ 0 the KKT solution
        // x_i = max(v_i − μwᵢ, 0) is 0 at any μ ≥ 0, same as for the
        // clamped coordinate (the `simplex` operator uses the same step).
        let mut wsum = 0.0f64;
        for (i, x) in v.iter_mut().enumerate() {
            if *x < 0.0 {
                *x = 0.0;
            }
            wsum += self.weight(i) * *x as f64;
        }
        let total = self.total as f64;
        if wsum <= total {
            return;
        }
        // Bisection on μ (KKT multiplier of the cut): wᵀx(μ) is monotone
        // nonincreasing, wᵀx(0) > total, and x(μ_hi) = 0.
        let mut hi = 0.0f64;
        for (i, &x) in v.iter().enumerate() {
            if x > 0.0 {
                hi = hi.max(x as f64 / self.weight(i));
            }
        }
        let mut lo = 0.0f64;
        for _ in 0..64 {
            let mu = 0.5 * (lo + hi);
            let s: f64 = v
                .iter()
                .enumerate()
                .map(|(i, &x)| {
                    let w = self.weight(i);
                    w * ((x as f64) - mu * w).max(0.0)
                })
                .sum();
            if s > total {
                lo = mu;
            } else {
                hi = mu;
            }
        }
        let mu = 0.5 * (lo + hi);
        for (i, x) in v.iter_mut().enumerate() {
            *x = ((*x as f64) - mu * self.weight(i)).max(0.0) as f32;
        }
    }

    /// Width-strided batched bisection. The scalar path re-derives
    /// `weights[i % len]` with a modulo per element inside every one of
    /// the 64 bisection sweeps; weights are positional with period
    /// `weights.len()`, so a per-sweep cycled iterator reproduces the
    /// same column weights modulo-free without a per-call table — this
    /// override runs inside the solver's hot loop and must not allocate
    /// (the f32→f64 convert stays per element; it is a single
    /// instruction). Bit-identical to looping the scalar `project` over
    /// real prefixes: real entries occupy the row head, so column
    /// weights line up with scalar indices, gathered padding is exactly
    /// ±0.0 and contributes exact zeros to every f64 accumulation (μ > 0
    /// in the binding branch), and a final tail fill pins padding to
    /// +0.0.
    fn project_rows(&self, slab: &mut [f32], rows: usize, width: usize, mask: &[f32]) {
        debug_assert_eq!(slab.len(), rows * width);
        debug_assert_eq!(mask.len(), rows * width);
        let total = self.total as f64;
        let w_cycle = || self.weights.iter().cycle().map(|&w| w as f64);
        for r in 0..rows {
            let row = &mut slab[r * width..(r + 1) * width];
            let real =
                mask[r * width..(r + 1) * width].iter().take_while(|&&m| m > 0.0).count();
            let mut wsum = 0.0f64;
            for (x, w) in row.iter_mut().zip(w_cycle()) {
                if *x < 0.0 {
                    *x = 0.0;
                }
                wsum += w * *x as f64;
            }
            if wsum > total {
                let mut hi = 0.0f64;
                for (&x, w) in row.iter().zip(w_cycle()) {
                    if x > 0.0 {
                        hi = hi.max(x as f64 / w);
                    }
                }
                let mut lo = 0.0f64;
                for _ in 0..64 {
                    let mu = 0.5 * (lo + hi);
                    let mut s = 0.0f64;
                    for (&x, w) in row.iter().zip(w_cycle()) {
                        s += w * ((x as f64) - mu * w).max(0.0);
                    }
                    if s > total {
                        lo = mu;
                    } else {
                        hi = mu;
                    }
                }
                let mu = 0.5 * (lo + hi);
                for (x, w) in row.iter_mut().zip(w_cycle()) {
                    *x = ((*x as f64) - mu * w).max(0.0) as f32;
                }
            }
            row[real..].fill(0.0);
        }
    }

    fn batched_project_rows(&self) -> bool {
        true
    }

    fn emit_hlo(&self, rows: usize, width: usize) -> Option<String> {
        emit_for(
            self.family(),
            &HloProjection::Weighted { total: self.total, weights: &self.weights },
            rows,
            width,
        )
    }

    fn violation(&self, v: &[f32]) -> f64 {
        let neg = v.iter().map(|&x| (-x).max(0.0) as f64).fold(0.0, f64::max);
        let wsum: f64 = v
            .iter()
            .enumerate()
            .map(|(i, &x)| self.weight(i) * x as f64)
            .sum();
        (wsum - self.total as f64).max(0.0).max(neg)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn project(total: f32, weights: &[f32], v: &[f32]) -> Vec<f32> {
        let mut p = v.to_vec();
        WeightedSimplexOp {
            total,
            weights: weights.to_vec(),
        }
        .project(&mut p);
        p
    }

    #[test]
    fn unit_weights_match_simplex() {
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..100 {
            let n = 1 + rng.below(10);
            let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
            let a = project(1.0, &[1.0], &v);
            let mut b = v.clone();
            crate::projection::project_simplex_ineq(&mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn binding_cut_respects_weights() {
        // w = (1, 3), total = 1, v = (1, 1): heavier coordinate is pushed
        // down harder (x = v − μw), and the cut holds with equality.
        let p = project(1.0, &[1.0, 3.0], &[1.0, 1.0]);
        let wsum = p[0] as f64 + 3.0 * p[1] as f64;
        assert!((wsum - 1.0).abs() < 1e-4, "wᵀx = {wsum}");
        assert!(p[0] > p[1], "{p:?}");
    }

    #[test]
    fn weights_cycle_across_wide_blocks() {
        // 4 coordinates, 2 weights → effective w = (1, 2, 1, 2)
        let v = [5.0f32; 4];
        let a = project(2.0, &[1.0, 2.0], &v);
        let b = project(2.0, &[1.0, 2.0, 1.0, 2.0], &v);
        assert_eq!(a, b);
    }

    #[test]
    fn interior_point_only_clamped() {
        let p = project(10.0, &[1.0, 2.0], &[0.5, -1.0, 0.25]);
        assert_eq!(p, vec![0.5, 0.0, 0.25]);
    }

    #[test]
    fn optimality_vs_random_feasible_probes() {
        let mut rng = crate::util::rng::Rng::new(42);
        for case in 0..50 {
            let n = 2 + rng.below(6);
            let total = (rng.uniform() * 2.0 + 0.1) as f32;
            let weights: Vec<f32> = (0..1 + rng.below(3))
                .map(|_| (rng.uniform() * 2.0 + 0.1) as f32)
                .collect();
            let op = WeightedSimplexOp {
                total,
                weights: weights.clone(),
            };
            let v: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
            let mut p = v.clone();
            op.project(&mut p);
            assert!(op.feasible(&p, 1e-3), "violation {}", op.violation(&p));
            let d_star: f64 = v.iter().zip(&p).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            for _ in 0..30 {
                // random feasible probe: scale a positive draw under the cut
                let mut y: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
                let wsum: f64 = y
                    .iter()
                    .enumerate()
                    .map(|(i, yi)| op.weight(i) * yi)
                    .sum();
                if wsum > total as f64 {
                    let s = total as f64 / wsum;
                    y.iter_mut().for_each(|x| *x *= s);
                }
                let d: f64 = v.iter().zip(&y).map(|(a, b)| (*a as f64 - b).powi(2)).sum();
                assert!(d_star <= d + 1e-4, "case {case}: probe beat projection");
            }
        }
    }

    #[test]
    fn spec_roundtrip_and_constructor() {
        let k = weighted_simplex(2.0, &[1.0, 2.0]);
        assert_eq!(k.spec(), "weighted_simplex:2:1,2");
        assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
        assert_eq!(k.name(), "weighted_simplex");
        assert!(!k.separable());
        // bare and total-only forms
        assert!(ProjectionKind::parse("weighted_simplex").is_some());
        assert!(ProjectionKind::parse("weighted_simplex:3").is_some());
        // malformed / invalid parameters rejected
        assert_eq!(ProjectionKind::parse("weighted_simplex:0:1"), None);
        assert_eq!(ProjectionKind::parse("weighted_simplex:1:-1"), None);
        assert_eq!(ProjectionKind::parse("weighted_simplex:1:"), None);
        assert_eq!(ProjectionKind::parse("weighted_simplex:a:b"), None);
    }
}
