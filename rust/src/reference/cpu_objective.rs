//! The "Scala DuaLip"-equivalent CPU baseline (paper §7's comparator).
//!
//! Faithful to the prior system's *semantics and layout*, not its JVM:
//! the Scala stack stored each source's data as a sequence of tuples
//! (destination, coefficient, cost) behind an object per source — we mirror
//! that with a per-source `Vec` of tuple structs (one heap allocation per
//! source, array-of-structs traversal, per-slice projection calls), which
//! reproduces the pointer/locality behaviour §6 contrasts against the CSC
//! slab layout. The math is identical to the accelerated path:
//!
//!   x_i = Π_C(−(A_iᵀλ + c_i) / (γ v_i²)),  ∇g = Σ_i A_i x_i − b.
//!
//! Rust-vs-JVM constant factors are noted in EXPERIMENTS.md; Table-2/Fig-3
//! comparisons report the *shape* (batched sharded vs unbatched serial).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::projection::{BlockProjection, ProjectionKind};

/// One eligible edge in the tuple-sequence layout.
#[derive(Clone, Copy, Debug)]
struct EdgeTuple {
    dest: u32,
    /// a coefficient per family is boxed separately (like the Scala
    /// object model's nested collections) — index into `fam` planes.
    edge: u32,
    cost: f32,
}

/// Per-source record, mirroring the Scala per-block object.
struct SourceBlock {
    tuples: Vec<EdgeTuple>,
    gamma_scale: f32,
    /// Projection operator, resolved from the registry once at
    /// construction so the per-iteration hot loop stays lock-free.
    op: Arc<dyn BlockProjection>,
}

pub struct CpuObjective<'a> {
    lp: &'a MatchingLp,
    blocks: Vec<SourceBlock>,
    /// scratch: per-block projection input (reused across blocks)
    scratch: Vec<f32>,
    /// scratch: Ax accumulator (reused across iterations, same pattern as
    /// the projection scratch). The result's gradient must be owned, so
    /// the end of `calculate` still clones this once; together with the
    /// hoisted `full_b` that takes the per-iteration allocations from two
    /// (ax + full_b) to one.
    ax: Vec<f32>,
    /// full rhs over all dual rows, precomputed once
    full_b: Vec<f32>,
}

impl<'a> CpuObjective<'a> {
    pub fn new(lp: &'a MatchingLp) -> Self {
        let mut blocks = Vec::with_capacity(lp.num_sources());
        // memoize registry lookups per distinct kind (one lock acquisition
        // per kind, not per block)
        let mut ops: BTreeMap<ProjectionKind, Arc<dyn BlockProjection>> = BTreeMap::new();
        for i in 0..lp.num_sources() {
            let (e0, e1) = (lp.a.src_ptr[i], lp.a.src_ptr[i + 1]);
            let tuples = (e0..e1)
                .map(|e| EdgeTuple {
                    dest: lp.a.dest_idx[e],
                    edge: e as u32,
                    cost: lp.cost[e],
                })
                .collect();
            let kind = lp.projection.kind_of(i);
            let op = ops.entry(kind).or_insert_with(|| kind.op()).clone();
            blocks.push(SourceBlock { tuples, gamma_scale: lp.gamma_scale(i), op });
        }
        let full_b = lp.full_b();
        CpuObjective { lp, blocks, scratch: Vec::new(), ax: Vec::new(), full_b }
    }

    /// Compute x for one block into `self.scratch`.
    fn block_primal(&mut self, i: usize, lam: &[f32], gamma: f32) {
        let jj = self.lp.num_dests();
        let m = self.lp.num_families();
        let mj = self.lp.matching_dual_dim();
        let block = &self.blocks[i];
        let g_eff = gamma * block.gamma_scale;
        self.scratch.clear();
        for t in &block.tuples {
            // u = Σ_k a_k λ_k[j] + Σ_r coeffs_r λ_{mJ+r}
            let mut u = 0.0f32;
            for k in 0..m {
                u += self.lp.a.a[k][t.edge as usize] * lam[k * jj + t.dest as usize];
            }
            for (r, g) in self.lp.global_rows.iter().enumerate() {
                u += g.coeffs[t.edge as usize] * lam[mj + r];
            }
            self.scratch.push(-(u + t.cost) / g_eff);
        }
        block.op.project(&mut self.scratch);
    }
}

impl ObjectiveFunction for CpuObjective<'_> {
    fn dual_dim(&self) -> usize {
        self.lp.dual_dim()
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        assert_eq!(lam.len(), self.lp.dual_dim());
        let jj = self.lp.num_dests();
        let m = self.lp.num_families();
        self.ax.clear();
        self.ax.resize(self.lp.dual_dim(), 0.0);
        let mut cx = 0.0f64;
        let mut xsq_w = 0.0f64;

        let mj = self.lp.matching_dual_dim();
        for i in 0..self.lp.num_sources() {
            self.block_primal(i, lam, gamma);
            let block = &self.blocks[i];
            for (t, &x) in block.tuples.iter().zip(self.scratch.iter()) {
                if x == 0.0 {
                    continue;
                }
                cx += t.cost as f64 * x as f64;
                xsq_w += block.gamma_scale as f64 * (x as f64) * (x as f64);
                for k in 0..m {
                    self.ax[k * jj + t.dest as usize] +=
                        self.lp.a.a[k][t.edge as usize] * x;
                }
                for (r, g) in self.lp.global_rows.iter().enumerate() {
                    self.ax[mj + r] += g.coeffs[t.edge as usize] * x;
                }
            }
        }

        // grad = Ax − b (matching rows then global rows); the result owns
        // its gradient, so the scratch is cloned out rather than moved
        for (g, b) in self.ax.iter_mut().zip(&self.full_b) {
            *g -= *b;
        }
        ObjectiveResult::assemble(self.ax.clone(), cx, xsq_w, lam, gamma)
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        let mut x = vec![0.0f32; self.lp.nnz()];
        for i in 0..self.lp.num_sources() {
            self.block_primal(i, lam, gamma);
            let e0 = self.lp.a.src_ptr[i];
            x[e0..e0 + self.scratch.len()].copy_from_slice(&self.scratch);
        }
        x
    }

    fn name(&self) -> &'static str {
        "cpu-reference"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionKind;
    use crate::sparse::BlockedMatrix;

    fn tiny_lp() -> MatchingLp {
        let a = BlockedMatrix {
            num_sources: 2,
            num_dests: 2,
            num_families: 1,
            src_ptr: vec![0, 2, 4],
            dest_idx: vec![0, 1, 0, 1],
            a: vec![vec![1.0, 1.0, 1.0, 1.0]],
        };
        MatchingLp::new_uniform(
            a,
            vec![-2.0, -1.0, -1.0, -2.0],
            vec![0.6, 0.6],
            ProjectionKind::Simplex,
        )
    }

    #[test]
    fn gradient_matches_hand_computation() {
        let lp = tiny_lp();
        let mut obj = CpuObjective::new(&lp);
        let gamma = 1.0;
        // λ = 0: v_i = -c/γ = (2,1) and (1,2); Σ>1 ⇒ project onto simplex:
        // Π([2,1]) = [1,0] (θ=1); Π([1,2]) = [0,1].
        let res = obj.calculate(&[0.0, 0.0], gamma);
        // Ax = (1, 1); grad = Ax - b = (0.4, 0.4)
        assert!((res.grad[0] - 0.4).abs() < 1e-6, "{:?}", res.grad);
        assert!((res.grad[1] - 0.4).abs() < 1e-6);
        // cx = -2 + -2 = -4; xsq = 2
        assert!((res.cx - (-4.0)).abs() < 1e-6);
        assert!((res.xsq_weighted - 2.0).abs() < 1e-6);
        // g = cx + γ/2 xsq + λ·grad = -4 + 1 + 0 = -3
        assert!((res.dual_obj - (-3.0)).abs() < 1e-6);
    }

    #[test]
    fn gradient_is_numerical_derivative() {
        // Danskin check on a random instance.
        let lp = crate::gen::generate(&crate::gen::SyntheticConfig {
            num_requests: 40,
            num_resources: 8,
            avg_nnz_per_row: 4.0,
            seed: 3,
            ..Default::default()
        });
        let mut obj = CpuObjective::new(&lp);
        let gamma = 0.3;
        let mut rng = crate::util::rng::Rng::new(1);
        let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| rng.uniform() as f32 * 0.2).collect();
        let res = obj.calculate(&lam, gamma);
        let eps = 1e-3f32;
        for r in 0..lp.dual_dim() {
            let mut lp_ = lam.clone();
            lp_[r] += eps;
            let gp = obj.calculate(&lp_, gamma).dual_obj;
            let mut lm = lam.clone();
            lm[r] -= eps;
            let gm = obj.calculate(&lm, gamma).dual_obj;
            let num = (gp - gm) / (2.0 * eps as f64);
            assert!(
                (num - res.grad[r] as f64).abs() < 5e-2 * (1.0 + num.abs()),
                "row {r}: numerical {num} vs analytic {}",
                res.grad[r]
            );
        }
    }

    #[test]
    fn primal_scaling_changes_effective_gamma() {
        let mut lp = tiny_lp();
        lp.primal_scale = Some(vec![1.0, 2.0]); // block 1 gets γ·4
        let mut obj = CpuObjective::new(&lp);
        let x = obj.primal(&[0.0, 0.0], 1.0);
        // block 0 unchanged: Π([2,1]) = [1,0]
        assert!((x[0] - 1.0).abs() < 1e-6 && x[1].abs() < 1e-6);
        // block 1: v = (1,2)/4 = (0.25, 0.5), Σ=0.75 ≤ 1 ⇒ x = v
        assert!((x[2] - 0.25).abs() < 1e-6 && (x[3] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn primal_consistent_with_calculate() {
        let lp = crate::gen::generate(&crate::gen::SyntheticConfig {
            num_requests: 100,
            num_resources: 16,
            seed: 5,
            ..Default::default()
        });
        let mut obj = CpuObjective::new(&lp);
        let lam = vec![0.05f32; lp.dual_dim()];
        let res = obj.calculate(&lam, 0.1);
        let x = obj.primal(&lam, 0.1);
        let mut ax = vec![0.0f32; lp.dual_dim()];
        lp.a.scatter_ax(&x, &mut ax);
        for (r, (axr, br)) in ax.iter().zip(&lp.b).enumerate() {
            assert!(
                ((axr - br) - res.grad[r]).abs() < 1e-4,
                "row {r}"
            );
        }
    }
}
