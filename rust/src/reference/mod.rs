//! Reference ("Scala-equivalent") CPU backend: the baseline the paper's
//! experiments compare against, plus high-precision reference solves used
//! to compute L̂ for the Fig-4/5 convergence plots.

pub mod cpu_objective;

pub use cpu_objective::CpuObjective;
