//! `ObjectiveFunction` backed by the AOT slab kernels through PJRT — the
//! accelerated path of the paper (§6), for one shard (a contiguous source
//! range) of a matching LP.
//!
//! Per iteration and bucket the shard runs:
//!   1. **gather** (rust): per-edge u = Σ_k a_k·λ_k[j], divided by the
//!      per-source γ-scale when primal scaling is on;
//!   2. **kernel** (PJRT/HLO, fused Pallas slab): x = Π_C(−(u+c)/γ) plus
//!      the Σc⊙x and Σx² partials;
//!   3. **scatter** (rust): grad_k[j] += a_k·x.
//!
//! c and mask literals per (bucket, tile) are built once and reused across
//! iterations; only the u literal is rebuilt per step. The final partial
//! tile is zero-padded (mask 0 rows produce x = 0 exactly).

use anyhow::Result;

use super::pjrt::Engine;
use crate::problem::{MatchingLp, ObjectiveFunction, ObjectiveResult};
use crate::sparse::slabs::SlabLayout;
use crate::util::timer::PhaseTimers;

struct TileCache {
    c: xla::Literal,
    mask: xla::Literal,
    /// rows covered by this tile (≤ tile_rows; tail tile may be partial)
    rows: usize,
}

pub struct HloObjective<'a> {
    lp: &'a MatchingLp,
    layout: SlabLayout,
    engine: Engine,
    /// (src_lo, src_hi) shard bounds.
    shard: (usize, usize),
    /// cached per-(bucket, tile) literals
    tiles: Vec<Vec<TileCache>>,
    /// per-bucket per-row 1/(v_i²) gather scale (None when no scaling)
    row_gscale: Option<Vec<Vec<f32>>>,
    pub timers: PhaseTimers,
}

impl<'a> HloObjective<'a> {
    /// Build for the full problem.
    pub fn new(lp: &'a MatchingLp, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::new_shard(lp, artifacts_dir, 0, lp.num_sources())
    }

    /// Build for sources [src_lo, src_hi).
    pub fn new_shard(
        lp: &'a MatchingLp,
        artifacts_dir: impl AsRef<std::path::Path>,
        src_lo: usize,
        src_hi: usize,
    ) -> Result<Self> {
        let engine = Engine::new(artifacts_dir)?;
        let kind_of = |i: usize| lp.projection.kind_of(i);
        let layout = SlabLayout::build(&lp.a, &lp.cost, src_lo, src_hi, &kind_of)
            .map_err(anyhow::Error::msg)?;

        let t = engine.tile_rows();
        let mut tiles = Vec::with_capacity(layout.buckets.len());
        for bk in &layout.buckets {
            let w = bk.width;
            let mut bucket_tiles = Vec::new();
            let mut r0 = 0usize;
            while r0 < bk.rows() {
                let rows = (bk.rows() - r0).min(t);
                let mut c = vec![0.0f32; t * w];
                let mut mask = vec![0.0f32; t * w];
                c[..rows * w].copy_from_slice(&bk.cost[r0 * w..(r0 + rows) * w]);
                mask[..rows * w].copy_from_slice(&bk.mask[r0 * w..(r0 + rows) * w]);
                bucket_tiles.push(TileCache {
                    c: engine.literal_2d(&c, w)?,
                    mask: engine.literal_2d(&mask, w)?,
                    rows,
                });
                r0 += rows;
            }
            tiles.push(bucket_tiles);
        }

        // Per-row gather scale for primal scaling: divide (u + c) by v_i².
        // c is pre-divided into the cached literal? NO — c literals hold the
        // raw costs; instead both u and c must be scaled, so when scaling is
        // active we fold c into u on the rust side (u' = (u + c)/v² − c·0)
        // and pass a zeroed-c literal. To keep one code path we instead
        // store per-row scale and fold (u + c)/v² − c into u:
        //   kernel computes −(u' + c)/γ with u' = (u + c)/v² − c
        // which equals −(u + c)/(γ v²). cx/xsq partials are then recomputed
        // on the rust side during scatter (kernel partials use raw c).
        let row_gscale = if lp.primal_scale.is_some() {
            let mut per_bucket = Vec::with_capacity(layout.buckets.len());
            for bk in &layout.buckets {
                let scales: Vec<f32> =
                    bk.sources.iter().map(|&s| 1.0 / lp.gamma_scale(s as usize)).collect();
                per_bucket.push(scales);
            }
            Some(per_bucket)
        } else {
            None
        };

        Ok(HloObjective {
            lp,
            layout,
            engine,
            shard: (src_lo, src_hi),
            tiles,
            row_gscale,
            timers: PhaseTimers::new(),
        })
    }

    pub fn shard(&self) -> (usize, usize) {
        self.shard
    }

    pub fn layout(&self) -> &SlabLayout {
        &self.layout
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Pre-compile every executable this layout needs: one kernel per
    /// deduplicated (kind, bucket width) pair, resolved through the
    /// registry (manifest artifact when present, `emit_hlo` text
    /// otherwise) — any registered family warms up here, not just the
    /// seed artifact set (DESIGN.md §12).
    pub fn warmup(&mut self) -> Result<()> {
        let pairs: Vec<_> = {
            let mut ps: Vec<_> =
                self.layout.buckets.iter().map(|b| (b.kind, b.width)).collect();
            ps.sort();
            ps.dedup();
            ps
        };
        self.engine.warmup_pairs(&pairs)
    }

    /// Evaluate the shard's contribution: grad += A_shard x − 0 (b is NOT
    /// subtracted here — the leader owns b), returning (cx, xsq_weighted)
    /// partials. `x_out` optionally receives the per-edge primal (global
    /// edge indexing via bucket bookkeeping).
    pub fn eval_shard(
        &mut self,
        lam: &[f32],
        gamma: f32,
        grad: &mut [f32],
        mut x_out: Option<&mut Vec<f32>>,
    ) -> Result<(f64, f64)> {
        let jj = self.lp.num_dests();
        let m = self.lp.num_families();
        let t = self.engine.tile_rows();
        let mut cx_total = 0.0f64;
        let mut xsq_total = 0.0f64;
        let scaled = self.row_gscale.is_some();

        let mut u = vec![0.0f32; 0];
        for (bi, bk) in self.layout.buckets.iter().enumerate() {
            let w = bk.width;
            u.resize(t * w, 0.0);
            for (ti, tile) in self.tiles[bi].iter().enumerate() {
                let r0 = ti * t;
                let rows = tile.rows;
                let base = r0 * w;
                let n = rows * w;

                // --- gather ---------------------------------------------
                self.timers.time("gather", || {
                    u[..t * w].iter_mut().for_each(|v| *v = 0.0);
                    for k in 0..m {
                        let ak = &bk.a[k][base..base + n];
                        let lk = &lam[k * jj..(k + 1) * jj];
                        let di = &bk.dest_idx[base..base + n];
                        for e in 0..n {
                            u[e] += ak[e] * lk[di[e] as usize];
                        }
                    }
                    if !self.lp.global_rows.is_empty() {
                        let eids = &bk.edge_id[base..base + n];
                        let mj = self.lp.matching_dual_dim();
                        for (r, g) in self.lp.global_rows.iter().enumerate() {
                            let lr = lam[mj + r];
                            if lr == 0.0 {
                                continue;
                            }
                            for e in 0..n {
                                if eids[e] != u32::MAX {
                                    u[e] += g.coeffs[eids[e] as usize] * lr;
                                }
                            }
                        }
                    }
                    if let Some(gs) = &self.row_gscale {
                        // u' = (u + c)/v² − c  (see constructor comment)
                        let cvals = &bk.cost[base..base + n];
                        for r in 0..rows {
                            let s = gs[bi][r0 + r];
                            if (s - 1.0).abs() < 1e-12 {
                                continue;
                            }
                            for e in r * w..(r + 1) * w {
                                u[e] = (u[e] + cvals[e]) * s - cvals[e];
                            }
                        }
                    }
                });

                // --- kernel ---------------------------------------------
                let ul = self.engine.literal_2d(&u, w)?;
                let out = self.timers.time("kernel", || {
                    self.engine.run_slab(bk.kind, w, &ul, &tile.c, &tile.mask, gamma)
                })?;

                // --- scatter --------------------------------------------
                self.timers.time("scatter", || {
                    let x = &out.x[..n];
                    for k in 0..m {
                        let ak = &bk.a[k][base..base + n];
                        let di = &bk.dest_idx[base..base + n];
                        let gk = &mut grad[k * jj..(k + 1) * jj];
                        for e in 0..n {
                            gk[di[e] as usize] += ak[e] * x[e];
                        }
                    }
                    if !self.lp.global_rows.is_empty() {
                        let eids = &bk.edge_id[base..base + n];
                        let mj = self.lp.matching_dual_dim();
                        for (r, g) in self.lp.global_rows.iter().enumerate() {
                            let mut acc = 0.0f32;
                            for e in 0..n {
                                if eids[e] != u32::MAX {
                                    acc += g.coeffs[eids[e] as usize] * x[e];
                                }
                            }
                            grad[mj + r] += acc;
                        }
                    }
                    if scaled {
                        // recompute partials with true c and weight v_i²
                        let cvals = &bk.cost[base..base + n];
                        for r in 0..rows {
                            let src = bk.sources[r0 + r] as usize;
                            let vsq = self.lp.gamma_scale(src) as f64;
                            for e in r * w..(r + 1) * w {
                                let xe = x[e] as f64;
                                cx_total += cvals[e] as f64 * xe;
                                xsq_total += vsq * xe * xe;
                            }
                        }
                    } else {
                        cx_total += out.cx;
                        xsq_total += out.xsq;
                    }
                    if let Some(xo) = x_out.as_deref_mut() {
                        // write per-edge primal back via the edge_id plane
                        let eids = &bk.edge_id[base..base + n];
                        for e in 0..n {
                            if eids[e] != u32::MAX {
                                xo[eids[e] as usize] = x[e];
                            }
                        }
                    }
                });
            }
        }
        Ok((cx_total, xsq_total))
    }
}

impl ObjectiveFunction for HloObjective<'_> {
    fn dual_dim(&self) -> usize {
        self.lp.dual_dim()
    }

    fn calculate(&mut self, lam: &[f32], gamma: f32) -> ObjectiveResult {
        let mut grad = vec![0.0f32; self.lp.dual_dim()];
        let (cx, xsq) = self
            .eval_shard(lam, gamma, &mut grad, None)
            .expect("slab execution failed");
        for (g, b) in grad.iter_mut().zip(self.lp.full_b()) {
            *g -= b;
        }
        ObjectiveResult::assemble(grad, cx, xsq, lam, gamma)
    }

    fn primal(&mut self, lam: &[f32], gamma: f32) -> Vec<f32> {
        let mut grad = vec![0.0f32; self.lp.dual_dim()];
        let mut x = vec![0.0f32; self.lp.nnz()];
        self.eval_shard(lam, gamma, &mut grad, Some(&mut x))
            .expect("slab execution failed");
        x
    }

    fn name(&self) -> &'static str {
        "hlo-slab"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::reference::CpuObjective;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn matches_cpu_reference_on_synthetic() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = generate(&SyntheticConfig {
            num_requests: 300,
            num_resources: 40,
            avg_nnz_per_row: 6.0,
            seed: 11,
            ..Default::default()
        });
        let mut hlo = HloObjective::new(&lp, artifacts_dir()).unwrap();
        let mut cpu = CpuObjective::new(&lp);
        let mut rng = crate::util::rng::Rng::new(2);
        for gamma in [0.01f32, 0.16] {
            let lam: Vec<f32> =
                (0..lp.dual_dim()).map(|_| rng.uniform() as f32 * 0.1).collect();
            let rh = hlo.calculate(&lam, gamma);
            let rc = cpu.calculate(&lam, gamma);
            assert!(
                (rh.dual_obj - rc.dual_obj).abs() / rc.dual_obj.abs().max(1.0) < 1e-4,
                "dual {} vs {}",
                rh.dual_obj,
                rc.dual_obj
            );
            // tolerance: kernel θ is bisection-quantized (f32) vs the CPU
            // oracle's exact sort threshold; errors scale with |v|≈|c|/γ
            let gtol = 2e-3 + 5e-5 * (1.0 / gamma as f64);
            for (a, b) in rh.grad.iter().zip(&rc.grad) {
                assert!(((a - b).abs() as f64) < gtol * (1.0 + a.abs() as f64), "{a} vs {b}");
            }
            assert!((rh.cx - rc.cx).abs() / rc.cx.abs().max(1.0) < 1e-4);
            assert!((rh.xsq_weighted - rc.xsq_weighted).abs() < 1e-2);
        }
    }

    #[test]
    fn shards_sum_to_full_gradient() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = generate(&SyntheticConfig {
            num_requests: 200,
            num_resources: 32,
            seed: 4,
            ..Default::default()
        });
        let lam = vec![0.05f32; lp.dual_dim()];
        let gamma = 0.05;
        let mut full = HloObjective::new(&lp, artifacts_dir()).unwrap();
        let rf = full.calculate(&lam, gamma);

        let mut grad = vec![0.0f32; lp.dual_dim()];
        let (mut cx, mut xsq) = (0.0, 0.0);
        for (lo, hi) in [(0, 70), (70, 140), (140, 200)] {
            let mut sh = HloObjective::new_shard(&lp, artifacts_dir(), lo, hi).unwrap();
            let (c, s) = sh.eval_shard(&lam, gamma, &mut grad, None).unwrap();
            cx += c;
            xsq += s;
        }
        for (g, b) in grad.iter_mut().zip(&lp.b) {
            *g -= b;
        }
        for (a, b) in rf.grad.iter().zip(&grad) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert!((rf.cx - cx).abs() < 1e-6 * cx.abs().max(1.0) + 1e-6);
        assert!((rf.xsq_weighted - xsq).abs() < 1e-4);
    }

    #[test]
    fn primal_scaling_matches_cpu() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut lp = generate(&SyntheticConfig {
            num_requests: 150,
            num_resources: 24,
            seed: 6,
            ..Default::default()
        });
        crate::problem::apply_primal_scaling(&mut lp);
        let mut hlo = HloObjective::new(&lp, artifacts_dir()).unwrap();
        let mut cpu = CpuObjective::new(&lp);
        let lam = vec![0.02f32; lp.dual_dim()];
        let rh = hlo.calculate(&lam, 0.08);
        let rc = cpu.calculate(&lam, 0.08);
        assert!(
            (rh.dual_obj - rc.dual_obj).abs() / rc.dual_obj.abs().max(1.0) < 1e-4,
            "{} vs {}",
            rh.dual_obj,
            rc.dual_obj
        );
        for (a, b) in rh.grad.iter().zip(&rc.grad) {
            assert!((a - b).abs() < 2e-3);
        }
        assert!((rh.xsq_weighted - rc.xsq_weighted).abs() / rc.xsq_weighted.max(1.0) < 1e-3);
    }

    #[test]
    fn primal_recovery_matches_cpu() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let lp = generate(&SyntheticConfig {
            num_requests: 120,
            num_resources: 20,
            seed: 9,
            ..Default::default()
        });
        let lam = vec![0.01f32; lp.dual_dim()];
        let mut hlo = HloObjective::new(&lp, artifacts_dir()).unwrap();
        let mut cpu = CpuObjective::new(&lp);
        let xh = hlo.primal(&lam, 0.05);
        let xc = cpu.primal(&lam, 0.05);
        assert_eq!(xh.len(), xc.len());
        for (a, b) in xh.iter().zip(&xc) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
