//! Runtime layer: PJRT engine for the AOT HLO artifacts and the
//! slab-kernel-backed `ObjectiveFunction` (the paper's GPU execution path,
//! §6). Python is build-time only; this module is all that touches XLA at
//! solve time.

pub mod hlo_objective;
pub mod pjrt;

pub use hlo_objective::HloObjective;
pub use pjrt::{Engine, Manifest};

/// Default artifacts directory: `$DUALIP_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    match std::env::var_os("DUALIP_ARTIFACTS") {
        Some(d) => d.into(),
        None => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    }
}
