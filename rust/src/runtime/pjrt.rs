//! PJRT execution engine: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client, and runs
//! slab dual steps from the solve hot path.
//!
//! One `Engine` per logical device (worker thread); executables are cached
//! per (kind, rows, width). Interchange is HLO *text* — see DESIGN.md §2
//! and /opt/xla-example/README.md for why serialized protos are rejected.
//!
//! Kernel resolution is *manifest-first, registry-fallback* (DESIGN.md
//! §12): a (kind, rows, width) with an AOT artifact compiles from the
//! artifact file; any other registered kind compiles from the text its
//! operator's `BlockProjection::emit_hlo` hook emits. The manifest is
//! therefore an optimization (pre-generated, shared across processes),
//! not a gate — registering a family with an emission makes it fast on
//! this tier with zero edits here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::projection::ProjectionKind;

/// Slab artifact geometry parsed from `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// (kind, rows, width) → file name. BTreeMap so any future iteration
    /// (artifact listings, compile-order prefetch) is order-stable (D1).
    pub entries: BTreeMap<(ProjectionKind, usize, usize), String>,
    /// Fixed row count per slab execution (all current artifacts share it).
    pub tile_rows: usize,
    /// Available widths, ascending.
    pub widths: Vec<usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut entries = BTreeMap::new();
        let mut tile_rows = 0usize;
        let mut widths = std::collections::BTreeSet::new();
        for line in text.lines() {
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 4 {
                continue;
            }
            let kind = ProjectionKind::parse(f[0])
                .ok_or_else(|| anyhow!("unknown projection kind {:?} in manifest", f[0]))?;
            let rows: usize = f[1].parse()?;
            let width: usize = f[2].parse()?;
            entries.insert((kind, rows, width), f[3].to_string());
            tile_rows = tile_rows.max(rows);
            widths.insert(width);
        }
        if entries.is_empty() {
            return Err(anyhow!("empty manifest at {path:?}"));
        }
        Ok(Manifest { entries, tile_rows, widths: widths.into_iter().collect() })
    }
}

/// Result of one slab execution.
pub struct SlabOutput {
    /// Projected primal rows, flattened [rows × width].
    pub x: Vec<f32>,
    /// Σ c⊙x over the slab.
    pub cx: f64,
    /// Σ x² over the slab.
    pub xsq: f64,
}

/// Per-device PJRT engine with an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: BTreeMap<(ProjectionKind, usize, usize), xla::PjRtLoadedExecutable>,
    /// executions performed (diagnostics)
    pub launches: u64,
}

impl Engine {
    /// Create an engine over the artifact directory (must contain
    /// manifest.txt; see `make artifacts`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, manifest, dir, exes: BTreeMap::new(), launches: 0 })
    }

    pub fn tile_rows(&self) -> usize {
        self.manifest.tile_rows
    }

    /// Smallest artifact width ≥ `w`, if any.
    pub fn width_for(&self, w: usize) -> Option<usize> {
        self.manifest.widths.iter().copied().find(|&aw| aw >= w)
    }

    pub fn max_width(&self) -> usize {
        *self.manifest.widths.last().unwrap()
    }

    /// Lazily load + compile the executable for (kind, rows, width).
    fn executable_rows(
        &mut self,
        kind: ProjectionKind,
        rows: usize,
        width: usize,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(&(kind, rows, width)) {
            // Manifest-first: AOT artifacts win. Otherwise fall back to the
            // registry's emission hook, so any family implementing
            // `BlockProjection::emit_hlo` reaches this tier without an
            // artifact rebuild (DESIGN.md §12).
            let proto = match self.manifest.entries.get(&(kind, rows, width)) {
                Some(name) => {
                    let path = self.dir.join(name);
                    xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?
                }
                None => {
                    let text = kind.op().emit_hlo(rows, width).ok_or_else(|| {
                        anyhow!(
                            "no artifact and no registry emission for kind={} rows={rows} w={width}",
                            kind.name()
                        )
                    })?;
                    debug_assert!(
                        crate::projection::hlo::emission_is_well_formed(&text, rows, width),
                        "malformed emission for {}",
                        kind.spec()
                    );
                    xla::HloModuleProto::from_text(&text)
                        .map_err(|e| anyhow!("parsing emitted kernel for {}: {e:?}", kind.spec()))?
                }
            };
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| {
                    anyhow!("compiling kind={} rows={rows} w={width}: {e:?}", kind.name())
                })?;
            self.exes.insert((kind, rows, width), exe);
        }
        Ok(&self.exes[&(kind, rows, width)])
    }

    /// Pre-compile one (kind, width) kernel at the standard tile height,
    /// resolving manifest-first with registry-emission fallback.
    pub fn ensure_kernel(&mut self, kind: ProjectionKind, width: usize) -> Result<()> {
        let rows = self.manifest.tile_rows;
        self.executable_rows(kind, rows, width).map(|_| ())
    }

    /// Pre-compile all artifacts of the given kinds (avoids first-iteration
    /// compile latency skewing benchmarks). Only touches manifest widths;
    /// use [`Engine::warmup_pairs`] for the registry-driven layout warmup.
    pub fn warmup(&mut self, kinds: &[ProjectionKind]) -> Result<()> {
        let rows = self.manifest.tile_rows;
        for &kind in kinds {
            for w in self.manifest.widths.clone() {
                if self.manifest.entries.contains_key(&(kind, rows, w)) {
                    self.executable_rows(kind, rows, w)?;
                }
            }
        }
        Ok(())
    }

    /// Pre-compile exactly the (kind, width) pairs a slab layout needs —
    /// the registry-driven warmup: pairs without artifacts compile from
    /// `emit_hlo` text, so a newly registered family pays its compile
    /// cost here instead of on the first dual step.
    pub fn warmup_pairs(&mut self, pairs: &[(ProjectionKind, usize)]) -> Result<()> {
        for &(kind, w) in pairs {
            self.ensure_kernel(kind, w)?;
        }
        Ok(())
    }

    /// Build a [rows × width] f32 literal from a flat slice.
    pub fn literal_2d(&self, data: &[f32], width: usize) -> Result<xla::Literal> {
        let rows = data.len() / width;
        debug_assert_eq!(rows * width, data.len());
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, width as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Execute one slab dual step. `u`, plus cached `c` and `mask`
    /// literals, must all be [tile_rows × width].
    pub fn run_slab(
        &mut self,
        kind: ProjectionKind,
        width: usize,
        u: &xla::Literal,
        c: &xla::Literal,
        mask: &xla::Literal,
        gamma: f32,
    ) -> Result<SlabOutput> {
        self.run_slab_rows(kind, self.manifest.tile_rows, width, u, c, mask, gamma)
    }

    /// Execute one slab dual step against a specific row-count artifact
    /// (rows=1 artifacts back the per-slice launch baseline of E9).
    pub fn run_slab_rows(
        &mut self,
        kind: ProjectionKind,
        rows: usize,
        width: usize,
        u: &xla::Literal,
        c: &xla::Literal,
        mask: &xla::Literal,
        gamma: f32,
    ) -> Result<SlabOutput> {
        let g = xla::Literal::vec1(&[gamma]);
        let exe = self.executable_rows(kind, rows, width)?;
        let bufs = exe
            .execute::<&xla::Literal>(&[u, c, mask, &g])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.launches += 1;
        let out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (x_lit, cx_lit, xsq_lit) = out.to_tuple3().map_err(|e| anyhow!("tuple3: {e:?}"))?;
        let x = x_lit.to_vec::<f32>().map_err(|e| anyhow!("x to_vec: {e:?}"))?;
        let cx = cx_lit.to_vec::<f32>().map_err(|e| anyhow!("cx: {e:?}"))?[0] as f64;
        let xsq = xsq_lit.to_vec::<f32>().map_err(|e| anyhow!("xsq: {e:?}"))?[0] as f64;
        Ok(SlabOutput { x, cx, xsq })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.tile_rows, 1024);
        assert!(m.widths.contains(&4));
        assert!(m.widths.contains(&512));
    }

    #[test]
    fn box_slab_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::new(artifacts_dir()).unwrap();
        let t = e.tile_rows();
        let w = 4;
        let n = t * w;
        // v = -(u+c)/γ: choose u=-γ·target, c=0, mask=1 → x = clip(target,0,1)
        let gamma = 0.5f32;
        let target: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3 - 0.6).collect();
        let u: Vec<f32> = target.iter().map(|&t| -gamma * t).collect();
        let ul = e.literal_2d(&u, w).unwrap();
        let cl = e.literal_2d(&vec![0.0; n], w).unwrap();
        let ml = e.literal_2d(&vec![1.0; n], w).unwrap();
        let out = e.run_slab(ProjectionKind::Box, w, &ul, &cl, &ml, gamma).unwrap();
        for (x, t) in out.x.iter().zip(&target) {
            assert!((x - t.clamp(0.0, 1.0)).abs() < 1e-5, "{x} vs {t}");
        }
        assert!(out.cx.abs() < 1e-6);
        let xsq_ref: f64 = target.iter().map(|&t| (t.clamp(0.0, 1.0) as f64).powi(2)).sum();
        assert!((out.xsq - xsq_ref).abs() / xsq_ref.max(1.0) < 1e-4);
    }

    #[test]
    fn simplex_slab_respects_capacity() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::new(artifacts_dir()).unwrap();
        let t = e.tile_rows();
        let w = 8;
        let n = t * w;
        let gamma = 0.1f32;
        // big negative costs → unconstrained x would be large positive
        let c = vec![-1.0f32; n];
        let u = vec![0.0f32; n];
        let ul = e.literal_2d(&u, w).unwrap();
        let cl = e.literal_2d(&c, w).unwrap();
        let ml = e.literal_2d(&vec![1.0; n], w).unwrap();
        let out = e.run_slab(ProjectionKind::Simplex, w, &ul, &cl, &ml, gamma).unwrap();
        for row in out.x.chunks(w) {
            let s: f64 = row.iter().map(|&x| x as f64).sum();
            assert!(s <= 1.0 + 1e-4, "row sum {s}");
            // symmetric input → uniform row
            for &x in row {
                assert!((x - 1.0 / w as f32).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn width_selection() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let e = Engine::new(artifacts_dir()).unwrap();
        assert_eq!(e.width_for(3), Some(4));
        assert_eq!(e.width_for(4), Some(4));
        assert_eq!(e.width_for(5), Some(8));
        assert_eq!(e.width_for(513), None);
    }
}
