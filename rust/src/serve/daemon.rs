//! The resident serve daemon (DESIGN.md §9): an in-process request queue
//! in front of the cooperative executor, run as a long-lived host for one
//! hot instance.
//!
//! **Queue lifecycle.** `submit` admits a request (or sheds it immediately
//! when the queue is at capacity); `drain` processes the queue in order.
//! Requests that change the instance — a full [`LpSpec`]/[`MatchingLp`]
//! or an [`InstanceDelta`] — are barriers: the pending wave of solve
//! requests is flushed through [`Scheduler::run_coop`] first, then the
//! mutation is applied to the [`ResidentInstance`] in place (a shipped
//! instance whose fingerprint matches the resident one is absorbed as a
//! plane delta — zero rebuild). Every request, mutating or not, then
//! solves the resident instance and yields one [`ServeOutcome`].
//!
//! **Admission control.** Queue depth is bounded (`ServeConfig::max_queue`
//! → [`ShedReason::QueueFull`] at submit). Each request carries an SLO
//! budget measured from admission; at solve time the remaining budget
//! becomes the driver deadline (`DriverOptions::deadline_ms`, enforced
//! between iterations exactly as `SolveEngine::solve_batch_coop` does) and
//! a request whose budget is already exhausted is shed
//! ([`ShedReason::SloExpired`]) without spending a single iteration.
//!
//! **Durable warm-start state.** `snapshot_bytes`/`restore` round-trip the
//! daemon's LRU dual cache and the checkpoints of parked in-flight solves
//! through the versioned on-disk format in [`crate::serve::snapshot`]. A
//! bounded `drain_budget` parks unfinished solves (checkpointed by
//! fingerprint, re-queued at the front); a restored daemon, given the same
//! resident instance, finishes them **bit-identically** to a daemon that
//! never stopped — λ is published to the cache at every γ-decay checkpoint
//! either way, so even the cache's LRU clock matches tick for tick.

use std::collections::VecDeque;
use std::path::Path;

use crate::backend::slab_cpu::SlabCpuObjective;
use crate::backend::TimedObjective;
use crate::engine::{warm_options, Fingerprint, JobResult, Scheduler, WarmStartCache};
use crate::gen::workloads::StreamRequest;
use crate::problem::{LpSpec, MatchingLp};
use crate::serve::delta::{InstanceDelta, ResidentInstance};
use crate::serve::snapshot::{self, CheckpointEntry};
use crate::solver::{
    Agd, Checkpoint, DriverOptions, SolveDriver, SolveOptions, StepEvent, StopReason,
};
use crate::util::timer::Stopwatch;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Cold-solve option template (min_iters is pushed past the
    /// γ-continuation descent, as in `SolveEngine`).
    pub opts: SolveOptions,
    /// Tail decay steps for warm starts (`warm_options`).
    pub warm_tail: usize,
    /// Executor worker threads per wave.
    pub threads: usize,
    /// Warm-start cache capacity (entries).
    pub cache_capacity: usize,
    /// Threads per objective evaluation.
    pub objective_threads: usize,
    /// Iterations per job per cooperative round.
    pub quantum: usize,
    /// Admission bound: submits beyond this queue depth are shed.
    pub max_queue: usize,
    /// Default SLO budget (ms from admission) for requests that carry
    /// none. `None` = unbounded.
    pub default_slo_ms: Option<f64>,
    /// Run the O(nnz) delta parity gate after every applied delta
    /// (tests / smoke runs; not for the hot path).
    pub audit_parity: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            opts: SolveOptions::default(),
            warm_tail: 5,
            threads: std::thread::available_parallelism().map_or(1, |n| n.get()),
            cache_capacity: 64,
            objective_threads: 1,
            quantum: 16,
            max_queue: 64,
            default_slo_ms: None,
            audit_parity: false,
        }
    }
}

/// What a request carries.
#[derive(Debug)]
pub enum Payload {
    /// Build this spec and make it the resident instance (or absorb it as
    /// a plane delta if its fingerprint matches), then solve it.
    Spec(Box<LpSpec>),
    /// Same, for an already-built instance.
    Instance(Box<MatchingLp>),
    /// Apply a delta to the resident instance, then solve it.
    Delta(InstanceDelta),
    /// Solve the resident instance as-is.
    Solve,
}

impl Payload {
    fn mutates(&self) -> bool {
        !matches!(self, Payload::Solve)
    }
}

/// One queued request.
#[derive(Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub payload: Payload,
    /// SLO budget in ms, measured from admission. `None` falls back to
    /// `ServeConfig::default_slo_ms`.
    pub slo_ms: Option<f64>,
}

impl ServeRequest {
    pub fn solve(id: u64) -> ServeRequest {
        ServeRequest { id, payload: Payload::Solve, slo_ms: None }
    }

    pub fn instance(id: u64, lp: MatchingLp) -> ServeRequest {
        ServeRequest { id, payload: Payload::Instance(Box::new(lp)), slo_ms: None }
    }

    pub fn spec(id: u64, spec: LpSpec) -> ServeRequest {
        ServeRequest { id, payload: Payload::Spec(Box::new(spec)), slo_ms: None }
    }

    pub fn delta(id: u64, delta: InstanceDelta) -> ServeRequest {
        ServeRequest { id, payload: Payload::Delta(delta), slo_ms: None }
    }

    pub fn with_slo_ms(mut self, ms: f64) -> ServeRequest {
        self.slo_ms = Some(ms);
        self
    }
}

/// Why a request was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Admission bound hit at submit time.
    QueueFull,
    /// SLO budget exhausted before the solve could start.
    SloExpired,
}

/// Why a request failed. The request path never panics (P1, DESIGN.md
/// §10): every failure mode is a typed outcome the caller can match on,
/// and the daemon stays up to serve the next request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Solve or delta request arrived before any instance was loaded.
    NoResidentInstance,
    /// A parked solve's checkpoint fingerprint no longer matches the
    /// resident instance (it changed across a snapshot/restore cycle).
    FingerprintChanged,
    /// The stepper could not produce a checkpoint at park time, so the
    /// in-flight solve state was dropped (re-submit to start over).
    CheckpointUnavailable,
    /// Instance construction, plane absorb, delta application, or parity
    /// audit failed; the message is the underlying error.
    Instance(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::NoResidentInstance => write!(f, "no resident instance"),
            ServeError::FingerprintChanged => {
                write!(f, "resident instance changed since checkpoint")
            }
            ServeError::CheckpointUnavailable => {
                write!(f, "stepper yielded no checkpoint at park; solve state dropped")
            }
            ServeError::Instance(e) => write!(f, "{e}"),
        }
    }
}

/// Terminal outcome of one request.
#[derive(Debug)]
pub enum Outcome {
    Solved(Box<JobResult>),
    Shed(ShedReason),
    Failed(ServeError),
}

#[derive(Debug)]
pub struct ServeOutcome {
    pub id: u64,
    pub outcome: Outcome,
}

/// Daemon counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed_queue_full: u64,
    pub shed_slo: u64,
    pub parked: u64,
    pub resumed: u64,
    pub deadline_stops: u64,
    pub cancelled: u64,
    pub drains: u64,
    pub waves: u64,
    pub instance_loads: u64,
    pub plane_absorbs: u64,
    pub deltas: u64,
}

struct QueuedEntry {
    id: u64,
    payload: Payload,
    slo_ms: Option<f64>,
    admitted: Stopwatch,
    /// Parked solve to resume instead of starting fresh: the checkpoint
    /// plus the fingerprint of the instance it was solving.
    resume: Option<(Fingerprint, Checkpoint)>,
}

/// The resident daemon. Single-threaded control loop (submit/drain from
/// one owner); solves fan out over the cooperative executor inside
/// `drain`.
pub struct ServeDaemon {
    cfg: ServeConfig,
    resident: Option<ResidentInstance>,
    cache: WarmStartCache,
    queue: VecDeque<QueuedEntry>,
    stats: ServeStats,
}

impl ServeDaemon {
    pub fn new(cfg: ServeConfig) -> ServeDaemon {
        assert!(cfg.threads >= 1, "daemon needs at least one thread");
        let cache = WarmStartCache::new(cfg.cache_capacity);
        ServeDaemon {
            cfg,
            resident: None,
            cache,
            queue: VecDeque::new(),
            stats: ServeStats::default(),
        }
    }

    /// Rebuild a daemon from snapshot bytes: the warm-start cache is
    /// restored exactly (entries, LRU ticks, counters — the snapshot's
    /// capacity wins over `cfg.cache_capacity`), and parked solves are
    /// re-queued at the front. The operator must `load_instance` the
    /// matching instance before draining; a parked solve whose fingerprint
    /// no longer matches fails cleanly instead of resuming on wrong bits.
    pub fn restore(cfg: ServeConfig, bytes: &[u8]) -> Result<ServeDaemon, String> {
        Ok(Self::from_snapshot(cfg, snapshot::decode(bytes)?))
    }

    /// `restore` from a file written by [`Self::save_snapshot`].
    pub fn restore_from(cfg: ServeConfig, path: impl AsRef<Path>) -> Result<ServeDaemon, String> {
        Ok(Self::from_snapshot(cfg, snapshot::load(path)?))
    }

    fn from_snapshot(cfg: ServeConfig, snap: snapshot::ServeSnapshot) -> ServeDaemon {
        let mut d = ServeDaemon::new(cfg);
        d.cache = snap.cache;
        for e in snap.checkpoints {
            d.queue.push_back(QueuedEntry {
                id: e.request_id,
                payload: Payload::Solve,
                slo_ms: None,
                admitted: Stopwatch::start(),
                resume: Some((e.fingerprint, e.checkpoint)),
            });
        }
        d
    }

    /// Checkpoints of every parked solve currently queued. A checkpoint
    /// whose stepper cannot be duplicated is omitted rather than
    /// panicking the daemon — that solve simply restarts cold after a
    /// restore, which is the documented degradation for non-cloneable
    /// steppers.
    fn checkpoint_entries(&self) -> Vec<CheckpointEntry> {
        self.queue
            .iter()
            .filter_map(|e| {
                let (fp, ck) = e.resume.as_ref()?;
                let checkpoint = ck.try_clone()?;
                Some(CheckpointEntry { request_id: e.id, fingerprint: *fp, checkpoint })
            })
            .collect()
    }

    /// Serialize the durable state: the warm-start cache plus checkpoints
    /// of every parked solve currently queued.
    pub fn snapshot_bytes(&self) -> Result<Vec<u8>, String> {
        snapshot::encode(&self.cache, &self.checkpoint_entries())
    }

    /// Write the snapshot to disk (atomic rename).
    pub fn save_snapshot(&self, path: impl AsRef<Path>) -> Result<(), String> {
        snapshot::save(path, &self.cache, &self.checkpoint_entries())
    }

    /// Make `lp` resident without queuing a solve (operator path, e.g.
    /// right after `restore`). Matching fingerprint → plane absorb.
    pub fn load_instance(&mut self, lp: MatchingLp) -> Result<Fingerprint, String> {
        self.install_instance(lp)
    }

    pub fn resident(&self) -> Option<&ResidentInstance> {
        self.resident.as_ref()
    }

    pub fn cache(&self) -> &WarmStartCache {
        &self.cache
    }

    pub fn stats(&self) -> ServeStats {
        self.stats
    }

    /// Requests admitted but not yet resolved (includes parked solves).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admission control: bounded queue depth.
    pub fn submit(&mut self, req: ServeRequest) -> Result<(), ShedReason> {
        if self.queue.len() >= self.cfg.max_queue {
            self.stats.shed_queue_full += 1;
            return Err(ShedReason::QueueFull);
        }
        self.stats.submitted += 1;
        self.queue.push_back(QueuedEntry {
            id: req.id,
            payload: req.payload,
            slo_ms: req.slo_ms,
            admitted: Stopwatch::start(),
            resume: None,
        });
        Ok(())
    }

    /// Process the whole queue to completion.
    pub fn drain(&mut self) -> Vec<ServeOutcome> {
        self.drain_budget(None)
    }

    /// Process the queue, but park any solve that exceeds `iter_budget`
    /// iterations this drain: its driver is checkpointed and the request
    /// re-queued (front, original order) to continue next drain — or after
    /// a snapshot/restore cycle. `None` = run every solve to completion.
    pub fn drain_budget(&mut self, iter_budget: Option<usize>) -> Vec<ServeOutcome> {
        let work: Vec<QueuedEntry> = self.queue.drain(..).collect();
        let mut outcomes = Vec::new();
        let mut parked: Vec<QueuedEntry> = Vec::new();
        let mut wave: Vec<QueuedEntry> = Vec::new();
        for entry in work {
            if entry.payload.mutates() {
                if !wave.is_empty() {
                    let w = std::mem::take(&mut wave);
                    self.run_wave(w, iter_budget, &mut outcomes, &mut parked);
                }
                let id = entry.id;
                match self.apply_mutation(entry) {
                    Ok(solved_entry) => wave.push(solved_entry),
                    Err(e) => {
                        self.stats.failed += 1;
                        outcomes.push(ServeOutcome { id, outcome: Outcome::Failed(e) });
                    }
                }
            } else {
                wave.push(entry);
            }
        }
        if !wave.is_empty() {
            let w = std::mem::take(&mut wave);
            self.run_wave(w, iter_budget, &mut outcomes, &mut parked);
        }
        for p in parked {
            self.queue.push_back(p);
        }
        self.stats.drains += 1;
        outcomes
    }

    /// Submit-and-drain a generated request stream in bursts of `burst`
    /// (burst > queue bound exercises admission shedding). Shared by the
    /// `serve` CLI command and the E17 bench.
    pub fn run_stream(&mut self, stream: &[StreamRequest], burst: usize) -> Vec<ServeOutcome> {
        let mut out = Vec::new();
        for chunk in stream.chunks(burst.max(1)) {
            for r in chunk {
                let req = ServeRequest::instance(r.id, r.lp.clone()).with_slo_ms(r.slo_ms);
                if let Err(reason) = self.submit(req) {
                    out.push(ServeOutcome { id: r.id, outcome: Outcome::Shed(reason) });
                }
            }
            out.extend(self.drain());
        }
        out
    }

    /// One-paragraph operational report.
    pub fn report(&self) -> String {
        let s = &self.stats;
        let lookups = self.cache.hits + self.cache.misses;
        let hit_pct = if lookups > 0 {
            100.0 * self.cache.hits as f64 / lookups as f64
        } else {
            0.0
        };
        let patch = self.resident.as_ref().map(|r| r.report).unwrap_or_default();
        format!(
            "serve: {} submitted, {} completed ({} resumed, {} deadline-stopped), \
             {} shed ({} queue-full, {} slo-expired), {} parked, {} waves / {} drains, \
             instance: {} loads, {} plane-absorbs, {} deltas \
             ({} in-place, {} repacked, {} cost-patches), \
             cache {hit_pct:.0}% hit ({}/{lookups} lookups, {} evictions)",
            s.submitted,
            s.completed,
            s.resumed,
            s.deadline_stops,
            s.shed_queue_full + s.shed_slo,
            s.shed_queue_full,
            s.shed_slo,
            s.parked,
            s.waves,
            s.drains,
            s.instance_loads,
            s.plane_absorbs,
            s.deltas,
            patch.in_place,
            patch.repacked,
            patch.cost_patches,
            self.cache.hits,
            self.cache.evictions,
        )
    }

    fn install_instance(&mut self, lp: MatchingLp) -> Result<Fingerprint, String> {
        let fp = Fingerprint::of(&lp);
        match &mut self.resident {
            Some(r) if r.fingerprint() == fp => {
                r.absorb_planes(&lp)?;
                self.stats.plane_absorbs += 1;
            }
            _ => {
                self.resident = Some(ResidentInstance::new(lp)?);
                self.stats.instance_loads += 1;
            }
        }
        Ok(fp)
    }

    /// Apply a mutating request's payload; returns the entry downgraded to
    /// a plain solve of the (now updated) resident instance.
    fn apply_mutation(&mut self, mut entry: QueuedEntry) -> Result<QueuedEntry, ServeError> {
        let payload = std::mem::replace(&mut entry.payload, Payload::Solve);
        match payload {
            Payload::Spec(spec) => {
                let lp = spec.build().map_err(ServeError::Instance)?;
                self.install_instance(lp).map_err(ServeError::Instance)?;
            }
            Payload::Instance(lp) => {
                self.install_instance(*lp).map_err(ServeError::Instance)?;
            }
            Payload::Delta(d) => {
                let resident = self.resident.as_mut().ok_or(ServeError::NoResidentInstance)?;
                resident.apply(&d).map_err(ServeError::Instance)?;
                if self.cfg.audit_parity {
                    resident.parity_check().map_err(ServeError::Instance)?;
                }
                self.stats.deltas += 1;
            }
            Payload::Solve => {}
        }
        Ok(entry)
    }

    /// Solve one wave of requests against the current resident instance on
    /// the cooperative executor.
    fn run_wave(
        &mut self,
        entries: Vec<QueuedEntry>,
        iter_budget: Option<usize>,
        outcomes: &mut Vec<ServeOutcome>,
        parked_out: &mut Vec<QueuedEntry>,
    ) {
        let Some(resident) = self.resident.as_ref() else {
            for e in entries {
                self.stats.failed += 1;
                outcomes.push(ServeOutcome {
                    id: e.id,
                    outcome: Outcome::Failed(ServeError::NoResidentInstance),
                });
            }
            return;
        };
        let fp = resident.fingerprint();
        let quantum = self.cfg.quantum.max(1);
        let tail = self.cfg.warm_tail;

        struct WaveTask<'a> {
            driver: SolveDriver<'static>,
            obj: TimedObjective<SlabCpuObjective<'a>>,
            stepped: usize,
            parked: bool,
        }
        struct Meta {
            id: u64,
            warm: bool,
            resumed: bool,
            slo_ms: Option<f64>,
            admitted: Stopwatch,
        }

        let mut tasks: Vec<WaveTask> = Vec::new();
        let mut metas: Vec<Meta> = Vec::new();
        for e in entries {
            // admission: shed work whose SLO budget is already gone
            let slo = e.slo_ms.or(self.cfg.default_slo_ms);
            let remaining = slo.map(|s| s - e.admitted.elapsed_ms());
            if let Some(rem) = remaining {
                if rem <= 0.0 {
                    self.stats.shed_slo += 1;
                    outcomes.push(ServeOutcome {
                        id: e.id,
                        outcome: Outcome::Shed(ShedReason::SloExpired),
                    });
                    continue;
                }
            }
            let (driver, warm, resumed) = match e.resume {
                Some((ck_fp, ck)) => {
                    if ck_fp != fp {
                        self.stats.failed += 1;
                        outcomes.push(ServeOutcome {
                            id: e.id,
                            outcome: Outcome::Failed(ServeError::FingerprintChanged),
                        });
                        continue;
                    }
                    // no cache lookup on resume: the restored run must do
                    // exactly the cache ops the uninterrupted run would
                    (SolveDriver::resume(ck), true, true)
                }
                None => {
                    let warm = self.cache.lookup(&fp);
                    let mut cold = self.cfg.opts.clone();
                    cold.stopping.min_iters =
                        cold.stopping.min_iters.max(cold.gamma.iters_to_floor() + 1);
                    let (init, opts, is_warm) = match &warm {
                        Some(ws) => (ws.lam.clone(), warm_options(&cold, tail), true),
                        None => (vec![0.0f32; resident.lp().dual_dim()], cold, false),
                    };
                    let dopts = DriverOptions { deadline_ms: remaining, cancel: None };
                    (
                        SolveDriver::new(Box::new(Agd::default().stepper()), &init, opts, dopts),
                        is_warm,
                        false,
                    )
                }
            };
            let obj = TimedObjective::new(resident.objective(self.cfg.objective_threads));
            tasks.push(WaveTask { driver, obj, stepped: 0, parked: false });
            metas.push(Meta { id: e.id, warm, resumed, slo_ms: e.slo_ms, admitted: e.admitted });
        }
        if tasks.is_empty() {
            return;
        }

        let sched = Scheduler::new(self.cfg.threads);
        let cache = &mut self.cache;
        let (tasks, _reasons, _report) = sched.run_coop(
            tasks,
            |_i, task: &mut WaveTask<'_>| {
                let mut events: Vec<(Fingerprint, Vec<f32>, f32)> = Vec::new();
                for _ in 0..quantum {
                    if let Some(b) = iter_budget {
                        if task.stepped >= b {
                            // drain budget hit: stop scheduling this task
                            // WITHOUT stopping its driver — it gets
                            // checkpointed below. The reason is a
                            // scheduler-only sentinel.
                            task.parked = true;
                            return (events, Some(StopReason::Cancelled));
                        }
                    }
                    match task.driver.step(&mut task.obj) {
                        StepEvent::Stopped { reason } => return (events, Some(reason)),
                        StepEvent::GammaDecayed { record, .. } => {
                            task.stepped += 1;
                            // γ checkpoint: publish anytime λ, same
                            // protocol as solve_batch_coop
                            events.push((fp, task.driver.current_lam().to_vec(), record.gamma));
                        }
                        StepEvent::Continue { .. } => task.stepped += 1,
                    }
                }
                (events, None)
            },
            |_i, events| {
                for (f, lam, gamma) in events {
                    cache.insert(f, lam, gamma);
                }
            },
        );

        let mut publish: Vec<(Vec<f32>, f32)> = Vec::new();
        for (mut task, meta) in tasks.into_iter().zip(metas) {
            if task.parked {
                // every shipped stepper checkpoints, but a panic here
                // would take the daemon down mid-drain — fail the one
                // request instead and keep serving (P1)
                let Some(ck) = task.driver.checkpoint() else {
                    self.stats.failed += 1;
                    outcomes.push(ServeOutcome {
                        id: meta.id,
                        outcome: Outcome::Failed(ServeError::CheckpointUnavailable),
                    });
                    continue;
                };
                self.stats.parked += 1;
                parked_out.push(QueuedEntry {
                    id: meta.id,
                    payload: Payload::Solve,
                    slo_ms: meta.slo_ms,
                    admitted: meta.admitted,
                    resume: Some((fp, ck)),
                });
                continue;
            }
            let (batched_kernel_buckets, scalar_kernel_buckets) =
                task.obj.inner.kernel_tier_counts();
            let r = task.driver.result(&mut task.obj);
            self.stats.completed += 1;
            if meta.resumed {
                self.stats.resumed += 1;
            }
            match r.stop_reason {
                StopReason::Deadline => self.stats.deadline_stops += 1,
                StopReason::Cancelled => self.stats.cancelled += 1,
                _ => {}
            }
            if r.iterations > 0 {
                // zero-iteration λ is just the initial value — never cache
                publish.push((r.lam.clone(), r.final_gamma));
            }
            outcomes.push(ServeOutcome {
                id: meta.id,
                outcome: Outcome::Solved(Box::new(JobResult {
                    id: meta.id,
                    fingerprint: fp,
                    warm: meta.warm,
                    iterations: r.iterations,
                    stop_reason: r.stop_reason,
                    dual_obj: r.final_obj.dual_obj,
                    cx: r.final_obj.cx,
                    infeas_pos_norm: r.final_obj.infeas_pos_norm,
                    final_gamma: r.final_gamma,
                    wall_ms: r.total_wall_ms,
                    backend: "slab",
                    shards: 1,
                    objective_eval_ms: task.obj.eval_ms,
                    batched_kernel_buckets,
                    scalar_kernel_buckets,
                    lam: r.lam,
                })),
            });
        }
        for (lam, gamma) in publish {
            self.cache.insert(fp, lam, gamma);
        }
        self.stats.waves += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::workloads::{drift_stream, DriftStreamSpec};
    use crate::gen::{generate, SyntheticConfig};
    use crate::solver::GammaSchedule;

    fn test_cfg() -> ServeConfig {
        ServeConfig {
            opts: SolveOptions {
                max_iters: 60,
                gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 9 },
                ..Default::default()
            },
            threads: 2,
            quantum: 4,
            audit_parity: true,
            ..Default::default()
        }
    }

    fn base_lp(seed: u64) -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 140,
            num_resources: 12,
            seed,
            ..Default::default()
        })
    }

    fn solved(outcomes: &[ServeOutcome]) -> Vec<&JobResult> {
        outcomes
            .iter()
            .filter_map(|o| match &o.outcome {
                Outcome::Solved(r) => Some(r.as_ref()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn drift_stream_serves_warm_with_zero_rebuilds() {
        let base = base_lp(3);
        let stream = drift_stream(&base, &DriftStreamSpec { n: 6, ..Default::default() }, 11);
        let mut d = ServeDaemon::new(test_cfg());
        let outcomes = d.run_stream(&stream, 3);
        assert_eq!(solved(&outcomes).len(), 6, "{:?}", outcomes);
        let s = d.stats();
        // one structural load, every later request absorbed as planes
        assert_eq!(s.instance_loads, 1);
        assert_eq!(s.plane_absorbs, 5);
        let rep = d.resident().unwrap().report;
        assert_eq!(rep.repacked, 0, "pure c/b drift must never repack");
        assert_eq!(rep.cost_patches, 5);
        // same fingerprint throughout → first solve cold, rest warm
        assert_eq!((d.cache().hits, d.cache().misses), (5, 1));
        assert!(solved(&outcomes)[1..].iter().all(|r| r.warm));
        let text = d.report();
        assert!(text.contains("5 plane-absorbs"), "{text}");
        d.resident().unwrap().parity_check().unwrap();
    }

    #[test]
    fn admission_sheds_queue_overflow_and_expired_slo() {
        let mut cfg = test_cfg();
        cfg.max_queue = 2;
        let mut d = ServeDaemon::new(cfg);
        assert!(d.submit(ServeRequest::instance(0, base_lp(4))).is_ok());
        assert!(d.submit(ServeRequest::solve(1)).is_ok());
        assert_eq!(d.submit(ServeRequest::solve(2)), Err(ShedReason::QueueFull));
        // a request whose SLO budget is already spent is shed at solve time
        // (queue has room again after accounting — still depth 2 here, so
        // drain first)
        let first = d.drain();
        assert_eq!(solved(&first).len(), 2);
        d.submit(ServeRequest::solve(3).with_slo_ms(0.0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let out = d.drain();
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0].outcome, Outcome::Shed(ShedReason::SloExpired)));
        let s = d.stats();
        assert_eq!((s.shed_queue_full, s.shed_slo), (1, 1));
    }

    #[test]
    fn solve_without_resident_instance_fails_cleanly() {
        let mut d = ServeDaemon::new(test_cfg());
        d.submit(ServeRequest::solve(9)).unwrap();
        let out = d.drain();
        assert!(matches!(&out[0].outcome, Outcome::Failed(ServeError::NoResidentInstance)));
        // delta without a resident instance likewise
        d.submit(ServeRequest::delta(10, InstanceDelta::Budgets(vec![0.5]))).unwrap();
        let out = d.drain();
        assert!(matches!(&out[0].outcome, Outcome::Failed(ServeError::NoResidentInstance)));
        // failures are typed outcomes, not panics, and render for operators
        let Outcome::Failed(e) = &out[0].outcome else { panic!("expected failure") };
        assert!(e.to_string().contains("resident"));
        assert_eq!(d.stats().failed, 2);
    }

    #[test]
    fn delta_requests_are_barriers_and_keep_parity() {
        let base = base_lp(5);
        let nnz = base.nnz();
        let mut costs = base.cost.clone();
        for c in &mut costs {
            *c *= 1.01;
        }
        let mut d = ServeDaemon::new(test_cfg());
        d.submit(ServeRequest::instance(0, base)).unwrap();
        d.submit(ServeRequest::solve(1)).unwrap();
        d.submit(ServeRequest::delta(2, InstanceDelta::Costs(costs))).unwrap();
        d.submit(ServeRequest::solve(3)).unwrap();
        let out = d.drain();
        assert_eq!(solved(&out).len(), 4);
        let s = d.stats();
        // wave boundaries: [0,1] then [2,3] — the delta is a barrier
        assert_eq!(s.waves, 2);
        assert_eq!(s.deltas, 1);
        assert_eq!(d.resident().unwrap().lp().nnz(), nnz);
        d.resident().unwrap().parity_check().unwrap();
        // the cost delta keeps the fingerprint → later solves stay warm
        assert!(solved(&out)[3].warm);
    }

    #[test]
    fn park_snapshot_restore_resumes_bit_identically() {
        let cfg = test_cfg();
        let lp = base_lp(6);

        // uninterrupted daemon
        let mut a = ServeDaemon::new(cfg.clone());
        a.submit(ServeRequest::instance(7, lp.clone())).unwrap();
        let ra = a.drain();
        let ja = solved(&ra)[0].clone();

        // parked daemon: 13 iterations, then snapshot mid-solve
        let mut b = ServeDaemon::new(cfg.clone());
        b.submit(ServeRequest::instance(7, lp.clone())).unwrap();
        let rb = b.drain_budget(Some(13));
        assert!(solved(&rb).is_empty(), "must have parked, not finished");
        assert_eq!(b.pending(), 1);
        assert_eq!(b.stats().parked, 1);
        let bytes = b.snapshot_bytes().unwrap();

        // restored daemon: reload the instance, finish the solve
        let mut c = ServeDaemon::restore(cfg, &bytes).unwrap();
        assert_eq!(c.pending(), 1);
        c.load_instance(lp).unwrap();
        let rc = c.drain();
        let jc = &solved(&rc)[0];
        assert_eq!(jc.id, 7);
        assert_eq!(c.stats().resumed, 1);

        // bit-identical to the run that never stopped
        assert_eq!(ja.iterations, jc.iterations);
        assert_eq!(ja.stop_reason, jc.stop_reason);
        assert_eq!(ja.dual_obj.to_bits(), jc.dual_obj.to_bits());
        assert_eq!(ja.final_gamma.to_bits(), jc.final_gamma.to_bits());
        assert_eq!(ja.lam.len(), jc.lam.len());
        for (x, y) in ja.lam.iter().zip(&jc.lam) {
            assert_eq!(x.to_bits(), y.to_bits(), "λ diverged across restart");
        }

        // and the durable cache state matches tick for tick
        assert_eq!(a.cache().tick(), c.cache().tick());
        let ea = a.cache().export_entries();
        let ec = c.cache().export_entries();
        assert_eq!(ea.len(), ec.len());
        for ((fa, wa, ta), (fc, wc, tc)) in ea.iter().zip(&ec) {
            assert_eq!(fa, fc);
            assert_eq!(ta, tc);
            assert_eq!(wa.gamma.to_bits(), wc.gamma.to_bits());
            for (x, y) in wa.lam.iter().zip(&wc.lam) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn restored_checkpoint_rejects_changed_instance() {
        let cfg = test_cfg();
        let mut b = ServeDaemon::new(cfg.clone());
        b.submit(ServeRequest::instance(1, base_lp(6))).unwrap();
        b.drain_budget(Some(5));
        let bytes = b.snapshot_bytes().unwrap();
        let mut c = ServeDaemon::restore(cfg, &bytes).unwrap();
        c.load_instance(base_lp(7)).unwrap(); // different instance
        let out = c.drain();
        assert!(
            matches!(&out[0].outcome, Outcome::Failed(ServeError::FingerprintChanged)),
            "{:?}",
            out
        );
    }
}
