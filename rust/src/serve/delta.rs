//! In-place instance deltas against a resident slab (DESIGN.md §9): the
//! serve daemon keeps one instance hot — the [`MatchingLp`] plus its built
//! [`SlabLayout`] and canonical chunk grid — and absorbs request-stream
//! drift without ever rebuilding the layout from scratch:
//!
//! * **Plane deltas** (perturbed `c` / `b` / global RHS, same sparsity
//!   pattern): `c` is rewritten through [`SlabLayout::patch_costs`], `b`
//!   and global RHS live only on the LP (the objective reads them at
//!   construction). Zero structural work.
//! * **Edge deltas** (bounded insert/delete): spliced into the LP and then
//!   patched into the slab via [`SlabLayout::patch_edge_indexed`] — the
//!   resident [`SlabIndex`] locates the edited source's rows in O(1) —
//!   absorbed by
//!   padding headroom when the source stays in its bucket row
//!   ([`EdgePatch::InPlace`]), else a single-bucket repack
//!   ([`EdgePatch::Repacked`], grid refreshed). Never a full rebuild.
//!
//! The invariant — test-gated here and re-checked by the daemon's parity
//! gate — is that a patched resident layout is **bit-identical** to a
//! from-scratch [`SlabLayout::build`] of the edited LP, so the delta path
//! solves on exactly the bits a rebuild would have produced.

use std::sync::Arc;

use crate::backend::slab_cpu::SlabCpuObjective;
use crate::engine::Fingerprint;
use crate::problem::MatchingLp;
use crate::sparse::slabs::{BuildOptions, EdgePatch, PatchReport, MAX_WIDTH};
use crate::sparse::{SlabChunk, SlabIndex, SlabLayout};

/// One edit against the resident instance.
#[derive(Clone, Debug)]
pub enum InstanceDelta {
    /// Replace the full cost plane (length must equal `nnz`).
    Costs(Vec<f32>),
    /// Replace the full matching budget plane (length must equal the
    /// resident `b` length, i.e. families × dests).
    Budgets(Vec<f32>),
    /// Replace the global-row right-hand sides (length must equal the
    /// number of global rows).
    GlobalRhs(Vec<f32>),
    /// Insert edge `(source, dest)` with per-family coefficients and cost.
    InsertEdge { source: usize, dest: u32, a: Vec<f32>, cost: f32 },
    /// Remove edge `(source, dest)`.
    RemoveEdge { source: usize, dest: u32 },
}

/// A hot instance: the LP, its built slab layout (shared with any
/// outstanding objective via `Arc` — patching uses copy-on-write, so an
/// in-flight solve keeps reading the bits it started with), and the
/// canonical chunk grid.
pub struct ResidentInstance {
    lp: MatchingLp,
    layout: Arc<SlabLayout>,
    /// Inverted source→row index over `layout`, maintained incrementally
    /// by the edge-delta path so patches never rescan bucket source
    /// lists.
    index: SlabIndex,
    grid: Vec<SlabChunk>,
    fingerprint: Fingerprint,
    /// Running tally of how edits were absorbed (in-place vs repack) —
    /// the daemon surfaces this; `repacked == 0` under a pure c/b drift
    /// stream is the "zero rebuild" acceptance signal.
    pub report: PatchReport,
}

impl ResidentInstance {
    /// Build the resident slab for `lp`. Errors if the LP is invalid or
    /// the layout is unbuildable (overwide non-separable block).
    pub fn new(lp: MatchingLp) -> Result<ResidentInstance, String> {
        lp.validate()?;
        let layout = Arc::new(SlabLayout::build(&lp.a, &lp.cost, 0, lp.num_sources(), &|i| {
            lp.projection.kind_of(i)
        })?);
        let index = SlabIndex::build(&layout, 0, lp.num_sources());
        let grid = layout.fixed_chunk_grid();
        let fingerprint = Fingerprint::of(&lp);
        Ok(ResidentInstance {
            lp,
            layout,
            index,
            grid,
            fingerprint,
            report: PatchReport::default(),
        })
    }

    pub fn lp(&self) -> &MatchingLp {
        &self.lp
    }

    pub fn fingerprint(&self) -> Fingerprint {
        self.fingerprint
    }

    pub fn layout(&self) -> &Arc<SlabLayout> {
        &self.layout
    }

    pub fn grid(&self) -> &[SlabChunk] {
        &self.grid
    }

    /// The resident inverted source→row index (kept in lockstep with
    /// [`Self::layout`] by the edge-delta path).
    pub fn index(&self) -> &SlabIndex {
        &self.index
    }

    /// A full-range objective over the resident slab. Construction is
    /// O(buckets) — no layout build — so per-request objective setup stays
    /// cheap even as deltas accumulate.
    pub fn objective(&self, threads: usize) -> SlabCpuObjective<'_> {
        SlabCpuObjective::new_shard(
            &self.lp,
            self.layout.clone(),
            &self.grid,
            0,
            self.grid.len(),
            threads,
        )
    }

    /// Absorb another instance with the **same fingerprint** as a plane
    /// delta: its `c`, `b` and global RHS replace the resident planes with
    /// zero structural work. This is how the daemon treats a request that
    /// ships a full (drifted) instance whose pattern matches the resident
    /// one. Errors (resident untouched) on fingerprint mismatch.
    pub fn absorb_planes(&mut self, other: &MatchingLp) -> Result<(), String> {
        let fp = Fingerprint::of(other);
        if fp != self.fingerprint {
            return Err(
                "instance fingerprint does not match resident instance; \
                 load it as a new resident instance instead"
                    .to_string(),
            );
        }
        self.lp.cost.copy_from_slice(&other.cost);
        self.lp.b.copy_from_slice(&other.b);
        for (row, new) in self.lp.global_rows.iter_mut().zip(&other.global_rows) {
            row.rhs = new.rhs;
        }
        Arc::make_mut(&mut self.layout).patch_costs(&self.lp.cost);
        self.report.cost_patches += 1;
        Ok(())
    }

    /// Apply one delta in place. Plane deltas return `Ok(None)`; edge
    /// deltas return how the slab absorbed them. On `Err` the resident
    /// instance is untouched.
    pub fn apply(&mut self, delta: &InstanceDelta) -> Result<Option<EdgePatch>, String> {
        match delta {
            InstanceDelta::Costs(c) => {
                if c.len() != self.lp.nnz() {
                    return Err(format!(
                        "cost delta length {} != nnz {}",
                        c.len(),
                        self.lp.nnz()
                    ));
                }
                self.lp.cost.copy_from_slice(c);
                Arc::make_mut(&mut self.layout).patch_costs(&self.lp.cost);
                self.report.cost_patches += 1;
                Ok(None)
            }
            InstanceDelta::Budgets(b) => {
                if b.len() != self.lp.b.len() {
                    return Err(format!(
                        "budget delta length {} != b length {}",
                        b.len(),
                        self.lp.b.len()
                    ));
                }
                self.lp.b.copy_from_slice(b);
                Ok(None)
            }
            InstanceDelta::GlobalRhs(rhs) => {
                if rhs.len() != self.lp.global_rows.len() {
                    return Err(format!(
                        "global rhs delta length {} != {} global rows",
                        rhs.len(),
                        self.lp.global_rows.len()
                    ));
                }
                for (row, &v) in self.lp.global_rows.iter_mut().zip(rhs) {
                    row.rhs = v;
                }
                Ok(None)
            }
            InstanceDelta::InsertEdge { source, dest, a, cost } => {
                self.edge_edit(*source, |lp| lp.insert_edge(*source, *dest, a, *cost), true, 1)
            }
            InstanceDelta::RemoveEdge { source, dest } => {
                self.edge_edit(*source, |lp| lp.remove_edge(*source, *dest), false, -1)
            }
        }
    }

    fn edge_edit(
        &mut self,
        source: usize,
        splice: impl FnOnce(&mut MatchingLp) -> Result<usize, String>,
        insert: bool,
        deg_delta: isize,
    ) -> Result<Option<EdgePatch>, String> {
        // Global constraint rows index edges by position — a splice would
        // invalidate every coefficient vector. Reject rather than rebuild.
        if !self.lp.global_rows.is_empty() {
            return Err(
                "edge deltas are not supported while global constraint rows are resident \
                 (their coefficient planes are edge-indexed)"
                    .to_string(),
            );
        }
        // Pre-check the one failure `patch_edge` can hit AFTER the LP
        // splice, so an error never leaves LP and layout out of sync.
        let kind = self.lp.projection.kind_of(source);
        if source >= self.lp.num_sources() {
            return Err(format!("source {source} out of range"));
        }
        let new_deg = self.lp.a.degree(source) as isize + deg_delta;
        if new_deg > MAX_WIDTH as isize && !kind.separable() {
            return Err(format!(
                "source {source} degree {new_deg} would exceed slab width for a \
                 non-separable projection"
            ));
        }
        let edge = splice(&mut self.lp)?;
        let patch = Arc::make_mut(&mut self.layout)
            .patch_edge_indexed(
                &self.lp.a,
                &self.lp.cost,
                source,
                edge,
                insert,
                kind,
                &mut self.index,
            )
            // pre-checked above, so this arm is believed dead — but a
            // miss must surface as a shed request, not a daemon panic
            .map_err(|e| {
                format!(
                    "patch_edge failed after LP splice (resident layout may be \
                     stale; reload the instance): {e}"
                )
            })?;
        if matches!(patch, EdgePatch::Repacked) {
            self.grid = self.layout.fixed_chunk_grid();
        }
        self.fingerprint = Fingerprint::of(&self.lp);
        self.report.note(patch);
        Ok(Some(patch))
    }

    /// Parity gate: assert the patched resident layout (and grid) is
    /// bit-identical to a from-scratch rebuild of the current LP. O(nnz) —
    /// meant for tests and the daemon's opt-in audit mode, not the hot
    /// path.
    pub fn parity_check(&self) -> Result<(), String> {
        let opts = BuildOptions { policy: self.layout.policy, threads: 0 };
        let fresh = SlabLayout::build_opts(
            &self.lp.a,
            &self.lp.cost,
            0,
            self.lp.num_sources(),
            &|i| self.lp.projection.kind_of(i),
            opts,
        )?;
        self.layout.bit_eq(&fresh)?;
        self.index.parity_check(&self.layout)?;
        let fresh_grid = fresh.fixed_chunk_grid();
        if self.grid.len() != fresh_grid.len() {
            return Err(format!(
                "grid has {} chunks, rebuild has {}",
                self.grid.len(),
                fresh_grid.len()
            ));
        }
        for (i, (a, b)) in self.grid.iter().zip(&fresh_grid).enumerate() {
            if (a.bucket, a.row_lo, a.row_hi) != (b.bucket, b.row_lo, b.row_hi) {
                return Err(format!("grid chunk {i} differs from rebuild"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::workloads::{perturb_instance, PerturbSpec};
    use crate::gen::{generate, SyntheticConfig};
    use crate::problem::ObjectiveFunction;
    use crate::solver::{Agd, DriverOptions, SolveDriver, SolveOptions, StepEvent};

    fn base_lp(seed: u64) -> MatchingLp {
        generate(&SyntheticConfig {
            num_requests: 160,
            num_resources: 14,
            seed,
            ..Default::default()
        })
    }

    fn solve_bits(obj: &mut dyn ObjectiveFunction, dual_dim: usize, iters: usize) -> Vec<u32> {
        let opts = SolveOptions { max_iters: iters, ..Default::default() };
        let init = vec![0.0f32; dual_dim];
        let mut d = SolveDriver::new(
            Box::new(Agd::default().stepper()),
            &init,
            opts,
            DriverOptions::default(),
        );
        loop {
            if let StepEvent::Stopped { .. } = d.step(obj) {
                break;
            }
        }
        d.current_lam().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn plane_delta_patches_in_place_and_solves_like_rebuild() {
        let base = base_lp(5);
        let drifted = perturb_instance(&base, &PerturbSpec::default(), 99);
        let mut resident = ResidentInstance::new(base).unwrap();
        let before_ptr = Arc::as_ptr(resident.layout());
        resident.absorb_planes(&drifted).unwrap();
        // no outstanding objective → copy-on-write patched the same
        // allocation: literally zero rebuild, zero copy
        assert_eq!(Arc::as_ptr(resident.layout()), before_ptr);
        assert_eq!(resident.report.cost_patches, 1);
        resident.parity_check().unwrap();

        let dim = drifted.dual_dim();
        let mut patched = resident.objective(1);
        let mut fresh = SlabCpuObjective::new(&drifted, 1).unwrap();
        assert_eq!(solve_bits(&mut patched, dim, 30), solve_bits(&mut fresh, dim, 30));
    }

    #[test]
    fn absorb_planes_rejects_different_pattern() {
        let base = base_lp(5);
        let other = base_lp(6); // different seed → different sparsity
        let mut resident = ResidentInstance::new(base).unwrap();
        assert!(resident.absorb_planes(&other).is_err());
        assert_eq!(resident.report.cost_patches, 0);
        resident.parity_check().unwrap();
    }

    #[test]
    fn edge_deltas_patch_without_rebuild_and_keep_parity() {
        let mut resident = ResidentInstance::new(base_lp(7)).unwrap();
        let fam = resident.lp().num_families();
        let fp0 = resident.fingerprint();

        // find a source with a missing dest to insert
        let lp = resident.lp();
        let (src, dest) = (0..lp.num_sources())
            .find_map(|s| {
                let (e0, e1) = (lp.a.src_ptr[s], lp.a.src_ptr[s + 1]);
                let have: Vec<u32> = lp.a.dest_idx[e0..e1].to_vec();
                (0..lp.num_dests() as u32).find(|d| !have.contains(d)).map(|d| (s, d))
            })
            .expect("some source has a free dest");

        let ins = InstanceDelta::InsertEdge {
            source: src,
            dest,
            a: vec![0.5; fam],
            cost: -0.25,
        };
        resident.apply(&ins).unwrap().expect("edge patch");
        assert_ne!(resident.fingerprint(), fp0, "pattern edit must re-fingerprint");
        resident.parity_check().unwrap();

        let rm = InstanceDelta::RemoveEdge { source: src, dest };
        resident.apply(&rm).unwrap().expect("edge patch");
        resident.parity_check().unwrap();
        assert_eq!(resident.report.in_place + resident.report.repacked, 2);

        // and the patched slab still solves exactly like a rebuild
        let dim = resident.lp().dual_dim();
        let lp_copy = resident.lp().clone();
        let mut patched = resident.objective(1);
        let mut fresh = SlabCpuObjective::new(&lp_copy, 1).unwrap();
        assert_eq!(solve_bits(&mut patched, dim, 25), solve_bits(&mut fresh, dim, 25));
    }

    #[test]
    fn repack_refreshes_grid() {
        let lp = generate(&SyntheticConfig {
            num_requests: 120,
            num_resources: 64,
            seed: 8,
            ..Default::default()
        });
        let mut resident = ResidentInstance::new(lp).unwrap();
        let fam = resident.lp().num_families();
        // pick the thinnest (non-isolated) source and fill its row: its
        // bucket width must cross a power-of-two boundary on the way up,
        // forcing at least one repack
        let src = (0..resident.lp().num_sources())
            .filter(|&s| resident.lp().a.degree(s) > 0)
            .min_by_key(|&s| resident.lp().a.degree(s))
            .unwrap();
        for d in 0..resident.lp().num_dests() as u32 {
            let (e0, e1) = (resident.lp().a.src_ptr[src], resident.lp().a.src_ptr[src + 1]);
            if resident.lp().a.dest_idx[e0..e1].contains(&d) {
                continue;
            }
            let delta = InstanceDelta::InsertEdge {
                source: src,
                dest: d,
                a: vec![1.0; fam],
                cost: -0.5,
            };
            resident.apply(&delta).unwrap();
        }
        assert!(resident.report.repacked > 0, "filling a row must widen its bucket");
        resident.parity_check().unwrap(); // parity includes the grid
    }

    #[test]
    fn bad_deltas_leave_resident_untouched() {
        let mut resident = ResidentInstance::new(base_lp(9)).unwrap();
        let nnz = resident.lp().nnz();
        assert!(resident.apply(&InstanceDelta::Costs(vec![0.0; nnz + 1])).is_err());
        assert!(resident.apply(&InstanceDelta::Budgets(vec![0.0; 1])).is_err());
        assert!(resident
            .apply(&InstanceDelta::GlobalRhs(vec![1.0]))
            .is_err());
        // duplicate-dest insert: LP splice rejects, layout must not change
        let e0 = resident.lp().a.src_ptr[0];
        let existing = resident.lp().a.dest_idx[e0];
        let fam = resident.lp().num_families();
        let dup = InstanceDelta::InsertEdge {
            source: 0,
            dest: existing,
            a: vec![1.0; fam],
            cost: 0.0,
        };
        assert!(resident.apply(&dup).is_err());
        assert_eq!(resident.lp().nnz(), nnz);
        resident.parity_check().unwrap();
    }

    #[test]
    fn edge_deltas_rejected_with_global_rows() {
        let mut lp = base_lp(10);
        let nnz = lp.nnz();
        lp.push_global_row(vec![1.0; nnz], 5.0);
        let mut resident = ResidentInstance::new(lp).unwrap();
        let fam = resident.lp().num_families();
        let d = InstanceDelta::InsertEdge { source: 0, dest: 0, a: vec![1.0; fam], cost: 0.0 };
        let err = resident.apply(&d).unwrap_err();
        assert!(err.contains("global"), "{err}");
        // but plane deltas (incl. global rhs) still work
        resident.apply(&InstanceDelta::GlobalRhs(vec![6.0])).unwrap();
        assert_eq!(resident.lp().global_rows[0].rhs, 6.0);
    }
}
