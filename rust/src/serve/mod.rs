//! Resident serving subsystem (DESIGN.md §9): a long-lived daemon that
//! keeps one instance hot and turns the batch engine's cooperative
//! executor into a request-serving loop.
//!
//! Three pieces:
//!
//! * [`daemon`] — the request queue with admission control (bounded depth,
//!   SLO-budget shedding, per-request deadlines) and the wave loop over
//!   [`crate::engine::Scheduler::run_coop`];
//! * [`delta`] — in-place instance deltas against the resident slab
//!   (c/b/RHS plane patches with zero rebuild, bounded edge insert/delete
//!   via bucket patching) plus the bit-parity gate against a from-scratch
//!   rebuild;
//! * [`snapshot`] — the versioned on-disk codec for durable warm-start
//!   state (LRU dual cache + parked solve checkpoints) that lets a
//!   restarted daemon resume bit-identically.

pub mod daemon;
pub mod delta;
pub mod snapshot;

pub use daemon::{
    Outcome, Payload, ServeConfig, ServeDaemon, ServeError, ServeOutcome, ServeRequest,
    ServeStats, ShedReason,
};
pub use delta::{InstanceDelta, ResidentInstance};
pub use snapshot::{CheckpointEntry, ServeSnapshot};
