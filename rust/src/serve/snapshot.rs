//! Durable warm-start state (DESIGN.md §9): a versioned on-disk snapshot
//! of the serve daemon's LRU dual cache plus the checkpoints of any
//! in-flight (parked) solves, so a restarted daemon resumes warm and
//! continues parked solves **bit-identically** to a process that never
//! stopped.
//!
//! The codec is a hand-rolled little-endian binary format (no serde
//! offline, same constraint as `metrics::bench_json`): magic `DLPS`, a
//! `u32` version, then the cache section and the checkpoint section.
//! Floats travel as raw IEEE bits (`to_bits`/`from_bits`), never through
//! text — bit-identity is the contract, not approximate equality. Cache
//! entries are written oldest-first with their exact LRU ticks (ticks are
//! unique — see `WarmStartCache::export_entries`), so a restored cache
//! evicts in exactly the order the live one would have.
//!
//! What is NOT in a snapshot: the instances themselves (the daemon's
//! resident instance is reloaded by the operator; fingerprints are the
//! join key), observers (never part of a checkpoint), and cancellation
//! tokens (`DriverOptions::cancel` is a live process handle — a restored
//! checkpoint carries the deadline budget only).

use std::path::Path;

use crate::engine::{Fingerprint, WarmStart, WarmStartCache};
use crate::problem::ObjectiveResult;
use crate::solver::{
    restore_stepper, Checkpoint, DriverOptions, GammaSchedule, IterRecord, SolveOptions,
    SolveState, StepperState, StopReason, StoppingCriteria,
};

const MAGIC: &[u8; 4] = b"DLPS";
const VERSION: u32 = 1;

/// One parked solve in a snapshot: which request it was, which instance
/// (by fingerprint) it was solving, and the full driver checkpoint.
pub struct CheckpointEntry {
    pub request_id: u64,
    pub fingerprint: Fingerprint,
    pub checkpoint: Checkpoint,
}

/// A decoded snapshot.
pub struct ServeSnapshot {
    pub cache: WarmStartCache,
    pub checkpoints: Vec<CheckpointEntry>,
}

// ---------------------------------------------------------------------------
// byte stream primitives

struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
        }
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        // checked slice access: a truncated (or absurd-length) snapshot is
        // a decode error surfaced to the caller, never a daemon panic
        let s = self
            .pos
            .checked_add(n)
            .and_then(|end| self.buf.get(self.pos..end))
            .ok_or_else(|| {
                format!(
                    "snapshot truncated: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len().saturating_sub(self.pos)
                )
            })?;
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }

    /// A u64 that must fit a usize and be a sane element count for the
    /// remaining bytes (corrupt snapshots must error, not OOM). Use ONLY
    /// for lengths of data that follows in the stream — counters and
    /// dimensions (a fingerprint's `nnz`, a checkpoint's iteration count)
    /// legitimately dwarf the snapshot itself and go through [`Self::idx`].
    fn len(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        let n = usize::try_from(v).map_err(|_| format!("snapshot length {v} overflows usize"))?;
        if n > self.buf.len() {
            return Err(format!(
                "snapshot length {n} exceeds remaining payload ({} bytes total)",
                self.buf.len()
            ));
        }
        Ok(n)
    }

    /// A u64 that must fit a usize: plain data (dimension / counter), not
    /// an allocation length — no payload bound applies.
    fn idx(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("snapshot value {v} overflows usize"))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.len()?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| "snapshot string is not UTF-8".to_string())
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, String> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            t => Err(format!("bad Option tag {t}")),
        }
    }

    fn done(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "snapshot has {} trailing bytes after decode",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// stop-reason codes (stable wire values — NOT the enum's declaration order
// contractually, so spell the mapping out both ways)

fn stop_code(r: StopReason) -> u8 {
    match r {
        StopReason::MaxIters => 0,
        StopReason::GradNormTol => 1,
        StopReason::ObjectiveStall => 2,
        StopReason::Deadline => 3,
        StopReason::Cancelled => 4,
    }
}

fn stop_from(code: u8) -> Result<StopReason, String> {
    Ok(match code {
        0 => StopReason::MaxIters,
        1 => StopReason::GradNormTol,
        2 => StopReason::ObjectiveStall,
        3 => StopReason::Deadline,
        4 => StopReason::Cancelled,
        t => return Err(format!("bad StopReason code {t}")),
    })
}

// ---------------------------------------------------------------------------
// section codecs

fn write_fingerprint(w: &mut ByteWriter, fp: &Fingerprint) {
    w.u64(fp.num_sources as u64);
    w.u64(fp.num_dests as u64);
    w.u64(fp.num_families as u64);
    w.u64(fp.num_global_rows as u64);
    w.u64(fp.nnz as u64);
    w.u64(fp.pattern_hash);
    w.u64(fp.projection_hash);
    w.u64(fp.global_coeff_hash);
    w.u64(fp.coeff_hash);
}

fn read_fingerprint(r: &mut ByteReader) -> Result<Fingerprint, String> {
    Ok(Fingerprint {
        num_sources: r.idx()?,
        num_dests: r.idx()?,
        num_families: r.idx()?,
        num_global_rows: r.idx()?,
        nnz: r.idx()?,
        pattern_hash: r.u64()?,
        projection_hash: r.u64()?,
        global_coeff_hash: r.u64()?,
        coeff_hash: r.u64()?,
    })
}

fn write_f32s(w: &mut ByteWriter, v: &[f32]) {
    w.u64(v.len() as u64);
    for &x in v {
        w.f32(x);
    }
}

fn read_f32s(r: &mut ByteReader) -> Result<Vec<f32>, String> {
    let n = r.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.f32()?);
    }
    Ok(out)
}

fn write_cache(w: &mut ByteWriter, cache: &WarmStartCache) {
    w.u64(cache.capacity() as u64);
    w.u64(cache.tick());
    w.u64(cache.hits);
    w.u64(cache.misses);
    w.u64(cache.evictions);
    let entries = cache.export_entries();
    w.u64(entries.len() as u64);
    for (fp, ws, last_used) in &entries {
        write_fingerprint(w, fp);
        w.u64(*last_used);
        w.f32(ws.gamma);
        w.u64(ws.refreshes);
        write_f32s(w, &ws.lam);
    }
}

fn read_cache(r: &mut ByteReader) -> Result<WarmStartCache, String> {
    let capacity = r.idx()?;
    let tick = r.u64()?;
    let hits = r.u64()?;
    let misses = r.u64()?;
    let evictions = r.u64()?;
    let n = r.len()?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let fp = read_fingerprint(r)?;
        let last_used = r.u64()?;
        let gamma = r.f32()?;
        let refreshes = r.u64()?;
        let lam = read_f32s(r)?;
        if lam.len() != fp.dual_dim() {
            return Err(format!(
                "cache entry λ length {} does not match fingerprint dual dim {}",
                lam.len(),
                fp.dual_dim()
            ));
        }
        entries.push((fp, WarmStart { lam, gamma, refreshes }, last_used));
    }
    Ok(WarmStartCache::from_parts(capacity, tick, hits, misses, evictions, entries))
}

fn write_stepper(w: &mut ByteWriter, s: &StepperState) {
    w.str(&s.name);
    w.u64(s.flags.len() as u64);
    for &f in &s.flags {
        w.u8(f as u8);
    }
    w.u64(s.vecs.len() as u64);
    for v in &s.vecs {
        write_f32s(w, v);
    }
    w.u64(s.scalars.len() as u64);
    for &x in &s.scalars {
        w.f64(x);
    }
    w.u64(s.counters.len() as u64);
    for &c in &s.counters {
        w.u64(c);
    }
}

fn read_stepper(r: &mut ByteReader) -> Result<StepperState, String> {
    let name = r.str()?;
    let nf = r.len()?;
    let mut flags = Vec::with_capacity(nf);
    for _ in 0..nf {
        flags.push(match r.u8()? {
            0 => false,
            1 => true,
            t => return Err(format!("bad bool byte {t}")),
        });
    }
    let nv = r.len()?;
    let mut vecs = Vec::with_capacity(nv);
    for _ in 0..nv {
        vecs.push(read_f32s(r)?);
    }
    let ns = r.len()?;
    let mut scalars = Vec::with_capacity(ns);
    for _ in 0..ns {
        scalars.push(r.f64()?);
    }
    let nc = r.len()?;
    let mut counters = Vec::with_capacity(nc);
    for _ in 0..nc {
        counters.push(r.u64()?);
    }
    Ok(StepperState { name, flags, vecs, scalars, counters })
}

fn write_objective_result(w: &mut ByteWriter, o: &ObjectiveResult) {
    write_f32s(w, &o.grad);
    w.f64(o.dual_obj);
    w.f64(o.cx);
    w.f64(o.xsq_weighted);
    w.f64(o.infeas_pos_norm);
}

fn read_objective_result(r: &mut ByteReader) -> Result<ObjectiveResult, String> {
    Ok(ObjectiveResult {
        grad: read_f32s(r)?,
        dual_obj: r.f64()?,
        cx: r.f64()?,
        xsq_weighted: r.f64()?,
        infeas_pos_norm: r.f64()?,
    })
}

fn write_state(w: &mut ByteWriter, s: &SolveState) {
    w.u64(s.t as u64);
    w.u64(s.stall_run as u64);
    match &s.last {
        None => w.u8(0),
        Some(o) => {
            w.u8(1);
            write_objective_result(w, o);
        }
    }
    w.u64(s.trajectory.len() as u64);
    for t in &s.trajectory {
        w.u64(t.iter as u64);
        w.f64(t.dual_obj);
        w.f64(t.grad_norm);
        w.f64(t.infeas_pos_norm);
        w.f64(t.cx);
        w.f32(t.gamma);
        w.f64(t.step_size);
        w.f64(t.wall_ms);
    }
    match s.stop_reason {
        None => w.u8(255),
        Some(r) => w.u8(stop_code(r)),
    }
    w.f64(s.wall_offset_ms);
}

fn read_state(r: &mut ByteReader) -> Result<SolveState, String> {
    let t = r.idx()?;
    let stall_run = r.idx()?;
    let last = match r.u8()? {
        0 => None,
        1 => Some(read_objective_result(r)?),
        tag => return Err(format!("bad Option tag {tag}")),
    };
    let n = r.len()?;
    let mut trajectory = Vec::with_capacity(n);
    for _ in 0..n {
        trajectory.push(IterRecord {
            iter: r.idx()?,
            dual_obj: r.f64()?,
            grad_norm: r.f64()?,
            infeas_pos_norm: r.f64()?,
            cx: r.f64()?,
            gamma: r.f32()?,
            step_size: r.f64()?,
            wall_ms: r.f64()?,
        });
    }
    let stop_reason = match r.u8()? {
        255 => None,
        code => Some(stop_from(code)?),
    };
    let wall_offset_ms = r.f64()?;
    Ok(SolveState { t, stall_run, last, trajectory, stop_reason, wall_offset_ms })
}

fn write_options(w: &mut ByteWriter, o: &SolveOptions) {
    w.u64(o.max_iters as u64);
    w.f64(o.max_step_size);
    w.f64(o.initial_step_size);
    match o.gamma {
        GammaSchedule::Fixed(g) => {
            w.u8(0);
            w.f32(g);
        }
        GammaSchedule::Decay { init, floor, factor, every } => {
            w.u8(1);
            w.f32(init);
            w.f32(floor);
            w.f32(factor);
            w.u64(every as u64);
        }
    }
    w.opt_f64(o.stopping.grad_norm_tol);
    w.opt_f64(o.stopping.stall_tol);
    w.u64(o.stopping.stall_patience as u64);
    w.u64(o.stopping.min_iters as u64);
    w.u64(o.record_every as u64);
}

fn read_options(r: &mut ByteReader) -> Result<SolveOptions, String> {
    let max_iters = r.idx()?;
    let max_step_size = r.f64()?;
    let initial_step_size = r.f64()?;
    let gamma = match r.u8()? {
        0 => GammaSchedule::Fixed(r.f32()?),
        1 => GammaSchedule::Decay {
            init: r.f32()?,
            floor: r.f32()?,
            factor: r.f32()?,
            every: r.idx()?,
        },
        t => return Err(format!("bad GammaSchedule tag {t}")),
    };
    let stopping = StoppingCriteria {
        grad_norm_tol: r.opt_f64()?,
        stall_tol: r.opt_f64()?,
        stall_patience: r.idx()?,
        min_iters: r.idx()?,
    };
    let record_every = r.idx()?;
    Ok(SolveOptions {
        max_iters,
        max_step_size,
        initial_step_size,
        gamma,
        stopping,
        record_every,
    })
}

// ---------------------------------------------------------------------------
// public API

/// Serialize the daemon's durable state. Errors if a checkpoint's stepper
/// does not support export (every shipped stepper does).
pub fn encode(cache: &WarmStartCache, checkpoints: &[CheckpointEntry]) -> Result<Vec<u8>, String> {
    let mut w = ByteWriter::new();
    w.buf.extend_from_slice(MAGIC);
    w.u32(VERSION);
    write_cache(&mut w, cache);
    w.u64(checkpoints.len() as u64);
    for e in checkpoints {
        let stepper = e
            .checkpoint
            .export_stepper()
            .ok_or_else(|| "checkpoint stepper does not support state export".to_string())?;
        w.u64(e.request_id);
        write_fingerprint(&mut w, &e.fingerprint);
        write_stepper(&mut w, &stepper);
        write_state(&mut w, e.checkpoint.state());
        write_options(&mut w, e.checkpoint.options());
        w.opt_f64(e.checkpoint.driver_options().deadline_ms);
    }
    Ok(w.buf)
}

/// Decode a snapshot. Rejects bad magic, unknown versions, malformed
/// records, truncation and trailing garbage.
pub fn decode(bytes: &[u8]) -> Result<ServeSnapshot, String> {
    let mut r = ByteReader::new(bytes);
    if r.take(4)? != MAGIC {
        return Err("not a dualip snapshot (bad magic)".to_string());
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!("unsupported snapshot version {version} (expected {VERSION})"));
    }
    let cache = read_cache(&mut r)?;
    let n = r.len()?;
    let mut checkpoints = Vec::with_capacity(n);
    for _ in 0..n {
        let request_id = r.u64()?;
        let fingerprint = read_fingerprint(&mut r)?;
        let stepper_state = read_stepper(&mut r)?;
        let state = read_state(&mut r)?;
        let opts = read_options(&mut r)?;
        let deadline_ms = r.opt_f64()?;
        let stepper = restore_stepper(&stepper_state).ok_or_else(|| {
            format!("cannot restore stepper {:?} from snapshot", stepper_state.name)
        })?;
        let checkpoint = Checkpoint::from_parts(
            stepper,
            state,
            opts,
            DriverOptions { deadline_ms, cancel: None },
        );
        checkpoints.push(CheckpointEntry { request_id, fingerprint, checkpoint });
    }
    r.done()?;
    Ok(ServeSnapshot { cache, checkpoints })
}

/// Write a snapshot to disk (via a sibling temp file + rename, so a crash
/// mid-write never leaves a truncated snapshot at the target path).
pub fn save(
    path: impl AsRef<Path>,
    cache: &WarmStartCache,
    checkpoints: &[CheckpointEntry],
) -> Result<(), String> {
    let path = path.as_ref();
    let bytes = encode(cache, checkpoints)?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("rename to {}: {e}", path.display()))?;
    Ok(())
}

/// Read a snapshot from disk.
pub fn load(path: impl AsRef<Path>) -> Result<ServeSnapshot, String> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{generate, SyntheticConfig};
    use crate::reference::CpuObjective;
    use crate::solver::{Agd, SolveDriver, StepEvent};

    fn fp(n: usize) -> Fingerprint {
        Fingerprint {
            num_sources: n,
            num_dests: 4,
            num_families: 1,
            num_global_rows: 0,
            nnz: 4 * n,
            pattern_hash: 0x1234_5678_9abc_def0 ^ n as u64,
            projection_hash: 7,
            global_coeff_hash: 0,
            coeff_hash: 99,
        }
    }

    fn primed_cache() -> WarmStartCache {
        let mut c = WarmStartCache::new(4);
        c.insert(fp(1), vec![0.25, -0.5, 1.5e-9, f32::MIN_POSITIVE], 0.04);
        c.insert(fp(2), vec![0.0; 4], 0.01);
        let _ = c.lookup(&fp(1));
        let _ = c.lookup(&fp(9)); // miss
        c
    }

    #[test]
    fn cache_round_trip_is_bit_identical() {
        let cache = primed_cache();
        let bytes = encode(&cache, &[]).unwrap();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.cache.capacity(), cache.capacity());
        assert_eq!(snap.cache.tick(), cache.tick());
        assert_eq!(
            (snap.cache.hits, snap.cache.misses, snap.cache.evictions),
            (cache.hits, cache.misses, cache.evictions)
        );
        let a = cache.export_entries();
        let b = snap.cache.export_entries();
        assert_eq!(a.len(), b.len());
        for ((fa, wa, ta), (fb, wb, tb)) in a.iter().zip(&b) {
            assert_eq!(fa, fb);
            assert_eq!(ta, tb, "LRU ticks must restore exactly");
            assert_eq!(wa.gamma.to_bits(), wb.gamma.to_bits());
            assert_eq!(wa.refreshes, wb.refreshes);
            assert_eq!(wa.lam.len(), wb.lam.len());
            for (x, y) in wa.lam.iter().zip(&wb.lam) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // byte-stable: re-encoding the decoded cache reproduces the bytes
        let bytes2 = encode(&snap.cache, &[]).unwrap();
        assert_eq!(bytes, bytes2);
    }

    #[test]
    fn checkpoint_round_trip_resumes_bit_identically() {
        let lp = generate(&SyntheticConfig {
            num_requests: 120,
            num_resources: 12,
            seed: 31,
            ..Default::default()
        });
        let opts = SolveOptions {
            max_iters: 60,
            max_step_size: 1e-3,
            initial_step_size: 1e-5,
            gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 9 },
            ..Default::default()
        };
        let init = vec![0.0f32; lp.dual_dim()];
        let mk = || {
            let mut obj = CpuObjective::new(&lp);
            let mut d = SolveDriver::new(
                Box::new(Agd::default().stepper()),
                &init,
                opts.clone(),
                DriverOptions::default(),
            );
            for _ in 0..21 {
                if let StepEvent::Stopped { .. } = d.step(&mut obj) {
                    panic!("stopped too early");
                }
            }
            d.checkpoint().expect("AGD checkpoints")
        };
        let ck = mk();
        let bytes = encode(
            &WarmStartCache::new(0),
            &[CheckpointEntry { request_id: 7, fingerprint: fp(3), checkpoint: ck }],
        )
        .unwrap();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.checkpoints.len(), 1);
        assert_eq!(snap.checkpoints[0].request_id, 7);
        assert_eq!(snap.checkpoints[0].fingerprint, fp(3));

        // resume the DECODED checkpoint and an in-memory clone of the same
        // solve; both must finish on identical bits
        let restored = snap.checkpoints.into_iter().next().unwrap().checkpoint;
        let mut obj_a = CpuObjective::new(&lp);
        let mut obj_b = CpuObjective::new(&lp);
        let mut da = SolveDriver::resume(mk());
        let mut db = SolveDriver::resume(restored);
        let ra = da.run(&mut obj_a);
        let rb = db.run(&mut obj_b);
        assert_eq!(ra.iterations, rb.iterations);
        assert_eq!(ra.stop_reason, rb.stop_reason);
        assert_eq!(ra.final_obj.dual_obj.to_bits(), rb.final_obj.dual_obj.to_bits());
        for (x, y) in ra.lam.iter().zip(&rb.lam) {
            assert_eq!(x.to_bits(), y.to_bits(), "resumed λ diverged");
        }
        assert_eq!(ra.trajectory.len(), rb.trajectory.len());
        for (ta, tb) in ra.trajectory.iter().zip(&rb.trajectory) {
            assert_eq!(ta.iter, tb.iter);
            assert_eq!(ta.dual_obj.to_bits(), tb.dual_obj.to_bits());
            assert_eq!(ta.gamma.to_bits(), tb.gamma.to_bits());
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let cache = primed_cache();
        let bytes = encode(&cache, &[]).unwrap();
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().contains("magic"));
        // unknown version
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(decode(&bad).unwrap_err().contains("version"));
        // truncation
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(decode(&bad).unwrap_err().contains("trailing"));
        // absurd length prefix must error, not allocate
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn save_and_load_round_trip() {
        let dir = std::env::temp_dir().join("dualip_snapshot_test");
        let path = dir.join("state.dlps");
        let cache = primed_cache();
        save(&path, &cache, &[]).unwrap();
        let snap = load(&path).unwrap();
        assert_eq!(snap.cache.tick(), cache.tick());
        assert_eq!(snap.cache.len(), cache.len());
        assert!(snap.checkpoints.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
