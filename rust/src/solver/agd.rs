//! Nesterov accelerated gradient ascent with adaptive local-Lipschitz step
//! sizing — the paper's production optimizer, translated from DuaLip's
//! `AcceleratedGradientDescent.scala` (Appendix B):
//!
//! - local Lipschitz estimate L̂_t = ‖∇g(y_t) − ∇g(y_{t−1})‖ / ‖y_t − y_{t−1}‖,
//!   step η_t = min(1/L̂_t, η_max); first step uses η_init;
//! - dual feasibility λ ≥ 0 enforced by projection after every update;
//! - Nesterov momentum pair (λ, y): y_{t+1} = λ_{t+1} + β_t(λ_{t+1} − λ_t)
//!   with β_t = t/(t+3);
//! - η_max is scaled with γ at continuation transition points (handled by
//!   the shared driver via `step_cap_scale`).
//!
//! The update rule lives in [`AgdStepper`], a [`DualStepper`] plugged into
//! the shared [`crate::solver::driver::SolveDriver`]; [`Agd`] is the
//! one-shot `Maximizer` wrapper over it. The (λ₁, λ₂) = (λ_{t+1}, y_{t+1})
//! pair is exactly the momentum state the distributed pattern broadcasts
//! each iteration (paper §6 step 4), and exactly what a driver checkpoint
//! snapshots.

use super::driver::{maximize_with, DriverOptions, DualStepper, StepperState};
use super::maximizer::{Maximizer, SolveOptions, SolveResult};
use crate::problem::{ObjectiveFunction, ObjectiveResult};
use crate::util::mathvec;

pub struct Agd {
    /// Restart momentum when the objective decreases (function-value
    /// adaptive restart). The Scala implementation keeps momentum always;
    /// restarts make the method robust on poorly conditioned instances —
    /// default off for parity with the paper.
    pub restart_on_decrease: bool,
}

impl Default for Agd {
    fn default() -> Self {
        Agd { restart_on_decrease: false }
    }
}

impl Agd {
    /// The update rule as a driver-pluggable stepper.
    pub fn stepper(&self) -> AgdStepper {
        AgdStepper::new(self.restart_on_decrease)
    }
}

/// AGD iterates + momentum as an explicit, checkpointable state machine
/// step rule.
#[derive(Clone, Debug)]
pub struct AgdStepper {
    restart_on_decrease: bool,
    /// λ — the primal-dual candidate (the anytime dual).
    lam: Vec<f32>,
    /// y — the extrapolated query point.
    y: Vec<f32>,
    lam_prev: Vec<f32>,
    /// Curvature memory (empty until the first step has run).
    y_prev: Vec<f32>,
    grad_prev: Vec<f32>,
    prev_obj: f64,
    /// Restartable momentum clock.
    momentum_t: usize,
}

impl AgdStepper {
    pub fn new(restart_on_decrease: bool) -> AgdStepper {
        AgdStepper {
            restart_on_decrease,
            lam: Vec::new(),
            y: Vec::new(),
            lam_prev: Vec::new(),
            y_prev: Vec::new(),
            grad_prev: Vec::new(),
            prev_obj: f64::NEG_INFINITY,
            momentum_t: 0,
        }
    }

    /// Restore from an exported [`StepperState`] (inverse of
    /// `export_state`). `None` if the record isn't a well-formed AGD
    /// export: wrong name, wrong arity, or inconsistent iterate lengths.
    pub fn from_state(state: &StepperState) -> Option<AgdStepper> {
        if state.name != "agd"
            || state.flags.len() != 1
            || state.vecs.len() != 5
            || state.scalars.len() != 1
            || state.counters.len() != 1
        {
            return None;
        }
        let [lam, y, lam_prev, y_prev, grad_prev] = &state.vecs[..] else {
            return None;
        };
        let n = lam.len();
        if y.len() != n || lam_prev.len() != n {
            return None;
        }
        // Curvature memory is empty until the first step; afterwards both
        // planes are full-length.
        if y_prev.len() != grad_prev.len() || !(y_prev.is_empty() || y_prev.len() == n) {
            return None;
        }
        Some(AgdStepper {
            restart_on_decrease: state.flags[0],
            lam: lam.clone(),
            y: y.clone(),
            lam_prev: lam_prev.clone(),
            y_prev: y_prev.clone(),
            grad_prev: grad_prev.clone(),
            prev_obj: state.scalars[0],
            momentum_t: state.counters[0] as usize,
        })
    }
}

impl DualStepper for AgdStepper {
    fn init(&mut self, initial_value: &[f32]) {
        self.lam = initial_value.to_vec();
        self.y = initial_value.to_vec();
        self.lam_prev = initial_value.to_vec();
        self.y_prev.clear();
        self.grad_prev.clear();
        self.prev_obj = f64::NEG_INFINITY;
        self.momentum_t = 0;
    }

    fn step(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        t: usize,
        gamma: f32,
        eta_cap: f64,
        initial_step_size: f64,
    ) -> (ObjectiveResult, f64) {
        // ∇g at the extrapolated point y_t
        let res = obj.calculate(&self.y, gamma);

        // adaptive step size
        let eta = if t == 0 || self.y_prev.is_empty() {
            initial_step_size.min(eta_cap)
        } else {
            let dy = mathvec::dist2(&self.y, &self.y_prev);
            let dg = mathvec::dist2(&res.grad, &self.grad_prev);
            if dy > 0.0 && dg > 0.0 {
                (dy / dg).min(eta_cap)
            } else {
                eta_cap
            }
        };

        // λ_{t+1} = Π_{≥0}(y_t + η ∇g(y_t))   (ascent)
        self.lam_prev.copy_from_slice(&self.lam);
        self.lam.copy_from_slice(&self.y);
        mathvec::axpy(eta as f32, &res.grad, &mut self.lam);
        mathvec::clamp_nonneg(&mut self.lam);

        // momentum restart on objective decrease
        if self.restart_on_decrease && res.dual_obj < self.prev_obj {
            self.momentum_t = 0;
        } else {
            self.momentum_t += 1;
        }
        self.prev_obj = res.dual_obj;

        // y_{t+1} = λ_{t+1} + β(λ_{t+1} − λ_t)
        let beta = self.momentum_t as f32 / (self.momentum_t as f32 + 3.0);
        self.y_prev = self.y.clone();
        self.grad_prev = res.grad.clone();
        let mut y_next = vec![0.0f32; self.y.len()];
        mathvec::extrapolate(&self.lam, &self.lam_prev, beta, &mut y_next);
        mathvec::clamp_nonneg(&mut y_next);
        self.y = y_next;

        (res, eta)
    }

    fn lam(&self) -> &[f32] {
        &self.lam
    }

    fn name(&self) -> &'static str {
        "agd"
    }

    fn try_clone(&self) -> Option<Box<dyn DualStepper>> {
        Some(Box::new(self.clone()))
    }

    fn export_state(&self) -> Option<StepperState> {
        Some(StepperState {
            name: "agd".to_string(),
            flags: vec![self.restart_on_decrease],
            vecs: vec![
                self.lam.clone(),
                self.y.clone(),
                self.lam_prev.clone(),
                self.y_prev.clone(),
                self.grad_prev.clone(),
            ],
            scalars: vec![self.prev_obj],
            counters: vec![self.momentum_t as u64],
        })
    }
}

impl Maximizer for Agd {
    fn maximize(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        initial_value: &[f32],
        opts: &SolveOptions,
    ) -> SolveResult {
        maximize_with(Box::new(self.stepper()), obj, initial_value, opts, DriverOptions::default())
    }

    fn name(&self) -> &'static str {
        "agd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::{ObjectiveFunction, ObjectiveResult};
    use crate::solver::continuation::GammaSchedule;

    /// Concave quadratic test objective: g(λ) = −½‖λ − λ*‖² (+ constants),
    /// ∇g = λ* − λ. Maximizer must converge to max(λ*, 0).
    struct Quadratic {
        target: Vec<f32>,
    }

    impl ObjectiveFunction for Quadratic {
        fn dual_dim(&self) -> usize {
            self.target.len()
        }
        fn calculate(&mut self, lam: &[f32], _gamma: f32) -> ObjectiveResult {
            let grad: Vec<f32> = self.target.iter().zip(lam).map(|(t, l)| t - l).collect();
            let obj = -0.5 * grad.iter().map(|&g| (g as f64).powi(2)).sum::<f64>();
            ObjectiveResult {
                grad,
                dual_obj: obj,
                cx: obj,
                xsq_weighted: 0.0,
                infeas_pos_norm: 0.0,
            }
        }
        fn primal(&mut self, _lam: &[f32], _gamma: f32) -> Vec<f32> {
            vec![]
        }
        fn name(&self) -> &'static str {
            "quadratic"
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let mut obj = Quadratic { target: vec![2.0, 0.5, -1.0, 3.0] };
        let mut agd = Agd::default();
        let opts = SolveOptions {
            max_iters: 4000,
            max_step_size: 0.9, // 1/L = 1 for this objective
            initial_step_size: 0.1,
            gamma: GammaSchedule::Fixed(0.01),
            ..Default::default()
        };
        let res = agd.maximize(&mut obj, &vec![0.0; 4], &opts);
        // λ → max(target, 0): negative coordinate pinned at 0
        let expect = [2.0f32, 0.5, 0.0, 3.0];
        for (l, e) in res.lam.iter().zip(&expect) {
            assert!((l - e).abs() < 1e-2, "lam={:?}", res.lam);
        }
        // At the constrained optimum the raw gradient is (0,0,-1,0) — the
        // active λ≥0 bound keeps norm 1 — so check the objective instead:
        // g* = −½·(−1)² = −0.5.
        let final_obj = res.trajectory.last().unwrap().dual_obj;
        assert!((final_obj - (-0.5)).abs() < 1e-2, "final obj {final_obj}");
    }

    #[test]
    fn adaptive_step_reaches_cap_estimate() {
        // With unit curvature, 1/L̂ = 1 > cap ⇒ steps should settle at cap.
        let mut obj = Quadratic { target: vec![1.0; 8] };
        let mut agd = Agd::default();
        let opts = SolveOptions {
            max_iters: 50,
            max_step_size: 0.25,
            initial_step_size: 1e-3,
            ..Default::default()
        };
        let res = agd.maximize(&mut obj, &vec![0.0; 8], &opts);
        let later_steps: Vec<f64> =
            res.trajectory.iter().skip(5).map(|r| r.step_size).collect();
        assert!(later_steps.iter().all(|&s| (s - 0.25).abs() < 1e-9), "{later_steps:?}");
    }

    #[test]
    fn respects_dual_nonnegativity() {
        let mut obj = Quadratic { target: vec![-5.0, -2.0] };
        let mut agd = Agd::default();
        let opts = SolveOptions { max_iters: 200, max_step_size: 0.5, ..Default::default() };
        let res = agd.maximize(&mut obj, &vec![1.0, 1.0], &opts);
        assert!(res.lam.iter().all(|&l| l >= 0.0));
        assert!(res.lam.iter().all(|&l| l < 1e-2), "{:?}", res.lam);
    }

    #[test]
    fn trajectory_recorded_each_iteration() {
        let mut obj = Quadratic { target: vec![1.0] };
        let mut agd = Agd::default();
        let opts = SolveOptions { max_iters: 17, ..Default::default() };
        let res = agd.maximize(&mut obj, &vec![0.0], &opts);
        assert_eq!(res.trajectory.len(), 17);
        assert_eq!(res.iterations, 17);
    }

    #[test]
    fn restart_variant_also_converges() {
        let mut obj = Quadratic { target: vec![4.0, 1.0, 2.0] };
        let mut agd = Agd { restart_on_decrease: true };
        let opts = SolveOptions {
            max_iters: 3000,
            max_step_size: 0.9,
            initial_step_size: 0.05,
            ..Default::default()
        };
        let res = agd.maximize(&mut obj, &vec![0.0; 3], &opts);
        for (l, e) in res.lam.iter().zip(&[4.0f32, 1.0, 2.0]) {
            assert!((l - e).abs() < 2e-2, "{:?}", res.lam);
        }
    }
}
