//! γ-continuation schedule (paper §5.1 "Regularization decay").
//!
//! γ starts moderately large for stable, fast early progress and decays on
//! a pre-specified schedule toward a floor; the AGD max step size is scaled
//! proportionally at each decay to maintain stability across transition
//! points (the gradient Lipschitz constant is ‖A‖²/γ).

/// Ridge-parameter schedule.
#[derive(Clone, Debug)]
pub enum GammaSchedule {
    /// Constant γ.
    Fixed(f32),
    /// γ_0 · factor^⌊t/every⌋, floored. Paper Fig 5: init 0.16, floor 0.01,
    /// factor 0.5, every 25.
    Decay { init: f32, floor: f32, factor: f32, every: usize },
}

impl GammaSchedule {
    /// Paper Fig-5 continuation setting.
    pub fn paper_fig5() -> Self {
        GammaSchedule::Decay { init: 0.16, floor: 0.01, factor: 0.5, every: 25 }
    }

    /// γ at iteration t (0-based).
    pub fn gamma_at(&self, t: usize) -> f32 {
        match *self {
            GammaSchedule::Fixed(g) => g,
            GammaSchedule::Decay { init, floor, factor, every } => {
                let steps = t / every.max(1);
                let g = init * factor.powi(steps as i32);
                g.max(floor)
            }
        }
    }

    /// Step-size cap multiplier at iteration t relative to t=0: η_max is
    /// scaled proportionally with γ (paper §5.1).
    pub fn step_cap_scale(&self, t: usize) -> f32 {
        self.gamma_at(t) / self.gamma_at(0)
    }

    /// Whether iteration t is a decay transition point.
    pub fn decays_at(&self, t: usize) -> bool {
        match *self {
            GammaSchedule::Fixed(_) => false,
            GammaSchedule::Decay { .. } => {
                t > 0 && self.gamma_at(t) != self.gamma_at(t - 1)
            }
        }
    }

    pub fn final_gamma(&self) -> f32 {
        match *self {
            GammaSchedule::Fixed(g) => g,
            GammaSchedule::Decay { floor, .. } => floor,
        }
    }

    /// First iteration at which γ has reached its floor (0 for `Fixed`).
    /// Stopping criteria that compare solves "at matched γ" (the engine's
    /// warm-vs-cold protocol) set `min_iters` past this point.
    pub fn iters_to_floor(&self) -> usize {
        match *self {
            GammaSchedule::Fixed(_) => 0,
            GammaSchedule::Decay { init, floor, factor, every } => {
                let mut g = init;
                let mut steps = 0usize;
                while g > floor && factor < 1.0 && steps < 10_000 {
                    g = (g * factor).max(floor);
                    steps += 1;
                }
                steps * every.max(1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let s = GammaSchedule::Fixed(0.01);
        assert_eq!(s.gamma_at(0), 0.01);
        assert_eq!(s.gamma_at(1000), 0.01);
        assert!(!s.decays_at(25));
        assert_eq!(s.step_cap_scale(500), 1.0);
    }

    #[test]
    fn paper_schedule_halves_every_25() {
        let s = GammaSchedule::paper_fig5();
        assert_eq!(s.gamma_at(0), 0.16);
        assert_eq!(s.gamma_at(24), 0.16);
        assert_eq!(s.gamma_at(25), 0.08);
        assert_eq!(s.gamma_at(50), 0.04);
        assert_eq!(s.gamma_at(75), 0.02);
        assert_eq!(s.gamma_at(100), 0.01);
        // floored afterwards
        assert_eq!(s.gamma_at(125), 0.01);
        assert_eq!(s.gamma_at(10_000), 0.01);
    }

    #[test]
    fn decay_points_flagged() {
        let s = GammaSchedule::paper_fig5();
        assert!(!s.decays_at(0));
        assert!(!s.decays_at(24));
        assert!(s.decays_at(25));
        assert!(!s.decays_at(26));
        assert!(s.decays_at(100));
        assert!(!s.decays_at(125)); // already at floor
    }

    #[test]
    fn iters_to_floor_matches_schedule() {
        assert_eq!(GammaSchedule::Fixed(0.05).iters_to_floor(), 0);
        let s = GammaSchedule::paper_fig5(); // 0.16 →(×0.5 every 25)→ 0.01
        assert_eq!(s.iters_to_floor(), 100);
        assert_eq!(s.gamma_at(100), 0.01);
        assert!(s.gamma_at(99) > 0.01);
    }

    #[test]
    fn step_cap_tracks_gamma() {
        let s = GammaSchedule::paper_fig5();
        assert_eq!(s.step_cap_scale(0), 1.0);
        assert_eq!(s.step_cap_scale(25), 0.5);
        assert_eq!(s.step_cap_scale(200), 0.01 / 0.16);
    }
}
