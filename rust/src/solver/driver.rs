//! The steppable solve driver — the shared dual-ascent loop as an explicit
//! state machine.
//!
//! The seed stack's loop was a private run-to-completion closure: callers
//! got control back only after the solve ended, so the serving layer could
//! not enforce deadlines, stream diagnostics, checkpoint long solves, or
//! interleave tenants on one thread pool. [`SolveDriver`] turns the loop
//! inside out:
//!
//! ```text
//!            ┌──────────────────────────────────────────────┐
//!            │                 SolveDriver                  │
//!            │  SolveState: t, stall window, last result,   │
//!            │  trajectory, stop reason, wall-clock offset  │
//!            │  DualStepper: iterate + momentum (AGD/PGD)   │
//!            └──────────────────────────────────────────────┘
//!   step(obj) ──▶ Continue { record }          (one more iteration ran)
//!            ──▶ GammaDecayed { record, gamma } (γ transition next iter —
//!                                               the warm-start checkpoint)
//!            ──▶ Stopped { reason }            (terminal; idempotent)
//! ```
//!
//! - One `step` runs exactly one iteration: the [`DualStepper`] evaluates
//!   the objective at its query point and advances its iterates; the
//!   driver owns everything the steppers share — γ-schedule position,
//!   step-size cap scaling, stall window, trajectory recording, stopping,
//!   deadline and cancellation checks.
//! - `checkpoint()` / [`SolveDriver::resume`] snapshot and restore the
//!   full solve (stepper momentum included): resuming at iteration k is
//!   bit-identical to never having paused.
//! - [`IterObserver`] hooks stream per-iteration diagnostics without
//!   waiting for the solve to end; the built-in trajectory recorder
//!   follows the same per-iteration contract (kept inside [`SolveState`]
//!   so checkpoints carry it).
//! - `current_lam()` is the *anytime dual*: valid after every step, which
//!   is what lets a deadline-killed solve still warm its successors.
//!
//! Driver-stepped solves are bit-identical to the legacy `maximize()`
//! path — `Maximizer` is now a thin compat wrapper over this driver (see
//! `tests/driver_parity.rs`), mirroring the pause/inspect/re-parameterize
//! loops of restarted first-order LP methods (cuPDLP.jl; Lu & Yang's
//! GPU-LP survey).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::maximizer::{IterRecord, SolveOptions, SolveResult};
use super::stopping::StopReason;
use crate::problem::{ObjectiveFunction, ObjectiveResult};
use crate::util::timer::Stopwatch;

/// Optimizer-specific update rule plugged into the shared driver: the
/// stepper owns its iterates (λ and any momentum pair) and advances them
/// by one iteration per `step`; the driver owns schedule, stopping,
/// recording, and deadline/cancel policy.
pub trait DualStepper: Send {
    /// (Re)set the iterates to the given initial dual.
    fn init(&mut self, initial_value: &[f32]);

    /// Run ONE iteration at iteration index `t`: evaluate `obj` at the
    /// stepper's query point, advance the iterates, and return the
    /// evaluation plus the step size actually used. `eta_cap` is the
    /// γ-scaled maximum step size; `initial_step_size` the cold first-step
    /// size (both resolved by the driver from [`SolveOptions`]).
    fn step(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        t: usize,
        gamma: f32,
        eta_cap: f64,
        initial_step_size: f64,
    ) -> (ObjectiveResult, f64);

    /// The current dual candidate λ — valid after any number of steps
    /// (the anytime iterate; for AGD this is λ, not the extrapolated y).
    fn lam(&self) -> &[f32];

    fn name(&self) -> &'static str;

    /// Clone the full stepper state for a checkpoint. `None` means this
    /// stepper cannot be checkpointed (e.g. the legacy closure shim).
    fn try_clone(&self) -> Option<Box<dyn DualStepper>> {
        None
    }

    /// Export the full iterate/momentum state as plain data for durable
    /// snapshots (`serve::snapshot`). `None` means this stepper is not
    /// serializable; every shipped stepper (AGD, PGD) is. The layout of
    /// `flags`/`vecs`/`scalars`/`counters` is stepper-specific — only the
    /// matching `from_state` restore constructor interprets it.
    fn export_state(&self) -> Option<StepperState> {
        None
    }
}

/// Plain-data export of a [`DualStepper`]'s internal state, keyed by the
/// stepper's `name()` for restore. Field meaning is private to each
/// stepper; the snapshot codec treats this as an opaque record.
#[derive(Clone, Debug, PartialEq)]
pub struct StepperState {
    pub name: String,
    pub flags: Vec<bool>,
    pub vecs: Vec<Vec<f32>>,
    pub scalars: Vec<f64>,
    pub counters: Vec<u64>,
}

/// Rebuild a stepper from an exported [`StepperState`] (name-keyed
/// dispatch over the shipped steppers). `None` for unknown names or a
/// state record whose shape doesn't match the named stepper.
pub fn restore_stepper(state: &StepperState) -> Option<Box<dyn DualStepper>> {
    match state.name.as_str() {
        "agd" => super::agd::AgdStepper::from_state(state)
            .map(|s| Box::new(s) as Box<dyn DualStepper>),
        "pgd" => super::pgd::PgdStepper::from_state(state)
            .map(|s| Box::new(s) as Box<dyn DualStepper>),
        _ => None,
    }
}

/// Cooperative cancellation handle: clone it, hand one clone to the job,
/// keep the other, `cancel()` at any time. The driver checks it before
/// each iteration and stops with [`StopReason::Cancelled`].
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Driver-level execution policy, orthogonal to the optimization settings
/// in [`SolveOptions`]: how long the job may run and whether it can be
/// cancelled from outside.
#[derive(Clone, Debug, Default)]
pub struct DriverOptions {
    /// Wall-clock budget in milliseconds, measured from the driver's
    /// FIRST `step` (not from construction, so queued cooperative jobs
    /// don't burn budget before they run; checkpoint/resume segments
    /// accumulate). Checked AFTER each completed iteration, so a solve
    /// with `max_iters ≥ 1` always performs at least one iteration and
    /// stops with a usable λ.
    pub deadline_ms: Option<f64>,
    /// Cooperative cancellation, checked BEFORE each iteration.
    pub cancel: Option<CancelToken>,
}

impl DriverOptions {
    pub fn with_deadline_ms(ms: f64) -> DriverOptions {
        DriverOptions { deadline_ms: Some(ms), ..Default::default() }
    }
}

/// Everything the loop tracks besides the stepper's iterates. Cloneable,
/// so a [`Checkpoint`] is just this plus the stepper state.
#[derive(Clone, Debug, Default)]
pub struct SolveState {
    /// Iterations completed so far (= the next iteration index).
    pub t: usize,
    /// Consecutive small-objective-step count (stall window).
    pub stall_run: usize,
    /// Most recent objective evaluation.
    pub last: Option<ObjectiveResult>,
    /// Records kept per `SolveOptions::record_every`, PLUS the stopping
    /// iteration (always recorded — the trajectory never ends before the
    /// reported final objective).
    pub trajectory: Vec<IterRecord>,
    /// Set exactly once, when the solve reaches a terminal state.
    pub stop_reason: Option<StopReason>,
    /// Wall-clock accumulated by earlier run segments (checkpoint/resume
    /// restarts the stopwatch; this keeps `wall_ms` monotone).
    pub wall_offset_ms: f64,
}

/// One step outcome. `record` is the iteration's [`IterRecord`] whether or
/// not it was kept in the trajectory — callers can stream it without
/// configuring `record_every: 1`.
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// The iteration ran and the solve continues.
    Continue { record: IterRecord },
    /// The iteration ran, the solve continues, and the NEXT iteration
    /// starts at a decayed γ (`gamma`). This is the warm-start checkpoint
    /// signal: `current_lam()` is the λ optimized at `record.gamma`, and
    /// the last such event is the γ-floor arrival. The cooperative
    /// executor publishes λ to the warm-start cache on every one.
    GammaDecayed { record: IterRecord, gamma: f32 },
    /// Terminal. The call that first returns this may have run the
    /// stopping iteration (its record is in the trajectory); every
    /// subsequent `step` returns the same event and does no work. Call
    /// [`SolveDriver::result`] to assemble the `SolveResult`.
    Stopped { reason: StopReason },
}

/// Per-iteration diagnostics hooks — the streaming replacement for
/// "wait for `SolveResult.trajectory`". Observers are NOT part of a
/// checkpoint; re-attach after `resume`.
pub trait IterObserver: Send {
    /// Called after every iteration. `recorded` tells whether the record
    /// was also kept in the state trajectory (`record_every` cadence or
    /// the stopping iteration).
    fn on_iter(&mut self, record: &IterRecord, recorded: bool);

    /// Called when the NEXT iteration starts at a decayed γ.
    fn on_gamma_decay(&mut self, _t: usize, _gamma: f32) {}

    /// Called exactly once, when the solve reaches a terminal state.
    fn on_stop(&mut self, _reason: StopReason, _iterations: usize) {}
}

/// Snapshot of a solve in flight: stepper iterates + loop state + the
/// options it ran under. `SolveDriver::resume` continues bit-identically.
/// Always `'static` — a checkpoint owns its stepper clone outright.
pub struct Checkpoint {
    stepper: Box<dyn DualStepper>,
    state: SolveState,
    opts: SolveOptions,
    dopts: DriverOptions,
}

impl Checkpoint {
    /// Duplicate the checkpoint, `None` when the stepper is not cloneable.
    /// Not a `Clone` impl on purpose: stepper cloneability is a runtime
    /// property, and a panicking `clone` inside the serve snapshot path
    /// would take the daemon down for a condition the caller can shed.
    pub fn try_clone(&self) -> Option<Checkpoint> {
        Some(Checkpoint {
            stepper: self.stepper.try_clone()?,
            state: self.state.clone(),
            opts: self.opts.clone(),
            dopts: self.dopts.clone(),
        })
    }

    /// Iterations completed at snapshot time.
    pub fn iterations(&self) -> usize {
        self.state.t
    }

    /// Reassemble a checkpoint from its parts — the restore half of the
    /// durable-snapshot round trip (`serve::snapshot`). The caller is
    /// responsible for the stepper matching the state it ran under;
    /// `SolveDriver::resume` on the result is then bit-identical to
    /// resuming the original in-memory checkpoint.
    pub fn from_parts(
        stepper: Box<dyn DualStepper>,
        state: SolveState,
        opts: SolveOptions,
        dopts: DriverOptions,
    ) -> Checkpoint {
        Checkpoint { stepper, state, opts, dopts }
    }

    /// Loop state at snapshot time.
    pub fn state(&self) -> &SolveState {
        &self.state
    }

    /// Optimization settings the solve ran under.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Driver policy the solve ran under.
    pub fn driver_options(&self) -> &DriverOptions {
        &self.dopts
    }

    /// Export the stepper's iterates as plain data (`None` for steppers
    /// without serialization support).
    pub fn export_stepper(&self) -> Option<StepperState> {
        self.stepper.export_state()
    }
}

/// The resumable dual-ascent state machine. See the module docs for the
/// event protocol.
pub struct SolveDriver<'s> {
    stepper: Box<dyn DualStepper + 's>,
    opts: SolveOptions,
    dopts: DriverOptions,
    state: SolveState,
    observers: Vec<Box<dyn IterObserver + 's>>,
    /// Started lazily at the first `step` and frozen (folded into
    /// `state.wall_offset_ms`) at the terminal transition, so `wall_ms`
    /// measures the solve's active span — a cooperatively scheduled job
    /// does not accrue setup time before its first iteration or idle
    /// time after it stopped.
    sw: Option<Stopwatch>,
}

impl<'s> SolveDriver<'s> {
    pub fn new(
        mut stepper: Box<dyn DualStepper + 's>,
        initial_value: &[f32],
        opts: SolveOptions,
        dopts: DriverOptions,
    ) -> SolveDriver<'s> {
        stepper.init(initial_value);
        SolveDriver {
            stepper,
            opts,
            dopts,
            state: SolveState::default(),
            observers: Vec::new(),
            sw: None,
        }
    }

    /// Continue a checkpointed solve. The restored driver is bit-identical
    /// to one that never paused (observers excepted — re-attach them).
    pub fn resume(ck: Checkpoint) -> SolveDriver<'static> {
        SolveDriver {
            stepper: ck.stepper,
            opts: ck.opts,
            dopts: ck.dopts,
            state: ck.state,
            observers: Vec::new(),
            sw: None,
        }
    }

    /// Snapshot the solve. `None` if the stepper cannot be cloned (the
    /// legacy closure shim); every shipped stepper (AGD, PGD) can.
    pub fn checkpoint(&self) -> Option<Checkpoint> {
        let stepper = self.stepper.try_clone()?;
        let mut state = self.state.clone();
        state.wall_offset_ms = self.elapsed_ms();
        Some(Checkpoint { stepper, state, opts: self.opts.clone(), dopts: self.dopts.clone() })
    }

    pub fn add_observer(&mut self, obs: Box<dyn IterObserver + 's>) {
        self.observers.push(obs);
    }

    /// The anytime dual candidate λ.
    pub fn current_lam(&self) -> &[f32] {
        self.stepper.lam()
    }

    /// Loop state (iteration count, stall window, trajectory so far).
    pub fn state(&self) -> &SolveState {
        &self.state
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    pub fn stepper_name(&self) -> &'static str {
        self.stepper.name()
    }

    /// Total wall-clock attributed to this solve across run segments:
    /// active time only (first step → terminal transition), excluding
    /// pre-start setup and post-stop idling.
    pub fn elapsed_ms(&self) -> f64 {
        self.state.wall_offset_ms + self.sw.as_ref().map_or(0.0, |sw| sw.elapsed_ms())
    }

    fn stop(&mut self, reason: StopReason) -> StepEvent {
        // freeze the clock: wall_ms must not keep growing while a
        // finished cooperative job waits for the rest of its batch
        self.state.wall_offset_ms = self.elapsed_ms();
        self.sw = None;
        self.state.stop_reason = Some(reason);
        for obs in &mut self.observers {
            obs.on_stop(reason, self.state.t);
        }
        StepEvent::Stopped { reason }
    }

    /// Run ONE iteration (or report the terminal state).
    pub fn step(&mut self, obj: &mut dyn ObjectiveFunction) -> StepEvent {
        if let Some(reason) = self.state.stop_reason {
            return StepEvent::Stopped { reason };
        }
        if self.sw.is_none() {
            self.sw = Some(Stopwatch::start());
        }
        if self.state.t >= self.opts.max_iters {
            return self.stop(StopReason::MaxIters);
        }
        if let Some(c) = &self.dopts.cancel {
            if c.is_cancelled() {
                return self.stop(StopReason::Cancelled);
            }
        }

        let t = self.state.t;
        let gamma = self.opts.gamma.gamma_at(t);
        let eta_cap = self.opts.max_step_size * self.opts.gamma.step_cap_scale(t) as f64;
        let (res, eta_used) =
            self.stepper.step(obj, t, gamma, eta_cap, self.opts.initial_step_size);
        self.state.t = t + 1;

        let grad_norm = crate::util::mathvec::norm2(&res.grad);
        let record = IterRecord {
            iter: t,
            dual_obj: res.dual_obj,
            grad_norm,
            infeas_pos_norm: res.infeas_pos_norm,
            cx: res.cx,
            gamma,
            step_size: eta_used,
            wall_ms: self.elapsed_ms(),
        };

        let prev_obj = self.state.last.as_ref().map(|r| r.dual_obj);
        if self.opts.stopping.is_stall_step(prev_obj, res.dual_obj) {
            self.state.stall_run += 1;
        } else {
            self.state.stall_run = 0;
        }
        self.state.last = Some(res);

        let mut stop = self.opts.stopping.check(t, grad_norm, self.state.stall_run);
        if stop.is_none() && t + 1 >= self.opts.max_iters {
            stop = Some(StopReason::MaxIters);
        }
        if stop.is_none() {
            if let Some(deadline) = self.dopts.deadline_ms {
                if self.elapsed_ms() >= deadline {
                    stop = Some(StopReason::Deadline);
                }
            }
        }

        // The stopping iteration is ALWAYS recorded, so the trajectory
        // never ends before the reported final objective.
        let recorded = t % self.opts.record_every.max(1) == 0 || stop.is_some();
        if recorded {
            self.state.trajectory.push(record.clone());
        }
        for obs in &mut self.observers {
            obs.on_iter(&record, recorded);
        }

        if let Some(reason) = stop {
            return self.stop(reason);
        }
        if self.opts.gamma.decays_at(t + 1) {
            let next = self.opts.gamma.gamma_at(t + 1);
            for obs in &mut self.observers {
                obs.on_gamma_decay(t + 1, next);
            }
            return StepEvent::GammaDecayed { record, gamma: next };
        }
        StepEvent::Continue { record }
    }

    /// Assemble the solve outcome. A zero-iteration solve (zero budget, or
    /// cancelled before the first step) evaluates the objective at the
    /// initial λ so `final_obj` is always a real evaluation — never a −∞
    /// placeholder.
    pub fn result(&mut self, obj: &mut dyn ObjectiveFunction) -> SolveResult {
        let final_obj = match self.state.last.clone() {
            Some(r) => r,
            None => obj.calculate(self.stepper.lam(), self.opts.gamma.gamma_at(0)),
        };
        SolveResult {
            lam: self.stepper.lam().to_vec(),
            final_obj,
            trajectory: self.state.trajectory.clone(),
            stop_reason: self.state.stop_reason.unwrap_or(StopReason::MaxIters),
            iterations: self.state.t,
            total_wall_ms: self.elapsed_ms(),
            final_gamma: self.opts.gamma.gamma_at(self.state.t.saturating_sub(1)),
        }
    }

    /// Step to a terminal state, then assemble the result — the
    /// run-to-completion convenience every `Maximizer` wraps.
    pub fn run(&mut self, obj: &mut dyn ObjectiveFunction) -> SolveResult {
        loop {
            if let StepEvent::Stopped { .. } = self.step(obj) {
                return self.result(obj);
            }
        }
    }
}

/// Run-to-completion over an explicit stepper and driver policy — the one
/// entry point `Maximizer::maximize`, the engine, and the CLI deadline
/// path all share.
pub fn maximize_with<'s>(
    stepper: Box<dyn DualStepper + 's>,
    obj: &mut dyn ObjectiveFunction,
    initial_value: &[f32],
    opts: &SolveOptions,
    dopts: DriverOptions,
) -> SolveResult {
    assert_eq!(
        initial_value.len(),
        obj.dual_dim(),
        "initial dual length must match the objective's dual dimension"
    );
    let mut driver = SolveDriver::new(stepper, initial_value, opts.clone(), dopts);
    driver.run(obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::agd::AgdStepper;
    use crate::solver::continuation::GammaSchedule;
    use crate::solver::stopping::StoppingCriteria;

    /// Concave quadratic: ∇g = target − λ.
    struct Quadratic {
        target: Vec<f32>,
        evals: usize,
    }

    impl ObjectiveFunction for Quadratic {
        fn dual_dim(&self) -> usize {
            self.target.len()
        }
        fn calculate(&mut self, lam: &[f32], _gamma: f32) -> ObjectiveResult {
            self.evals += 1;
            let grad: Vec<f32> = self.target.iter().zip(lam).map(|(t, l)| t - l).collect();
            let obj = -0.5 * grad.iter().map(|&g| (g as f64).powi(2)).sum::<f64>();
            ObjectiveResult {
                grad,
                dual_obj: obj,
                cx: obj,
                xsq_weighted: 0.0,
                infeas_pos_norm: 0.0,
            }
        }
        fn primal(&mut self, _lam: &[f32], _gamma: f32) -> Vec<f32> {
            vec![]
        }
        fn name(&self) -> &'static str {
            "quadratic"
        }
    }

    fn quad(n: usize) -> Quadratic {
        Quadratic { target: (0..n).map(|i| 0.5 + i as f32).collect(), evals: 0 }
    }

    fn driver(obj: &Quadratic, opts: SolveOptions, dopts: DriverOptions) -> SolveDriver<'static> {
        SolveDriver::new(Box::new(AgdStepper::new(false)), &vec![0.0; obj.dual_dim()], opts, dopts)
    }

    #[test]
    fn stepping_until_stopped_matches_run() {
        let opts = SolveOptions { max_iters: 60, max_step_size: 0.5, ..Default::default() };
        let mut o1 = quad(4);
        let mut d1 = driver(&o1, opts.clone(), DriverOptions::default());
        let r1 = d1.run(&mut o1);

        let mut o2 = quad(4);
        let mut d2 = driver(&o2, opts, DriverOptions::default());
        let mut calls = 0usize;
        loop {
            calls += 1;
            if let StepEvent::Stopped { reason } = d2.step(&mut o2) {
                assert_eq!(reason, StopReason::MaxIters);
                break;
            }
        }
        let r2 = d2.result(&mut o2);
        // the stopping call itself runs the final iteration, so calls ==
        // iterations (59 Continue events + 1 working Stopped)
        assert_eq!(calls, 60);
        assert_eq!(r1.iterations, r2.iterations);
        assert_eq!(r1.lam, r2.lam);
        assert_eq!(r1.trajectory.len(), r2.trajectory.len());
    }

    #[test]
    fn stopped_is_terminal_and_idempotent() {
        let opts = SolveOptions { max_iters: 3, ..Default::default() };
        let mut o = quad(2);
        let mut d = driver(&o, opts, DriverOptions::default());
        let r = d.run(&mut o);
        assert_eq!(r.iterations, 3);
        let evals = o.evals;
        for _ in 0..4 {
            match d.step(&mut o) {
                StepEvent::Stopped { reason } => assert_eq!(reason, StopReason::MaxIters),
                other => panic!("expected Stopped, got {other:?}"),
            }
        }
        assert_eq!(o.evals, evals, "terminal steps must not evaluate");
    }

    #[test]
    fn gamma_decay_events_fire_at_transitions() {
        let opts = SolveOptions {
            max_iters: 30,
            gamma: GammaSchedule::Decay { init: 0.16, floor: 0.04, factor: 0.5, every: 10 },
            ..Default::default()
        };
        let mut o = quad(3);
        let mut d = driver(&o, opts, DriverOptions::default());
        let mut decays = Vec::new();
        loop {
            match d.step(&mut o) {
                StepEvent::GammaDecayed { record, gamma } => decays.push((record.iter, gamma)),
                StepEvent::Stopped { .. } => break,
                StepEvent::Continue { .. } => {}
            }
        }
        // transitions into iterations 10 (γ 0.08) and 20 (γ 0.04 = floor)
        assert_eq!(decays, vec![(9, 0.08), (19, 0.04)]);
    }

    #[test]
    fn stopping_iteration_is_always_recorded() {
        // stall stop at an iteration that record_every would skip
        let opts = SolveOptions {
            max_iters: 1000,
            max_step_size: 0.5,
            record_every: 7,
            stopping: StoppingCriteria {
                stall_tol: Some(1e-12),
                stall_patience: 3,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut o = quad(2);
        let mut d = driver(&o, opts, DriverOptions::default());
        let r = d.run(&mut o);
        assert_eq!(r.stop_reason, StopReason::ObjectiveStall);
        let last = r.trajectory.last().expect("trajectory non-empty");
        assert_eq!(last.iter, r.iterations - 1, "stopping iteration must be recorded");
        assert_eq!(last.dual_obj.to_bits(), r.final_obj.dual_obj.to_bits());
    }

    #[test]
    fn zero_budget_solve_evaluates_at_init() {
        let opts = SolveOptions { max_iters: 0, ..Default::default() };
        let mut o = quad(3);
        let mut d = driver(&o, opts, DriverOptions::default());
        let r = d.run(&mut o);
        assert_eq!(r.iterations, 0);
        assert!(r.trajectory.is_empty());
        assert!(r.final_obj.dual_obj.is_finite(), "no −∞ placeholder");
        assert_eq!(r.final_obj.grad.len(), 3);
        assert_eq!(o.evals, 1, "exactly one evaluation at the initial λ");
    }

    #[test]
    fn deadline_stops_after_at_least_one_iteration() {
        let opts = SolveOptions { max_iters: 10_000, max_step_size: 0.5, ..Default::default() };
        let mut o = quad(4);
        let mut d = driver(&o, opts, DriverOptions::with_deadline_ms(0.0));
        let r = d.run(&mut o);
        assert_eq!(r.stop_reason, StopReason::Deadline);
        assert_eq!(r.iterations, 1, "zero deadline still runs one iteration");
        assert_eq!(r.trajectory.last().unwrap().iter, 0);
        assert!(r.final_obj.dual_obj.is_finite());
    }

    #[test]
    fn cancel_token_stops_before_next_iteration() {
        let token = CancelToken::new();
        let opts = SolveOptions { max_iters: 1000, max_step_size: 0.5, ..Default::default() };
        let mut o = quad(2);
        let mut d = driver(
            &o,
            opts,
            DriverOptions { cancel: Some(token.clone()), ..Default::default() },
        );
        for _ in 0..5 {
            d.step(&mut o);
        }
        token.cancel();
        match d.step(&mut o) {
            StepEvent::Stopped { reason } => assert_eq!(reason, StopReason::Cancelled),
            other => panic!("expected Cancelled, got {other:?}"),
        }
        let evals = o.evals;
        let r = d.result(&mut o);
        assert_eq!(o.evals, evals, "cancellation must not pay for another eval");
        assert_eq!(r.iterations, 5);
        assert_eq!(r.stop_reason, StopReason::Cancelled);
    }

    #[test]
    fn cancelled_before_first_step_still_yields_finite_result() {
        let token = CancelToken::new();
        token.cancel();
        let opts = SolveOptions { max_iters: 100, ..Default::default() };
        let mut o = quad(2);
        let mut d = driver(&o, opts, DriverOptions { cancel: Some(token), ..Default::default() });
        let r = d.run(&mut o);
        assert_eq!(r.stop_reason, StopReason::Cancelled);
        assert_eq!(r.iterations, 0);
        assert!(r.final_obj.dual_obj.is_finite());
    }

    #[test]
    fn checkpoint_resume_is_bit_identical_to_uninterrupted() {
        let opts = SolveOptions {
            max_iters: 80,
            max_step_size: 0.5,
            gamma: GammaSchedule::Decay { init: 0.16, floor: 0.02, factor: 0.5, every: 9 },
            ..Default::default()
        };
        let mut o1 = quad(5);
        let mut straight = driver(&o1, opts.clone(), DriverOptions::default());
        let r1 = straight.run(&mut o1);

        let mut o2 = quad(5);
        let mut d = driver(&o2, opts, DriverOptions::default());
        for _ in 0..33 {
            d.step(&mut o2);
        }
        let ck = d.checkpoint().expect("AGD steppers are checkpointable");
        assert_eq!(ck.iterations(), 33);
        drop(d);
        let mut resumed = SolveDriver::resume(ck);
        let r2 = resumed.run(&mut o2);

        assert_eq!(r1.iterations, r2.iterations);
        assert_eq!(r1.stop_reason, r2.stop_reason);
        assert_eq!(r1.lam.len(), r2.lam.len());
        for (a, b) in r1.lam.iter().zip(&r2.lam) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(r1.trajectory.len(), r2.trajectory.len());
        for (a, b) in r1.trajectory.iter().zip(&r2.trajectory) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
            assert_eq!(a.step_size.to_bits(), b.step_size.to_bits());
        }
    }

    #[test]
    fn observers_see_every_iteration_and_the_stop() {
        use std::sync::Mutex;

        #[derive(Default)]
        struct Log {
            iters: Vec<usize>,
            recorded: usize,
            decays: Vec<usize>,
            stops: Vec<(StopReason, usize)>,
        }
        struct Probe(Arc<Mutex<Log>>);
        impl IterObserver for Probe {
            fn on_iter(&mut self, record: &IterRecord, recorded: bool) {
                let mut log = self.0.lock().unwrap();
                log.iters.push(record.iter);
                if recorded {
                    log.recorded += 1;
                }
            }
            fn on_gamma_decay(&mut self, t: usize, _gamma: f32) {
                self.0.lock().unwrap().decays.push(t);
            }
            fn on_stop(&mut self, reason: StopReason, iterations: usize) {
                self.0.lock().unwrap().stops.push((reason, iterations));
            }
        }

        let opts = SolveOptions {
            max_iters: 20,
            record_every: 6,
            gamma: GammaSchedule::Decay { init: 0.08, floor: 0.04, factor: 0.5, every: 10 },
            ..Default::default()
        };
        let mut o = quad(2);
        let mut d = driver(&o, opts, DriverOptions::default());
        let log = Arc::new(Mutex::new(Log::default()));
        d.add_observer(Box::new(Probe(log.clone())));
        let r = d.run(&mut o);
        assert_eq!(r.iterations, 20);
        assert_eq!(
            r.trajectory.iter().map(|t| t.iter).collect::<Vec<_>>(),
            vec![0, 6, 12, 18, 19],
            "record cadence plus the stopping iteration"
        );
        let log = log.lock().unwrap();
        assert_eq!(log.iters, (0..20).collect::<Vec<_>>(), "observer sees EVERY iteration");
        assert_eq!(log.recorded, r.trajectory.len());
        assert_eq!(log.decays, vec![10], "one γ transition at iteration 10");
        assert_eq!(log.stops, vec![(StopReason::MaxIters, 20)]);
    }

    #[test]
    fn exported_stepper_state_restores_bit_identically() {
        let opts = SolveOptions {
            max_iters: 60,
            max_step_size: 0.5,
            gamma: GammaSchedule::Decay { init: 0.16, floor: 0.02, factor: 0.5, every: 9 },
            ..Default::default()
        };
        let mut o = quad(5);
        let mut d = driver(&o, opts, DriverOptions::default());
        for _ in 0..21 {
            d.step(&mut o);
        }
        let ck = d.checkpoint().unwrap();
        let exported = ck.export_stepper().expect("AGD exports its state");
        assert_eq!(exported.name, "agd");
        let restored = restore_stepper(&exported).expect("AGD restores from export");
        let ck2 = Checkpoint::from_parts(
            restored,
            ck.state().clone(),
            ck.options().clone(),
            ck.driver_options().clone(),
        );
        let r1 = SolveDriver::resume(ck).run(&mut o);
        let r2 = SolveDriver::resume(ck2).run(&mut o);
        assert_eq!(r1.iterations, r2.iterations);
        for (a, b) in r1.lam.iter().zip(&r2.lam) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in r1.trajectory.iter().zip(&r2.trajectory) {
            assert_eq!(a.dual_obj.to_bits(), b.dual_obj.to_bits());
            assert_eq!(a.step_size.to_bits(), b.step_size.to_bits());
        }
    }

    #[test]
    fn restore_rejects_malformed_state() {
        let good = AgdStepper::new(false).export_state().unwrap();
        assert!(restore_stepper(&good).is_some());
        let mut bad = good.clone();
        bad.name = "no_such_stepper".into();
        assert!(restore_stepper(&bad).is_none());
        let mut bad = good.clone();
        bad.vecs.pop();
        assert!(restore_stepper(&bad).is_none());
        let mut bad = good;
        bad.name = "pgd".into(); // AGD-shaped record under PGD's name
        assert!(restore_stepper(&bad).is_none());
    }

    #[test]
    fn wall_clock_accumulates_across_resume() {
        let opts = SolveOptions { max_iters: 10, ..Default::default() };
        let mut o = quad(2);
        let mut d = driver(&o, opts, DriverOptions::default());
        for _ in 0..4 {
            d.step(&mut o);
        }
        let before = d.elapsed_ms();
        let ck = d.checkpoint().unwrap();
        let mut resumed = SolveDriver::resume(ck);
        assert!(resumed.elapsed_ms() >= before, "resume carries the wall offset");
        let r = resumed.run(&mut o);
        assert!(r.total_wall_ms >= before);
    }
}
