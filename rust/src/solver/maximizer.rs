//! The `Maximizer` contract (paper Table 1) and the shared solve loop:
//! trajectory recording, γ-continuation, stopping, and diagnostics are
//! identical across optimizers — an optimizer only supplies its update
//! rule.

use super::continuation::GammaSchedule;
use super::stopping::{StopReason, StoppingCriteria};
use crate::problem::{ObjectiveFunction, ObjectiveResult};
use crate::util::timer::Stopwatch;

/// One recorded iteration (feeds Fig 1/2/4/5-style CSV series).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    pub dual_obj: f64,
    pub grad_norm: f64,
    pub infeas_pos_norm: f64,
    pub cx: f64,
    pub gamma: f32,
    pub step_size: f64,
    pub wall_ms: f64,
}

/// Full solve outcome.
#[derive(Debug)]
pub struct SolveResult {
    /// Final dual iterate λ (in the solved — possibly row-scaled — system).
    pub lam: Vec<f32>,
    pub final_obj: ObjectiveResult,
    pub trajectory: Vec<IterRecord>,
    pub stop_reason: StopReason,
    pub iterations: usize,
    pub total_wall_ms: f64,
    pub final_gamma: f32,
}

/// Algorithm settings shared by the maximizers (paper Appendix B values).
#[derive(Clone, Debug)]
pub struct SolveOptions {
    pub max_iters: usize,
    /// Maximum allowable step size (paper: 1e-3). Scaled with γ decay.
    pub max_step_size: f64,
    /// Initial step size before curvature information exists (paper: 1e-5).
    pub initial_step_size: f64,
    pub gamma: GammaSchedule,
    pub stopping: StoppingCriteria,
    /// Record every k-th iteration (1 = all).
    pub record_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 200,
            max_step_size: 1e-3,
            initial_step_size: 1e-5,
            gamma: GammaSchedule::Fixed(0.01),
            stopping: StoppingCriteria::default(),
            record_every: 1,
        }
    }
}

/// Paper Table 1, row "Maximizer": single required method.
pub trait Maximizer {
    fn maximize(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        initial_value: &[f32],
        opts: &SolveOptions,
    ) -> SolveResult;

    fn name(&self) -> &'static str;
}

/// Drive the shared solve loop given an optimizer-specific step closure.
///
/// `step(t, gamma, eta_cap) -> (ObjectiveResult, step_used)` must evaluate
/// the objective at its query point and advance its internal iterates.
pub(crate) fn run_loop(
    dual_dim: usize,
    opts: &SolveOptions,
    mut step: impl FnMut(usize, f32, f64) -> (ObjectiveResult, f64),
    final_lam: impl FnOnce() -> Vec<f32>,
) -> SolveResult {
    let sw = Stopwatch::start();
    let mut trajectory = Vec::new();
    let mut stop_reason = StopReason::MaxIters;
    let mut last: Option<ObjectiveResult> = None;
    let mut iters = 0usize;
    let mut stall_run = 0usize; // consecutive small objective steps

    for t in 0..opts.max_iters {
        let gamma = opts.gamma.gamma_at(t);
        let eta_cap = opts.max_step_size * opts.gamma.step_cap_scale(t) as f64;
        let (res, eta_used) = step(t, gamma, eta_cap);
        iters = t + 1;

        let grad_norm = crate::util::mathvec::norm2(&res.grad);
        if t % opts.record_every == 0 || t + 1 == opts.max_iters {
            trajectory.push(IterRecord {
                iter: t,
                dual_obj: res.dual_obj,
                grad_norm,
                infeas_pos_norm: res.infeas_pos_norm,
                cx: res.cx,
                gamma,
                step_size: eta_used,
                wall_ms: sw.elapsed_ms(),
            });
        }

        let prev_obj = last.as_ref().map(|r| r.dual_obj);
        if opts.stopping.is_stall_step(prev_obj, res.dual_obj) {
            stall_run += 1;
        } else {
            stall_run = 0;
        }
        last = Some(res);
        if let Some(reason) = opts.stopping.check(t, grad_norm, stall_run) {
            stop_reason = reason;
            break;
        }
    }

    let final_obj = last.unwrap_or_else(|| ObjectiveResult {
        grad: vec![0.0; dual_dim],
        dual_obj: f64::NEG_INFINITY,
        cx: 0.0,
        xsq_weighted: 0.0,
        infeas_pos_norm: 0.0,
    });
    SolveResult {
        lam: final_lam(),
        final_obj,
        trajectory,
        stop_reason,
        iterations: iters,
        total_wall_ms: sw.elapsed_ms(),
        final_gamma: opts.gamma.gamma_at(iters.saturating_sub(1)),
    }
}
