//! The `Maximizer` contract (paper Table 1) and the shared solve types.
//!
//! Since the steppable-driver redesign the shared loop lives in
//! [`super::driver::SolveDriver`] — an explicit state machine with
//! `step`/`checkpoint`/`resume`, per-iteration observers, deadlines and
//! cancellation. `Maximizer::maximize` is a thin run-to-completion wrapper
//! over that driver (bit-identical to stepping it manually), kept so the
//! one-shot call sites — engine, coordinator, CLI, examples — stay a
//! single line. Trajectory recording, γ-continuation, stopping, and
//! diagnostics remain identical across optimizers; an optimizer supplies
//! only its update rule (a [`super::driver::DualStepper`]).

use super::continuation::GammaSchedule;
use super::driver::{DriverOptions, DualStepper, SolveDriver};
use super::stopping::{StopReason, StoppingCriteria};
use crate::problem::{ObjectiveFunction, ObjectiveResult};

/// One recorded iteration (feeds Fig 1/2/4/5-style CSV series).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    pub dual_obj: f64,
    pub grad_norm: f64,
    pub infeas_pos_norm: f64,
    pub cx: f64,
    pub gamma: f32,
    pub step_size: f64,
    pub wall_ms: f64,
}

/// Full solve outcome.
#[derive(Debug)]
pub struct SolveResult {
    /// Final dual iterate λ (in the solved — possibly row-scaled — system).
    pub lam: Vec<f32>,
    /// Last objective evaluation. For a zero-iteration solve (zero budget
    /// or cancelled before the first step) this is a real evaluation at
    /// the initial λ — never a placeholder.
    pub final_obj: ObjectiveResult,
    pub trajectory: Vec<IterRecord>,
    pub stop_reason: StopReason,
    pub iterations: usize,
    pub total_wall_ms: f64,
    pub final_gamma: f32,
}

/// Algorithm settings shared by the maximizers (paper Appendix B values).
#[derive(Clone, Debug)]
pub struct SolveOptions {
    pub max_iters: usize,
    /// Maximum allowable step size (paper: 1e-3). Scaled with γ decay.
    pub max_step_size: f64,
    /// Initial step size before curvature information exists (paper: 1e-5).
    pub initial_step_size: f64,
    pub gamma: GammaSchedule,
    pub stopping: StoppingCriteria,
    /// Record every k-th iteration (1 = all). The stopping iteration is
    /// always recorded regardless of cadence.
    pub record_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 200,
            max_step_size: 1e-3,
            initial_step_size: 1e-5,
            gamma: GammaSchedule::Fixed(0.01),
            stopping: StoppingCriteria::default(),
            record_every: 1,
        }
    }
}

/// Paper Table 1, row "Maximizer": single required method. One-shot
/// convenience over the steppable [`SolveDriver`] — for deadlines,
/// checkpointing, observers, or cooperative scheduling, build the driver
/// directly (or go through [`super::driver::maximize_with`]).
pub trait Maximizer {
    fn maximize(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        initial_value: &[f32],
        opts: &SolveOptions,
    ) -> SolveResult;

    fn name(&self) -> &'static str;
}

/// Adapter that runs a legacy update closure as a [`DualStepper`]. The
/// closure owns its objective capture and iterates, so `lam()` only knows
/// the initial value — `run_loop` patches the final λ afterwards. Not
/// checkpointable (`try_clone` → `None`).
struct ClosureStepper<F> {
    step_fn: F,
    lam: Vec<f32>,
}

impl<F> DualStepper for ClosureStepper<F>
where
    F: FnMut(usize, f32, f64) -> (ObjectiveResult, f64) + Send,
{
    fn init(&mut self, initial_value: &[f32]) {
        self.lam = initial_value.to_vec();
    }

    fn step(
        &mut self,
        _obj: &mut dyn ObjectiveFunction,
        t: usize,
        gamma: f32,
        eta_cap: f64,
        _initial_step_size: f64,
    ) -> (ObjectiveResult, f64) {
        (self.step_fn)(t, gamma, eta_cap)
    }

    fn lam(&self) -> &[f32] {
        &self.lam
    }

    fn name(&self) -> &'static str {
        "closure"
    }
}

/// Objective stand-in for the legacy closure path, where evaluation
/// happens inside the caller's closure. Never evaluated: `run_loop`
/// requires `max_iters ≥ 1`, so the driver always has a real last result.
struct NullObjective {
    dim: usize,
}

impl ObjectiveFunction for NullObjective {
    fn dual_dim(&self) -> usize {
        self.dim
    }
    fn calculate(&mut self, _lam: &[f32], _gamma: f32) -> ObjectiveResult {
        // never reached (run_loop requires max_iters >= 1, so the closure
        // stepper always evaluates); an inert zero result instead of a
        // panic keeps this off the solver's reachable-panic surface
        debug_assert!(false, "legacy run_loop evaluates through its step closure");
        ObjectiveResult {
            grad: vec![0.0; self.dim],
            dual_obj: 0.0,
            cx: 0.0,
            xsq_weighted: 0.0,
            infeas_pos_norm: 0.0,
        }
    }
    fn primal(&mut self, _lam: &[f32], _gamma: f32) -> Vec<f32> {
        debug_assert!(false, "legacy run_loop has no primal path");
        vec![0.0; self.dim]
    }
    fn name(&self) -> &'static str {
        "null"
    }
}

/// Legacy closure-based entry to the shared loop, kept as a thin compat
/// wrapper over [`SolveDriver`] (same recording, stopping, and γ handling
/// — including the always-record-the-stopping-iteration fix).
///
/// `step(t, gamma, eta_cap) -> (ObjectiveResult, step_used)` must evaluate
/// the objective at its query point and advance its internal iterates.
/// Limitations of the shim: `max_iters` must be ≥ 1, and mid-solve
/// `current_lam`/checkpointing are unavailable (the closure owns the
/// iterates) — new code should implement [`DualStepper`] instead.
pub fn run_loop(
    dual_dim: usize,
    opts: &SolveOptions,
    step: impl FnMut(usize, f32, f64) -> (ObjectiveResult, f64) + Send,
    final_lam: impl FnOnce() -> Vec<f32>,
) -> SolveResult {
    assert!(
        opts.max_iters >= 1,
        "run_loop requires max_iters >= 1; zero-budget solves go through SolveDriver"
    );
    let stepper = ClosureStepper { step_fn: step, lam: Vec::new() };
    let mut driver = SolveDriver::new(
        Box::new(stepper),
        &vec![0.0f32; dual_dim],
        opts.clone(),
        DriverOptions::default(),
    );
    let mut result = driver.run(&mut NullObjective { dim: dual_dim });
    result.lam = final_lam();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_loop_shim_matches_driver_semantics() {
        // a hand-rolled gradient ascent on g(λ) = −½(λ−2)² through the
        // legacy closure entry: records every iteration, stops on budget
        let mut lam = vec![0.0f32];
        let lam_out = std::sync::Arc::new(std::sync::Mutex::new(lam.clone()));
        let lam_out2 = lam_out.clone();
        let r = run_loop(
            1,
            &SolveOptions { max_iters: 50, max_step_size: 0.5, ..Default::default() },
            move |_t, _gamma, eta_cap| {
                let grad = vec![2.0 - lam[0]];
                let obj = -0.5 * (grad[0] as f64).powi(2);
                lam[0] += eta_cap as f32 * grad[0];
                *lam_out2.lock().unwrap() = lam.clone();
                (
                    ObjectiveResult {
                        grad,
                        dual_obj: obj,
                        cx: obj,
                        xsq_weighted: 0.0,
                        infeas_pos_norm: 0.0,
                    },
                    eta_cap,
                )
            },
            move || lam_out.lock().unwrap().clone(),
        );
        assert_eq!(r.iterations, 50);
        assert_eq!(r.stop_reason, StopReason::MaxIters);
        assert_eq!(r.trajectory.len(), 50);
        assert!((r.lam[0] - 2.0).abs() < 1e-3, "λ={:?}", r.lam);
        assert!(r.final_obj.dual_obj > -1e-6);
    }

    #[test]
    #[should_panic]
    fn run_loop_rejects_zero_budget() {
        let _ = run_loop(
            1,
            &SolveOptions { max_iters: 0, ..Default::default() },
            |_, _, _| unreachable!(),
            Vec::new,
        );
    }
}
