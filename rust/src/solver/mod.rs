//! Optimization stack (paper §5): the steppable [`driver::SolveDriver`]
//! state machine that owns the shared dual-ascent loop (step events,
//! checkpoints, observers, deadlines, cancellation), the `Maximizer`
//! one-shot contract wrapped over it, Nesterov AGD with adaptive Lipschitz
//! step sizing (the production optimizer), a plain PGD baseline,
//! γ-continuation, and stopping criteria.

pub mod agd;
pub mod continuation;
pub mod driver;
pub mod maximizer;
pub mod pgd;
pub mod stopping;

pub use agd::{Agd, AgdStepper};
pub use continuation::GammaSchedule;
pub use driver::{
    maximize_with, restore_stepper, CancelToken, Checkpoint, DriverOptions, DualStepper,
    IterObserver, SolveDriver, SolveState, StepEvent, StepperState,
};
pub use maximizer::{run_loop, IterRecord, Maximizer, SolveOptions, SolveResult};
pub use pgd::{Pgd, PgdStepper};
pub use stopping::{StopReason, StoppingCriteria};
