//! Optimization stack (paper §5): the `Maximizer` contract, Nesterov AGD
//! with adaptive Lipschitz step sizing (the production optimizer), a plain
//! PGD baseline, γ-continuation, and stopping criteria.

pub mod agd;
pub mod continuation;
pub mod maximizer;
pub mod pgd;
pub mod stopping;

pub use agd::Agd;
pub use continuation::GammaSchedule;
pub use maximizer::{IterRecord, Maximizer, SolveOptions, SolveResult};
pub use pgd::Pgd;
pub use stopping::{StopReason, StoppingCriteria};
