//! Plain projected gradient ascent — the non-accelerated baseline
//! Maximizer. Same adaptive step sizing as AGD but no momentum; used by
//! ablations to isolate the contribution of acceleration, and as the
//! simplest reference implementation of the `Maximizer` contract — and,
//! as [`PgdStepper`], of the driver's [`DualStepper`] update-rule
//! contract.

use super::driver::{maximize_with, DriverOptions, DualStepper, StepperState};
use super::maximizer::{Maximizer, SolveOptions, SolveResult};
use crate::problem::{ObjectiveFunction, ObjectiveResult};
use crate::util::mathvec;

#[derive(Default)]
pub struct Pgd;

impl Pgd {
    /// The update rule as a driver-pluggable stepper.
    pub fn stepper(&self) -> PgdStepper {
        PgdStepper::new()
    }
}

/// PGD iterate + curvature memory as a checkpointable step rule.
#[derive(Clone, Debug, Default)]
pub struct PgdStepper {
    lam: Vec<f32>,
    /// Curvature memory (empty until the first step has run).
    lam_prev: Vec<f32>,
    grad_prev: Vec<f32>,
}

impl PgdStepper {
    pub fn new() -> PgdStepper {
        PgdStepper::default()
    }

    /// Restore from an exported [`StepperState`] (inverse of
    /// `export_state`). `None` if the record isn't a well-formed PGD
    /// export.
    pub fn from_state(state: &StepperState) -> Option<PgdStepper> {
        if state.name != "pgd"
            || !state.flags.is_empty()
            || state.vecs.len() != 3
            || !state.scalars.is_empty()
            || !state.counters.is_empty()
        {
            return None;
        }
        let [lam, lam_prev, grad_prev] = &state.vecs[..] else {
            return None;
        };
        if lam_prev.len() != grad_prev.len()
            || !(lam_prev.is_empty() || lam_prev.len() == lam.len())
        {
            return None;
        }
        Some(PgdStepper {
            lam: lam.clone(),
            lam_prev: lam_prev.clone(),
            grad_prev: grad_prev.clone(),
        })
    }
}

impl DualStepper for PgdStepper {
    fn init(&mut self, initial_value: &[f32]) {
        self.lam = initial_value.to_vec();
        self.lam_prev.clear();
        self.grad_prev.clear();
    }

    fn step(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        t: usize,
        gamma: f32,
        eta_cap: f64,
        initial_step_size: f64,
    ) -> (ObjectiveResult, f64) {
        let res = obj.calculate(&self.lam, gamma);
        let eta = if t == 0 || self.lam_prev.is_empty() {
            initial_step_size.min(eta_cap)
        } else {
            let dl = mathvec::dist2(&self.lam, &self.lam_prev);
            let dg = mathvec::dist2(&res.grad, &self.grad_prev);
            if dl > 0.0 && dg > 0.0 {
                (dl / dg).min(eta_cap)
            } else {
                eta_cap
            }
        };
        self.lam_prev = self.lam.clone();
        self.grad_prev = res.grad.clone();
        mathvec::axpy(eta as f32, &res.grad, &mut self.lam);
        mathvec::clamp_nonneg(&mut self.lam);
        (res, eta)
    }

    fn lam(&self) -> &[f32] {
        &self.lam
    }

    fn name(&self) -> &'static str {
        "pgd"
    }

    fn try_clone(&self) -> Option<Box<dyn DualStepper>> {
        Some(Box::new(self.clone()))
    }

    fn export_state(&self) -> Option<StepperState> {
        Some(StepperState {
            name: "pgd".to_string(),
            flags: Vec::new(),
            vecs: vec![self.lam.clone(), self.lam_prev.clone(), self.grad_prev.clone()],
            scalars: Vec::new(),
            counters: Vec::new(),
        })
    }
}

impl Maximizer for Pgd {
    fn maximize(
        &mut self,
        obj: &mut dyn ObjectiveFunction,
        initial_value: &[f32],
        opts: &SolveOptions,
    ) -> SolveResult {
        maximize_with(Box::new(self.stepper()), obj, initial_value, opts, DriverOptions::default())
    }

    fn name(&self) -> &'static str {
        "pgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::{ObjectiveFunction, ObjectiveResult};

    struct Quadratic {
        target: Vec<f32>,
    }
    impl ObjectiveFunction for Quadratic {
        fn dual_dim(&self) -> usize {
            self.target.len()
        }
        fn calculate(&mut self, lam: &[f32], _g: f32) -> ObjectiveResult {
            let grad: Vec<f32> = self.target.iter().zip(lam).map(|(t, l)| t - l).collect();
            let obj = -0.5 * grad.iter().map(|&g| (g as f64).powi(2)).sum::<f64>();
            ObjectiveResult { grad, dual_obj: obj, cx: obj, xsq_weighted: 0.0, infeas_pos_norm: 0.0 }
        }
        fn primal(&mut self, _l: &[f32], _g: f32) -> Vec<f32> {
            vec![]
        }
        fn name(&self) -> &'static str {
            "quadratic"
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let mut obj = Quadratic { target: vec![1.5, 0.0, 2.5] };
        let mut pgd = Pgd;
        let opts = SolveOptions {
            max_iters: 2000,
            max_step_size: 0.9,
            initial_step_size: 0.1,
            ..Default::default()
        };
        let res = pgd.maximize(&mut obj, &vec![0.0; 3], &opts);
        for (l, e) in res.lam.iter().zip(&[1.5f32, 0.0, 2.5]) {
            assert!((l - e).abs() < 1e-2, "{:?}", res.lam);
        }
    }

    #[test]
    fn agd_beats_pgd_on_iterations_to_tolerance() {
        // The acceleration ablation in miniature: same budget, AGD ends
        // closer on an ill-conditioned quadratic.
        struct Aniso;
        impl ObjectiveFunction for Aniso {
            fn dual_dim(&self) -> usize {
                2
            }
            fn calculate(&mut self, lam: &[f32], _g: f32) -> ObjectiveResult {
                // g = -(50 (λ0-1)² + 0.5 (λ1-1)²)
                let grad = vec![-100.0 * (lam[0] - 1.0), -1.0 * (lam[1] - 1.0)];
                let obj = -(50.0 * ((lam[0] - 1.0) as f64).powi(2)
                    + 0.5 * ((lam[1] - 1.0) as f64).powi(2));
                ObjectiveResult { grad, dual_obj: obj, cx: obj, xsq_weighted: 0.0, infeas_pos_norm: 0.0 }
            }
            fn primal(&mut self, _l: &[f32], _g: f32) -> Vec<f32> {
                vec![]
            }
            fn name(&self) -> &'static str {
                "aniso"
            }
        }
        let opts = SolveOptions {
            max_iters: 300,
            max_step_size: 0.009, // < 1/L = 0.01
            initial_step_size: 1e-3,
            ..Default::default()
        };
        let ra = crate::solver::agd::Agd::default()
            .maximize(&mut Aniso, &vec![0.0; 2], &opts);
        let rp = Pgd.maximize(&mut Aniso, &vec![0.0; 2], &opts);
        assert!(
            ra.final_obj.dual_obj >= rp.final_obj.dual_obj - 1e-9,
            "agd {} vs pgd {}",
            ra.final_obj.dual_obj,
            rp.final_obj.dual_obj
        );
    }
}
