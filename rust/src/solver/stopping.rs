//! Stopping criteria for the dual ascent loop. Production solves terminate
//! on a fixed iteration budget (paper Appendix B); the library additionally
//! supports gradient-norm tolerance and objective-stall detection.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    MaxIters,
    GradNormTol,
    ObjectiveStall,
}

#[derive(Clone, Debug)]
pub struct StoppingCriteria {
    /// Stop when ‖∇g‖₂ falls below this (None = never).
    pub grad_norm_tol: Option<f64>,
    /// Stop when |Δg| stays below `stall_tol` for `stall_patience`
    /// consecutive iterations (None = never). Interacts with continuation:
    /// disabled until γ reaches its floor would be ideal; we keep it simple
    /// and recommend patience > decay interval.
    pub stall_tol: Option<f64>,
    pub stall_patience: usize,
    /// Never stop before this many iterations.
    pub min_iters: usize,
}

impl Default for StoppingCriteria {
    fn default() -> Self {
        StoppingCriteria {
            grad_norm_tol: None,
            stall_tol: None,
            stall_patience: 10,
            min_iters: 1,
        }
    }
}

impl StoppingCriteria {
    /// Stateless check — stall tracking folds the consecutive count into
    /// the caller via an internal counter.
    pub fn check(
        &self,
        t: usize,
        grad_norm: f64,
        prev_obj: Option<f64>,
        obj: f64,
    ) -> Option<StopReason> {
        if t + 1 < self.min_iters {
            return None;
        }
        if let Some(tol) = self.grad_norm_tol {
            if grad_norm <= tol {
                return Some(StopReason::GradNormTol);
            }
        }
        if let (Some(tol), Some(prev)) = (self.stall_tol, prev_obj) {
            // Cheap stall check without internal state: relative change.
            // (The patience window is enforced by callers that care; the
            // default loop treats a single tiny step after min_iters +
            // patience iterations as a stall signal.)
            if t >= self.min_iters + self.stall_patience
                && (obj - prev).abs() <= tol * obj.abs().max(1.0)
            {
                return Some(StopReason::ObjectiveStall);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_never_stops_early() {
        let s = StoppingCriteria::default();
        assert_eq!(s.check(100, 1e-30, Some(1.0), 1.0), None);
    }

    #[test]
    fn grad_tol_triggers() {
        let s = StoppingCriteria { grad_norm_tol: Some(1e-6), ..Default::default() };
        assert_eq!(s.check(5, 1e-7, None, 0.0), Some(StopReason::GradNormTol));
        assert_eq!(s.check(5, 1e-5, None, 0.0), None);
    }

    #[test]
    fn min_iters_respected() {
        let s = StoppingCriteria {
            grad_norm_tol: Some(1e-6),
            min_iters: 10,
            ..Default::default()
        };
        assert_eq!(s.check(3, 0.0, None, 0.0), None);
        assert_eq!(s.check(9, 0.0, None, 0.0), Some(StopReason::GradNormTol));
    }

    #[test]
    fn stall_requires_patience_window() {
        let s = StoppingCriteria {
            stall_tol: Some(1e-9),
            stall_patience: 5,
            min_iters: 1,
            ..Default::default()
        };
        assert_eq!(s.check(2, 1.0, Some(5.0), 5.0), None); // too early
        assert_eq!(
            s.check(10, 1.0, Some(5.0), 5.0),
            Some(StopReason::ObjectiveStall)
        );
        assert_eq!(s.check(10, 1.0, Some(5.0), 6.0), None); // still moving
    }
}
