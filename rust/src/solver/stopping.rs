//! Stopping criteria for the dual ascent loop. Production solves terminate
//! on a fixed iteration budget (paper Appendix B); the library additionally
//! supports gradient-norm tolerance and objective-stall detection.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    MaxIters,
    GradNormTol,
    ObjectiveStall,
    /// The driver's per-job wall-clock deadline expired
    /// (`DriverOptions::deadline_ms`). The final iterate is still a valid
    /// anytime dual — the engine publishes it to the warm-start cache.
    Deadline,
    /// The job's `CancelToken` fired. Checked before each iteration, so a
    /// cancelled solve never pays for another objective evaluation.
    Cancelled,
}

#[derive(Clone, Debug)]
pub struct StoppingCriteria {
    /// Stop when ‖∇g‖₂ falls below this (None = never). NOTE: the RAW
    /// gradient does not vanish at a constrained dual optimum (slack rows
    /// hold λ = 0 against a negative gradient), so for matching LPs prefer
    /// the stall criterion; grad tolerance suits unconstrained objectives.
    pub grad_norm_tol: Option<f64>,
    /// Stop when |Δg| ≤ stall_tol · max(|g|, 1) for `stall_patience`
    /// consecutive iterations (None = never). The consecutive window is
    /// tracked by the solve loop (`is_stall_step`), which makes the
    /// criterion robust to momentum oscillations — a single transient tiny
    /// step resets nothing it shouldn't. Interacts with continuation: set
    /// `min_iters` past the γ descent (`GammaSchedule::iters_to_floor`) so
    /// stalls are only declared at the floor; the engine layer does this
    /// automatically.
    pub stall_tol: Option<f64>,
    pub stall_patience: usize,
    /// Never stop before this many iterations.
    pub min_iters: usize,
}

impl Default for StoppingCriteria {
    fn default() -> Self {
        StoppingCriteria {
            grad_norm_tol: None,
            stall_tol: None,
            stall_patience: 10,
            min_iters: 1,
        }
    }
}

impl StoppingCriteria {
    /// Whether one objective transition counts toward the stall window:
    /// |Δg| ≤ stall_tol · max(|g|, 1). The loop accumulates consecutive
    /// true results and feeds the count to `check`.
    pub fn is_stall_step(&self, prev_obj: Option<f64>, obj: f64) -> bool {
        match (self.stall_tol, prev_obj) {
            (Some(tol), Some(prev)) => (obj - prev).abs() <= tol * obj.abs().max(1.0),
            _ => false,
        }
    }

    /// Stateless check given the loop-tracked consecutive stall count.
    pub fn check(&self, t: usize, grad_norm: f64, stall_run: usize) -> Option<StopReason> {
        if t + 1 < self.min_iters {
            return None;
        }
        if let Some(tol) = self.grad_norm_tol {
            if grad_norm <= tol {
                return Some(StopReason::GradNormTol);
            }
        }
        if self.stall_tol.is_some() && stall_run >= self.stall_patience.max(1) {
            return Some(StopReason::ObjectiveStall);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_never_stops_early() {
        let s = StoppingCriteria::default();
        assert_eq!(s.check(100, 1e-30, 1000), None);
        assert!(!s.is_stall_step(Some(1.0), 1.0)); // no stall_tol configured
    }

    #[test]
    fn grad_tol_triggers() {
        let s = StoppingCriteria { grad_norm_tol: Some(1e-6), ..Default::default() };
        assert_eq!(s.check(5, 1e-7, 0), Some(StopReason::GradNormTol));
        assert_eq!(s.check(5, 1e-5, 0), None);
    }

    #[test]
    fn min_iters_respected() {
        let s = StoppingCriteria {
            grad_norm_tol: Some(1e-6),
            min_iters: 10,
            ..Default::default()
        };
        assert_eq!(s.check(3, 0.0, 0), None);
        assert_eq!(s.check(9, 0.0, 0), Some(StopReason::GradNormTol));
    }

    #[test]
    fn stall_requires_consecutive_window() {
        let s = StoppingCriteria {
            stall_tol: Some(1e-9),
            stall_patience: 5,
            min_iters: 1,
            ..Default::default()
        };
        // step classification: relative to max(|g|, 1)
        assert!(s.is_stall_step(Some(5.0), 5.0));
        assert!(!s.is_stall_step(Some(5.0), 6.0));
        assert!(!s.is_stall_step(None, 5.0)); // no previous value yet
        // window: 4 consecutive small steps is not enough, 5 is
        assert_eq!(s.check(10, 1.0, 4), None);
        assert_eq!(s.check(10, 1.0, 5), Some(StopReason::ObjectiveStall));
        // min_iters = 1 is already satisfied at t = 0
        assert_eq!(s.check(0, 1.0, 5), Some(StopReason::ObjectiveStall));
    }

    #[test]
    fn zero_patience_still_needs_one_small_step() {
        let s = StoppingCriteria {
            stall_tol: Some(1e-9),
            stall_patience: 0,
            ..Default::default()
        };
        assert_eq!(s.check(5, 1.0, 0), None);
        assert_eq!(s.check(5, 1.0, 1), Some(StopReason::ObjectiveStall));
    }
}
