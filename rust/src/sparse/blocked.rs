//! Blocked matching constraint matrix (paper Definition 1).
//!
//! `A ∈ R^{mJ × IJ}` is, per constraint family k, a horizontal concatenation
//! of diagonal J×J blocks across sources i. Only eligible (i,j) pairs carry
//! variables, so we store the matrix as per-source edge lists — the CSC
//! "columns ordered by source, variables of a source contiguous" layout of
//! §6 — with one value plane per family (all families share the eligibility
//! pattern, as in the Appendix-B construction a_kij = s_jk · c_ij).
//!
//! Dual/row index convention: row (k, j) ↦ k*J + j.

#[derive(Clone, Debug)]
pub struct BlockedMatrix {
    /// I — number of sources (variable blocks).
    pub num_sources: usize,
    /// J — number of destinations.
    pub num_dests: usize,
    /// m — number of matching constraint families.
    pub num_families: usize,
    /// Per-source edge ranges: edges of source i live in
    /// `src_ptr[i]..src_ptr[i+1]`. len = I+1.
    pub src_ptr: Vec<usize>,
    /// Destination of each edge. len = nnz.
    pub dest_idx: Vec<u32>,
    /// Family coefficient planes: `a[k][e]` = a_{k, i(e), j(e)}. m × nnz.
    pub a: Vec<Vec<f32>>,
}

impl BlockedMatrix {
    pub fn nnz(&self) -> usize {
        self.dest_idx.len()
    }

    /// Dual dimension mJ.
    pub fn dual_dim(&self) -> usize {
        self.num_families * self.num_dests
    }

    /// Degree (number of eligible destinations) of source i.
    pub fn degree(&self, i: usize) -> usize {
        self.src_ptr[i + 1] - self.src_ptr[i]
    }

    pub fn max_degree(&self) -> usize {
        (0..self.num_sources).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// u = (Aᵀ λ) restricted to edges: u[e] = Σ_k a_k[e] · λ[k*J + j(e)].
    pub fn gather_dual(&self, lam: &[f32], u: &mut [f32]) {
        assert_eq!(lam.len(), self.dual_dim());
        assert_eq!(u.len(), self.nnz());
        let j_of = &self.dest_idx;
        match self.num_families {
            1 => {
                let a0 = &self.a[0];
                for e in 0..u.len() {
                    u[e] = a0[e] * lam[j_of[e] as usize];
                }
            }
            _ => {
                let jj = self.num_dests;
                u.iter_mut().for_each(|v| *v = 0.0);
                for (k, ak) in self.a.iter().enumerate() {
                    let lk = &lam[k * jj..(k + 1) * jj];
                    for e in 0..ak.len() {
                        u[e] += ak[e] * lk[j_of[e] as usize];
                    }
                }
            }
        }
    }

    /// out += A x  where x is per-edge: out[k*J + j] += Σ_e a_k[e] x[e].
    pub fn scatter_ax(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.nnz());
        assert_eq!(out.len(), self.dual_dim());
        let jj = self.num_dests;
        for (k, ak) in self.a.iter().enumerate() {
            let ok = &mut out[k * jj..(k + 1) * jj];
            for e in 0..ak.len() {
                ok[self.dest_idx[e] as usize] += ak[e] * x[e];
            }
        }
    }

    /// Squared norm of each constraint row (k,j): Σ_e over edges with
    /// j(e)=j of a_k[e]² — i.e. diag(AAᵀ). Used for Jacobi normalization.
    pub fn row_sq_norms(&self) -> Vec<f64> {
        let mut n = vec![0.0f64; self.dual_dim()];
        let jj = self.num_dests;
        for (k, ak) in self.a.iter().enumerate() {
            for e in 0..ak.len() {
                let r = k * jj + self.dest_idx[e] as usize;
                n[r] += ak[e] as f64 * ak[e] as f64;
            }
        }
        n
    }

    /// Scale rows: a_k[e] ← a_k[e] · d[k*J + j(e)]  (A ← diag(d) A).
    pub fn scale_rows(&mut self, d: &[f32]) {
        assert_eq!(d.len(), self.dual_dim());
        let jj = self.num_dests;
        for (k, ak) in self.a.iter_mut().enumerate() {
            for e in 0..ak.len() {
                ak[e] *= d[k * jj + self.dest_idx[e] as usize];
            }
        }
    }

    /// Upper bound on ‖A‖₂² via ‖A‖₁·‖A‖_∞ (Holder); cheap and good enough
    /// for the Lipschitz constant L = ‖A‖₂²/γ in Lemma A.1 checks.
    pub fn op_norm_sq_upper(&self) -> f64 {
        let jj = self.num_dests;
        // ‖A‖_∞ = max row abs sum; ‖A‖₁ = max col abs sum.
        let mut row_abs = vec![0.0f64; self.dual_dim()];
        let mut col_max = 0.0f64;
        for i in 0..self.num_sources {
            for e in self.src_ptr[i]..self.src_ptr[i + 1] {
                let mut col_sum = 0.0f64;
                for (k, ak) in self.a.iter().enumerate() {
                    let v = ak[e].abs() as f64;
                    row_abs[k * jj + self.dest_idx[e] as usize] += v;
                    col_sum += v;
                }
                col_max = col_max.max(col_sum);
            }
        }
        let row_max = row_abs.iter().cloned().fold(0.0, f64::max);
        row_max * col_max
    }

    /// Materialize as generic CSC over (rows = mJ, cols = edges) — for
    /// conditioning experiments and tests.
    pub fn to_csc(&self) -> super::csc::Csc {
        let mut coo = super::coo::Coo::with_capacity(
            self.dual_dim(),
            self.nnz(),
            self.nnz() * self.num_families,
        );
        let jj = self.num_dests;
        for (k, ak) in self.a.iter().enumerate() {
            for e in 0..ak.len() {
                if ak[e] != 0.0 {
                    coo.push(k * jj + self.dest_idx[e] as usize, e, ak[e]);
                }
            }
        }
        super::csc::Csc::from_coo(&coo)
    }

    /// Validity checks: monotone src_ptr covering nnz, dest indices in
    /// range, consistent plane lengths, no duplicate dest within a source.
    pub fn validate(&self) -> Result<(), String> {
        if self.src_ptr.len() != self.num_sources + 1 {
            return Err("src_ptr length".into());
        }
        if self.src_ptr[0] != 0 || *self.src_ptr.last().unwrap() != self.nnz() {
            return Err("src_ptr bounds".into());
        }
        if self.a.len() != self.num_families {
            return Err("family plane count".into());
        }
        for ak in &self.a {
            if ak.len() != self.nnz() {
                return Err("plane length".into());
            }
        }
        let mut seen = vec![u32::MAX; self.num_dests];
        for i in 0..self.num_sources {
            if self.src_ptr[i] > self.src_ptr[i + 1] {
                return Err(format!("src_ptr not monotone at {i}"));
            }
            for e in self.src_ptr[i]..self.src_ptr[i + 1] {
                let j = self.dest_idx[e] as usize;
                if j >= self.num_dests {
                    return Err(format!("dest {j} out of range"));
                }
                if seen[j] == i as u32 {
                    return Err(format!("duplicate dest {j} in source {i}"));
                }
                seen[j] = i as u32;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3 sources, 4 dests, 2 families.
    fn sample() -> BlockedMatrix {
        BlockedMatrix {
            num_sources: 3,
            num_dests: 4,
            num_families: 2,
            src_ptr: vec![0, 2, 3, 5],
            dest_idx: vec![0, 2, 1, 2, 3],
            a: vec![
                vec![1.0, 2.0, 3.0, 4.0, 5.0],
                vec![0.5, 0.5, 0.5, 0.5, 0.5],
            ],
        }
    }

    #[test]
    fn validates() {
        sample().validate().unwrap();
    }

    #[test]
    fn detects_duplicate_dest() {
        let mut m = sample();
        m.dest_idx = vec![0, 0, 1, 2, 3];
        assert!(m.validate().is_err());
    }

    #[test]
    fn gather_matches_manual() {
        let m = sample();
        // lam[k*4+j]
        let lam: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let mut u = vec![0.0; 5];
        m.gather_dual(&lam, &mut u);
        // edge0: src0,d0: 1.0*lam[0] + 0.5*lam[4] = 0 + 2 = 2
        assert_eq!(u[0], 1.0 * 0.0 + 0.5 * 4.0);
        // edge4: src2,d3: 5*3 + 0.5*7 = 18.5
        assert_eq!(u[4], 5.0 * 3.0 + 0.5 * 7.0);
    }

    #[test]
    fn gather_single_family_fast_path() {
        let mut m = sample();
        m.num_families = 1;
        m.a.truncate(1);
        let lam: Vec<f32> = (0..4).map(|v| v as f32 + 1.0).collect();
        let mut u = vec![0.0; 5];
        m.gather_dual(&lam, &mut u);
        assert_eq!(u, vec![1.0, 6.0, 6.0, 12.0, 20.0]);
    }

    #[test]
    fn scatter_matches_manual() {
        let m = sample();
        let x = vec![1.0, 1.0, 2.0, 1.0, 3.0];
        let mut out = vec![0.0; 8];
        m.scatter_ax(&x, &mut out);
        // family0: d0: 1*1=1; d1: 3*2=6; d2: 2*1+4*1=6; d3: 5*3=15
        assert_eq!(&out[0..4], &[1.0, 6.0, 6.0, 15.0]);
        // family1: all 0.5: d0:0.5, d1:1.0, d2:0.5+0.5=1.0, d3:1.5
        assert_eq!(&out[4..8], &[0.5, 1.0, 1.0, 1.5]);
    }

    #[test]
    fn gather_scatter_adjoint() {
        // <A x, λ> == <x, Aᵀ λ> — the fundamental adjoint identity.
        let m = sample();
        let x = vec![0.3, -0.2, 0.7, 1.1, -0.4];
        let lam: Vec<f32> = (0..8).map(|v| (v as f32) * 0.13 - 0.4).collect();
        let mut ax = vec![0.0; 8];
        m.scatter_ax(&x, &mut ax);
        let mut atl = vec![0.0; 5];
        m.gather_dual(&lam, &mut atl);
        let lhs: f64 = ax.iter().zip(&lam).map(|(a, b)| *a as f64 * *b as f64).sum();
        let rhs: f64 = atl.iter().zip(&x).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-5, "{lhs} vs {rhs}");
    }

    #[test]
    fn row_norms_and_scaling() {
        let mut m = sample();
        let n = m.row_sq_norms();
        assert_eq!(n[0], 1.0); // family0, d0: 1²
        assert_eq!(n[2], 4.0 + 16.0); // family0, d2: 2² + 4²
        let d: Vec<f32> = n
            .iter()
            .map(|&v| if v > 0.0 { 1.0 / (v as f32).sqrt() } else { 1.0 })
            .collect();
        m.scale_rows(&d);
        for v in m.row_sq_norms() {
            if v > 0.0 {
                assert!((v - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn csc_roundtrip_spmv_agrees() {
        let m = sample();
        let csc = m.to_csc();
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut ax1 = vec![0.0; 8];
        m.scatter_ax(&x, &mut ax1);
        let mut ax2 = vec![0.0; 8];
        csc.spmv(&x, &mut ax2);
        for (a, b) in ax1.iter().zip(&ax2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn op_norm_upper_dominates_true_norm() {
        let m = sample();
        // crude power iteration on AAᵀ via csc
        let csc = m.to_csc();
        let mut v = vec![1.0f32; 8];
        let mut tmp = vec![0.0f32; 5];
        for _ in 0..50 {
            csc.spmv_t(&v, &mut tmp);
            csc.spmv(&tmp, &mut v);
            let n = crate::util::mathvec::norm2(&v) as f32;
            v.iter_mut().for_each(|x| *x /= n);
        }
        csc.spmv_t(&v, &mut tmp);
        let mut av = vec![0.0f32; 8];
        csc.spmv(&tmp, &mut av);
        let sigma_sq = crate::util::mathvec::dot(&v, &av);
        assert!(m.op_norm_sq_upper() >= sigma_sq - 1e-4);
    }
}
