//! Triplet (COO) builder — the mutable staging format the generator and
//! tests use before converting to CSC / blocked layouts.

/// Coordinate-format sparse matrix builder.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.nrows && c < self.ncols, "({r},{c}) out of bounds");
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Dense materialization (row-major) — tests only.
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for k in 0..self.nnz() {
            d[self.rows[k] as usize][self.cols[k] as usize] += self.vals[k];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_dense() {
        let mut m = Coo::new(2, 3);
        m.push(0, 1, 2.0);
        m.push(1, 2, 3.0);
        m.push(0, 1, 1.0); // duplicate accumulates in dense
        assert_eq!(m.nnz(), 3);
        let d = m.to_dense();
        assert_eq!(d[0], vec![0.0, 3.0, 0.0]);
        assert_eq!(d[1], vec![0.0, 0.0, 3.0]);
    }
}
