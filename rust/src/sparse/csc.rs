//! Compressed Sparse Column matrix.
//!
//! The paper (§6) stores constraint matrices in CSC with columns ordered so
//! each source's variables are contiguous. This generic CSC type backs the
//! row-normalization statistics (row norms need a full pass), the Lemma-5.1
//! conditioning tests, and small dense comparisons; the solve hot path uses
//! the specialized `BlockedMatrix` instead.

use super::coo::Coo;

#[derive(Clone, Debug)]
pub struct Csc {
    pub nrows: usize,
    pub ncols: usize,
    /// column pointers, len ncols+1
    pub col_ptr: Vec<usize>,
    /// row indices per nonzero, len nnz
    pub row_idx: Vec<u32>,
    /// values, len nnz
    pub vals: Vec<f32>,
}

impl Csc {
    /// Build from COO (duplicates summed, rows sorted within columns).
    pub fn from_coo(coo: &Coo) -> Self {
        let nnz = coo.nnz();
        // counting sort by column
        let mut counts = vec![0usize; coo.ncols + 1];
        for &c in &coo.cols {
            counts[c as usize + 1] += 1;
        }
        for i in 0..coo.ncols {
            counts[i + 1] += counts[i];
        }
        let col_start = counts.clone();
        let mut row_idx = vec![0u32; nnz];
        let mut vals = vec![0.0f32; nnz];
        let mut next = col_start.clone();
        for k in 0..nnz {
            let c = coo.cols[k] as usize;
            let p = next[c];
            row_idx[p] = coo.rows[k];
            vals[p] = coo.vals[k];
            next[c] += 1;
        }
        // sort within each column by row, summing duplicates
        let mut out_ptr = vec![0usize; coo.ncols + 1];
        let mut out_rows = Vec::with_capacity(nnz);
        let mut out_vals = Vec::with_capacity(nnz);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for c in 0..coo.ncols {
            scratch.clear();
            scratch.extend(
                row_idx[col_start[c]..col_start[c + 1]]
                    .iter()
                    .copied()
                    .zip(vals[col_start[c]..col_start[c + 1]].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(r, _)| r);
            let mut i = 0;
            while i < scratch.len() {
                let (r, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == r {
                    v += scratch[j].1;
                    j += 1;
                }
                out_rows.push(r);
                out_vals.push(v);
                i = j;
            }
            out_ptr[c + 1] = out_rows.len();
        }
        Csc {
            nrows: coo.nrows,
            ncols: coo.ncols,
            col_ptr: out_ptr,
            row_idx: out_rows,
            vals: out_vals,
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// y = A x  (y: nrows, x: ncols).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..self.ncols {
            let xc = x[c];
            if xc == 0.0 {
                continue;
            }
            for k in self.col_ptr[c]..self.col_ptr[c + 1] {
                y[self.row_idx[k] as usize] += self.vals[k] * xc;
            }
        }
    }

    /// y = Aᵀ x  (y: ncols, x: nrows).
    pub fn spmv_t(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        for c in 0..self.ncols {
            let mut acc = 0.0f32;
            for k in self.col_ptr[c]..self.col_ptr[c + 1] {
                acc += self.vals[k] * x[self.row_idx[k] as usize];
            }
            y[c] = acc;
        }
    }

    /// Squared Euclidean norm of each row: diag(AAᵀ).
    pub fn row_sq_norms(&self) -> Vec<f64> {
        let mut n = vec![0.0f64; self.nrows];
        for k in 0..self.nnz() {
            n[self.row_idx[k] as usize] += (self.vals[k] as f64) * (self.vals[k] as f64);
        }
        n
    }

    /// Squared Euclidean norm of each column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        let mut n = vec![0.0f64; self.ncols];
        for c in 0..self.ncols {
            for k in self.col_ptr[c]..self.col_ptr[c + 1] {
                n[c] += (self.vals[k] as f64) * (self.vals[k] as f64);
            }
        }
        n
    }

    /// Scale every row r by d[r] (in place): A ← diag(d) A.
    pub fn scale_rows(&mut self, d: &[f32]) {
        assert_eq!(d.len(), self.nrows);
        for k in 0..self.vals.len() {
            self.vals[k] *= d[self.row_idx[k] as usize];
        }
    }

    /// Dense AAᵀ (tests / conditioning experiments only — O(nrows²)).
    pub fn aat_dense(&self) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0.0f64; self.nrows]; self.nrows];
        for c in 0..self.ncols {
            let lo = self.col_ptr[c];
            let hi = self.col_ptr[c + 1];
            for p in lo..hi {
                for q in lo..hi {
                    m[self.row_idx[p] as usize][self.row_idx[q] as usize] +=
                        self.vals[p] as f64 * self.vals[q] as f64;
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csc {
        // [[1, 0, 2],
        //  [0, 3, 4]]
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(0, 2, 2.0);
        coo.push(1, 2, 4.0);
        Csc::from_coo(&coo)
    }

    #[test]
    fn from_coo_structure() {
        let a = sample();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.col_ptr, vec![0, 1, 2, 4]);
        assert_eq!(a.row_idx, vec![0, 1, 0, 1]);
    }

    #[test]
    fn duplicates_sum() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        let a = Csc::from_coo(&coo);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.vals, vec![3.0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = sample();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 2];
        a.spmv(&x, &mut y);
        assert_eq!(y, vec![1.0 + 6.0, 6.0 + 12.0]);
    }

    #[test]
    fn spmv_t_matches_dense() {
        let a = sample();
        let x = vec![1.0, 2.0];
        let mut y = vec![0.0; 3];
        a.spmv_t(&x, &mut y);
        assert_eq!(y, vec![1.0, 6.0, 2.0 + 8.0]);
    }

    #[test]
    fn row_and_col_norms() {
        let a = sample();
        assert_eq!(a.row_sq_norms(), vec![5.0, 25.0]);
        assert_eq!(a.col_sq_norms(), vec![1.0, 9.0, 20.0]);
    }

    #[test]
    fn scale_rows_changes_norms() {
        let mut a = sample();
        let d: Vec<f32> = a.row_sq_norms().iter().map(|&n| 1.0 / (n as f32).sqrt()).collect();
        a.scale_rows(&d);
        let n = a.row_sq_norms();
        for v in n {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn aat_dense_symmetry() {
        let a = sample();
        let m = a.aat_dense();
        assert_eq!(m[0][0], 5.0);
        assert_eq!(m[1][1], 25.0);
        assert_eq!(m[0][1], m[1][0]);
        assert_eq!(m[0][1], 8.0); // 2*4 from shared col 2
    }
}
