//! Sparse substrates: generic COO/CSC, the matching-structured blocked
//! matrix (paper Definition 1), and the log₂-bucketed padded slab layout
//! the batched projection kernels execute on (paper §6).

pub mod blocked;
pub mod coo;
pub mod csc;
pub mod slabs;

pub use blocked::BlockedMatrix;
pub use coo::Coo;
pub use csc::Csc;
pub use slabs::{Bucket, BuildOptions, SlabChunk, SlabIndex, SlabLayout, WidthPolicy};
